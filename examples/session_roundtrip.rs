//! Session-protocol walkthrough: build the Fig-6 network, write it to
//! `.hsn`, and drive the `serve-session` wire format **in-process**
//! through `sim::session::Session` — every request/response pair is
//! printed, so this doubles as living documentation of the protocol the
//! Python `hs_api` `backend="rust"` client speaks over a subprocess.
//!
//! Run: `cargo run --release --example session_roundtrip`

use hiaer_spike::model_fmt::write_hsn;
use hiaer_spike::sim::session::Session;
use hiaer_spike::sim::SimOptions;
use hiaer_spike::snn::{NetworkBuilder, NeuronModel};

fn main() -> anyhow::Result<()> {
    // the Supplementary-A.1 example network (hs_api's fig6_network)
    let lif = NeuronModel::lif(3, 0, 63, false)?;
    let lif_c = NeuronModel::lif(4, 0, 2, false)?;
    let ann_d = NeuronModel::ann(5, 0, true)?;
    let mut b = NetworkBuilder::new().seed(7);
    b.add_neuron("a", lif, &[("b", 1), ("d", 2)])?;
    b.add_neuron("b", lif, &[])?;
    b.add_neuron("c", lif_c, &[])?;
    b.add_neuron("d", ann_d, &[("c", 1)])?;
    b.add_axon("alpha", &[("a", 3), ("c", 2)])?;
    b.add_axon("beta", &[("b", 3)])?;
    b.add_output("a");
    b.add_output("b");
    let (net, _keys) = b.build()?;

    let mut path = std::env::temp_dir();
    path.push(format!("session_roundtrip_{}.hsn", std::process::id()));
    write_hsn(&net, &path)?;

    let mut session = Session::new(SimOptions::default());
    println!("<- {}", session.hello());

    let requests = [
        format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", path.display()),
        // alpha+beta for two ticks, then let the charge propagate
        r#"{"op":"step","axons":[0,1]}"#.to_string(),
        r#"{"op":"step_many","batch":[[0,1],[],[]]}"#.to_string(),
        r#"{"op":"read_membrane","ids":[0,1,2,3]}"#.to_string(),
        r#"{"op":"cost"}"#.to_string(),
        // a structured error: axon 9 does not exist (session survives)
        r#"{"op":"step","axons":[9]}"#.to_string(),
        r#"{"op":"reset"}"#.to_string(),
        r#"{"op":"shutdown"}"#.to_string(),
    ];
    for req in &requests {
        let (resp, done) = session.handle_line(req);
        println!("-> {req}");
        println!("<- {resp}");
        if done {
            break;
        }
    }

    std::fs::remove_file(&path).ok();
    Ok(())
}
