//! DVS Pong (paper §6, Fig 4): the converted spiking policy network plays
//! Atari-style Pong against the scripted opponent, observing DVS ON/OFF
//! frame-difference events. Reports the mean score over N episodes
//! (paper scale: max +21), the Table-2 "Score" column.
//!
//! The environment reimplements `python/data/pong.py` (the training
//! environment) move-for-move; constants must stay in sync with that
//! spec.
//!
//!     make models
//!     cargo run --release --example dvs_pong [-- --episodes 50]

use anyhow::Result;
use hiaer_spike::convert::{run_inference, Readout};
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::metrics::CostSeries;
use hiaer_spike::sim::SimConfig;
use hiaer_spike::util::cli::Args;
use hiaer_spike::util::prng::Xorshift32;

// ---- environment constants (sync with python/data/pong.py) ----
const W: f32 = 160.0;
const H: f32 = 210.0;
const PADDLE_H: f32 = 16.0;
const PADDLE_W: f32 = 4.0;
const BALL: f32 = 2.0;
const AGENT_X: f32 = W - 8.0;
const OPP_X: f32 = 4.0;
const DVS_THRESH: f32 = 10.0;
const FRAME_LAG: usize = 4;

struct Pong {
    rng: Xorshift32,
    agent_y: f32,
    opp_y: f32,
    ball: [f32; 2],
    vel: [f32; 2],
    score: [i32; 2],
    history: Vec<Vec<u8>>, // grayscale frames, H*W
}

impl Pong {
    fn new(seed: u32) -> Self {
        let mut p = Pong {
            rng: Xorshift32::new(seed),
            agent_y: H / 2.0,
            opp_y: H / 2.0,
            ball: [W / 2.0, H / 2.0],
            vel: [2.5, 0.0],
            score: [0, 0],
            history: Vec::new(),
        };
        p.serve();
        let f = p.render();
        p.history = vec![f; FRAME_LAG + 1];
        p
    }

    fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.rng.next_u32() as f32 / u32::MAX as f32) * (hi - lo)
    }

    fn normal_ish(&mut self, sd: f32) -> f32 {
        // triangular approximation is fine for opponent jitter
        (self.uniform(-1.0, 1.0) + self.uniform(-1.0, 1.0)) * sd * 0.7071
    }

    fn serve(&mut self) {
        self.ball = [W / 2.0, H / 2.0];
        let dir = if self.rng.chance(0.5) { 1.0 } else { -1.0 };
        self.vel = [dir * self.uniform(2.0, 3.0), self.uniform(-2.0, 2.0)];
    }

    /// Returns reward.
    fn step(&mut self, action: usize) -> f32 {
        match action {
            2 | 4 => self.agent_y = (self.agent_y - 4.0).max(PADDLE_H / 2.0),
            3 | 5 => self.agent_y = (self.agent_y + 4.0).min(H - PADDLE_H / 2.0),
            _ => {}
        }
        let target = self.ball[1] + self.normal_ish(4.0);
        if target > self.opp_y + 2.0 {
            self.opp_y = (self.opp_y + 3.0).min(H - PADDLE_H / 2.0);
        } else if target < self.opp_y - 2.0 {
            self.opp_y = (self.opp_y - 3.0).max(PADDLE_H / 2.0);
        }

        self.ball[0] += self.vel[0];
        self.ball[1] += self.vel[1];
        let mut reward = 0.0;
        if self.ball[1] < BALL || self.ball[1] > H - BALL {
            self.vel[1] = -self.vel[1];
            self.ball[1] = self.ball[1].clamp(BALL, H - BALL);
        }
        if self.ball[0] >= AGENT_X - PADDLE_W && self.vel[0] > 0.0 {
            if (self.ball[1] - self.agent_y).abs() <= PADDLE_H / 2.0 + BALL {
                self.vel[0] = -self.vel[0].abs() * 1.05;
                self.vel[1] += (self.ball[1] - self.agent_y) * 0.15;
                self.ball[0] = AGENT_X - PADDLE_W;
            } else if self.ball[0] > W {
                self.score[0] += 1;
                reward = -1.0;
                self.serve();
            }
        }
        if self.ball[0] <= OPP_X + PADDLE_W && self.vel[0] < 0.0 {
            if (self.ball[1] - self.opp_y).abs() <= PADDLE_H / 2.0 + BALL {
                self.vel[0] = self.vel[0].abs() * 1.05;
                self.vel[1] += (self.ball[1] - self.opp_y) * 0.15;
                self.ball[0] = OPP_X + PADDLE_W;
            } else if self.ball[0] < 0.0 {
                self.score[1] += 1;
                reward = 1.0;
                self.serve();
            }
        }
        self.vel[0] = self.vel[0].clamp(-6.0, 6.0);
        self.vel[1] = self.vel[1].clamp(-5.0, 5.0);

        let f = self.render();
        self.history.push(f);
        if self.history.len() > FRAME_LAG + 1 {
            self.history.remove(0);
        }
        reward
    }

    fn render(&self) -> Vec<u8> {
        let (w, h) = (W as usize, H as usize);
        let mut f = vec![0u8; w * h];
        let mut rect = |x0: usize, x1: usize, y0: usize, y1: usize, v: u8| {
            for y in y0..y1.min(h) {
                for x in x0..x1.min(w) {
                    f[y * w + x] = v;
                }
            }
        };
        let ay = self.agent_y as usize;
        let oy = self.opp_y as usize;
        let ph = PADDLE_H as usize / 2;
        rect(AGENT_X as usize, AGENT_X as usize + PADDLE_W as usize, ay.saturating_sub(ph), ay + ph, 200);
        rect(OPP_X as usize, OPP_X as usize + PADDLE_W as usize, oy.saturating_sub(ph), oy + ph, 200);
        let (bx, by) = (self.ball[0] as usize, self.ball[1] as usize);
        rect(bx.saturating_sub(2), bx + 2, by.saturating_sub(2), by + 2, 255);
        f
    }

    /// DVS observation: active input-axon ids (2x84x84 layout, ON then
    /// OFF channel), mirroring python/data/pong.py::dvs_frame.
    fn dvs_axons(&self) -> Vec<u32> {
        let (w, h) = (W as usize, H as usize);
        let cur = &self.history[FRAME_LAG];
        let old = &self.history[0];
        let c0 = (h - 168) / 2;
        let mut axons = Vec::new();
        for oy in 0..84 {
            for ox in 0..80 {
                // 2x2 mean downsample of the 168x160 crop
                let mut dc = 0f32;
                let mut doo = 0f32;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let idx = (c0 + oy * 2 + dy) * w + ox * 2 + dx;
                        dc += cur[idx] as f32;
                        doo += old[idx] as f32;
                    }
                }
                let d = (dc - doo) / 4.0;
                let x = ox + 2; // pad 80 -> 84 centered
                if d > DVS_THRESH {
                    axons.push((oy * 84 + x) as u32);
                } else if d < -DVS_THRESH {
                    axons.push((84 * 84 + oy * 84 + x) as u32);
                }
            }
        }
        axons.sort_unstable();
        axons
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    let episodes = args.get_usize("episodes", 50).map_err(anyhow::Error::msg)?;
    let max_frames = args.get_usize("max-frames", 3000).map_err(anyhow::Error::msg)?;
    let dir = models_dir();
    let (graph, conv) = harness::load_model(&dir, "pong_dqn")?;
    let mut engine = SimConfig::new(conv.net.clone()).build()?;
    let energy = EnergyModel::default();
    let layers = graph.layers.len();
    let t = graph.timesteps;

    println!(
        "DVS Pong: {} neurons, {} synapses, T={} rate steps/decision",
        conv.net.n_neurons(),
        conv.net.n_synapses(),
        t
    );

    let mut scores = Vec::new();
    let mut costs = CostSeries::default();
    for ep in 0..episodes {
        let mut env = Pong::new(1000 + ep as u32);
        let mut frames_played = 0usize;
        while env.score[0].max(env.score[1]) < 21 && frames_played < max_frames {
            // rate-coded decision: present the DVS observation T times
            let obs = env.dvs_axons();
            let frames: Vec<Vec<u32>> = (0..t).map(|_| obs.clone()).collect();
            let inf =
                run_inference(&mut *engine, &conv, &frames, layers, Readout::Rate, &energy)?;
            costs.push(&inf.cost);
            env.step(inf.prediction);
            frames_played += 1;
        }
        let score = env.score[1] - env.score[0];
        scores.push(score as f64);
        if ep < 5 || (ep + 1) % 10 == 0 {
            println!(
                "  episode {:>3}: agent {:>2} - {:<2} opponent (score {:+})",
                ep + 1,
                env.score[1],
                env.score[0],
                score
            );
        }
    }
    let (mean, sd) = hiaer_spike::util::stats::mean_std(&scores);
    let (em, es) = costs.energy_mean_std();
    let (lm, ls) = costs.latency_mean_std();
    println!("\nmean score over {episodes} episodes: {mean:.2} ± {sd:.2} (max +21)");
    println!("per-decision HBM energy {em:.1}±{es:.1} uJ, latency {lm:.1}±{ls:.1} us");
    Ok(())
}
