//! Online Pong (PR 9, runtime plasticity): a tiny rate-coded paddle
//! controller that **adapts while it plays** through the `Simulator`
//! facade's live-edit surface — `write_synapse` re-weights existing
//! synapses in place and `add_synapse` grows new ones, all without ever
//! resetting membranes or rebuilding the engine (the paper's online
//! `write_synapse` path; the server-side STDP kernel is the other half,
//! see `SimConfig::learning`).
//!
//! The task is a 1-D pong: the ball random-walks over `LANES` lanes,
//! one stimulus axon per lane, three integrate-and-fire action neurons
//! (up / stay / down) vote by spike count over a short rate window, and
//! the paddle moves by the argmax. Two engines run the **same** seeded
//! ball trajectory from the **same** initial network:
//!
//! * **frozen** — inference only; its initial lane→stay wiring parks
//!   the paddle, so it scores only when the ball wanders past it;
//! * **online** — after every miss it nudges the active lane's synapses
//!   (delta-rule: reinforce the correct action, weaken the chosen one),
//!   creating lane→up / lane→down synapses on first use.
//!
//! The run prints both tracking accuracies over the scored second half
//! and asserts the online agent wins — the "online adaptation beats
//! frozen weights" check.
//!
//!     cargo run --release --example pong_online [-- --frames 400]

use anyhow::Result;
use hiaer_spike::sim::{Backend, SimConfig, Simulator};
use hiaer_spike::snn::{EdgeList, NeuronModel};
use hiaer_spike::util::cli::Args;
use hiaer_spike::util::prng::Xorshift32;

/// Ball / paddle positions live on this many lanes (= stimulus axons).
const LANES: usize = 12;
/// Action neurons: 0 = up (toward lane 0), 1 = stay, 2 = down.
const UP: usize = 0;
const STAY: usize = 1;
const DOWN: usize = 2;
/// IF threshold: a synapse of weight `w` yields roughly `T * w / 5`
/// spikes over the rate window, so spike counts order like weights.
const THETA: i32 = 4;
/// Rate-coding window: steps the ball lane is presented per frame.
const T_STEPS: usize = 6;
/// Delta-rule step and weight ceiling for the online agent.
const LR: i16 = 2;
const W_MAX: i16 = 24;

/// Initial policy network: every lane weakly wired to **stay** only.
/// The per-lane axon row this creates is what later `add_synapse`
/// calls grow into — the up/down synapses do not exist yet.
fn initial_net() -> hiaer_spike::snn::Network {
    let mut edges = EdgeList::with_capacity(3, LANES, LANES);
    for lane in 0..LANES {
        edges.push_axon(lane as u32, STAY as u32, 2);
    }
    edges.into_network(vec![NeuronModel::if_neuron(THETA); 3], vec![0, 1, 2], 7)
}

/// One rate-coded decision: present the ball's lane axon for the whole
/// window and count output spikes per action neuron. Membranes are
/// reset first so the vote is a pure function of the current weights —
/// live edits survive `reset()` because they live in the HBM image.
fn decide(sim: &mut dyn Simulator, ball: usize) -> Result<usize> {
    sim.reset();
    let mut counts = [0usize; 3];
    for _ in 0..T_STEPS {
        let r = sim.step(&[ball as u32])?;
        for &f in r.output_spikes {
            counts[f as usize] += 1;
        }
    }
    // argmax, stay on ties (and when nothing fired at all)
    let mut best = STAY;
    for a in [UP, DOWN] {
        if counts[a] > counts[best] {
            best = a;
        }
    }
    Ok(best)
}

/// Delta-rule weight nudge on one lane→action synapse: in-place
/// `write_synapse` when it exists, `add_synapse` (structural growth)
/// when a positive nudge targets a synapse that does not exist yet.
fn nudge(sim: &mut dyn Simulator, lane: usize, action: usize, delta: i16) -> Result<()> {
    let (lane, action) = (lane as u32, action as u32);
    match sim.read_synapse(true, lane, action)? {
        Some(cur) => {
            sim.write_synapse(true, lane, action, (cur + delta).clamp(0, W_MAX))?;
        }
        None if delta > 0 => {
            sim.add_synapse(true, lane, action, delta.min(W_MAX))?;
        }
        None => {}
    }
    Ok(())
}

fn step_paddle(paddle: usize, action: usize) -> usize {
    match action {
        UP => paddle.saturating_sub(1),
        DOWN => (paddle + 1).min(LANES - 1),
        _ => paddle,
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    let frames = args.get_usize("frames", 400).map_err(anyhow::Error::msg)?;
    let seed = args.get_u32("seed", 11).map_err(anyhow::Error::msg)?;

    let net = initial_net();
    let mut online = SimConfig::new(net.clone()).backend(Backend::Rust).build()?;
    let mut frozen = SimConfig::new(net).backend(Backend::Rust).build()?;

    let mut rng = Xorshift32::new(seed);
    let mut ball = LANES / 2;
    let (mut p_online, mut p_frozen) = (LANES / 2, LANES / 2);
    let scored_from = frames / 2; // let the online agent learn first
    let (mut hits_online, mut hits_frozen, mut scored) = (0usize, 0usize, 0usize);
    let mut edits = 0usize;

    for frame in 0..frames {
        // ball random-walks one lane every other frame (shared
        // trajectory; the paddle is faster, so the task is learnable)
        if frame % 2 == 0 {
            ball = match rng.below(3) {
                0 => ball.saturating_sub(1),
                1 => ball,
                _ => (ball + 1).min(LANES - 1),
            };
        }

        // the action this frame *should* take: move toward the ball
        let want = if ball < p_online {
            UP
        } else if ball > p_online {
            DOWN
        } else {
            STAY
        };
        let act = decide(&mut *online, ball)?;
        if act != want {
            // reinforce the correct action, weaken the one chosen
            nudge(&mut *online, ball, want, LR)?;
            nudge(&mut *online, ball, act, -LR)?;
            edits += 1;
        }
        p_online = step_paddle(p_online, act);

        p_frozen = step_paddle(p_frozen, decide(&mut *frozen, ball)?);

        if frame >= scored_from {
            scored += 1;
            hits_online += (p_online == ball) as usize;
            hits_frozen += (p_frozen == ball) as usize;
        }
    }

    let acc = |hits: usize| 100.0 * hits as f64 / scored.max(1) as f64;
    let (acc_online, acc_frozen) = (acc(hits_online), acc(hits_frozen));
    println!(
        "online pong: {frames} frames ({scored} scored), {edits} corrective edit frames"
    );
    println!("  frozen weights : {acc_frozen:>5.1}% tracking accuracy");
    println!("  online edits   : {acc_online:>5.1}% tracking accuracy");
    assert!(
        acc_online > acc_frozen,
        "online adaptation ({acc_online:.1}%) must beat frozen weights ({acc_frozen:.1}%)"
    );
    println!("  online adaptation beats frozen weights");
    Ok(())
}
