//! End-to-end driver (the repo's headline validation run): evaluate every
//! trained MNIST model on the full platform path —
//!
//!   .hsl (quantized torch export) -> Supp-A.2 converter -> HBM routing
//!   table -> event-driven core engine -> membrane readout
//!
//! and report the Table-2 columns: software(quantized) vs HiAER accuracy
//! (which must match EXACTLY — the paper's conversion-fidelity claim),
//! HBM energy and latency per inference.
//!
//!     make models   # once (trains + exports)
//!     cargo run --release --example mnist_mlp [-- --samples 500]

use anyhow::Result;
use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::sim::SimOptions;
use hiaer_spike::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    let samples = args.get_usize("samples", 500).map_err(anyhow::Error::msg)?;
    let dir = models_dir();
    let entries = harness::load_manifest(&dir)?;
    let opts = SimOptions::from_args(&args)?;

    println!("== MNIST end-to-end (event-driven HBM engine, single core) ==\n");
    harness::print_header();
    let mut all_parity = true;
    for e in entries.iter().filter(|e| e.task == "mnist") {
        let r = harness::evaluate_model(&dir, e, samples, &opts)?;
        harness::print_row(e, &r);
        let parity = (r.accuracy - e.acc_quant).abs() < 1e-9;
        all_parity &= parity;
        if !parity {
            println!(
                "   !! parity broken: quantized-software {:.4} vs HiAER {:.4}",
                e.acc_quant, r.accuracy
            );
        }
    }
    println!(
        "\nconversion fidelity: software==hardware accuracy parity {}",
        if all_parity { "HOLDS for all models" } else { "VIOLATED (see above)" }
    );
    Ok(())
}
