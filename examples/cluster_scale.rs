//! Cluster scaling (paper §3, Fig 9): partition the largest gesture model
//! across increasing slices of the 5-server x 8-FPGA x 32-core HiAER-Spike
//! topology, verify the multi-core run matches the single-core run
//! bit-exactly (same-tick HiAER delivery), and report cut synapses,
//! per-level router traffic and the latency/energy behaviour.
//!
//! Both the single-core baseline and every cluster slice are built
//! through the same `SimConfig` facade — only the topology differs.
//!
//!     make models
//!     cargo run --release --example cluster_scale [-- --samples 10]

use anyhow::Result;
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::model_fmt::read_hsd;
use hiaer_spike::partition::CoreCapacity;
use hiaer_spike::sim::{SimConfig, Simulator};
use hiaer_spike::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    let samples = args.get_usize("samples", 10).map_err(anyhow::Error::msg)?;
    let dir = models_dir();
    let name = args.get_or("model", "dvs_c16c24");
    let (graph, conv) = harness::load_model(&dir, name)?;
    let ts = read_hsd(dir.join(format!("{name}.hsd")))?;
    let net = conv.net.clone();
    println!(
        "model {name}: {} neurons, {} synapses, {} axons\n",
        net.n_neurons(),
        net.n_synapses(),
        net.n_axons()
    );

    // single-core baseline trace (output spikes per step per sample)
    let mut single = SimConfig::new(net.clone()).build()?;
    let steps = graph.timesteps + graph.layers.len();
    let mut baseline: Vec<Vec<Vec<u32>>> = Vec::new();
    for s in &ts.samples[..samples.min(ts.samples.len())] {
        single.reset();
        let mut trace = Vec::new();
        for t in 0..steps {
            let empty = Vec::new();
            let frame = s.frames.get(t).unwrap_or(&empty);
            let out = single.step(frame)?;
            trace.push(out.output_spikes.to_vec());
        }
        baseline.push(trace);
    }

    let energy = EnergyModel::default();
    println!(
        "{:>6} {:>6} {:>10} {:>9} {:>9} {:>9} {:>11} {:>11} {:>8}",
        "cores", "used", "cut syn", "NoC ev", "FF ev", "Eth ev", "energy uJ", "latency us", "parity"
    );
    for (servers, fpgas, cores) in
        [(1, 1, 1), (1, 1, 2), (1, 1, 8), (1, 2, 8), (2, 4, 8), (5, 8, 32)]
    {
        let n_cores = servers * fpgas * cores;
        // shrink per-core capacity so the partitioner actually spreads
        let cap = CoreCapacity {
            max_neurons: net.n_neurons().div_ceil(n_cores).max(64),
            max_synapses: usize::MAX,
        };
        let mut mc = SimConfig::new(net.clone())
            .topology(servers, fpgas, cores)
            .capacity(cap)
            .build()?;
        // a 1-core topology builds the plain single-core engine: no
        // placement, nothing cut
        let (cut_synapses, used) = match mc.placement() {
            Some(p) => (p.cut_stats(&net).cut_synapses, p.n_used_cores()),
            None => (0, 1),
        };
        let mut parity = true;
        let (mut tot_energy, mut tot_latency) = (0.0f64, 0.0f64);
        let mut level_events = [0u64; 4];
        for (si, s) in ts.samples[..baseline.len()].iter().enumerate() {
            mc.reset(); // also clears per-sample cost counters
            for t in 0..steps {
                let empty = Vec::new();
                let frame = s.frames.get(t).unwrap_or(&empty);
                let out = mc.step(frame)?;
                if out.output_spikes != &baseline[si][t][..] {
                    parity = false;
                }
            }
            let cost = mc.cost(&energy);
            tot_energy += cost.energy_uj;
            tot_latency += cost.latency_us;
            if let Some(router) = cost.router {
                for (tot, ev) in level_events.iter_mut().zip(router.events_by_level) {
                    *tot += ev;
                }
            }
        }
        let n = baseline.len() as f64;
        println!(
            "{:>6} {:>6} {:>10} {:>9} {:>9} {:>9} {:>11.1} {:>11.1} {:>8}",
            n_cores,
            used,
            cut_synapses,
            level_events[1],
            level_events[2],
            level_events[3],
            tot_energy / n,
            tot_latency / n,
            if parity { "OK" } else { "FAIL" },
        );
    }
    println!("\nparity OK = multi-core output spikes bit-identical to single core");
    println!("(remote events delivered within the 1 ms tick; router latency adds to the cycle model)");
    Ok(())
}
