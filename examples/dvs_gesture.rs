//! DVS gesture recognition on the spiking-CNN family (paper §6, Fig 3/5):
//! renders one event frame as ASCII (the Fig-3 ON/OFF overlap view), then
//! evaluates each family member, reproducing the accuracy-vs-size and
//! energy/latency-vs-size trends.
//!
//!     make models
//!     cargo run --release --example dvs_gesture [-- --samples 100]

use anyhow::Result;
use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::model_fmt::read_hsd;
use hiaer_spike::sim::SimOptions;
use hiaer_spike::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    let samples = args.get_usize("samples", 100).map_err(anyhow::Error::msg)?;
    let dir = models_dir();
    let entries = harness::load_manifest(&dir)?;
    let gestures: Vec<_> = entries.iter().filter(|e| e.task == "dvs_gesture").collect();
    anyhow::ensure!(!gestures.is_empty(), "no gesture models; run `make models`");

    // ---- Fig-3 style frame view from the first test sample
    let ts = read_hsd(dir.join(format!("{}.hsd", gestures[0].name)))?;
    let (c, h, w) = gestures[0].input;
    assert_eq!(c, 2);
    let frame = &ts.samples[0].frames[4.min(ts.frames_per_sample - 1)];
    let mut on = vec![false; h * w];
    let mut off = vec![false; h * w];
    for &a in frame {
        let a = a as usize;
        if a < h * w {
            on[a] = true;
        } else {
            off[a - h * w] = true;
        }
    }
    println!("Fig-3 view (sample 0, frame 4; + = ON, - = OFF, * = both):");
    for y in (0..h).step_by(2) {
        let row: String = (0..w)
            .map(|x| match (on[y * w + x], off[y * w + x]) {
                (true, true) => '*',
                (true, false) => '+',
                (false, true) => '-',
                _ => '.',
            })
            .collect();
        println!("  {row}");
    }

    // ---- family evaluation
    println!("\n== DVS gesture spiking-CNN family ==\n");
    harness::print_header();
    let opts = SimOptions::from_args(&args)?;
    for e in &gestures {
        let r = harness::evaluate_model(&dir, e, samples, &opts)?;
        harness::print_row(e, &r);
    }
    println!("\nlarger models: higher accuracy at higher energy/latency per gesture (paper Fig 5)");
    Ok(())
}
