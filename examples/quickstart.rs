//! Quickstart: build and run the paper's Supplementary-A.1 example
//! network (Fig 6) through the full platform path — keyed builder ->
//! flattened network -> `SimConfig` -> event-driven simulator session —
//! and poke the hs_api-style interaction surface (step / read_membrane /
//! read_synapse / write_synapse).
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::hbm::SlotStrategy;
use hiaer_spike::sim::{Backend, SimConfig, Simulator};
use hiaer_spike::snn::{NetworkBuilder, NeuronModel};

fn main() -> Result<()> {
    // --- define neuron models (paper §5.1)
    let lif_ab = NeuronModel::lif(3, 0, 63, false)?; // theta 3, ~no leak
    let lif_c = NeuronModel::lif(4, 0, 2, false)?; // theta 4, leak lam=2
    let ann_d = NeuronModel::ann(5, 0, true)?; // stochastic binary

    // --- define the network (axons dict / neurons dict / outputs list)
    let mut b = NetworkBuilder::new().seed(42);
    b.add_neuron("a", lif_ab, &[("b", 1), ("d", 2)])?;
    b.add_neuron("b", lif_ab, &[])?;
    b.add_neuron("c", lif_c, &[])?;
    b.add_neuron("d", ann_d, &[("c", 1)])?;
    b.add_axon("alpha", &[("a", 3), ("c", 2)])?;
    b.add_axon("beta", &[("b", 3)])?;
    b.add_output("a");
    b.add_output("b");
    let (mut net, keys) = b.build()?;

    // --- write_synapse before deployment (hs_api API surface)
    let a = keys.neuron("a").unwrap();
    let bn = keys.neuron("b").unwrap();
    let w = net.read_synapse(false, a, bn).unwrap();
    println!("synapse a->b weight = {w}, bumping by 1");
    net.write_synapse(false, a, bn, w + 1);

    // --- build the session and inspect its HBM routing-table layout
    let mut core = SimConfig::new(net)
        .strategy(SlotStrategy::BalanceFanIn)
        .backend(Backend::Rust)
        .build()?;
    let stats = core.hbm_stats().expect("event-driven session has an HBM image");
    println!(
        "HBM image: {} synapse rows, packing density {:.2}",
        stats.synapse_rows, stats.packing_density
    );

    let alpha = keys.axon("alpha").unwrap();
    let beta = keys.axon("beta").unwrap();
    for t in 0..6 {
        let inputs: Vec<u32> = if t < 2 { vec![alpha, beta] } else { vec![] };
        let out = core.step(&inputs)?;
        let fired: Vec<&str> = out
            .output_spikes
            .iter()
            .map(|&i| keys.neuron_keys[i as usize].as_str())
            .collect();
        drop(out);
        let pots = core.read_membrane(&[a, bn]);
        println!("t={t}: outputs fired {fired:?}, V(a)={}, V(b)={}", pots[0], pots[1]);
    }

    let cost = core.cost(&EnergyModel::default());
    println!(
        "run cost: {} HBM row accesses, {:.4} uJ, {:.4} us (simulated)",
        cost.hbm_rows, cost.energy_uj, cost.latency_us
    );
    Ok(())
}
