//! Sharded scaling (PR 8): run a million-neuron clustered net through
//! `Backend::Sharded` — real worker subprocesses joined by binary AER
//! frames over pipes — at 1, 2 and 4 shards on a 4-core topology, and
//! report the steps/s curve plus the cross-shard-count determinism check
//! (identical output-spike streams regardless of how many processes the
//! cores are split across).
//!
//! The worker binary is discovered next to this example
//! (`target/release/hiaer-spike`); set `$HS_BIN` to override.
//!
//!     cargo build --release
//!     cargo run --release --example shard_scale [-- --neurons 1000000 --steps 20]

use anyhow::Result;
use hiaer_spike::partition::CoreCapacity;
use hiaer_spike::sim::{SimConfig, Simulator};
use hiaer_spike::snn::{EdgeList, Network, NeuronModel};
use hiaer_spike::util::cli::Args;
use hiaer_spike::util::prng::Xorshift32;
use std::time::Instant;

/// Clustered random net (the shard-friendly workload): most synapses
/// stay inside a `block`-sized neighbourhood, so contiguous-core shards
/// keep the bulk of traffic off the inter-shard pipes — the regime the
/// paper's hierarchical AER routing is built for.
fn make_net(n: usize, d: usize, block: usize, p_local: f64, seed: u32) -> Network {
    let mut rng = Xorshift32::new(seed);
    let a = 64.min(n);
    let mut edges = EdgeList::with_capacity(n, a, n * d + a * 8);
    for i in 0..n {
        let b0 = (i / block) * block;
        for _ in 0..d {
            let target = if rng.chance(p_local) {
                (b0 + rng.below(block as u32) as usize).min(n - 1) as u32
            } else {
                rng.below(n as u32)
            };
            edges.push_neuron(i as u32, target, rng.range_i32(5, 40) as i16);
        }
    }
    for ax in 0..a {
        for _ in 0..8 {
            edges.push_axon(ax as u32, rng.below(n as u32), 80);
        }
    }
    // deterministic IF neurons: output spikes must be bit-identical
    // across shard counts, so the parity column below is meaningful
    edges.into_network(
        vec![NeuronModel::if_neuron(60); n],
        (0..(n as u32).min(32)).collect(),
        seed,
    )
}

/// Burst drive every third step, like the hot-path bench.
fn drive(step: usize, n_axons: usize) -> Vec<u32> {
    if step % 3 == 0 {
        (0..n_axons as u32).step_by(2).collect()
    } else {
        Vec::new()
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    let n = args.get_usize("neurons", 1_000_000).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 8).map_err(anyhow::Error::msg)?;
    let steps = args.get_usize("steps", 20).map_err(anyhow::Error::msg)?;

    let block = (n / 40).max(1);
    let net = make_net(n, degree, block, 0.95, 11);
    let cap = CoreCapacity { max_neurons: n.div_ceil(4), max_synapses: usize::MAX };
    println!(
        "net: {} neurons, {} synapses, {} axons; topology 1x1x4, {steps} steps\n",
        net.n_neurons(),
        net.n_synapses(),
        net.n_axons()
    );

    println!("{:>7} {:>12} {:>9} {:>14} {:>8}", "shards", "steps/s", "scaleup", "spikes", "parity");
    let (mut base_rate, mut base_sig) = (0.0f64, None::<(u64, u64)>);
    for shards in [1usize, 2, 4] {
        let mut sim = SimConfig::new(net.clone())
            .topology(1, 1, 4)
            .capacity(cap)
            .shards(shards)
            .build()?;
        // spike-stream signature: (total output spikes, order-sensitive
        // rolling hash) — equal across shard counts iff the merged
        // cross-shard event streams are bit-identical
        let (mut total, mut hash) = (0u64, 0u64);
        let t0 = Instant::now();
        for s in 0..steps {
            let out = sim.step(&drive(s, net.n_axons()))?;
            for &id in out.output_spikes {
                total += 1;
                hash = hash.wrapping_mul(0x100000001b3).wrapping_add(id as u64 + 1);
            }
            hash = hash.wrapping_mul(0x100000001b3); // step boundary
        }
        let rate = steps as f64 / t0.elapsed().as_secs_f64();
        if shards == 1 {
            base_rate = rate;
        }
        let parity = match base_sig {
            None => {
                base_sig = Some((total, hash));
                "ref"
            }
            Some(sig) if sig == (total, hash) => "OK",
            Some(_) => "FAIL",
        };
        println!(
            "{:>7} {:>12.2} {:>8.2}x {:>14} {:>8}",
            shards,
            rate,
            rate / base_rate,
            total,
            parity
        );
        assert_ne!(parity, "FAIL", "output spikes diverged at {shards} shards");
    }
    println!(
        "\nparity OK = output spike stream bit-identical to the 1-shard run \
         (deterministic cross-shard merge)"
    );
    Ok(())
}
