//! Regenerates paper Table 4: DVS-Gesture across neuromorphic platforms.
//! HiAER rows measured live (lowest-energy + best-accuracy gesture CNN);
//! Loihi / SpiNNaker2 / TrueNorth rows are the published numbers the
//! paper cites ([17], [18], [19]).

use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::sim::SimOptions;

fn main() {
    let dir = models_dir();
    let opts = SimOptions::default();
    let entries = match harness::load_manifest(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("table4: {e:#}\nrun `make models` first");
            return;
        }
    };
    let gest: Vec<_> = entries.iter().filter(|e| e.task == "dvs_gesture").collect();
    if gest.is_empty() {
        eprintln!("no gesture models in manifest");
        return;
    }
    let mut results = Vec::new();
    for e in &gest {
        match harness::evaluate_model(&dir, e, usize::MAX, &opts) {
            Ok(r) => results.push((e, r)),
            Err(err) => eprintln!("{}: {err:#}", e.name),
        }
    }
    let lowest = results
        .iter()
        .min_by(|a, b| a.1.energy_mean.partial_cmp(&b.1.energy_mean).unwrap())
        .expect("nonempty");
    let best = results
        .iter()
        .max_by(|a, b| a.1.accuracy.partial_cmp(&b.1.accuracy).unwrap())
        .expect("nonempty");

    println!("== Table 4: DVS Gesture across neuromorphic platforms ==\n");
    println!(
        "{:<30} {:>10} {:>9} {:>12} {:>12}",
        "System", "Neurons", "Acc (%)", "Energy (uJ)", "Latency (us)"
    );
    println!("{}", "-".repeat(80));
    for (label, r) in
        [("HiAER-Spike (lowest energy)", lowest), ("HiAER-Spike (best acc)", best)]
    {
        println!(
            "{:<30} {:>10} {:>9.2} {:>12.1} {:>12.1}",
            label,
            r.1.neurons,
            r.1.accuracy * 100.0,
            r.1.energy_mean,
            r.1.latency_mean
        );
    }
    for (sys, n, acc, e, l) in [
        ("Loihi [17] (published)", "N/A", "89.64", "N/A", "11,430"),
        ("SpiNNaker2 [18] (published)", "9,907", "94.13", "459,000", "N/A"),
        ("TrueNorth [19] (published)", "N/A", "96.49", "18,700", "104,600"),
    ] {
        println!("{:<30} {:>10} {:>9} {:>12} {:>12}", sys, n, acc, e, l);
    }
    println!(
        "\nshape check: HiAER-Spike trades accuracy (10 binarized frames, synthetic\n\
         gestures) for orders-of-magnitude lower per-inference energy and latency —\n\
         the relation the paper reports."
    );
}
