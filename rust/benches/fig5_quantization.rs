//! Regenerates paper Fig 5: DVS-gesture test accuracy across model sizes
//! for (a) full-precision software, (b) int16-quantized software, and
//! (c) the hardware (event-driven HBM engine). Quantized-vs-hardware must
//! match exactly; float-vs-quantized shows the quantization cost.

use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::sim::SimOptions;

fn main() {
    let dir = models_dir();
    let opts = SimOptions::default();
    let entries = match harness::load_manifest(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("fig5: {e:#}\nrun `make models` first");
            return;
        }
    };
    let mut gest: Vec<_> = entries.iter().filter(|e| e.task == "dvs_gesture").collect();
    gest.sort_by_key(|e| e.params);

    println!("== Fig 5: DVS gesture accuracy vs model size and precision ==\n");
    println!(
        "{:<12} {:>9} {:>9} {:>11} {:>11} {:>10}",
        "Model", "Params", "Neurons", "float32 %", "int16 %", "hardware %"
    );
    println!("{}", "-".repeat(68));
    let mut series = Vec::new();
    for e in &gest {
        match harness::evaluate_model(&dir, e, usize::MAX, &opts) {
            Ok(r) => {
                println!(
                    "{:<12} {:>9} {:>9} {:>11.2} {:>11.2} {:>10.2}",
                    e.name,
                    e.params,
                    r.neurons,
                    e.acc_float * 100.0,
                    e.acc_quant * 100.0,
                    r.accuracy * 100.0
                );
                series.push((e.params as f64, r.accuracy));
            }
            Err(err) => println!("{:<12} ERROR {err:#}", e.name),
        }
    }
    if series.len() >= 2 {
        let first = series.first().unwrap().1;
        let last = series.last().unwrap().1;
        println!(
            "\ntrend: accuracy {} with model size ({}: {:.1}% -> {}: {:.1}%), as in Fig 5",
            if last >= first { "increases" } else { "decreases" },
            gest.first().unwrap().name,
            first * 100.0,
            gest.last().unwrap().name,
            last * 100.0
        );
    }
    println!("int16 == hardware column-match is the conversion-fidelity invariant.");
}
