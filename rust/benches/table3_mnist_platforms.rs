//! Regenerates paper Table 3: MNIST across neuromorphic platforms.
//!
//! HiAER-Spike rows are measured live (lowest-energy model + best-accuracy
//! model); the Loihi / SpiNNaker / TrueNorth rows are the published
//! numbers the paper cites ([14], [15], [16]) — they are comparison
//! constants, not measurements of this substrate.

use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::sim::SimOptions;

struct PlatformRow {
    system: &'static str,
    neurons: String,
    acc: String,
    energy_uj: String,
    latency_us: String,
}

fn main() {
    let dir = models_dir();
    let opts = SimOptions::default();
    let entries = match harness::load_manifest(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("table3: {e:#}\nrun `make models` first");
            return;
        }
    };
    let mnist: Vec<_> = entries.iter().filter(|e| e.task == "mnist").collect();
    if mnist.is_empty() {
        eprintln!("no MNIST models in manifest");
        return;
    }
    let samples = usize::MAX;
    let mut results = Vec::new();
    for e in &mnist {
        match harness::evaluate_model(&dir, e, samples, &opts) {
            Ok(r) => results.push((e, r)),
            Err(err) => eprintln!("{}: {err:#}", e.name),
        }
    }
    // paper convention: row 1 = lowest HBM energy+latency, row 2 = best acc
    let lowest = results
        .iter()
        .min_by(|a, b| a.1.energy_mean.partial_cmp(&b.1.energy_mean).unwrap())
        .expect("nonempty");
    let best = results
        .iter()
        .max_by(|a, b| a.1.accuracy.partial_cmp(&b.1.accuracy).unwrap())
        .expect("nonempty");

    let mut rows = vec![
        PlatformRow {
            system: "HiAER-Spike (lowest energy)",
            neurons: lowest.1.neurons.to_string(),
            acc: format!("{:.2}", lowest.1.accuracy * 100.0),
            energy_uj: format!("{:.1}", lowest.1.energy_mean),
            latency_us: format!("{:.1}", lowest.1.latency_mean),
        },
        PlatformRow {
            system: "HiAER-Spike (best acc)",
            neurons: best.1.neurons.to_string(),
            acc: format!("{:.2}", best.1.accuracy * 100.0),
            energy_uj: format!("{:.1}", best.1.energy_mean),
            latency_us: format!("{:.1}", best.1.latency_mean),
        },
    ];
    // published comparison rows (paper Table 3, refs [14][15][16])
    rows.push(PlatformRow {
        system: "Loihi [14] (published)",
        neurons: "5,400".into(),
        acc: "99.23".into(),
        energy_uj: "182.46".into(),
        latency_us: "4,900".into(),
    });
    rows.push(PlatformRow {
        system: "SpiNNaker [15] (published)",
        neurons: "1,790".into(),
        acc: "95.01".into(),
        energy_uj: "N/A".into(),
        latency_us: "20,000".into(),
    });
    rows.push(PlatformRow {
        system: "TrueNorth [16] (published)",
        neurons: "7,680".into(),
        acc: "99.42".into(),
        energy_uj: "108".into(),
        latency_us: "N/A".into(),
    });

    println!("== Table 3: MNIST across neuromorphic platforms ==\n");
    println!(
        "{:<28} {:>10} {:>9} {:>12} {:>12}",
        "System", "Neurons", "Acc (%)", "Energy (uJ)", "Latency (us)"
    );
    println!("{}", "-".repeat(76));
    for r in &rows {
        println!(
            "{:<28} {:>10} {:>9} {:>12} {:>12}",
            r.system, r.neurons, r.acc, r.energy_uj, r.latency_us
        );
    }
    println!(
        "\nshape check: HiAER energy and latency sit orders of magnitude below the\n\
         published platforms (the paper's qualitative claim), with lower accuracy\n\
         (single-timestep binary nets on a synthetic MNIST here)."
    );
}
