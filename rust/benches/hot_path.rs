//! Hot-path performance bench + ablations (EXPERIMENTS.md §Perf):
//!
//! 0. **headline**: the sparse-activity config (n = 100k, avg degree 16)
//!    run on (a) a faithful replica of the pre-refactor hot path (O(N)
//!    scalar spike scan + split target/weight event arrays) and (b) the
//!    CSR + bitmask engine, plus the membrane-sweep rate alone (branch-
//!    free kernel, scalar and chunk-parallel via `Backend::Pool`) — one record
//!    per run is **appended** to the `BENCH_hotpath.json` trajectory at
//!    the repo root (override with BENCH_OUT, label with BENCH_PR); the
//!    chunk-parallel sweep rate is measured as idle `Backend::Pool`
//!    facade steps (sweep + empty route) since PR 3; since PR 6 the
//!    record also carries the shared-server serving tier's aggregate
//!    steps/s over 1 and 4 concurrent TCP sessions; since PR 7 it also
//!    carries the cold-start breakdown (v1 parse vs zero-copy v2 mmap
//!    load, compile-from-view time, process peak RSS) and asserts the
//!    mmap load beats the parse; since PR 8 it also carries the
//!    `Backend::Sharded` multi-process scaling curve (1/2/4 shard
//!    workers over a 4-core topology, binary AER frames over pipes);
//!    since PR 9 it also carries the runtime-plasticity numbers
//!    (STDP-enabled steps/s vs frozen weights, and the mean in-place
//!    `write_synapse` live-edit latency); since PR 10 it also carries
//!    the serving tier's binary-wire comparison (`step_many` over JSON
//!    vs negotiated STIM/SPIKES frames on a marshalling-heavy dense
//!    stimulus, `serve_wire_speedup` asserted > 1.0);
//! 1. event-driven core engine steps/s across network sizes (rust
//!    backend), synaptic events/s;
//! 2. dense software-simulator baseline (the paper's Fig-8 CPU
//!    comparison): event-driven wins on sparse activity;
//! 3. HBM slot-strategy ablation (Modulo vs BalanceFanIn packing);
//! 4. XLA/PJRT backend (the AOT Pallas artifact path) vs native rust
//!    backend, when artifacts are present;
//! 5. multi-core scaling of wall-clock throughput.
//!
//! env: HOTPATH_STEPS (default 300), HOTPATH_XLA=0 to skip PJRT,
//! BENCH_OUT to redirect the JSON record.

use std::time::Instant;

use hiaer_spike::energy::EnergyModel;
use hiaer_spike::engine::{mask_words, CoreParams, RustBackend, UpdateBackend};
use hiaer_spike::hbm::{HbmImage, HbmSim, Pointer, SlotStrategy};
use hiaer_spike::model_fmt::{open_netfile, read_hsn, write_hsn, write_hsn_v1};
use hiaer_spike::partition::CoreCapacity;
use hiaer_spike::sim::{Backend, SimConfig, Simulator};
use hiaer_spike::snn::{EdgeList, Network, NeuronModel, FLAG_LIF, FLAG_NOISE};
use hiaer_spike::util::json::{obj, Json};
use hiaer_spike::util::prng::{mix_seed, noise17, shift_noise, Xorshift32};

/// Drive an engine `steps` ticks under the standard burst stimulus and
/// return steps/s (the bench's common inner loop over the facade).
fn rate(sim: &mut dyn Simulator, steps: usize, n_axons: usize) -> f64 {
    let t0 = Instant::now();
    for s in 0..steps {
        sim.step(&drive(s, n_axons)).unwrap();
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

/// Process-lifetime peak resident set (VmHWM) in MB from
/// `/proc/self/status`; 0.0 where procfs is unavailable.
fn peak_rss_mb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|kb| kb.parse::<f64>().ok())
        })
        .map(|kb| kb / 1024.0)
        .unwrap_or(0.0)
}

/// Best-of-3 wall time for `f`, in milliseconds.
fn best_of_3_ms(f: &mut dyn FnMut()) -> f64 {
    (0..3)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .fold(f64::INFINITY, f64::min)
}

/// Random net: n neurons, avg degree d, theta tuned for sustained sparse
/// activity from periodic axon drive. `hubs` adds heavy-fan-in targets
/// (the packing-ablation stressor).
fn make_net(n: usize, d: usize, seed: u32, hubs: bool) -> Network {
    let mut rng = Xorshift32::new(seed);
    let a = 64.min(n);
    let mut edges = EdgeList::with_capacity(n, a, n * d + a * 8);
    for i in 0..n {
        for _ in 0..d {
            edges.push_neuron(i as u32, rng.below(n as u32), rng.range_i32(5, 40) as i16);
        }
    }
    for ax in 0..a {
        for _ in 0..8 {
            edges.push_axon(ax as u32, rng.below(n as u32), 80);
        }
    }
    if hubs {
        // first 16 neurons become hubs to stress slot skew
        let mut hub_rng = Xorshift32::new(9);
        for i in 0..n {
            if hub_rng.chance(0.3) {
                edges.push_neuron(i as u32, hub_rng.below(16), 10);
            }
        }
    }
    edges.into_network(
        vec![NeuronModel::if_neuron(60); n],
        (0..(n as u32).min(8)).collect(),
        seed,
    )
}

/// Clustered net: `p_local` of synapses stay within the neuron's block.
fn make_clustered_net(n: usize, d: usize, block: usize, p_local: f64, seed: u32) -> Network {
    let mut rng = Xorshift32::new(seed);
    let a = 64.min(n);
    let mut edges = EdgeList::with_capacity(n, a, n * d + a * 8);
    for i in 0..n {
        let b0 = (i / block) * block;
        for _ in 0..d {
            let target = if rng.chance(p_local) {
                (b0 + rng.below(block as u32) as usize).min(n - 1) as u32
            } else {
                rng.below(n as u32)
            };
            edges.push_neuron(i as u32, target, rng.range_i32(5, 40) as i16);
        }
    }
    for ax in 0..a {
        for _ in 0..8 {
            edges.push_axon(ax as u32, rng.below(n as u32), 80);
        }
    }
    edges.into_network(
        vec![NeuronModel::if_neuron(60); n],
        (0..(n as u32).min(8)).collect(),
        seed,
    )
}

fn drive(step: usize, n_axons: usize) -> Vec<u32> {
    // burst every 3 steps
    if step % 3 == 0 {
        (0..n_axons as u32).step_by(2).collect()
    } else {
        Vec::new()
    }
}

/// Faithful replica of the pre-refactor per-step hot path, kept so the
/// headline speedup is measured against the real predecessor rather than
/// guessed: scalar membrane loop writing a per-neuron 0/1 i32 mask, a
/// full O(N) scan to extract fired ids, and phase-2 gather into split
/// target/weight arrays consumed by a second full pass. It shares
/// `HbmImage`/`HbmSim`, so everything except the hot path is identical.
struct LegacyEngine {
    hbm: HbmSim,
    params: CoreParams,
    v: Vec<i32>,
    base_seed: u32,
    step_num: u32,
    spike_mask: Vec<i32>,
    fired_buf: Vec<u32>,
    fired_sorted: Vec<u32>,
    ptr_queue: Vec<Pointer>,
    targets: Vec<u32>,
    weights: Vec<i32>,
}

impl LegacyEngine {
    fn new(net: &Network, strategy: SlotStrategy) -> Self {
        let image = HbmImage::compile(net, strategy).unwrap();
        let n = net.n_neurons();
        Self {
            hbm: HbmSim::new(image),
            params: CoreParams::from_network(net),
            v: vec![0; n],
            base_seed: net.base_seed,
            step_num: 0,
            spike_mask: vec![0; n],
            fired_buf: Vec::with_capacity(n),
            fired_sorted: Vec::with_capacity(n),
            ptr_queue: Vec::new(),
            targets: Vec::new(),
            weights: Vec::new(),
        }
    }

    fn step(&mut self, axon_in: &[u32]) {
        let ss = mix_seed(self.base_seed, self.step_num);
        for i in 0..self.v.len() {
            let flags = self.params.flags[i];
            let mut x = self.v[i];
            if flags & FLAG_NOISE != 0 {
                x = x.wrapping_add(shift_noise(noise17(ss, i as u32), self.params.nu[i]));
            }
            let s = (x > self.params.theta[i]) as i32;
            if s != 0 {
                x = 0;
            }
            if flags & FLAG_LIF != 0 {
                x -= x >> self.params.lam[i].clamp(0, 31);
            } else {
                x = 0;
            }
            self.v[i] = x;
            self.spike_mask[i] = s;
        }
        self.fired_buf.clear();
        for (i, &s) in self.spike_mask.iter().enumerate() {
            if s != 0 {
                self.fired_buf.push(i as u32);
            }
        }
        self.ptr_queue.clear();
        self.hbm.fetch_axon_pointers(axon_in, &mut self.ptr_queue);
        self.fired_sorted.clear();
        self.fired_sorted.extend_from_slice(&self.fired_buf);
        let rows = &self.hbm.image.neuron_ptr_row;
        self.fired_sorted.sort_unstable_by_key(|&i| (rows[i as usize], i));
        self.hbm.fetch_neuron_pointers(&self.fired_sorted, &mut self.ptr_queue);
        self.targets.clear();
        self.weights.clear();
        let (targets, weights) = (&mut self.targets, &mut self.weights);
        for k in 0..self.ptr_queue.len() {
            let ptr = self.ptr_queue[k];
            self.hbm.read_region(ptr, |e| {
                targets.push(e.target);
                weights.push(e.weight as i32);
            });
        }
        for (&t, &w) in self.targets.iter().zip(self.weights.iter()) {
            let slot = &mut self.v[t as usize];
            *slot = slot.wrapping_add(w);
        }
        self.step_num += 1;
    }
}

fn main() {
    let steps: usize = std::env::var("HOTPATH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let do_xla = std::env::var("HOTPATH_XLA").map(|v| v != "0").unwrap_or(true);

    println!("== hot-path bench (steps = {steps}) ==\n");

    // ---------- 0. headline: sparse-activity config, legacy vs CSR+bitmask
    let (hn, hd) = (100_000usize, 16usize);
    println!("[0] sparse-activity headline (n = {hn}, d = {hd}): pre-refactor vs CSR+bitmask");
    let net = make_net(hn, hd, 42, false);
    let mut legacy = LegacyEngine::new(&net, SlotStrategy::BalanceFanIn);
    let t0 = Instant::now();
    for s in 0..steps {
        legacy.step(&drive(s, net.n_axons()));
    }
    let legacy_rate = steps as f64 / t0.elapsed().as_secs_f64();

    let mut e = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
    let new_rate = rate(&mut *e, steps, net.n_axons());
    let events_per_s = e.cost(&EnergyModel::default()).events as f64 * new_rate / steps as f64;
    let all_ids: Vec<u32> = (0..hn as u32).collect();
    assert_eq!(
        legacy.v,
        e.read_membrane(&all_ids),
        "legacy replica and CSR engine must stay bit-exact"
    );
    let speedup = new_rate / legacy_rate;
    println!("  legacy hot path : {legacy_rate:>10.0} steps/s");
    println!("  csr + bitmask   : {new_rate:>10.0} steps/s   ({speedup:.2}x)");

    // membrane-sweep rate alone (phases 1-3, branch-free kernel) on the
    // same n=100k params: single-threaded, then chunk-parallel across the
    // pool-backend workers
    let params = CoreParams::from_network(&net);
    let mut sweep_v = vec![0i32; hn];
    let mut sweep_words = vec![0u64; mask_words(hn)];
    let t0 = Instant::now();
    for s in 0..steps {
        RustBackend
            .update(&mut sweep_v, &params, mix_seed(42, s as u32), &mut sweep_words)
            .unwrap();
    }
    let sweep_rate = steps as f64 / t0.elapsed().as_secs_f64();
    let mut pool = SimConfig::new(net.clone()).backend(Backend::Pool).build().unwrap();
    let t0 = Instant::now();
    for _ in 0..steps {
        // idle tick: nothing fires in this net without drive, so a pool
        // step is the chunk-parallel sweep plus an empty route phase
        pool.step(&[]).unwrap();
    }
    let sweep_chunked_rate = steps as f64 / t0.elapsed().as_secs_f64();
    drop(pool);
    println!(
        "  membrane sweep  : {sweep_rate:>10.0} sweeps/s scalar, {sweep_chunked_rate:>10.0} chunk-parallel ({:.2}x)",
        sweep_chunked_rate / sweep_rate
    );

    // batched stimulus marshalling: one `step_many(batch)` call vs the
    // per-step `step` loop on the same n=100k net (fresh engines; the
    // session protocol and `run` ride on step_many)
    let batch: Vec<Vec<u32>> = (0..steps).map(|s| drive(s, net.n_axons())).collect();
    let mut loop_sim = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
    let t0 = Instant::now();
    for axons in &batch {
        loop_sim.step(axons).unwrap();
    }
    let step_loop_rate = steps as f64 / t0.elapsed().as_secs_f64();
    let mut many_sim = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
    let t0 = Instant::now();
    let br = many_sim.step_many(&batch).unwrap();
    let stepmany_rate = steps as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(
        loop_sim.read_membrane(&all_ids),
        many_sim.read_membrane(&all_ids),
        "step_many must stay bit-exact with the step loop"
    );
    let stepmany_speedup = stepmany_rate / step_loop_rate;
    println!(
        "  step_many batch : {step_loop_rate:>10.0} steps/s per-step loop, \
         {stepmany_rate:>10.0} batched ({stepmany_speedup:.2}x, {} fired)",
        br.fired_total
    );

    // route phase: per-core (serial gather on the one engine) vs
    // chunk-parallel gather spread over the pool workers, same driven
    // stimulus so phase B dominates; bit-exactness asserted
    use hiaer_spike::sim::RouteGranularity;
    let mut route_serial = SimConfig::new(net.clone())
        .backend(Backend::Pool)
        .route_granularity(RouteGranularity::Core)
        .build()
        .unwrap();
    let route_core_rate = rate(&mut *route_serial, steps, net.n_axons());
    let mut route_par = SimConfig::new(net.clone())
        .backend(Backend::Pool)
        .route_granularity(RouteGranularity::Chunk)
        .build()
        .unwrap();
    let route_chunk_rate = rate(&mut *route_par, steps, net.n_axons());
    assert_eq!(
        route_serial.read_membrane(&all_ids),
        route_par.read_membrane(&all_ids),
        "chunk-parallel route must stay bit-exact with per-core routing"
    );
    let route_speedup = route_chunk_rate / route_core_rate;
    println!(
        "  route phase     : {route_core_rate:>10.0} steps/s per-core, \
         {route_chunk_rate:>10.0} chunk-parallel ({route_speedup:.2}x)"
    );

    // shared-server serving tier: aggregate steps/s over real TCP
    // sessions against an in-process `serve_tcp` (PR 6). Each client
    // configures its own simulator from the same .hsn and drives one
    // step_many batch — protocol marshalling, admission-gate queueing
    // and the per-connection threads are all on the measured path. A
    // smaller net than the headline keeps per-session setup sane while
    // the update sweep still dominates a step.
    let (sn, sd_deg) = (20_000usize, 16usize);
    let serve_net = make_net(sn, sd_deg, 42, false);
    let serve_axons = serve_net.n_axons();
    let hsn = std::env::temp_dir().join(format!("hotpath_serve_{}.hsn", std::process::id()));
    write_hsn(&serve_net, &hsn).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let shutdown = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let server = {
        let sd = shutdown.clone();
        std::thread::spawn(move || {
            hiaer_spike::sim::serve::serve_tcp(
                listener,
                hiaer_spike::sim::SimOptions::default(),
                hiaer_spike::sim::serve::ServeLimits::default(),
                sd,
            )
        })
    };
    let bench_serve = |sessions: usize| -> f64 {
        use std::io::{BufRead, Write};
        let t0 = Instant::now();
        let handles: Vec<_> = (0..sessions)
            .map(|_| {
                let hsn = hsn.clone();
                std::thread::spawn(move || {
                    let stream = std::net::TcpStream::connect(addr).unwrap();
                    stream.set_nodelay(true).unwrap();
                    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
                    let mut w = stream;
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap(); // hello
                    writeln!(w, r#"{{"op":"configure","net":"{}","seed":7}}"#, hsn.display())
                        .unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.contains(r#""ok":true"#), "configure failed: {line}");
                    let rows: Vec<String> = (0..steps)
                        .map(|s| {
                            let row: Vec<String> =
                                drive(s, serve_axons).iter().map(u32::to_string).collect();
                            format!("[{}]", row.join(","))
                        })
                        .collect();
                    writeln!(w, r#"{{"op":"step_many","batch":[{}]}}"#, rows.join(",")).unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.contains(r#""ok":true"#), "step_many failed: {line}");
                    writeln!(w, r#"{{"op":"shutdown"}}"#).unwrap();
                    line.clear();
                    let _ = reader.read_line(&mut line);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        (sessions * steps) as f64 / t0.elapsed().as_secs_f64()
    };
    let serve1_rate = bench_serve(1);
    let serve4_rate = bench_serve(4);

    // binary wire (PR 10): the same dense schedule over the JSON wire
    // and the negotiated binary STIM/SPIKES wire, against the same
    // server. A marshalling-heavy workload — tiny net (per-step compute
    // negligible), every axon fired every step — so the wire encoding
    // dominates the round trip; timed end to end (client encode +
    // server decode/execute/encode + client decode), best of 3
    // exchanges per wire, bit-identical spike trains asserted.
    use hiaer_spike::sim::frames;
    let wire_net = make_net(256, 4, 42, false);
    let wire_axons = wire_net.n_axons();
    let wire_hsn = std::env::temp_dir().join(format!("hotpath_wire_{}.hsn", std::process::id()));
    write_hsn(&wire_net, &wire_hsn).unwrap();
    let wire_steps = 2048usize;
    let wire_batch: Vec<Vec<u32>> =
        (0..wire_steps).map(|_| (0..wire_axons as u32).collect()).collect();

    let (json_wire_rate, json_rows, json_fired) = {
        use std::io::{BufRead, Write};
        let stream = std::net::TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let mut w = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // hello
        writeln!(w, r#"{{"op":"configure","net":"{}","seed":7}}"#, wire_hsn.display()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains(r#""ok":true"#), "configure failed: {line}");
        let mut best_dt = f64::INFINITY;
        let mut first: Option<(Vec<Vec<i64>>, i64)> = None;
        for _ in 0..3 {
            let t0 = Instant::now();
            let rows: Vec<String> = wire_batch
                .iter()
                .map(|r| {
                    let ids: Vec<String> = r.iter().map(u32::to_string).collect();
                    format!("[{}]", ids.join(","))
                })
                .collect();
            writeln!(w, r#"{{"op":"step_many","batch":[{}]}}"#, rows.join(",")).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(line.trim_end()).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "step_many failed: {line}");
            let got: Vec<Vec<i64>> = j
                .get("spikes")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|r| r.int_vec().unwrap())
                .collect();
            let fired = j.get("fired_total").and_then(Json::as_i64).unwrap();
            best_dt = best_dt.min(t0.elapsed().as_secs_f64());
            first.get_or_insert((got, fired));
        }
        writeln!(w, r#"{{"op":"shutdown"}}"#).unwrap();
        line.clear();
        let _ = reader.read_line(&mut line);
        let (rows, fired) = first.unwrap();
        (wire_steps as f64 / best_dt, rows, fired)
    };

    let (binary_wire_rate, bin_rows, bin_fired) = {
        use std::io::{BufRead, Read, Write};
        let stream = std::net::TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let mut w = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // hello
        writeln!(
            w,
            r#"{{"op":"configure","net":"{}","seed":7,"wire":"binary"}}"#,
            wire_hsn.display()
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.contains(r#""wire":"binary""#),
            "binary wire not negotiated: {line}"
        );
        let mut best_dt = f64::INFINITY;
        let mut first: Option<(Vec<Vec<u32>>, u64)> = None;
        for _ in 0..3 {
            let t0 = Instant::now();
            let frame = frames::encode_wire_frame(
                frames::FRAME_STIM,
                &frames::encode_stim(&wire_batch),
            )
            .unwrap();
            w.write_all(&frame).unwrap();
            w.flush().unwrap();
            let mut head = [0u8; 5];
            reader.read_exact(&mut head).unwrap();
            assert_eq!(head[0], frames::WIRE_SENTINEL, "expected a SPIKES frame");
            let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]) as usize;
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).unwrap();
            assert_eq!(body[0], frames::FRAME_SPIKES);
            let (rows, fired) = frames::decode_spikes(&body[1..]).unwrap();
            best_dt = best_dt.min(t0.elapsed().as_secs_f64());
            first.get_or_insert((rows, fired));
        }
        writeln!(w, r#"{{"op":"shutdown"}}"#).unwrap();
        line.clear();
        let _ = reader.read_line(&mut line);
        let (rows, fired) = first.unwrap();
        (wire_steps as f64 / best_dt, rows, fired)
    };
    let bin_rows_i64: Vec<Vec<i64>> =
        bin_rows.iter().map(|r| r.iter().map(|&s| s as i64).collect()).collect();
    assert_eq!(bin_rows_i64, json_rows, "binary and JSON wires must be bit-identical");
    assert_eq!(bin_fired as i64, json_fired, "fired_total must match across wires");
    let serve_wire_speedup = binary_wire_rate / json_wire_rate;
    assert!(
        serve_wire_speedup > 1.0,
        "binary wire ({binary_wire_rate:.0} steps/s) must beat JSON \
         ({json_wire_rate:.0} steps/s) on the marshalling-heavy workload"
    );
    let _ = std::fs::remove_file(&wire_hsn);

    shutdown.store(true, std::sync::atomic::Ordering::SeqCst);
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_file(&hsn);
    let serve_scaleup = serve4_rate / serve1_rate;
    println!(
        "  serve tier      : {serve1_rate:>10.0} steps/s 1 session, \
         {serve4_rate:>10.0} aggregate over 4 sessions ({serve_scaleup:.2}x, n = {sn})"
    );
    println!(
        "  binary wire     : {json_wire_rate:>10.0} steps/s JSON, \
         {binary_wire_rate:>10.0} binary ({serve_wire_speedup:.2}x, dense stimulus)"
    );

    // cold start: serving the same headline net from disk — the v1
    // per-synapse parse into an owned CSR vs the v2 mmap + validate
    // (`NetFile`, zero-copy), then the compile phase from the mapped
    // view. VmHWM is the process-lifetime peak RSS, recorded so the
    // trajectory shows the memory trend as load paths change.
    let cold_v1 = std::env::temp_dir().join(format!("hotpath_cold_v1_{}.hsn", std::process::id()));
    let cold_v2 = std::env::temp_dir().join(format!("hotpath_cold_v2_{}.hsn", std::process::id()));
    write_hsn_v1(&net, &cold_v1).unwrap();
    write_hsn(&net, &cold_v2).unwrap();
    let cold_net_bytes = std::fs::metadata(&cold_v2).unwrap().len();
    let mut sink = 0usize; // keeps the timed loads observable
    let cold_v1_load_ms = best_of_3_ms(&mut || sink += read_hsn(&cold_v1).unwrap().n_synapses());
    let cold_v2_load_ms =
        best_of_3_ms(&mut || sink += open_netfile(&cold_v2).unwrap().view().syn_targets.len());
    let mapped = open_netfile(&cold_v2).unwrap();
    let cold_compile_ms = best_of_3_ms(&mut || {
        let e = SimConfig::new(mapped.clone()).backend(Backend::Rust).build().unwrap();
        sink += e.backend_name().len();
    });
    assert!(sink > 0);
    assert!(
        cold_v2_load_ms < cold_v1_load_ms,
        "v2 mmap load ({cold_v2_load_ms:.2} ms) must beat the v1 parse ({cold_v1_load_ms:.2} ms)"
    );
    std::fs::remove_file(&cold_v1).ok();
    std::fs::remove_file(&cold_v2).ok();
    let cold_speedup = cold_v1_load_ms / cold_v2_load_ms;
    let rss_mb = peak_rss_mb();
    println!(
        "  cold start      : {cold_v1_load_ms:>10.2} ms v1 parse, \
         {cold_v2_load_ms:>10.3} ms v2 mmap ({cold_speedup:.0}x), \
         compile {cold_compile_ms:.1} ms, peak RSS {rss_mb:.0} MB"
    );

    // sharded execution (PR 8): a clustered net partitioned over 4
    // cores, run over 1/2/4 worker subprocesses exchanging binary AER
    // frames through the parent's HiAER tree router. Spike trains are
    // pinned bit-identical to the in-process cluster by the facade
    // parity suite; here we record the wall-clock scaling curve of the
    // multi-process path (worker spawn + compile excluded — cold start
    // is covered separately above).
    let (shn, shd) = (40_000usize, 8usize);
    let shard_net = make_clustered_net(shn, shd, 2_500, 0.95, 11);
    let shard_cap = CoreCapacity { max_neurons: shn.div_ceil(4), max_synapses: usize::MAX };
    let shard_steps = steps.min(100);
    let shard_rate = |shards: usize| -> f64 {
        let mut sim = SimConfig::new(shard_net.clone())
            .topology(1, 1, 4)
            .capacity(shard_cap)
            .shards(shards)
            .shard_bin(env!("CARGO_BIN_EXE_hiaer-spike"))
            .build()
            .unwrap();
        rate(&mut *sim, shard_steps, shard_net.n_axons())
    };
    let shard1_rate = shard_rate(1);
    let shard2_rate = shard_rate(2);
    let shard4_rate = shard_rate(4);
    let shard_scaleup = shard4_rate / shard1_rate;
    println!(
        "  sharded         : {shard1_rate:>10.0} steps/s 1 shard, {shard2_rate:>10.0} 2 shards, \
         {shard4_rate:>10.0} 4 shards ({shard_scaleup:.2}x, n = {shn})"
    );

    // runtime plasticity (PR 9): the headline net re-run with the
    // pair-based STDP kernel enabled — trace decay/bump, depression and
    // potentiation all ride the sweep/route hot path, so comparing
    // against a frozen-weight run of the same length is the kernel's
    // true overhead. Also measured: the live-edit path, i.e. the mean
    // in-place `write_synapse` upsert latency on the compiled engine
    // (what one session-protocol `write_synapse` op costs server-side,
    // marshalling excluded). Edits target existing synapses so every
    // call takes the hit path (slot rewrite), not the cheap miss.
    use hiaer_spike::plasticity::PlasticityConfig;
    let stdp_steps = steps.min(100);
    let mut frozen = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
    let frozen_rate = rate(&mut *frozen, stdp_steps, net.n_axons());
    let mut learner = SimConfig::new(net.clone())
        .backend(Backend::Rust)
        .learning(PlasticityConfig::default())
        .build()
        .unwrap();
    let stdp_rate = rate(&mut *learner, stdp_steps, net.n_axons());
    let stdp_overhead = frozen_rate / stdp_rate;
    let n_edits = 2_000usize;
    let mut edit_rng = Xorshift32::new(7);
    let t0 = Instant::now();
    for _ in 0..n_edits {
        // every neuron in make_net has fan-out d, so sampling a source
        // neuron and one of its targets always names a real synapse
        let p = edit_rng.below(hn as u32);
        let row = net.neuron_targets(p as usize);
        let q = row[edit_rng.below(row.len() as u32) as usize];
        let w = edit_rng.range_i32(5, 40) as i16; // nonzero: stays plastic
        assert!(learner.write_synapse(false, p, q, w).unwrap());
    }
    let edit_apply_us = t0.elapsed().as_secs_f64() * 1e6 / n_edits as f64;
    println!(
        "  stdp learning   : {frozen_rate:>10.0} steps/s frozen, {stdp_rate:>10.0} learning \
         ({stdp_overhead:.2}x cost); write_synapse {edit_apply_us:.2} us/edit in place"
    );

    // ---- append one record to the perf trajectory (one entry per PR)
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("BENCH_hotpath.json")
            .display()
            .to_string()
    });
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let pr = std::env::var("BENCH_PR").unwrap_or_else(|_| "dev".to_string());
    let mut records: Vec<Json> = std::fs::read_to_string(&out)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|doc| doc.get("records").and_then(|r| r.as_arr().map(|a| a.to_vec())))
        .unwrap_or_default();
    records.push(obj(vec![
        ("pr", Json::Str(pr)),
        ("unix_time", Json::Int(unix_time as i64)),
        (
            "config",
            obj(vec![
                ("neurons", Json::Int(hn as i64)),
                ("avg_degree", Json::Int(hd as i64)),
                ("steps", Json::Int(steps as i64)),
                ("strategy", Json::Str("BalanceFanIn".into())),
            ]),
        ),
        ("legacy_steps_per_s", Json::Num(legacy_rate)),
        ("csr_bitmask_steps_per_s", Json::Num(new_rate)),
        ("speedup", Json::Num(speedup)),
        ("events_per_s", Json::Num(events_per_s)),
        ("sweep_steps_per_s", Json::Num(sweep_rate)),
        ("sweep_chunked_steps_per_s", Json::Num(sweep_chunked_rate)),
        ("step_loop_steps_per_s", Json::Num(step_loop_rate)),
        ("stepmany_steps_per_s", Json::Num(stepmany_rate)),
        ("stepmany_speedup", Json::Num(stepmany_speedup)),
        // driven pool steps: per-core routing vs chunk-parallel gather
        ("route_core_steps_per_s", Json::Num(route_core_rate)),
        ("route_chunk_steps_per_s", Json::Num(route_chunk_rate)),
        ("route_speedup", Json::Num(route_speedup)),
        // semantics marker: since PR 3 the chunk-parallel number is an
        // idle facade step (sweep + empty route), not phase_update alone
        // — a cross-PR-3 diff of this key is not apples-to-apples
        ("sweep_chunked_measure", Json::Str("idle-pool-step".into())),
        // serving tier: aggregate steps/s over concurrent TCP sessions
        // (n = 20k net, each session its own simulator + step_many batch)
        ("serve_sessions1_steps_per_s", Json::Num(serve1_rate)),
        ("serve_sessions4_steps_per_s", Json::Num(serve4_rate)),
        ("serve_scaleup", Json::Num(serve_scaleup)),
        // binary wire (PR 10): the dense-stimulus schedule over JSON vs
        // negotiated STIM/SPIKES frames (n = 256 marshalling-heavy
        // workload, best of 3); asserted > 1.0 above
        ("serve_json_steps_per_s", Json::Num(json_wire_rate)),
        ("serve_binary_steps_per_s", Json::Num(binary_wire_rate)),
        ("serve_wire_speedup", Json::Num(serve_wire_speedup)),
        // cold start on the headline net: v1 per-synapse parse vs the
        // zero-copy v2 mmap+validate, compile from the mapped view,
        // and the process peak RSS (VmHWM, MB) at measurement time
        ("coldstart_net_bytes", Json::Int(cold_net_bytes as i64)),
        ("coldstart_v1_load_ms", Json::Num(cold_v1_load_ms)),
        ("coldstart_v2_load_ms", Json::Num(cold_v2_load_ms)),
        ("coldstart_load_speedup", Json::Num(cold_speedup)),
        ("coldstart_compile_ms", Json::Num(cold_compile_ms)),
        ("peak_rss_mb", Json::Num(rss_mb)),
        // sharded execution (PR 8): multi-process steps/s on the 40k
        // clustered net over a 4-core topology, 1/2/4 shard workers
        ("shard1_steps_per_s", Json::Num(shard1_rate)),
        ("shard2_steps_per_s", Json::Num(shard2_rate)),
        ("shard4_steps_per_s", Json::Num(shard4_rate)),
        ("shard_scaleup", Json::Num(shard_scaleup)),
        // runtime plasticity (PR 9): headline net with the STDP kernel
        // on vs frozen weights, and the mean in-place write_synapse
        // upsert latency on the compiled engine (hit path)
        ("stdp_steps_per_s", Json::Num(stdp_rate)),
        ("stdp_overhead", Json::Num(stdp_overhead)),
        ("edit_apply_us", Json::Num(edit_apply_us)),
    ]));
    let n_records = records.len();
    let doc = obj(vec![
        ("bench", Json::Str("hot_path sparse-activity trajectory".into())),
        (
            "note",
            Json::Str(
                "appended per PR by `cargo bench --bench hot_path` section [0]; \
                 CI diffs the last two records"
                    .into(),
            ),
        ),
        ("records", Json::Arr(records)),
    ]);
    match std::fs::write(&out, doc.to_string() + "\n") {
        Ok(()) => println!("  appended record {n_records} to {out}"),
        Err(err) => eprintln!("  could not write {out}: {err}"),
    }

    // ---------- 1. event-driven engine scaling
    println!("\n[1] event-driven core engine (rust backend)");
    println!("{:>8} {:>6} {:>12} {:>14} {:>12}", "neurons", "deg", "steps/s", "events/s", "rows/step");
    for &(n, d) in &[(1_000, 16), (10_000, 16), (50_000, 16), (100_000, 8)] {
        let net = make_net(n, d, 42, false);
        let mut e = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
        let steps_per_s = rate(&mut *e, steps, net.n_axons());
        let c = e.cost(&EnergyModel::default());
        println!(
            "{:>8} {:>6} {:>12.0} {:>14.0} {:>12.1}",
            n,
            d,
            steps_per_s,
            c.events as f64 * steps_per_s / steps as f64,
            c.hbm_rows as f64 / steps as f64
        );
    }

    // ---------- 2. dense software baseline (Fig 8 comparison)
    println!("\n[2] dense software simulator baseline (same nets)");
    println!("{:>8} {:>12} {:>16}", "neurons", "steps/s", "vs event-driven");
    for &(n, d) in &[(1_000, 16), (10_000, 16)] {
        let net = make_net(n, d, 42, false);
        let mut ev = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
        let ev_rate = rate(&mut *ev, steps, net.n_axons());
        let mut de = SimConfig::new(net.clone()).backend(Backend::Dense).build().unwrap();
        let de_rate = rate(&mut *de, steps.min(100), net.n_axons());
        println!("{:>8} {:>12.0} {:>15.1}x", n, de_rate, ev_rate / de_rate);
    }

    // ---------- 3. slot-strategy ablation
    println!("\n[3] HBM packing ablation (50k neurons, hub-heavy fan-in)");
    let net = make_net(50_000, 12, 7, true);
    for strat in [SlotStrategy::Modulo, SlotStrategy::BalanceFanIn] {
        let mut e = SimConfig::new(net.clone()).strategy(strat).build().unwrap();
        let steps_per_s = rate(&mut *e, steps, net.n_axons());
        println!(
            "  {:?}: density {:.3}, rows/step {:.1}, steps/s {:.0}",
            strat,
            e.hbm_stats().expect("hbm image").packing_density,
            e.cost(&EnergyModel::default()).hbm_rows as f64 / steps as f64,
            steps_per_s
        );
    }

    // ---------- 4. XLA backend vs rust backend
    if do_xla {
        println!("\n[4] AOT Pallas artifact path (PJRT CPU) vs native backend (10k neurons)");
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("neuron_update_n16384.hlo.txt").exists() {
            let net = make_net(10_000, 16, 42, false);
            let xla_steps = steps.min(100);
            match SimConfig::new(net.clone())
                .backend(Backend::Xla)
                .artifacts(&dir)
                .build()
            {
                Ok(mut e) => {
                    println!("  xla backend:  {:.0} steps/s", rate(&mut *e, xla_steps, net.n_axons()));
                }
                Err(e) => println!("  xla backend unavailable: {e:#}"),
            }
            let mut e = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
            println!("  rust backend: {:.0} steps/s", rate(&mut *e, steps, net.n_axons()));
        } else {
            println!("  (skipped: run `make artifacts` first)");
        }
    }

    // ---------- 5. multi-core scaling
    // Locality matters: the paper's fabric keeps most traffic on-chip by
    // partitioning *clustered* networks (cortical-column-like). A uniform
    // random graph has no cut smaller than ~(1 - 1/k) and inflates HBM
    // routing when split; a clustered one parallelises.
    println!("\n[5] multi-core wall-clock scaling (100k neurons, clustered: 95% local)");
    let net = make_clustered_net(100_000, 8, 6_250, 0.95, 11);
    for cores in [1usize, 2, 4, 8, 16] {
        let cap = CoreCapacity {
            max_neurons: net.n_neurons().div_ceil(cores),
            max_synapses: usize::MAX,
        };
        match SimConfig::new(net.clone()).topology(1, 1, cores).capacity(cap).build() {
            Ok(mut mc) => {
                let r = rate(&mut *mc, steps.min(100), net.n_axons());
                println!("  {cores:>2} cores: {r:>8.0} steps/s");
            }
            Err(e) => println!("  {cores:>2} cores: {e:#}"),
        }
    }
}
