//! Hot-path performance bench + ablations (EXPERIMENTS.md §Perf):
//!
//! 1. event-driven core engine steps/s across network sizes (rust
//!    backend), synaptic events/s;
//! 2. dense software-simulator baseline (the paper's Fig-8 CPU
//!    comparison): event-driven wins on sparse activity;
//! 3. HBM slot-strategy ablation (Modulo vs BalanceFanIn packing);
//! 4. XLA/PJRT backend (the AOT Pallas artifact path) vs native rust
//!    backend, when artifacts are present;
//! 5. multi-core scaling of wall-clock throughput.
//!
//! env: HOTPATH_STEPS (default 300), HOTPATH_XLA=0 to skip PJRT.

use std::time::Instant;

use hiaer_spike::cluster::MultiCoreEngine;
use hiaer_spike::engine::{CoreEngine, DenseEngine, RustBackend};
use hiaer_spike::hbm::SlotStrategy;
use hiaer_spike::partition::{ClusterTopology, CoreCapacity};
use hiaer_spike::runtime::{Runtime, XlaBackend};
use hiaer_spike::snn::{Network, NeuronModel, Synapse};
use hiaer_spike::util::prng::Xorshift32;

/// Random net: n neurons, avg degree d, theta tuned for sustained sparse
/// activity from periodic axon drive.
fn make_net(n: usize, d: usize, seed: u32) -> Network {
    let mut rng = Xorshift32::new(seed);
    let m = NeuronModel::if_neuron(60);
    let mut net = Network {
        params: vec![m; n],
        neuron_adj: vec![Vec::new(); n],
        axon_adj: vec![Vec::new(); 64.min(n)],
        outputs: (0..(n as u32).min(8)).collect(),
        base_seed: seed,
    };
    for i in 0..n {
        for _ in 0..d {
            net.neuron_adj[i].push(Synapse {
                target: rng.below(n as u32),
                weight: rng.range_i32(5, 40) as i16,
            });
        }
    }
    for a in 0..net.axon_adj.len() {
        for _ in 0..8 {
            net.axon_adj[a].push(Synapse {
                target: rng.below(n as u32),
                weight: 80,
            });
        }
    }
    net
}

/// Clustered net: `p_local` of synapses stay within the neuron's block.
fn make_clustered_net(n: usize, d: usize, block: usize, p_local: f64, seed: u32) -> Network {
    let mut rng = Xorshift32::new(seed);
    let m = NeuronModel::if_neuron(60);
    let mut net = Network {
        params: vec![m; n],
        neuron_adj: vec![Vec::new(); n],
        axon_adj: vec![Vec::new(); 64.min(n)],
        outputs: (0..(n as u32).min(8)).collect(),
        base_seed: seed,
    };
    for i in 0..n {
        let b0 = (i / block) * block;
        for _ in 0..d {
            let target = if rng.chance(p_local) {
                (b0 + rng.below(block as u32) as usize).min(n - 1) as u32
            } else {
                rng.below(n as u32)
            };
            net.neuron_adj[i].push(Synapse { target, weight: rng.range_i32(5, 40) as i16 });
        }
    }
    for a in 0..net.axon_adj.len() {
        for _ in 0..8 {
            net.axon_adj[a].push(Synapse { target: rng.below(n as u32), weight: 80 });
        }
    }
    net
}

fn drive(step: usize, n_axons: usize) -> Vec<u32> {
    // burst every 3 steps
    if step % 3 == 0 {
        (0..n_axons as u32).step_by(2).collect()
    } else {
        Vec::new()
    }
}

fn main() {
    let steps: usize = std::env::var("HOTPATH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let do_xla = std::env::var("HOTPATH_XLA").map(|v| v != "0").unwrap_or(true);

    println!("== hot-path bench (steps = {steps}) ==\n");

    // ---------- 1. event-driven engine scaling
    println!("[1] event-driven core engine (rust backend)");
    println!("{:>8} {:>6} {:>12} {:>14} {:>12}", "neurons", "deg", "steps/s", "events/s", "rows/step");
    for &(n, d) in &[(1_000, 16), (10_000, 16), (50_000, 16), (100_000, 8)] {
        let net = make_net(n, d, 42);
        let mut e = CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap();
        let t0 = Instant::now();
        for s in 0..steps {
            e.step(&drive(s, net.n_axons())).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let c = e.counters();
        println!(
            "{:>8} {:>6} {:>12.0} {:>14.0} {:>12.1}",
            n,
            d,
            steps as f64 / dt,
            c.events as f64 / dt,
            c.hbm_rows() as f64 / steps as f64
        );
    }

    // ---------- 2. dense software baseline (Fig 8 comparison)
    println!("\n[2] dense software simulator baseline (same nets)");
    println!("{:>8} {:>12} {:>16}", "neurons", "steps/s", "vs event-driven");
    for &(n, d) in &[(1_000, 16), (10_000, 16)] {
        let net = make_net(n, d, 42);
        let mut ev = CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap();
        let t0 = Instant::now();
        for s in 0..steps {
            ev.step(&drive(s, net.n_axons())).unwrap();
        }
        let ev_rate = steps as f64 / t0.elapsed().as_secs_f64();
        let mut de = DenseEngine::new(&net);
        let t0 = Instant::now();
        let dense_steps = steps.min(100);
        for s in 0..dense_steps {
            de.step(&drive(s, net.n_axons()));
        }
        let de_rate = dense_steps as f64 / t0.elapsed().as_secs_f64();
        println!("{:>8} {:>12.0} {:>15.1}x", n, de_rate, ev_rate / de_rate);
    }

    // ---------- 3. slot-strategy ablation
    println!("\n[3] HBM packing ablation (50k neurons, hub-heavy fan-in)");
    let mut net = make_net(50_000, 12, 7);
    // add hub targets to stress slot skew
    let mut rng = Xorshift32::new(9);
    for i in 0..net.n_neurons() {
        if rng.chance(0.3) {
            let hub = rng.below(16); // first 16 neurons are hubs
            net.neuron_adj[i].push(Synapse { target: hub, weight: 10 });
        }
    }
    for strat in [SlotStrategy::Modulo, SlotStrategy::BalanceFanIn] {
        let mut e = CoreEngine::new(&net, strat, RustBackend).unwrap();
        let t0 = Instant::now();
        for s in 0..steps {
            e.step(&drive(s, net.n_axons())).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {:?}: density {:.3}, rows/step {:.1}, steps/s {:.0}",
            strat,
            e.hbm.image.stats.packing_density,
            e.counters().hbm_rows() as f64 / steps as f64,
            steps as f64 / dt
        );
    }

    // ---------- 4. XLA backend vs rust backend
    if do_xla {
        println!("\n[4] AOT Pallas artifact path (PJRT CPU) vs native backend (10k neurons)");
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("neuron_update_n16384.hlo.txt").exists() {
            let net = make_net(10_000, 16, 42);
            let xla_steps = steps.min(100);
            match Runtime::cpu(&dir).map(std::sync::Arc::new).and_then(|rt| {
                let backend = XlaBackend::new(rt, net.n_neurons())?;
                CoreEngine::new(&net, SlotStrategy::BalanceFanIn, backend)
            }) {
                Ok(mut e) => {
                    let t0 = Instant::now();
                    for s in 0..xla_steps {
                        e.step(&drive(s, net.n_axons())).unwrap();
                    }
                    let dt = t0.elapsed().as_secs_f64();
                    println!("  xla backend:  {:.0} steps/s", xla_steps as f64 / dt);
                }
                Err(e) => println!("  xla backend unavailable: {e:#}"),
            }
            let mut e = CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap();
            let t0 = Instant::now();
            for s in 0..steps {
                e.step(&drive(s, net.n_axons())).unwrap();
            }
            println!(
                "  rust backend: {:.0} steps/s",
                steps as f64 / t0.elapsed().as_secs_f64()
            );
        } else {
            println!("  (skipped: run `make artifacts` first)");
        }
    }

    // ---------- 5. multi-core scaling
    // Locality matters: the paper's fabric keeps most traffic on-chip by
    // partitioning *clustered* networks (cortical-column-like). A uniform
    // random graph has no cut smaller than ~(1 - 1/k) and inflates HBM
    // routing when split; a clustered one parallelises.
    println!("\n[5] multi-core wall-clock scaling (100k neurons, clustered: 95% local)");
    let net = make_clustered_net(100_000, 8, 6_250, 0.95, 11);
    for cores in [1usize, 2, 4, 8, 16] {
        let topo = ClusterTopology { servers: 1, fpgas_per_server: 1, cores_per_fpga: cores };
        let cap = CoreCapacity {
            max_neurons: net.n_neurons().div_ceil(cores),
            max_synapses: usize::MAX,
        };
        match MultiCoreEngine::new(&net, topo, cap, SlotStrategy::BalanceFanIn) {
            Ok(mut mc) => {
                let t0 = Instant::now();
                for s in 0..steps.min(100) {
                    mc.step(&drive(s, net.n_axons())).unwrap();
                }
                let dt = t0.elapsed().as_secs_f64();
                println!("  {cores:>2} cores: {:>8.0} steps/s", steps.min(100) as f64 / dt);
            }
            Err(e) => println!("  {cores:>2} cores: {e:#}"),
        }
    }
}
