//! Regenerates paper Fig 10 + the §6 scaling analysis: HBM energy and
//! latency per inference vs neuron count, with OLS linear fits per model
//! family (MLP, LeNet-5, DVS-Gesture spiking CNN).
//!
//! The paper reports, for the DVS family (n = 5):
//!   Energy(uJ)  = 0.0294 x - 30.293   (R^2 = 0.994)
//!   Latency(us) = 0.0658 x - 53.031   (R^2 = 0.995)
//! and per-neuron cost ratios MLP ~2.4x / DVS ~10.5x the LeNet slope.
//! The shape to reproduce: strong linear fits (R^2 > 0.9) and the same
//! family ordering of per-neuron cost.

use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::sim::SimOptions;
use hiaer_spike::util::stats::linear_fit;

fn main() {
    let dir = models_dir();
    let opts = SimOptions::default();
    let entries = match harness::load_manifest(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("fig10: {e:#}\nrun `make models` first");
            return;
        }
    };
    let families: &[(&str, Box<dyn Fn(&str) -> bool>)] = &[
        ("MLP", Box::new(|n: &str| n.starts_with("mlp_"))),
        ("LeNet-5", Box::new(|n: &str| n.starts_with("lenet5_"))),
        ("DVS CNN", Box::new(|n: &str| n.starts_with("dvs_"))),
    ];

    println!("== Fig 10: HBM energy/latency per inference vs neuron count ==\n");
    let mut slopes: Vec<(String, f64, f64)> = Vec::new();
    for (fam, pred) in families {
        let mut pts_e = Vec::new();
        let mut pts_l = Vec::new();
        println!("family {fam}:");
        println!(
            "  {:<12} {:>9} {:>13} {:>13}",
            "model", "neurons", "energy uJ", "latency us"
        );
        let mut members: Vec<_> = entries.iter().filter(|e| pred(&e.name)).collect();
        members.sort_by_key(|e| e.params);
        for e in members {
            match harness::evaluate_model(&dir, e, 100, &opts) {
                Ok(r) => {
                    println!(
                        "  {:<12} {:>9} {:>13.2} {:>13.2}",
                        e.name, r.neurons, r.energy_mean, r.latency_mean
                    );
                    pts_e.push((r.neurons as f64, r.energy_mean));
                    pts_l.push((r.neurons as f64, r.latency_mean));
                }
                Err(err) => println!("  {:<12} ERROR {err:#}", e.name),
            }
        }
        if let (Some(fe), Some(fl)) = (linear_fit(&pts_e), linear_fit(&pts_l)) {
            println!(
                "  fit: Energy(uJ)  = {:.5} x + {:.3}   (R^2 = {:.4}, n = {})",
                fe.slope, fe.intercept, fe.r2, fe.n
            );
            println!(
                "  fit: Latency(us) = {:.5} x + {:.3}   (R^2 = {:.4}, n = {})",
                fl.slope, fl.intercept, fl.r2, fl.n
            );
            slopes.push((fam.to_string(), fe.slope, fl.slope));
        } else {
            println!("  (family too small for a fit)");
        }
        println!();
    }
    if let (Some(mlp), Some(lenet), Some(dvs)) = (
        slopes.iter().find(|s| s.0 == "MLP"),
        slopes.iter().find(|s| s.0 == "LeNet-5"),
        slopes.iter().find(|s| s.0 == "DVS CNN"),
    ) {
        println!("per-neuron HBM energy cost relative to LeNet-5 (paper: MLP ~2.4x, DVS ~10.5x):");
        println!("  MLP / LeNet   = {:.2}x (energy)  {:.2}x (latency)",
            mlp.1 / lenet.1, mlp.2 / lenet.2);
        println!("  DVS / LeNet   = {:.2}x (energy)  {:.2}x (latency)",
            dvs.1 / lenet.1, dvs.2 / lenet.2);
    }
    println!("paper DVS fits: E = 0.0294x - 30.3 (R2 .994); L = 0.0658x - 53.0 (R2 .995)");
}
