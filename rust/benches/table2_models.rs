//! Regenerates paper Table 2: accuracy / HBM energy / latency for every
//! trained model family (MNIST MLPs + LeNets, DVS-Gesture spiking CNNs,
//! CIFAR-10 CNN, Pong policy net).
//!
//! criterion is unavailable offline; this is a harness=false bench that
//! prints the table rows (the paper's artifact) plus wall-clock
//! throughput. Run via `cargo bench --bench table2_models`.
//!
//! Substrate caveat (DESIGN.md): datasets are synthetic and the FPGA is
//! simulated — the columns to compare with the paper are *shapes*:
//! SW Acc% == HiAER% (conversion parity), energy/latency ordering and
//! linearity, MLP > LeNet per-neuron cost, DVS >> MNIST cost.

use std::time::Instant;

use hiaer_spike::harness::{self, models_dir};
use hiaer_spike::sim::SimOptions;

fn main() {
    let dir = models_dir();
    let opts = SimOptions::default();
    let entries = match harness::load_manifest(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("table2_models: {e:#}");
            eprintln!("run `make models` first to train + export the model zoo");
            return;
        }
    };
    let samples: usize = std::env::var("TABLE2_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(usize::MAX); // full test set: parity is only meaningful on identical samples

    println!("== Table 2: accuracy, latency and energy of HiAER-Spike ==\n");
    harness::print_header();
    let t0 = Instant::now();
    let mut total_inferences = 0usize;
    let mut parity_ok = true;
    for e in &entries {
        if e.task == "pong" {
            continue; // Table-2 Pong row = mean score; see `cargo run --example dvs_pong`
        }
        match harness::evaluate_model(&dir, e, samples, &opts) {
            Ok(r) => {
                harness::print_row(e, &r);
                total_inferences += r.n_samples;
                parity_ok &= (r.accuracy - e.acc_quant).abs() < 1e-9;
            }
            Err(err) => println!("{:<12} ERROR: {err:#}", e.name),
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("\npong row: `cargo run --release --example dvs_pong` (score metric)");
    println!(
        "software==hardware accuracy parity: {}",
        if parity_ok { "HOLDS (paper's conversion-fidelity result)" } else { "VIOLATED" }
    );
    println!(
        "bench wall-clock: {total_inferences} inferences in {dt:.2}s = {:.1} inf/s",
        total_inferences as f64 / dt
    );
}
