//! Fault injection for `Backend::Sharded` (`cluster::shard`): a shard
//! worker that is killed or hung mid-session must fail the step with a
//! **typed** `SimError::Engine` naming the shard — never a hang or a
//! panic — and dropping the parent session must reap every worker
//! subprocess (no zombies, no orphans). Companion to the serving-tier
//! fault suite in `serve_tcp.rs`: same philosophy, one layer down.
//!
//! The tests drive `ShardedSim::build` directly (the `#[doc(hidden)]`
//! seam) so they can reach `shard_pids()`; the worker binary is the
//! crate's own `hiaer-spike` via `CARGO_BIN_EXE`.

use std::time::{Duration, Instant};

use hiaer_spike::cluster::shard::ShardedSim;
use hiaer_spike::partition::CoreCapacity;
use hiaer_spike::sim::{Backend, SimError, SimOptions, Simulator};
use hiaer_spike::snn::{Network, NeuronModel, Synapse, FLAG_NOISE};
use hiaer_spike::util::prng::Xorshift32;

/// Deterministic multi-core net: enough neurons to spread over 2 cores
/// under the capacity below, noise stripped so steps are reproducible.
fn test_net() -> Network {
    let mut rng = Xorshift32::new(0xFA);
    let n = 60usize;
    let params: Vec<NeuronModel> = (0..n).map(|_| NeuronModel::if_neuron(5)).collect();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..4 {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-3, 8) as i16 });
        }
    }
    let axon_adj: Vec<Vec<Synapse>> = (0..4)
        .map(|_| (0..6).map(|_| Synapse { target: rng.below(n as u32), weight: 6 }).collect())
        .collect();
    let mut net = Network::from_adj(params, &neuron_adj, &axon_adj, vec![0, 1, 2], 9);
    for p in &mut net.params {
        p.flags &= !FLAG_NOISE;
    }
    net
}

fn sharded_opts(shards: usize, timeout_ms: u64) -> SimOptions {
    let mut opts = SimOptions::default();
    opts.topology =
        hiaer_spike::partition::ClusterTopology { servers: 1, fpgas_per_server: 1, cores_per_fpga: 2 };
    opts.capacity = CoreCapacity { max_neurons: 40, max_synapses: usize::MAX };
    opts.backend = Backend::Sharded;
    opts.shards = Some(shards);
    opts.shard_bin = Some(env!("CARGO_BIN_EXE_hiaer-spike").into());
    opts.shard_timeout_ms = Some(timeout_ms);
    opts
}

fn build_sharded(shards: usize, timeout_ms: u64) -> ShardedSim {
    ShardedSim::build(test_net().into(), &sharded_opts(shards, timeout_ms))
        .expect("sharded build")
}

fn send_signal(pid: u32, sig: &str) {
    let status = std::process::Command::new("kill")
        .arg(sig)
        .arg(pid.to_string())
        .status()
        .expect("running kill");
    assert!(status.success(), "kill {sig} {pid} failed");
}

/// `/proc/<pid>` vanishes only once the process is dead *and* reaped
/// (zombies keep their entry), so this is exactly "no zombie, no orphan".
fn proc_gone(pid: u32) -> bool {
    !std::path::Path::new(&format!("/proc/{pid}")).exists()
}

fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    cond()
}

/// A SIGKILLed worker turns the next step into a typed engine error
/// naming the dead shard — the parent never hangs on the vanished pipe.
#[test]
fn killed_shard_is_a_typed_engine_error_naming_the_shard() {
    let mut sim = build_sharded(2, 10_000);
    assert_eq!(sim.n_shards(), 2);
    sim.step(&[0, 1]).expect("healthy step before the kill");

    let pids = sim.shard_pids();
    assert_eq!(pids.len(), 2);
    send_signal(pids[1], "-KILL");

    // the kill races the in-flight pipes: poll until the failure
    // surfaces (it must, well within the 10 s frame deadline)
    let deadline = Instant::now() + Duration::from_secs(20);
    let err = loop {
        match sim.step(&[0]) {
            Err(e) => break e,
            Ok(_) => assert!(Instant::now() < deadline, "killed shard never surfaced an error"),
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    match &err {
        SimError::Engine(e) => {
            let msg = format!("{e:#}");
            assert!(msg.contains("shard 1"), "error must name the dead shard: {msg}");
        }
        other => panic!("expected SimError::Engine, got {other}"),
    }
}

/// A stopped (hung) worker trips the per-frame deadline with a typed
/// error naming the shard, instead of wedging the parent forever.
#[test]
fn hung_shard_times_out_with_typed_engine_error() {
    let mut sim = build_sharded(2, 300);
    sim.step(&[0]).expect("healthy step before the stall");

    let pids = sim.shard_pids();
    send_signal(pids[0], "-STOP");

    let t0 = Instant::now();
    let err = sim.step(&[1]).expect_err("stalled shard must time the step out");
    assert!(
        t0.elapsed() < Duration::from_secs(15),
        "timeout took {:?} — deadline not honoured",
        t0.elapsed()
    );
    match &err {
        SimError::Engine(e) => {
            let msg = format!("{e:#}");
            assert!(msg.contains("shard 0"), "error must name the hung shard: {msg}");
            assert!(msg.contains("within"), "error should mention the deadline: {msg}");
        }
        other => panic!("expected SimError::Engine, got {other}"),
    }

    // SIGKILL terminates even a stopped process — un-wedge the worker
    // so Drop's orderly shutdown stays fast
    send_signal(pids[0], "-KILL");
    drop(sim);
    assert!(
        wait_until(Duration::from_secs(10), || pids.iter().all(|&p| proc_gone(p))),
        "workers not reaped after drop"
    );
}

/// Dropping the session reaps every worker: orderly SHUTDOWN first,
/// escalating to SIGKILL, and always wait()ed — `/proc` entries vanish.
#[test]
fn drop_reaps_all_worker_processes() {
    let pids = {
        let mut sim = build_sharded(2, 5_000);
        sim.step(&[0, 2]).expect("healthy step");
        let pids = sim.shard_pids();
        for &p in &pids {
            assert!(!proc_gone(p), "worker {p} should be alive while the session runs");
        }
        pids
    }; // <- Drop: SHUTDOWN frames, reap, join readers
    assert!(
        wait_until(Duration::from_secs(10), || pids.iter().all(|&p| proc_gone(p))),
        "worker pids {pids:?} still present after drop"
    );
}

/// One sharded session dying must not disturb an independent healthy
/// one (process isolation is the point of the backend).
#[test]
fn shard_failure_is_isolated_to_its_own_session() {
    let mut healthy = build_sharded(2, 10_000);
    let mut victim = build_sharded(2, 10_000);
    healthy.step(&[0]).expect("healthy session step");

    send_signal(victim.shard_pids()[0], "-KILL");
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        match victim.step(&[0]) {
            Err(_) => break,
            Ok(_) => assert!(Instant::now() < deadline, "killed shard never errored"),
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // the healthy session keeps stepping bit-deterministically
    for _ in 0..3 {
        healthy.step(&[0, 1]).expect("healthy session survives the neighbour's death");
    }
}
