//! Cross-language golden tests: the vectors emitted by
//! `python/compile/aot.py` (jnp reference semantics) must match the Rust
//! engines bit-for-bit. This closes the python <-> rust loop without
//! python on the request path.
//!
//! Skipped (with a notice) when `make artifacts` hasn't run.

use std::path::{Path, PathBuf};

use hiaer_spike::engine::backend::{mask_bit, mask_words, CoreParams, RustBackend, UpdateBackend};
use hiaer_spike::model_fmt::golden;
use hiaer_spike::sim::{Backend, SimConfig, Simulator};
use hiaer_spike::snn::{Network, NeuronModel, Synapse};
use hiaer_spike::util::prng;

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("golden")
}

fn skip() -> bool {
    if !golden_dir().join("prng.json").exists() {
        eprintln!("golden vectors missing — run `make artifacts`; skipping");
        return true;
    }
    false
}

#[test]
fn prng_matches_python() {
    if skip() {
        return;
    }
    let g = golden::load_prng(&golden_dir().join("prng.json")).unwrap();
    assert!(!g.mix_seed.is_empty() && !g.noise17.is_empty());
    for (base, step, want) in g.mix_seed {
        assert_eq!(prng::mix_seed(base, step), want, "mix_seed({base}, {step})");
    }
    for (seed, idx, want) in g.noise17 {
        assert_eq!(prng::noise17(seed, idx), want, "noise17({seed}, {idx})");
    }
}

#[test]
fn neuron_update_matches_python() {
    if skip() {
        return;
    }
    let g = golden::load_neuron_update(&golden_dir().join("neuron_update.json")).unwrap();
    let n = g.v.len();
    let params = CoreParams {
        theta: g.theta.clone(),
        nu: g.nu.clone(),
        lam: g.lam.clone(),
        flags: g.flags.iter().map(|&f| f as u32).collect(),
    };
    let mut v = g.v.clone();
    let mut words = vec![0u64; mask_words(n)];
    RustBackend.update(&mut v, &params, g.step_seed, &mut words).unwrap();
    assert_eq!(v, g.v_out, "membrane mismatch vs jnp reference");
    // unpack the bitmask to the reference's 0/1 vector
    let spikes: Vec<i32> = (0..n).map(|i| mask_bit(&words, i) as i32).collect();
    assert_eq!(spikes, g.spikes, "spike mismatch vs jnp reference");
}

#[test]
fn synapse_accum_matches_python() {
    if skip() {
        return;
    }
    let g = golden::load_synapse_accum(&golden_dir().join("synapse_accum.json")).unwrap();
    let mut v = g.v.clone();
    // python pads with target == n (dropped); emulate the drop here
    let mut events: Vec<(u32, i32)> = Vec::new();
    for (&t, &w) in g.targets.iter().zip(&g.weights) {
        if (t as usize) < g.n {
            events.push((t as u32, w));
        }
    }
    RustBackend.accumulate(&mut v, &events).unwrap();
    assert_eq!(v, g.v_out);
}

#[test]
fn dense_net_trace_matches_python() {
    if skip() {
        return;
    }
    let g = golden::load_dense_net(&golden_dir().join("dense_net.json")).unwrap();
    // rebuild the network from the dense matrices
    let params: Vec<NeuronModel> = (0..g.n)
        .map(|i| NeuronModel {
            theta: g.theta[i],
            nu: g.nu[i],
            lam: g.lam[i],
            flags: g.flags[i] as u32,
        })
        .collect();
    let sparsify = |rows: &[Vec<i32>]| -> Vec<Vec<Synapse>> {
        rows.iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .filter(|(_, &w)| w != 0)
                    .map(|(j, &w)| Synapse { target: j as u32, weight: w as i16 })
                    .collect()
            })
            .collect()
    };
    let net = Network::from_adj(
        params,
        &sparsify(&g.w_neuron),
        &sparsify(&g.w_axon),
        vec![],
        g.base_seed,
    );
    let mut e = SimConfig::new(net).backend(Backend::Dense).build().unwrap();
    let all_ids: Vec<u32> = (0..g.n as u32).collect();
    for t in 0..g.steps {
        let axons: Vec<u32> = g.axon_seq[t]
            .iter()
            .enumerate()
            .filter(|(_, &x)| x != 0)
            .map(|(i, _)| i as u32)
            .collect();
        let fired = e.step(&axons).unwrap().fired.to_vec();
        // unpack fired ids to the reference's per-neuron 0/1 vector
        let mut spikes = vec![0i32; g.n];
        for &f in &fired {
            spikes[f as usize] = 1;
        }
        assert_eq!(spikes, g.spikes[t], "spike trace diverged at step {t}");
        assert_eq!(e.read_membrane(&all_ids), g.v[t], "membrane trace diverged at step {t}");
    }
}
