//! The backend-parity matrix — the facade's central contract: every
//! available `Backend` variant, driven through the same `Simulator`
//! trait object on a shared random network, must produce **bit-identical
//! spike trains and membranes** and **monotone cost counters**. This
//! replaces the per-pair parity harnesses that used to live in
//! `tests/parity.rs` (dense-vs-core, core-vs-xla, ...): any new backend
//! joins the matrix by appearing in `Backend::ALL`.
//!
//! The XLA variant participates automatically when a `pjrt` build has
//! artifacts on disk; otherwise the matrix asserts the clean
//! `BackendUnavailable` error instead.

use std::path::Path;

use hiaer_spike::energy::EnergyModel;
use hiaer_spike::sim::{
    Backend, RouteGranularity, RunRecord, SimConfig, SimError, SimOptions, Simulator,
};
use hiaer_spike::snn::{Network, NeuronModel, Synapse, FLAG_NOISE};
use hiaer_spike::util::cli::Args;
use hiaer_spike::util::prng::Xorshift32;

/// Random network with all three neuron models, stochastic lanes
/// included — single-core backends share the global index space and
/// base seed, so even noise must agree bit-for-bit.
fn random_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
    let models = [
        NeuronModel::if_neuron(rng.range_i32(5, 60)),
        NeuronModel::lif(rng.range_i32(5, 60), -5, 4, true).unwrap(),
        NeuronModel::ann(rng.range_i32(2, 40), -8, true).unwrap(),
    ];
    let params: Vec<NeuronModel> = (0..n).map(|_| models[rng.below(3) as usize]).collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|_| rng.chance(0.2)).collect();
    let base_seed = rng.next_u32();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..rng.below(10) as usize {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-60, 60) as i16 });
        }
    }
    let mut axon_adj: Vec<Vec<Synapse>> = vec![Vec::new(); a];
    for adj in axon_adj.iter_mut() {
        for _ in 0..1 + rng.below(6) as usize {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-60, 80) as i16 });
        }
    }
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, base_seed)
}

/// All backend sessions this build can instantiate for a single-core
/// run on `net`, labelled. Pool appears twice: default chunking and
/// forced one-word chunks (maximal parallel split).
fn single_core_sessions(net: &Network) -> Vec<(String, Box<dyn Simulator>)> {
    let mut sims: Vec<(String, Box<dyn Simulator>)> = Vec::new();
    for b in Backend::ALL {
        if b == Backend::Sharded {
            // subprocess-backed: spawning workers per matrix case is
            // disproportionate here; the dedicated parity test below
            // pins sharded against the cluster reference instead
            continue;
        }
        let cfg = SimConfig::new(net.clone()).backend(b).artifacts(
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        );
        match cfg.build() {
            Ok(sim) => sims.push((b.name().to_string(), sim)),
            Err(SimError::BackendUnavailable { .. }) if b == Backend::Xla => {
                assert!(!b.available(), "available backend failed to build");
            }
            Err(e) if b == Backend::Xla => {
                // pjrt build without artifacts on disk: engine-level
                // error is acceptable, the variant just sits out
                eprintln!("xla variant sits out: {e}");
            }
            Err(e) => panic!("backend {} failed to build: {e}", b.name()),
        }
    }
    sims.push((
        "pool-maxchunk".to_string(),
        SimConfig::new(net.clone())
            .backend(Backend::Pool)
            .chunk_words(1)
            .build()
            .unwrap(),
    ));
    sims
}

#[test]
fn backend_matrix_bit_identical_and_monotone_cost() {
    let mut rng = Xorshift32::new(0xFACADE);
    for case in 0..4 {
        let n = 40 + rng.below(300) as usize;
        let a = 3 + rng.below(10) as usize;
        let net = random_net(&mut rng, n, a);
        let mut sims = single_core_sessions(&net);
        assert!(sims.len() >= 4, "dense, rust, pool, pool-maxchunk at minimum");
        let energy = EnergyModel::default();
        let all_ids: Vec<u32> = (0..n as u32).collect();
        let mut prev_cost = vec![(0u64, 0.0f64); sims.len()];
        for t in 0..12 {
            let axons: Vec<u32> = (0..a as u32).filter(|_| rng.chance(0.4)).collect();
            // reference: first session (dense)
            let (fired_ref, out_ref) = {
                let (_, sim) = &mut sims[0];
                let r = sim.step(&axons).unwrap();
                (r.fired.to_vec(), r.output_spikes.to_vec())
            };
            let v_ref = sims[0].1.read_membrane(&all_ids);
            for (i, (name, sim)) in sims.iter_mut().enumerate() {
                if i > 0 {
                    let r = sim.step(&axons).unwrap();
                    assert_eq!(r.fired, &fired_ref[..], "case {case} t {t}: {name} fired");
                    assert_eq!(
                        r.output_spikes,
                        &out_ref[..],
                        "case {case} t {t}: {name} outputs"
                    );
                    assert_eq!(
                        sim.read_membrane(&all_ids),
                        v_ref,
                        "case {case} t {t}: {name} membranes"
                    );
                }
                // cost counters must accumulate monotonically
                let c = sim.cost(&energy);
                let (rows0, e0) = prev_cost[i];
                assert!(
                    c.hbm_rows >= rows0 && c.energy_uj >= e0,
                    "case {case} t {t}: {name} cost went backwards"
                );
                prev_cost[i] = (c.hbm_rows, c.energy_uj);
            }
        }
    }
}

/// The cluster variant of the matrix: a deterministic network (per-core
/// noise seeds legitimately differ) partitioned over a 2x2x2 topology
/// must match the dense reference through the same trait surface.
#[test]
fn cluster_backend_matches_dense_reference() {
    let mut rng = Xorshift32::new(0xC1);
    let n = 90;
    let mut net = random_net(&mut rng, n, 6);
    for p in &mut net.params {
        p.flags &= !FLAG_NOISE;
    }
    let mut dense = SimConfig::new(net.clone()).backend(Backend::Dense).build().unwrap();
    let cap = hiaer_spike::partition::CoreCapacity {
        max_neurons: (n / 3).max(4),
        max_synapses: usize::MAX,
    };
    let mut cluster = SimConfig::new(net.clone())
        .topology(2, 2, 2)
        .capacity(cap)
        .build()
        .unwrap();
    assert_eq!(cluster.backend_name(), "cluster");
    assert!(cluster.n_cores() > 1);
    assert!(cluster.placement().is_some());
    let all_ids: Vec<u32> = (0..n as u32).collect();
    for t in 0..12 {
        let axons: Vec<u32> = (0..net.n_axons() as u32).filter(|_| rng.chance(0.4)).collect();
        let want = {
            let r = dense.step(&axons).unwrap();
            (r.fired.to_vec(), r.output_spikes.to_vec())
        };
        let r = cluster.step(&axons).unwrap();
        assert_eq!(r.fired, &want.0[..], "t {t}: cluster fired");
        assert_eq!(r.output_spikes, &want.1[..], "t {t}: cluster outputs");
        drop(r);
        assert_eq!(cluster.read_membrane(&all_ids), dense.read_membrane(&all_ids), "t {t}");
    }
}

/// `step_many(batch)` must be bit-identical to the equivalent `step`
/// loop on every backend (the batched-stimulus contract the session
/// protocol and `run` are built on), and a stimulus error anywhere in
/// the batch must be atomic: detected up-front, nothing executed.
#[test]
fn step_many_matches_step_loop_on_every_backend() {
    let mut rng = Xorshift32::new(0xBA7C4);
    let net = random_net(&mut rng, 110, 6);
    let batch: Vec<Vec<u32>> = (0..10)
        .map(|_| (0..net.n_axons() as u32).filter(|_| rng.chance(0.4)).collect())
        .collect();
    let all_ids: Vec<u32> = (0..net.n_neurons() as u32).collect();
    for (name, mut batched) in single_core_sessions(&net) {
        // the per-step reference session of the same backend
        let (_, mut looped) = single_core_sessions(&net)
            .into_iter()
            .find(|(n, _)| *n == name)
            .unwrap();
        let r = batched.step_many(&batch).unwrap();
        assert_eq!(r.spikes.len(), batch.len(), "{name}: one spike row per step");
        let mut fired_total = 0u64;
        for (t, axons) in batch.iter().enumerate() {
            let want = looped.step(axons).unwrap();
            fired_total += want.fired.len() as u64;
            assert_eq!(r.spikes[t], want.output_spikes, "{name} t {t}: spikes");
        }
        assert_eq!(r.fired_total, fired_total, "{name}: fired_total");
        assert_eq!(
            batched.read_membrane(&all_ids),
            looped.read_membrane(&all_ids),
            "{name}: membranes after batch"
        );

        // atomic validation: a bad row mid-batch executes nothing
        let v_before = batched.read_membrane(&all_ids);
        let fired_before = batched.fired().to_vec();
        let bad = vec![vec![0], vec![net.n_axons() as u32 + 5], vec![1]];
        let err = batched.step_many(&bad).unwrap_err();
        assert!(matches!(err, SimError::Stimulus(_)), "{name}: {err}");
        assert_eq!(batched.read_membrane(&all_ids), v_before, "{name}: membranes touched");
        assert_eq!(batched.fired(), &fired_before[..], "{name}: fired view touched");
    }

    // the cluster backend honours the same contract (deterministic net:
    // per-core noise seeds legitimately differ)
    let mut det = random_net(&mut rng, 80, 5);
    for p in &mut det.params {
        p.flags &= !FLAG_NOISE;
    }
    let cap = hiaer_spike::partition::CoreCapacity { max_neurons: 30, max_synapses: usize::MAX };
    let mut batched =
        SimConfig::new(det.clone()).topology(1, 1, 3).capacity(cap).build().unwrap();
    let mut looped = SimConfig::new(det.clone()).topology(1, 1, 3).capacity(cap).build().unwrap();
    let batch: Vec<Vec<u32>> = (0..8)
        .map(|_| (0..det.n_axons() as u32).filter(|_| rng.chance(0.5)).collect())
        .collect();
    let r = batched.step_many(&batch).unwrap();
    for (t, axons) in batch.iter().enumerate() {
        let want = looped.step(axons).unwrap();
        assert_eq!(r.spikes[t], want.output_spikes, "cluster t {t}");
    }
}

/// `run_many` reuses one warm engine; results must equal running each
/// sample on a freshly built session.
#[test]
fn run_many_reuses_engine_and_matches_fresh_builds() {
    let mut rng = Xorshift32::new(0xBA7C);
    let net = random_net(&mut rng, 120, 5);
    let energy = EnergyModel::default();
    let samples: Vec<Vec<Vec<u32>>> = (0..3)
        .map(|_| {
            (0..8)
                .map(|_| (0..5u32).filter(|_| rng.chance(0.5)).collect())
                .collect()
        })
        .collect();
    for backend in [Backend::Rust, Backend::Pool, Backend::Dense] {
        let mut warm = SimConfig::new(net.clone()).backend(backend).build().unwrap();
        let records = warm.run_many(&samples, &energy).unwrap();
        assert_eq!(records.len(), samples.len());
        for (rec, sample) in records.iter().zip(&samples) {
            let mut fresh = SimConfig::new(net.clone()).backend(backend).build().unwrap();
            let want = fresh.run(sample, &energy).unwrap();
            assert_eq!(rec.spikes, want.spikes, "{backend:?} warm vs fresh spikes");
            assert_eq!(rec.fired_total, want.fired_total, "{backend:?} fired_total");
            assert_eq!(rec.cost.hbm_rows, want.cost.hbm_rows, "{backend:?} per-run cost");
        }
    }
}

fn assert_records_identical(tag: &str, a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.steps, b.steps, "{tag}: steps");
    assert_eq!(a.spikes, b.spikes, "{tag}: per-step spikes");
    assert_eq!(a.fired_total, b.fired_total, "{tag}: fired_total");
    assert_eq!(a.cost.events, b.cost.events, "{tag}: cost events");
    assert_eq!(a.cost.hbm_rows, b.cost.hbm_rows, "{tag}: cost hbm_rows");
    assert_eq!(a.cost.cycles, b.cost.cycles, "{tag}: cost cycles");
    assert_eq!(a.cost.energy_uj, b.cost.energy_uj, "{tag}: cost energy");
    assert_eq!(a.cost.latency_us, b.cost.latency_us, "{tag}: cost latency");
}

/// Satellite: worker count is a pure throughput knob — the same
/// `SimConfig` run with 1, 2, and N workers, under both routing
/// granularities, produces identical `RunRecord`s including the
/// `CostSummary` event counts. Covers the single-core pool and the
/// partitioned cluster (whose internal pool takes the same knobs).
#[test]
fn worker_count_and_route_granularity_leave_run_records_invariant() {
    let mut rng = Xorshift32::new(0x1277);
    let net = random_net(&mut rng, 140, 6);
    let energy = EnergyModel::default();
    let stimulus: Vec<Vec<u32>> = (0..10)
        .map(|_| (0..net.n_axons() as u32).filter(|_| rng.chance(0.4)).collect())
        .collect();

    // pool backend: reference = 1 worker, core-granularity routing
    let reference = {
        let mut sim = SimConfig::new(net.clone())
            .backend(Backend::Pool)
            .workers(1)
            .route_granularity(RouteGranularity::Core)
            .build()
            .unwrap();
        sim.run(&stimulus, &energy).unwrap()
    };
    assert!(reference.fired_total > 0, "test net too quiet to prove anything");
    for workers in [1usize, 2, 6] {
        for route in [RouteGranularity::Core, RouteGranularity::Chunk] {
            let mut sim = SimConfig::new(net.clone())
                .backend(Backend::Pool)
                .workers(workers)
                .route_granularity(route)
                .build()
                .unwrap();
            let rec = sim.run(&stimulus, &energy).unwrap();
            assert_records_identical(&format!("pool w={workers} {route:?}"), &rec, &reference);
        }
    }

    // cluster: same invariance on its internal pool (cluster-vs-cluster,
    // so per-core noise seeds are identical across the comparison)
    let cap = hiaer_spike::partition::CoreCapacity { max_neurons: 50, max_synapses: usize::MAX };
    let cluster_ref = {
        let mut sim = SimConfig::new(net.clone())
            .topology(1, 1, 3)
            .capacity(cap)
            .workers(1)
            .route_granularity(RouteGranularity::Core)
            .build()
            .unwrap();
        sim.run(&stimulus, &energy).unwrap()
    };
    for workers in [2usize, 5] {
        for route in [RouteGranularity::Core, RouteGranularity::Chunk] {
            let mut sim = SimConfig::new(net.clone())
                .topology(1, 1, 3)
                .capacity(cap)
                .workers(workers)
                .route_granularity(route)
                .build()
                .unwrap();
            let rec = sim.run(&stimulus, &energy).unwrap();
            assert_records_identical(
                &format!("cluster w={workers} {route:?}"),
                &rec,
                &cluster_ref,
            );
        }
    }
}

/// Tentpole (PR 8): `Backend::Sharded` joins the parity matrix — the
/// multi-process execution must be **bit-identical** to the in-process
/// cluster backend (`RunRecord` including the full f64 `CostSummary`,
/// plus membranes), invariant across shard counts {1, 2, 4} and worker
/// counts {1, 2}, and its spike train must equal the dense golden
/// reference on a noise-free net.
#[test]
fn sharded_backend_matches_cluster_bit_for_bit_across_shard_counts() {
    let mut rng = Xorshift32::new(0x5A4D);
    let n = 100usize;
    let mut net = random_net(&mut rng, n, 6);
    // dense runs one global noise lane, cluster/sharded one per core:
    // strip noise so all three references legitimately agree
    for p in &mut net.params {
        p.flags &= !FLAG_NOISE;
    }
    let energy = EnergyModel::default();
    let cap = hiaer_spike::partition::CoreCapacity { max_neurons: 30, max_synapses: usize::MAX };
    let stimulus: Vec<Vec<u32>> = (0..10)
        .map(|_| (0..net.n_axons() as u32).filter(|_| rng.chance(0.4)).collect())
        .collect();
    let all_ids: Vec<u32> = (0..n as u32).collect();

    let dense_rec = {
        let mut sim = SimConfig::new(net.clone()).backend(Backend::Dense).build().unwrap();
        sim.run(&stimulus, &energy).unwrap()
    };

    // in-process cluster reference on a 1x2x2 topology (4 cores)
    let mut cluster =
        SimConfig::new(net.clone()).topology(1, 2, 2).capacity(cap).workers(1).build().unwrap();
    let cluster_rec = cluster.run(&stimulus, &energy).unwrap();
    let cluster_v = cluster.read_membrane(&all_ids);
    assert_eq!(cluster_rec.spikes, dense_rec.spikes, "cluster vs dense spikes");
    assert_eq!(cluster_rec.fired_total, dense_rec.fired_total, "cluster vs dense fired");
    assert!(cluster_rec.fired_total > 0, "test net too quiet to prove anything");

    for shards in [1usize, 2, 4] {
        for workers in [1usize, 2] {
            let mut sim = SimConfig::new(net.clone())
                .topology(1, 2, 2)
                .capacity(cap)
                .workers(workers)
                .shards(shards)
                .shard_bin(env!("CARGO_BIN_EXE_hiaer-spike"))
                .build()
                .unwrap_or_else(|e| panic!("sharded s={shards} w={workers} build: {e}"));
            assert_eq!(sim.backend_name(), "sharded");
            assert_eq!(sim.n_cores(), 4);
            let tag = format!("sharded s={shards} w={workers}");
            let rec = sim.run(&stimulus, &energy).unwrap();
            assert_records_identical(&tag, &rec, &cluster_rec);
            assert_eq!(sim.read_membrane(&all_ids), cluster_v, "{tag}: membranes");
        }
    }
}

/// After `reset()`, every backend reports the (empty) initial state
/// from `fired()`/`output_spikes()` — not the pre-reset step's spikes.
#[test]
fn reset_clears_last_step_spike_views_on_every_backend() {
    let mut rng = Xorshift32::new(0x5E7);
    let net = random_net(&mut rng, 80, 4);
    for (name, mut sim) in single_core_sessions(&net) {
        // drive until something fires (noise + drive makes this quick)
        for _ in 0..20 {
            sim.step(&[0, 1]).unwrap();
            if !sim.fired().is_empty() {
                break;
            }
        }
        assert!(!sim.fired().is_empty(), "{name}: net never fired — test net too quiet");
        sim.reset();
        assert!(sim.fired().is_empty(), "{name}: fired() stale after reset");
        assert!(sim.output_spikes().is_empty(), "{name}: output_spikes() stale after reset");
    }
}

#[test]
fn out_of_range_axon_is_error_not_panic_on_every_backend() {
    let mut rng = Xorshift32::new(7);
    let net = random_net(&mut rng, 50, 3);
    let mut sessions = single_core_sessions(&net);
    // the cluster variant must honour the same contract
    let cap = hiaer_spike::partition::CoreCapacity {
        max_neurons: 20,
        max_synapses: usize::MAX,
    };
    sessions.push((
        "cluster".to_string(),
        SimConfig::new(net.clone()).topology(1, 1, 3).capacity(cap).build().unwrap(),
    ));
    for (name, mut sim) in sessions {
        let err = sim.step(&[99]).unwrap_err();
        assert!(matches!(err, SimError::Stimulus(_)), "{name}: {err}");
    }
}

/// Restored from the deleted `tests/parity.rs`: a dense fan-out net
/// whose single step emits far more events than the smallest XLA
/// accumulate-variant capacity (4096 for n1024), forcing the
/// chunked-accumulate path — checked against the dense golden model
/// through the facade. Sits out unless a `pjrt` build with artifacts
/// can construct the backend.
#[test]
fn xla_backend_handles_large_event_batches() {
    let n = 900usize;
    // one axon hits everyone; every neuron hits 20 targets -> ~18k
    // events per fully-active step
    let axon_adj: Vec<Vec<Synapse>> =
        vec![(0..n as u32).map(|t| Synapse { target: t, weight: 10 }).collect()];
    let mut rng = Xorshift32::new(3);
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..20 {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-5, 8) as i16 });
        }
    }
    let net = Network::from_adj(
        vec![NeuronModel::if_neuron(1); n],
        &neuron_adj,
        &axon_adj,
        vec![0],
        5,
    );
    let mut xla = match SimConfig::new(net.clone())
        .backend(Backend::Xla)
        .artifacts(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
        .build()
    {
        Ok(sim) => sim,
        Err(e) => {
            eprintln!("xla large-batch test sits out: {e}");
            return;
        }
    };
    let mut dense = SimConfig::new(net).backend(Backend::Dense).build().unwrap();
    let all_ids: Vec<u32> = (0..n as u32).collect();
    for t in 0..4 {
        let want = dense.step(&[0]).unwrap().fired.to_vec();
        let got = xla.step(&[0]).unwrap().fired.to_vec();
        assert_eq!(got, want, "step {t}: xla fired");
        assert_eq!(
            xla.read_membrane(&all_ids),
            dense.read_membrane(&all_ids),
            "step {t}: xla membranes"
        );
    }
}

#[test]
fn xla_backend_unavailable_without_pjrt_feature() {
    if cfg!(feature = "pjrt") {
        return; // gate applies to default builds only
    }
    let mut rng = Xorshift32::new(3);
    let net = random_net(&mut rng, 20, 2);
    assert!(!Backend::Xla.available());
    match SimConfig::new(net).backend(Backend::Xla).build() {
        Err(SimError::BackendUnavailable { backend, reason }) => {
            assert_eq!(backend, "xla");
            assert!(reason.contains("pjrt"), "{reason}");
        }
        Err(e) => panic!("expected BackendUnavailable, got {e}"),
        Ok(_) => panic!("xla backend must not build without the pjrt feature"),
    }
}

#[test]
fn from_args_rejects_unknown_backend_and_strategy_with_options_listed() {
    let parse = |toks: &[&str]| {
        SimOptions::from_args(
            &Args::parse_from(toks.iter().map(|s| s.to_string()), &["xla"]).unwrap(),
        )
    };
    let err = parse(&["--backend", "fpga"]).unwrap_err().to_string();
    assert!(err.contains("dense, rust, pool, xla"), "{err}");
    let err = parse(&["--strategy", "tight"]).unwrap_err().to_string();
    assert!(err.contains("modulo, balance"), "{err}");
    let ok = parse(&["--backend", "pool", "--strategy", "modulo", "--cores", "4"]).unwrap();
    assert_eq!(ok.backend, Backend::Pool);
    assert_eq!(ok.topology.n_cores(), 4);
}

/// Multi-core topologies require the cluster-capable backend; others
/// fail fast with a configuration error.
#[test]
fn single_core_backends_reject_multi_core_topologies() {
    let mut rng = Xorshift32::new(11);
    let net = random_net(&mut rng, 30, 2);
    for b in [Backend::Dense, Backend::Pool] {
        let err = SimConfig::new(net.clone()).backend(b).topology(1, 1, 4).build();
        assert!(
            matches!(err, Err(SimError::Config(_))),
            "{} must reject a 4-core topology",
            b.name()
        );
    }
}
