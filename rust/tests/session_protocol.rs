//! End-to-end session-protocol test: spawn the real `hiaer-spike`
//! binary (`CARGO_BIN_EXE_hiaer-spike`), pipe canned JSON request lines
//! through `serve-session`, and check the response stream against a
//! direct in-process facade run. This is the transport the Python
//! `hs_api` `backend="rust"` client speaks.

use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};

use hiaer_spike::model_fmt::write_hsn;
use hiaer_spike::sim::{SimConfig, Simulator};
use hiaer_spike::snn::{Network, NetworkBuilder, NeuronModel};
use hiaer_spike::util::json::Json;

fn fig6_net() -> Network {
    let lif = NeuronModel::lif(3, 0, 63, false).unwrap();
    let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
    let ann_d = NeuronModel::ann(5, 0, true).unwrap();
    let mut b = NetworkBuilder::new().seed(7);
    b.add_neuron("a", lif, &[("b", 1), ("d", 2)]).unwrap();
    b.add_neuron("b", lif, &[]).unwrap();
    b.add_neuron("c", lif_c, &[]).unwrap();
    b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
    b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
    b.add_axon("beta", &[("b", 3)]).unwrap();
    b.add_output("a");
    b.add_output("b");
    b.build().unwrap().0
}

struct Server {
    child: Child,
    out: BufReader<std::process::ChildStdout>,
}

impl Server {
    fn spawn(extra: &[&str]) -> Server {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_hiaer-spike"));
        cmd.arg("serve-session").args(extra).stdin(Stdio::piped()).stdout(Stdio::piped());
        let mut child = cmd.spawn().expect("spawning hiaer-spike serve-session");
        let out = BufReader::new(child.stdout.take().unwrap());
        Server { child, out }
    }

    fn request(&mut self, line: &str) -> Json {
        let stdin = self.child.stdin.as_mut().unwrap();
        writeln!(stdin, "{line}").unwrap();
        stdin.flush().unwrap();
        self.read_line()
    }

    fn read_line(&mut self) -> Json {
        let mut line = String::new();
        self.out.read_line(&mut line).expect("reading server response");
        assert!(!line.is_empty(), "server closed the stream unexpectedly");
        Json::parse(line.trim_end()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
    }

    fn finish(mut self) {
        drop(self.child.stdin.take());
        let status = self.child.wait().expect("waiting for server exit");
        assert!(status.success(), "serve-session exited with {status:?}");
    }
}

fn temp_hsn(tag: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("hiaer_proto_{}_{tag}.hsn", std::process::id()));
    p
}

fn ok(j: &Json) -> bool {
    j.get("ok") == Some(&Json::Bool(true))
}

#[test]
fn canned_request_stream_matches_direct_facade() {
    let net = fig6_net();
    let p = temp_hsn("stream");
    write_hsn(&net, &p).unwrap();

    let mut server = Server::spawn(&[]);
    let hello = server.read_line();
    assert_eq!(hello.get("op").and_then(Json::as_str), Some("hello"));
    assert_eq!(hello.get("protocol").and_then(Json::as_i64), Some(1));

    let conf =
        server.request(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
    assert!(ok(&conf), "{conf:?}");
    assert_eq!(conf.get("neurons").and_then(Json::as_i64), Some(4));
    assert_eq!(conf.get("backend").and_then(Json::as_str), Some("rust"));

    // reference: same network driven directly through the facade
    let mut reference = SimConfig::new(net).build().unwrap();
    let stimulus: Vec<Vec<u32>> = vec![vec![0, 1], vec![0, 1], vec![], vec![1], vec![]];

    // per-step ops
    for axons in &stimulus[..2] {
        let ids: Vec<String> = axons.iter().map(|a| a.to_string()).collect();
        let resp = server.request(&format!("{{\"op\":\"step\",\"axons\":[{}]}}", ids.join(",")));
        assert!(ok(&resp), "{resp:?}");
        let want = reference.step(axons).unwrap();
        let want_spikes: Vec<i64> = want.output_spikes.iter().map(|&s| s as i64).collect();
        assert_eq!(resp.get("spikes").and_then(Json::int_vec), Some(want_spikes));
        assert_eq!(
            resp.get("fired").and_then(Json::as_i64),
            Some(want.fired.len() as i64)
        );
    }

    // batched remainder in one round trip
    let rows: Vec<String> = stimulus[2..]
        .iter()
        .map(|r| {
            let ids: Vec<String> = r.iter().map(|a| a.to_string()).collect();
            format!("[{}]", ids.join(","))
        })
        .collect();
    let resp =
        server.request(&format!("{{\"op\":\"step_many\",\"batch\":[{}]}}", rows.join(",")));
    assert!(ok(&resp), "{resp:?}");
    let want = reference.step_many(&stimulus[2..]).unwrap();
    let got: Vec<Vec<i64>> = resp
        .get("spikes")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|r| r.int_vec().unwrap())
        .collect();
    let want_spikes: Vec<Vec<i64>> = want
        .spikes
        .iter()
        .map(|r| r.iter().map(|&s| s as i64).collect())
        .collect();
    assert_eq!(got, want_spikes);

    // membranes bit-identical after the same schedule
    let resp = server.request(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
    let want_v = reference.read_membrane(&[0, 1, 2, 3]);
    assert_eq!(resp.get("v").and_then(Json::i32_vec), Some(want_v));

    // cost counters flow through
    let resp = server.request(r#"{"op":"cost"}"#);
    assert!(ok(&resp), "{resp:?}");
    assert!(resp.get("cycles").and_then(Json::as_i64).unwrap() > 0);

    // structured errors leave the server serving
    let resp = server.request("definitely not json");
    assert_eq!(resp.get("code").and_then(Json::as_str), Some("malformed_request"));
    let resp = server.request(r#"{"op":"warp"}"#);
    assert_eq!(resp.get("code").and_then(Json::as_str), Some("unknown_op"));
    let resp = server.request(r#"{"op":"step","axons":[5]}"#);
    assert_eq!(resp.get("code").and_then(Json::as_str), Some("stimulus"));

    // reset + replay stays deterministic
    let resp = server.request(r#"{"op":"reset"}"#);
    assert!(ok(&resp), "{resp:?}");
    reference.reset();
    let resp = server.request(r#"{"op":"step","axons":[0,1]}"#);
    let want = reference.step(&[0, 1]).unwrap();
    let want_spikes: Vec<i64> = want.output_spikes.iter().map(|&s| s as i64).collect();
    assert_eq!(resp.get("spikes").and_then(Json::int_vec), Some(want_spikes));

    let resp = server.request(r#"{"op":"shutdown"}"#);
    assert!(ok(&resp), "{resp:?}");
    server.finish();
    std::fs::remove_file(&p).ok();
}

#[test]
fn deployment_flags_reach_the_session() {
    let net = fig6_net();
    let p = temp_hsn("flags");
    write_hsn(&net, &p).unwrap();

    // --backend dense + --seed override: hello and configure reflect both
    let mut server = Server::spawn(&["--backend", "dense", "--seed", "123"]);
    let hello = server.read_line();
    assert_eq!(hello.get("backend").and_then(Json::as_str), Some("dense"));
    let conf =
        server.request(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
    assert!(ok(&conf), "{conf:?}");
    assert_eq!(conf.get("backend").and_then(Json::as_str), Some("dense"));

    // dense must match a rust-backend reference with the same seed
    // override (cross-backend determinism through the wire)
    let mut net2 = fig6_net();
    net2.base_seed = 123;
    let mut reference = SimConfig::new(net2).build().unwrap();
    let batch: Vec<Vec<u32>> = (0..6).map(|t| if t % 2 == 0 { vec![0, 1] } else { vec![] }).collect();
    let want = reference.step_many(&batch).unwrap();
    let rows: Vec<String> = batch
        .iter()
        .map(|r| {
            let ids: Vec<String> = r.iter().map(|a| a.to_string()).collect();
            format!("[{}]", ids.join(","))
        })
        .collect();
    let resp =
        server.request(&format!("{{\"op\":\"step_many\",\"batch\":[{}]}}", rows.join(",")));
    assert!(ok(&resp), "{resp:?}");
    let got: Vec<Vec<i64>> = resp
        .get("spikes")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|r| r.int_vec().unwrap())
        .collect();
    let want_spikes: Vec<Vec<i64>> = want
        .spikes
        .iter()
        .map(|r| r.iter().map(|&s| s as i64).collect())
        .collect();
    assert_eq!(got, want_spikes, "dense-over-wire vs rust-in-process");

    let resp = server.request(r#"{"op":"shutdown"}"#);
    assert!(ok(&resp), "{resp:?}");
    server.finish();
    std::fs::remove_file(&p).ok();
}

#[test]
fn eof_without_shutdown_exits_cleanly() {
    // read the greeting, then just close stdin: the loop must end
    let mut server = Server::spawn(&[]);
    let hello = server.read_line();
    assert_eq!(hello.get("op").and_then(Json::as_str), Some("hello"));
    server.finish();
}
