//! Chunk-parallel sweep invariants through the public API: a `CorePool`
//! forced into maximal chunking (one spike word per chunk) must stay
//! bit-exact with the unchunked single-core engine AND the dense golden
//! model — fired ids, output spikes, and membranes — including stochastic
//! neurons, whose per-index counter noise makes chunking order-invariant.

use hiaer_spike::cluster::CorePool;
use hiaer_spike::engine::{CoreEngine, DenseEngine, RustBackend};
use hiaer_spike::hbm::SlotStrategy;
use hiaer_spike::snn::{Network, NeuronModel, Synapse};
use hiaer_spike::util::prng::Xorshift32;

/// Random net sized to span several spike words with a ragged tail.
fn noisy_net(n: usize, seed: u32) -> Network {
    let mut rng = Xorshift32::new(seed);
    let models = [
        NeuronModel::if_neuron(30),
        NeuronModel::lif(25, -3, 3, true).unwrap(),
        NeuronModel::ann(18, -6, true).unwrap(),
    ];
    let params: Vec<NeuronModel> = (0..n).map(|_| models[rng.below(3) as usize]).collect();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..6 {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-20, 40) as i16 });
        }
    }
    let axon_adj: Vec<Vec<Synapse>> = (0..4)
        .map(|_| {
            (0..12)
                .map(|_| Synapse { target: rng.below(n as u32), weight: 25 })
                .collect()
        })
        .collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|_| rng.chance(0.25)).collect();
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, seed)
}

#[test]
fn max_chunked_pool_matches_engine_and_dense() {
    let n = 777; // 13 spike words, ragged tail
    let net = noisy_net(n, 0x51EE7);
    let mut dense = DenseEngine::new(&net);
    let mut direct = CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap();
    let pooled = vec![CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap()];
    let mut pool = CorePool::with_chunk_words(pooled, 1);

    let mut rng = Xorshift32::new(9);
    for step in 0..30 {
        let axons: Vec<u32> = (0..4u32).filter(|_| rng.chance(0.5)).collect();
        dense.step(&axons);
        let out = direct.step(&axons).unwrap();
        assert_eq!(out.fired.to_vec(), dense.fired(), "direct vs dense, step {step}");

        pool.phase_update().unwrap();
        pool.phase_route(std::slice::from_ref(&axons)).unwrap();
        assert_eq!(pool.core(0).fired(), direct.fired(), "fired, step {step}");
        assert_eq!(
            pool.core(0).output_spikes(),
            direct.output_spikes(),
            "output spikes, step {step}"
        );
        assert_eq!(pool.core(0).v, dense.v, "membranes, step {step}");
    }
}

/// Moderate chunking (several words per chunk, several chunks per core)
/// across a multi-core pool, driven for many steps.
#[test]
fn multi_core_chunked_pool_matches_direct() {
    let nets: Vec<Network> = (0..3).map(|i| noisy_net(200 + 70 * i, 0xA0 + i as u32)).collect();
    let mut direct: Vec<CoreEngine<RustBackend>> = nets
        .iter()
        .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
        .collect();
    let pooled: Vec<CoreEngine<RustBackend>> = nets
        .iter()
        .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
        .collect();
    let mut pool = CorePool::with_chunk_words(pooled, 2);

    for step in 0..20u32 {
        let inputs: Vec<Vec<u32>> = (0..3)
            .map(|c| if (step as usize + c) % 2 == 0 { vec![0, 2] } else { vec![1] })
            .collect();
        for (c, e) in direct.iter_mut().enumerate() {
            e.phase_update().unwrap();
            e.phase_route(&inputs[c]).unwrap();
        }
        pool.phase_update().unwrap();
        pool.phase_route(&inputs).unwrap();
        for c in 0..3 {
            assert_eq!(pool.core(c).fired(), direct[c].fired(), "core {c} step {step}");
            assert_eq!(pool.core(c).v, direct[c].v, "core {c} membranes step {step}");
        }
    }
}
