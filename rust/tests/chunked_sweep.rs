//! Chunk-parallel sweep invariants through the public facade: a
//! `Backend::Pool` session forced into maximal chunking (one spike word
//! per chunk via `SimConfig::chunk_words`) must stay bit-exact with the
//! unchunked event-driven engine AND the dense golden model — fired
//! ids, output spikes, and membranes — including stochastic neurons,
//! whose per-index counter noise makes chunking order-invariant. The
//! same granularity knob reaches the cluster engine's internal pool.

use hiaer_spike::partition::CoreCapacity;
use hiaer_spike::sim::{Backend, SimConfig, Simulator};
use hiaer_spike::snn::{Network, NeuronModel, Synapse, FLAG_NOISE};
use hiaer_spike::util::prng::Xorshift32;

/// Random net sized to span several spike words with a ragged tail.
fn noisy_net(n: usize, seed: u32) -> Network {
    let mut rng = Xorshift32::new(seed);
    let models = [
        NeuronModel::if_neuron(30),
        NeuronModel::lif(25, -3, 3, true).unwrap(),
        NeuronModel::ann(18, -6, true).unwrap(),
    ];
    let params: Vec<NeuronModel> = (0..n).map(|_| models[rng.below(3) as usize]).collect();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..6 {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-20, 40) as i16 });
        }
    }
    let axon_adj: Vec<Vec<Synapse>> = (0..4)
        .map(|_| {
            (0..12)
                .map(|_| Synapse { target: rng.below(n as u32), weight: 25 })
                .collect()
        })
        .collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|_| rng.chance(0.25)).collect();
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, seed)
}

#[test]
fn max_chunked_pool_matches_engine_and_dense() {
    let n = 777; // 13 spike words, ragged tail
    let net = noisy_net(n, 0x51EE7);
    let mut dense = SimConfig::new(net.clone()).backend(Backend::Dense).build().unwrap();
    let mut direct = SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
    let mut pool = SimConfig::new(net.clone())
        .backend(Backend::Pool)
        .chunk_words(1) // force maximal chunking
        .build()
        .unwrap();

    let all_ids: Vec<u32> = (0..n as u32).collect();
    let mut rng = Xorshift32::new(9);
    for step in 0..30 {
        let axons: Vec<u32> = (0..4u32).filter(|_| rng.chance(0.5)).collect();
        let dense_fired = dense.step(&axons).unwrap().fired.to_vec();
        let direct_out = direct.step(&axons).unwrap();
        assert_eq!(direct_out.fired, &dense_fired[..], "direct vs dense, step {step}");
        drop(direct_out);

        let out = pool.step(&axons).unwrap();
        assert_eq!(out.fired, direct.fired(), "fired, step {step}");
        assert_eq!(out.output_spikes, direct.output_spikes(), "output spikes, step {step}");
        drop(out);
        assert_eq!(
            pool.read_membrane(&all_ids),
            dense.read_membrane(&all_ids),
            "membranes, step {step}"
        );
    }
}

/// Moderate chunking: the cluster engine's internal pool at two words
/// per chunk must match the same cluster at default granularity and the
/// dense model (deterministic net — per-core seeds differ from the
/// single-core seed, so noise is stripped for the cross-engine check).
#[test]
fn cluster_chunk_granularity_is_invariant() {
    let n = 410;
    let mut net = noisy_net(n, 0xA0);
    for p in &mut net.params {
        p.flags &= !FLAG_NOISE;
    }
    let cap = CoreCapacity { max_neurons: n.div_ceil(3), max_synapses: usize::MAX };
    let mut dense = SimConfig::new(net.clone()).backend(Backend::Dense).build().unwrap();
    let mut fine = SimConfig::new(net.clone())
        .topology(1, 1, 3)
        .capacity(cap)
        .chunk_words(2)
        .build()
        .unwrap();
    let mut coarse =
        SimConfig::new(net.clone()).topology(1, 1, 3).capacity(cap).build().unwrap();

    let all_ids: Vec<u32> = (0..n as u32).collect();
    for step in 0..20u32 {
        let axons: Vec<u32> = if step % 2 == 0 { vec![0, 2] } else { vec![1] };
        let dense_fired = dense.step(&axons).unwrap().fired.to_vec();
        let f = fine.step(&axons).unwrap().fired.to_vec();
        let c = coarse.step(&axons).unwrap().fired.to_vec();
        assert_eq!(f, dense_fired, "fine-chunked cluster vs dense, step {step}");
        assert_eq!(c, dense_fired, "default-chunked cluster vs dense, step {step}");
        assert_eq!(
            fine.read_membrane(&all_ids),
            dense.read_membrane(&all_ids),
            "membranes, step {step}"
        );
    }
}
