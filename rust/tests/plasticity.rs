//! Runtime-plasticity contracts (PR 9), both halves of the subsystem:
//!
//! * **Edit journal** — a property test drives random
//!   `write/add/remove_synapse` sequences through an `EditJournal` and
//!   an eagerly-edited `Network` side by side: overlay reads, degrees
//!   and the post-compaction CSR must be bit-identical to the eager
//!   reference (duplicates of an edited key collapse, untouched base
//!   slots survive verbatim).
//! * **STDP kernel** — a scalar reference model re-implements the
//!   documented trace/update ordering contract (`crate::plasticity`
//!   module docs) from the network adjacency alone, fed only the
//!   engine's observed spike train; every weight must match after
//!   every step, on the serial engine and the chunk-parallel pool.
//! * **Determinism** — a learning-enabled run is bit-identical
//!   (RunRecord *and* final weights) across worker counts, chunk
//!   sizes, route granularities and shard counts, like every other
//!   parallelism knob in the facade.
//! * **Live edits** — `Simulator::write_synapse` and friends mutate
//!   the next step's behaviour without touching membranes.

use std::collections::BTreeMap;

use hiaer_spike::energy::EnergyModel;
use hiaer_spike::plasticity::{
    apply_delta, decay_trace, stdp_delta, PlasticityConfig, TRACE_CEIL, TRACE_ONE,
};
use hiaer_spike::sim::{Backend, RouteGranularity, RunRecord, SimConfig, SimError, Simulator};
use hiaer_spike::snn::{EditJournal, EditKey, Network, NeuronModel, Synapse};
use hiaer_spike::util::prng::Xorshift32;

/// Non-zero random weight: zero-weight slots are masked out of the HBM
/// image at compile time and would not be plastic.
fn nonzero_weight(rng: &mut Xorshift32) -> i16 {
    let w = rng.range_i32(-25, 25) as i16;
    if w == 0 {
        7
    } else {
        w
    }
}

/// One per-source synapse row with unique, sorted targets and non-zero
/// weights.
fn adj_row(rng: &mut Xorshift32, n: usize, count: usize) -> Vec<Synapse> {
    let mut tgts: Vec<u32> = (0..count).map(|_| rng.below(n as u32)).collect();
    tgts.sort_unstable();
    tgts.dedup();
    tgts.into_iter().map(|target| Synapse { target, weight: nonzero_weight(rng) }).collect()
}

/// Random network for learning tests: mixed neuron models (noise lanes
/// included — single-core backends share the global index space, so
/// even stochastic nets must agree), every weight non-zero (all slots
/// plastic), and **no duplicate (pre, post) pairs**, so each weight is
/// uniquely addressable through `read_synapse`.
fn learning_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
    let models = [
        NeuronModel::if_neuron(rng.range_i32(4, 30)),
        NeuronModel::lif(rng.range_i32(4, 30), -3, 4, false).unwrap(),
        NeuronModel::ann(rng.range_i32(3, 20), -6, true).unwrap(),
    ];
    let params: Vec<NeuronModel> = (0..n).map(|_| models[rng.below(3) as usize]).collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|i| i % 3 == 0).collect();
    let base_seed = rng.next_u32();
    let neuron_adj: Vec<Vec<Synapse>> = (0..n)
        .map(|_| {
            let count = rng.below(8) as usize;
            adj_row(rng, n, count)
        })
        .collect();
    let axon_adj: Vec<Vec<Synapse>> = (0..a)
        .map(|_| {
            let count = 2 + rng.below(6) as usize;
            adj_row(rng, n, count)
        })
        .collect();
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, base_seed)
}

/// Every (pre_is_axon, pre, post) synapse key of a network, deduped.
fn all_keys(net: &Network) -> Vec<(bool, u32, u32)> {
    let mut keys = Vec::new();
    for i in 0..net.n_neurons() {
        for &t in net.neuron_targets(i) {
            keys.push((false, i as u32, t));
        }
    }
    for i in 0..net.n_axons() {
        for &t in net.axon_targets(i) {
            keys.push((true, i as u32, t));
        }
    }
    keys.dedup();
    keys
}

fn weights_of(sim: &dyn Simulator, keys: &[(bool, u32, u32)]) -> Vec<Option<i16>> {
    keys.iter().map(|&(ax, p, q)| sim.read_synapse(ax, p, q).unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Edit journal: overlay + compaction vs an eagerly rebuilt Network
// ---------------------------------------------------------------------------

/// Random base network **with** duplicate (pre, post) pairs allowed —
/// compaction must collapse duplicates of edited keys and keep
/// untouched duplicates verbatim, so the generator must produce both.
fn dup_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
    let params: Vec<NeuronModel> =
        (0..n).map(|_| NeuronModel::if_neuron(rng.range_i32(3, 20))).collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|_| rng.chance(0.3)).collect();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..rng.below(7) as usize {
            adj.push(Synapse {
                target: rng.below(n as u32),
                weight: rng.range_i32(-60, 60) as i16,
            });
        }
    }
    let mut axon_adj: Vec<Vec<Synapse>> = vec![Vec::new(); a];
    for adj in axon_adj.iter_mut() {
        for _ in 0..1 + rng.below(5) as usize {
            adj.push(Synapse {
                target: rng.below(n as u32),
                weight: rng.range_i32(-60, 60) as i16,
            });
        }
    }
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, rng.next_u32())
}

/// The eager mirror of one journal `Set`: compaction collapses every
/// base duplicate of an edited key into a single slot, so the eager
/// reference removes all duplicates and re-inserts one.
fn eager_set(net: &mut Network, k: EditKey, w: i16) {
    net.remove_synapse(k.pre_is_axon, k.pre, k.post);
    net.add_synapse(k.pre_is_axon, k.pre, k.post, w);
}

fn assert_same_csr(tag: &str, got: &Network, want: &Network) {
    assert_eq!(got.syn_targets, want.syn_targets, "{tag}: syn_targets");
    assert_eq!(got.syn_weights, want.syn_weights, "{tag}: syn_weights");
    assert_eq!(got.neuron_off, want.neuron_off, "{tag}: neuron_off");
    assert_eq!(got.axon_off, want.axon_off, "{tag}: axon_off");
    assert_eq!(got.outputs, want.outputs, "{tag}: outputs");
    assert_eq!(got.base_seed, want.base_seed, "{tag}: base_seed");
}

#[test]
fn journal_overlay_and_compaction_match_eager_network() {
    let mut rng = Xorshift32::new(0xED17);
    for case in 0..6 {
        let n = 20 + rng.below(40) as usize;
        let a = 2 + rng.below(5) as usize;
        let base = dup_net(&mut rng, n, a);
        let mut eager = base.clone();
        let mut journal = EditJournal::new();
        let mut expect_recorded = 0u64;
        for op in 0..200 {
            let pre_is_axon = rng.chance(0.4);
            let bound = if pre_is_axon { a } else { n } as u32;
            let key =
                EditKey { pre_is_axon, pre: rng.below(bound), post: rng.below(n as u32) };
            let w = rng.range_i32(-60, 60) as i16;
            let existed = eager.read_synapse(key.pre_is_axon, key.pre, key.post).is_some();
            match rng.below(3) {
                0 => {
                    // write: miss records nothing, hit sets (and collapses)
                    let got = journal.write_synapse(base.view(), key, w);
                    assert_eq!(got, existed, "case {case} op {op}: write hit/miss");
                    if existed {
                        eager_set(&mut eager, key, w);
                        expect_recorded += 1;
                    }
                }
                1 => {
                    // add: upsert, created iff previously absent
                    let created = journal.add_synapse(base.view(), key, w);
                    assert_eq!(created, !existed, "case {case} op {op}: add created");
                    eager_set(&mut eager, key, w);
                    expect_recorded += 1;
                }
                _ => {
                    let got = journal.remove_synapse(base.view(), key);
                    assert_eq!(got, existed, "case {case} op {op}: remove hit/miss");
                    eager.remove_synapse(key.pre_is_axon, key.pre, key.post);
                    if existed {
                        expect_recorded += 1;
                    }
                }
            }
            // the touched key reads identically through the overlay
            assert_eq!(
                journal.view(base.view()).read_synapse(key.pre_is_axon, key.pre, key.post),
                eager.read_synapse(key.pre_is_axon, key.pre, key.post),
                "case {case} op {op}: overlay read of touched key"
            );
        }
        assert_eq!(journal.recorded(), expect_recorded, "case {case}: recorded()");

        // exhaustive overlay reads + effective degrees
        let view = journal.view(base.view());
        for i in 0..n as u32 {
            for j in 0..n as u32 {
                assert_eq!(
                    view.read_synapse(false, i, j),
                    eager.read_synapse(false, i, j),
                    "case {case}: neuron {i}->{j}"
                );
            }
            assert_eq!(view.degree(false, i), eager.neuron_degree(i as usize), "case {case}");
        }
        for i in 0..a as u32 {
            for j in 0..n as u32 {
                assert_eq!(
                    view.read_synapse(true, i, j),
                    eager.read_synapse(true, i, j),
                    "case {case}: axon {i}->{j}"
                );
            }
            assert_eq!(view.degree(true, i), eager.axon_degree(i as usize), "case {case}");
        }

        // compaction materialises the exact same CSR the eager edits built
        let compacted = journal.compact(&base);
        compacted.validate().unwrap_or_else(|e| panic!("case {case}: compacted invalid: {e}"));
        assert_same_csr(&format!("case {case}: compacted"), &compacted, &eager);

        // an empty journal compacts to the base verbatim
        assert_same_csr(
            &format!("case {case}: identity"),
            &EditJournal::new().compact(&base),
            &base,
        );
    }
}

// ---------------------------------------------------------------------------
// STDP kernel vs a scalar reference model
// ---------------------------------------------------------------------------

/// Scalar re-implementation of the `crate::plasticity` ordering
/// contract, built from the network adjacency alone and fed the
/// engine's observed per-step spike train. Shares only the exported
/// fixed-point primitives (`decay_trace`/`stdp_delta`/`apply_delta`) —
/// the trace bookkeeping, in-edge indexing and update ordering are all
/// independent of the engine's chunked/HBM-indexed implementation.
struct ScalarStdp {
    cfg: PlasticityConfig,
    tr_pre: Vec<i32>,
    tr_post: Vec<i32>,
    tr_axon: Vec<i32>,
    w: BTreeMap<(bool, u32, u32), i16>,
    out_n: Vec<Vec<u32>>,
    out_a: Vec<Vec<u32>>,
    in_edges: Vec<Vec<(bool, u32)>>,
}

impl ScalarStdp {
    fn new(net: &Network, cfg: PlasticityConfig) -> Self {
        let (n, a) = (net.n_neurons(), net.n_axons());
        let mut w = BTreeMap::new();
        let mut out_n = vec![Vec::new(); n];
        let mut out_a = vec![Vec::new(); a];
        let mut in_edges = vec![Vec::new(); n];
        for i in 0..n {
            let (tg, wt) = net.neuron_syns(i);
            for (&t, &ww) in tg.iter().zip(wt) {
                w.insert((false, i as u32, t), ww);
                out_n[i].push(t);
                in_edges[t as usize].push((false, i as u32));
            }
        }
        for i in 0..a {
            let (tg, wt) = net.axon_syns(i);
            for (&t, &ww) in tg.iter().zip(wt) {
                w.insert((true, i as u32, t), ww);
                out_a[i].push(t);
                in_edges[t as usize].push((true, i as u32));
            }
        }
        Self {
            cfg,
            tr_pre: vec![0; n],
            tr_post: vec![0; n],
            tr_axon: vec![0; a],
            w,
            out_n,
            out_a,
            in_edges,
        }
    }

    /// One step of the ordering contract: neuron traces decay+bump,
    /// axon traces decay+bump, depression for every fired/delivered
    /// source's outgoing slots, then potentiation for every fired
    /// neuron's incoming slots — each delta clamped at application.
    fn step(&mut self, axon_in: &[u32], fired: &[u32]) {
        let c = self.cfg;
        for i in 0..self.tr_pre.len() {
            let f = fired.binary_search(&(i as u32)).is_ok() as i32;
            self.tr_pre[i] =
                (decay_trace(self.tr_pre[i], c.tau_pre) + f * TRACE_ONE).min(TRACE_CEIL);
            self.tr_post[i] =
                (decay_trace(self.tr_post[i], c.tau_post) + f * TRACE_ONE).min(TRACE_CEIL);
        }
        for tr in self.tr_axon.iter_mut() {
            *tr = decay_trace(*tr, c.tau_pre);
        }
        for &a in axon_in {
            let tr = &mut self.tr_axon[a as usize];
            *tr = (*tr + TRACE_ONE).min(TRACE_CEIL);
        }
        for &a in axon_in {
            for &t in &self.out_a[a as usize] {
                let d = stdp_delta(c.a_minus, self.tr_post[t as usize]);
                let e = self.w.get_mut(&(true, a, t)).unwrap();
                *e = apply_delta(*e, -d, &c);
            }
        }
        for &f in fired {
            for &t in &self.out_n[f as usize] {
                let d = stdp_delta(c.a_minus, self.tr_post[t as usize]);
                let e = self.w.get_mut(&(false, f, t)).unwrap();
                *e = apply_delta(*e, -d, &c);
            }
        }
        for &post in fired {
            for &(ax, src) in &self.in_edges[post as usize] {
                let tr = if ax {
                    self.tr_axon[src as usize]
                } else {
                    self.tr_pre[src as usize]
                };
                let d = stdp_delta(c.a_plus, tr);
                let e = self.w.get_mut(&(ax, src, post)).unwrap();
                *e = apply_delta(*e, d, &c);
            }
        }
    }
}

#[test]
fn stdp_kernel_matches_scalar_reference() {
    let mut rng = Xorshift32::new(0x57D9);
    let cfg = PlasticityConfig {
        a_plus: 8,
        a_minus: 9,
        tau_pre: 2,
        tau_post: 3,
        w_min: -30,
        w_max: 30,
    };
    for case in 0..3 {
        let n = 40 + rng.below(60) as usize;
        let a = 3 + rng.below(4) as usize;
        let net = learning_net(&mut rng, n, a);
        let schedule: Vec<Vec<u32>> = (0..15)
            .map(|_| (0..a as u32).filter(|_| rng.chance(0.5)).collect())
            .collect();
        let sessions: Vec<(&str, Box<dyn Simulator>)> = vec![
            (
                "rust",
                SimConfig::new(net.clone()).backend(Backend::Rust).learning(cfg).build().unwrap(),
            ),
            (
                "pool",
                SimConfig::new(net.clone())
                    .backend(Backend::Pool)
                    .workers(3)
                    .chunk_words(1)
                    .learning(cfg)
                    .build()
                    .unwrap(),
            ),
        ];
        for (name, mut sim) in sessions {
            let mut scalar = ScalarStdp::new(&net, cfg);
            let mut changed = false;
            for (t, axons) in schedule.iter().enumerate() {
                let fired = sim.step(axons).unwrap().fired.to_vec();
                scalar.step(axons, &fired);
                for (&(ax, pre, post), &want) in scalar.w.iter() {
                    let got = sim.read_synapse(ax, pre, post).unwrap();
                    assert_eq!(
                        got,
                        Some(want),
                        "{name} case {case} t {t}: weight ({ax}, {pre} -> {post})"
                    );
                    changed |= net.read_synapse(ax, pre, post) != Some(want);
                }
            }
            assert!(changed, "{name} case {case}: learning never moved a weight");
        }
    }
}

// ---------------------------------------------------------------------------
// Determinism: learning runs are invariant under every parallelism knob
// ---------------------------------------------------------------------------

fn assert_records_identical(tag: &str, a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.steps, b.steps, "{tag}: steps");
    assert_eq!(a.spikes, b.spikes, "{tag}: per-step spikes");
    assert_eq!(a.fired_total, b.fired_total, "{tag}: fired_total");
    assert_eq!(a.cost.events, b.cost.events, "{tag}: cost events");
    assert_eq!(a.cost.hbm_rows, b.cost.hbm_rows, "{tag}: cost hbm_rows");
    assert_eq!(a.cost.cycles, b.cost.cycles, "{tag}: cost cycles");
}

#[test]
fn learning_run_is_invariant_across_workers_chunks_and_routes() {
    let mut rng = Xorshift32::new(0x1EA4);
    let net = learning_net(&mut rng, 120, 6);
    let cfg = PlasticityConfig { w_min: -40, w_max: 40, ..PlasticityConfig::default() };
    let energy = EnergyModel::default();
    let keys = all_keys(&net);
    let stimulus: Vec<Vec<u32>> = (0..12)
        .map(|_| (0..net.n_axons() as u32).filter(|_| rng.chance(0.5)).collect())
        .collect();

    // serial event-driven reference
    let (reference, ref_weights) = {
        let mut sim =
            SimConfig::new(net.clone()).backend(Backend::Rust).learning(cfg).build().unwrap();
        let rec = sim.run(&stimulus, &energy).unwrap();
        (rec, weights_of(sim.as_ref(), &keys))
    };
    assert!(reference.fired_total > 0, "test net too quiet to prove anything");
    let initial: Vec<Option<i16>> =
        keys.iter().map(|&(ax, p, q)| net.read_synapse(ax, p, q)).collect();
    assert_ne!(ref_weights, initial, "learning never moved a weight");

    for workers in [1usize, 2, 6] {
        for route in [RouteGranularity::Core, RouteGranularity::Chunk] {
            for chunk_words in [0usize, 1] {
                let mut c = SimConfig::new(net.clone())
                    .backend(Backend::Pool)
                    .workers(workers)
                    .route_granularity(route)
                    .learning(cfg);
                if chunk_words > 0 {
                    c = c.chunk_words(chunk_words);
                }
                let mut sim = c.build().unwrap();
                let tag = format!("pool w={workers} {route:?} cw={chunk_words}");
                let rec = sim.run(&stimulus, &energy).unwrap();
                assert_records_identical(&tag, &rec, &reference);
                assert_eq!(weights_of(sim.as_ref(), &keys), ref_weights, "{tag}: weights");
            }
        }
    }
}

#[test]
fn learning_run_is_invariant_across_cluster_workers_and_shard_counts() {
    let mut rng = Xorshift32::new(0x1EA5);
    let net = learning_net(&mut rng, 100, 6);
    let cfg = PlasticityConfig { w_min: -40, w_max: 40, ..PlasticityConfig::default() };
    let energy = EnergyModel::default();
    let keys = all_keys(&net);
    let cap = hiaer_spike::partition::CoreCapacity { max_neurons: 30, max_synapses: usize::MAX };
    let stimulus: Vec<Vec<u32>> = (0..10)
        .map(|_| (0..net.n_axons() as u32).filter(|_| rng.chance(0.5)).collect())
        .collect();

    // in-process cluster reference (1x2x2 = 4 cores, 1 worker)
    let (cluster_rec, cluster_w, cluster_v) = {
        let mut sim = SimConfig::new(net.clone())
            .topology(1, 2, 2)
            .capacity(cap)
            .workers(1)
            .learning(cfg)
            .build()
            .unwrap();
        let rec = sim.run(&stimulus, &energy).unwrap();
        let w = weights_of(sim.as_ref(), &keys);
        let v = sim.read_membrane(&(0..net.n_neurons() as u32).collect::<Vec<_>>());
        (rec, w, v)
    };
    assert!(cluster_rec.fired_total > 0, "test net too quiet to prove anything");

    // cluster: worker count and route granularity are pure throughput knobs
    for workers in [2usize, 5] {
        for route in [RouteGranularity::Core, RouteGranularity::Chunk] {
            let mut sim = SimConfig::new(net.clone())
                .topology(1, 2, 2)
                .capacity(cap)
                .workers(workers)
                .route_granularity(route)
                .learning(cfg)
                .build()
                .unwrap();
            let tag = format!("cluster w={workers} {route:?}");
            let rec = sim.run(&stimulus, &energy).unwrap();
            assert_records_identical(&tag, &rec, &cluster_rec);
            assert_eq!(weights_of(sim.as_ref(), &keys), cluster_w, "{tag}: weights");
        }
    }

    // sharded: the multi-process execution matches the in-process
    // cluster bit-for-bit (spikes, membranes AND final weights) for
    // every shard count
    let all_ids: Vec<u32> = (0..net.n_neurons() as u32).collect();
    for shards in [1usize, 2, 4] {
        let mut sim = SimConfig::new(net.clone())
            .topology(1, 2, 2)
            .capacity(cap)
            .workers(2)
            .shards(shards)
            .shard_bin(env!("CARGO_BIN_EXE_hiaer-spike"))
            .learning(cfg)
            .build()
            .unwrap_or_else(|e| panic!("sharded s={shards} build: {e}"));
        let tag = format!("sharded s={shards}");
        let rec = sim.run(&stimulus, &energy).unwrap();
        assert_records_identical(&tag, &rec, &cluster_rec);
        assert_eq!(sim.read_membrane(&all_ids), cluster_v, "{tag}: membranes");
        assert_eq!(weights_of(sim.as_ref(), &keys), cluster_w, "{tag}: weights");
    }
}

// ---------------------------------------------------------------------------
// Facade live edits: next-step behaviour changes, membranes survive
// ---------------------------------------------------------------------------

/// Two-neuron chain: a0 -(4)-> n0 -(1)-> n1, IF theta 3. n0 fires every
/// step once charged; n1 charges 1/step through the chain synapse, so
/// re-weighting that synapse provably changes n1's firing rate.
fn chain_net() -> Network {
    let lif = NeuronModel::if_neuron(3);
    Network::from_adj(
        vec![lif; 2],
        &[vec![Synapse { target: 1, weight: 1 }], vec![]],
        &[vec![Synapse { target: 0, weight: 4 }]],
        vec![0, 1],
        9,
    )
}

#[test]
fn live_edits_change_next_step_without_membrane_reset() {
    for backend in [Backend::Rust, Backend::Pool] {
        let name = backend.name();
        let build = || SimConfig::new(chain_net()).backend(backend).build().unwrap();
        let mut edited = build();
        let mut frozen = build();
        for _ in 0..4 {
            edited.step(&[0]).unwrap();
            frozen.step(&[0]).unwrap();
        }
        let v_before = edited.read_membrane(&[0, 1]);
        assert_eq!(v_before, frozen.read_membrane(&[0, 1]), "{name}: twins diverged early");

        // in-place weight edit: visible immediately, membranes untouched
        assert!(edited.write_synapse(false, 0, 1, 3).unwrap(), "{name}: existing synapse");
        assert_eq!(edited.read_synapse(false, 0, 1).unwrap(), Some(3), "{name}");
        assert_eq!(edited.read_membrane(&[0, 1]), v_before, "{name}: membranes reset by edit");

        // n1 now charges 3/step instead of 1/step: more n1 spikes
        let mut edited_n1 = 0;
        let mut frozen_n1 = 0;
        for _ in 0..8 {
            edited_n1 += edited.step(&[0]).unwrap().fired.contains(&1) as u32;
            frozen_n1 += frozen.step(&[0]).unwrap().fired.contains(&1) as u32;
        }
        assert!(
            edited_n1 > frozen_n1,
            "{name}: edit had no behavioural effect ({edited_n1} vs {frozen_n1})"
        );

        // structural edits through the same surface
        assert!(!edited.write_synapse(true, 0, 1, 5).unwrap(), "{name}: missing synapse");
        assert!(edited.add_synapse(true, 0, 1, 5).unwrap(), "{name}: created");
        assert_eq!(edited.read_synapse(true, 0, 1).unwrap(), Some(5), "{name}");
        assert!(!edited.add_synapse(true, 0, 1, 6).unwrap(), "{name}: upsert re-weighted");
        assert_eq!(edited.read_synapse(true, 0, 1).unwrap(), Some(6), "{name}");
        assert_eq!(edited.remove_synapse(true, 0, 1).unwrap(), 1, "{name}: removed");
        assert_eq!(edited.read_synapse(true, 0, 1).unwrap(), None, "{name}");
    }

    // the dense golden model runs frozen weights only
    let mut dense = SimConfig::new(chain_net()).backend(Backend::Dense).build().unwrap();
    assert!(matches!(dense.write_synapse(false, 0, 1, 3), Err(SimError::Config(_))));
}
