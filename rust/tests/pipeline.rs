//! Full-pipeline integration: a synthetic .hsl layer graph written from
//! Rust goes through the converter, the `SimConfig` facade (single-core
//! and clustered), .hsn round-trip and the job queue — and every path
//! agrees. No trained models or artifacts required.

use hiaer_spike::cluster::{parse_stimulus, run_job, Job, JobStatus};
use hiaer_spike::convert::{convert, reference_forward_binary, run_inference, BiasMode, Readout};
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::hbm::{HbmImage, SlotStrategy};
use hiaer_spike::model_fmt::{read_hsn, write_hsn, Layer, LayerGraph, NeuronKind};
use hiaer_spike::partition::CoreCapacity;
use hiaer_spike::sim::{SimConfig, SimOptions, Simulator};
use hiaer_spike::util::prng::Xorshift32;

fn little_cnn(rng: &mut Xorshift32, kind: NeuronKind, timesteps: usize) -> LayerGraph {
    let conv_w: Vec<i16> = (0..3 * 1 * 3 * 3).map(|_| rng.range_i32(-30, 30) as i16).collect();
    let fc_in = 3 * 3 * 3;
    let fc_w: Vec<i16> = (0..4 * fc_in).map(|_| rng.range_i32(-20, 20) as i16).collect();
    LayerGraph {
        neuron_kind: kind,
        in_c: 1,
        in_h: 8,
        in_w: 8,
        timesteps,
        layers: vec![
            Layer::Conv {
                out_c: 3,
                kh: 3,
                kw: 3,
                stride: 2,
                pad: 0,
                theta: rng.range_i32(0, 40),
                weights: conv_w,
                bias: Some(vec![rng.range_i32(-20, 20), 0, 5]),
            },
            Layer::Fc {
                out_features: 4,
                theta: rng.range_i32(0, 30),
                weights: fc_w,
                bias: None,
            },
        ],
    }
}

#[test]
fn binary_model_end_to_end_matches_reference() {
    let mut rng = Xorshift32::new(0xAB);
    for _case in 0..5 {
        let graph = little_cnn(&mut rng, NeuronKind::AnnBinary, 1);
        let conv = convert(&graph, BiasMode::Threshold, 0).unwrap();
        // HBM layout validates
        let img = HbmImage::compile(&conv.net, SlotStrategy::BalanceFanIn).unwrap();
        img.validate(&conv.net).unwrap();

        let input: Vec<i32> = (0..64).map(|_| rng.chance(0.35) as i32).collect();
        let want = reference_forward_binary(&graph, &input).unwrap();
        let frames: Vec<Vec<u32>> = vec![input
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0)
            .map(|(i, _)| i as u32)
            .collect()];

        let mut engine = SimConfig::new(conv.net.clone())
            .strategy(SlotStrategy::BalanceFanIn)
            .build()
            .unwrap();
        let inf = run_inference(
            &mut *engine,
            &conv,
            &frames,
            graph.layers.len(),
            Readout::Membrane,
            &EnergyModel::default(),
        )
        .unwrap();
        // reference_forward_binary binarizes every layer; the paper's
        // membrane readout needs the RAW logits of the last (FC) layer,
        // so recompute them from the penultimate activations.
        let penult = &want[want.len() - 2];
        let logits: Vec<i64> = match &graph.layers[1] {
            Layer::Fc { out_features, weights, .. } => (0..*out_features)
                .map(|o| {
                    penult
                        .iter()
                        .enumerate()
                        .map(|(i, &x)| x as i64 * weights[o * penult.len() + i] as i64)
                        .sum()
                })
                .collect(),
            _ => unreachable!(),
        };
        let mut best = 0usize;
        for (i, &l) in logits.iter().enumerate() {
            if l > logits[best] {
                best = i;
            }
        }
        assert_eq!(inf.prediction, best);
        assert_eq!(inf.scores, logits, "output membranes must equal reference logits");
    }
}

#[test]
fn hsn_roundtrip_preserves_inference() {
    let mut rng = Xorshift32::new(0xCD);
    let graph = little_cnn(&mut rng, NeuronKind::IntegrateFire, 4);
    let conv = convert(&graph, BiasMode::Threshold, 99).unwrap();
    let p = std::env::temp_dir().join(format!("pipe_{}.hsn", std::process::id()));
    write_hsn(&conv.net, &p).unwrap();
    let net2 = read_hsn(&p).unwrap();

    let frames: Vec<Vec<u32>> =
        (0..4).map(|_| (0..64u32).filter(|_| rng.chance(0.3)).collect()).collect();
    let run = |net: &hiaer_spike::snn::Network| -> Vec<Vec<u32>> {
        let mut e = SimConfig::new(net.clone()).strategy(SlotStrategy::Modulo).build().unwrap();
        let mut out = Vec::new();
        for t in 0..frames.len() + 2 {
            let empty = Vec::new();
            let f = frames.get(t).unwrap_or(&empty);
            out.push(e.step(f).unwrap().fired.to_vec());
        }
        out
    };
    assert_eq!(run(&conv.net), run(&net2));

    // job queue path over the same file
    let stim = "0 5 9\n\n1 2\n";
    let job = Job {
        id: 0,
        net_path: p.clone(),
        stimulus: parse_stimulus(stim).unwrap(),
        options: SimOptions::default(),
    };
    let r = run_job(&job, &EnergyModel::default());
    std::fs::remove_file(&p).ok();
    assert_eq!(r.status, JobStatus::Done);
    assert!(r.energy_uj > 0.0);
}

#[test]
fn multicore_matches_single_core_on_converted_model() {
    let mut rng = Xorshift32::new(0xEF);
    let graph = little_cnn(&mut rng, NeuronKind::IntegrateFire, 3);
    let conv = convert(&graph, BiasMode::Threshold, 0).unwrap();
    let frames: Vec<Vec<u32>> =
        (0..3).map(|_| (0..64u32).filter(|_| rng.chance(0.4)).collect()).collect();
    let steps = frames.len() + graph.layers.len();

    let mut single =
        SimConfig::new(conv.net.clone()).strategy(SlotStrategy::Modulo).build().unwrap();
    let mut single_out = Vec::new();
    for t in 0..steps {
        let empty = Vec::new();
        let f = frames.get(t).unwrap_or(&empty);
        single_out.push(single.step(f).unwrap().output_spikes.to_vec());
    }

    let cap = CoreCapacity {
        max_neurons: conv.net.n_neurons().div_ceil(3),
        max_synapses: usize::MAX,
    };
    let mut mc = SimConfig::new(conv.net.clone())
        .topology(1, 2, 2)
        .capacity(cap)
        .strategy(SlotStrategy::Modulo)
        .build()
        .unwrap();
    for t in 0..steps {
        let empty = Vec::new();
        let f = frames.get(t).unwrap_or(&empty);
        let got = mc.step(f).unwrap();
        assert_eq!(got.output_spikes, &single_out[t][..], "step {t}");
    }
}
