//! Route-parity property suite — the route-phase twin of
//! `prop_chunked_sweep_matches_scalar_reference`: the chunk-parallel
//! Route phase (gather spread over pool workers in pointer chunks,
//! merged in chunk order before the accumulate) must be **bit-exact**
//! with the serial `phase_route` reference for every chunk size and
//! worker count, including oversubscribed pools — membranes, fired ids,
//! output spikes AND the reconstructed HBM access/event accounting.
//!
//! Everything runs through the public facade: `Backend::Rust` is the
//! serial reference (one engine, serial `phase_route`), `Backend::Pool`
//! with `workers(n)` / `route_chunk_ptrs(k)` / `route_granularity` is
//! the system under test.

use hiaer_spike::energy::EnergyModel;
use hiaer_spike::sim::{Backend, RouteGranularity, SimConfig, Simulator};
use hiaer_spike::snn::{Network, NeuronModel, Synapse};
use hiaer_spike::util::prng::Xorshift32;
use hiaer_spike::util::ptest;

/// Random CSR net with all three neuron models, stochastic lanes
/// included (noise is per-index counter hash, so the single-core pool
/// shares the serial engine's seed schedule bit-for-bit).
fn random_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
    let models = [
        NeuronModel::if_neuron(rng.range_i32(5, 60)),
        NeuronModel::lif(rng.range_i32(5, 60), -5, 4, true).unwrap(),
        NeuronModel::ann(rng.range_i32(2, 40), -8, true).unwrap(),
    ];
    let params: Vec<NeuronModel> = (0..n).map(|_| models[rng.below(3) as usize]).collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|_| rng.chance(0.25)).collect();
    let base_seed = rng.next_u32();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..rng.below(9) as usize {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-60, 60) as i16 });
        }
    }
    let mut axon_adj: Vec<Vec<Synapse>> = vec![Vec::new(); a];
    for adj in axon_adj.iter_mut() {
        for _ in 0..1 + rng.below(6) as usize {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-60, 80) as i16 });
        }
    }
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, base_seed)
}

/// Drive `sut` and `reference` in lockstep and assert bit-exact spike
/// trains, membranes, and cost counters every step.
fn assert_lockstep(
    tag: &str,
    reference: &mut dyn Simulator,
    sut: &mut dyn Simulator,
    steps: usize,
    rng: &mut Xorshift32,
) -> Result<(), String> {
    let n = reference.n_neurons();
    let a = reference.n_axons();
    let all_ids: Vec<u32> = (0..n as u32).collect();
    let energy = EnergyModel::default();
    for t in 0..steps {
        let axons: Vec<u32> = (0..a as u32).filter(|_| rng.chance(0.4)).collect();
        let (want_fired, want_out) = {
            let r = reference.step(&axons).map_err(|e| e.to_string())?;
            (r.fired.to_vec(), r.output_spikes.to_vec())
        };
        let got = sut.step(&axons).map_err(|e| e.to_string())?;
        ptest::prop_assert_eq(got.fired.to_vec(), want_fired, &format!("{tag} t{t} fired"))?;
        ptest::prop_assert_eq(
            got.output_spikes.to_vec(),
            want_out,
            &format!("{tag} t{t} outputs"),
        )?;
        drop(got);
        ptest::prop_assert_eq(
            sut.read_membrane(&all_ids),
            reference.read_membrane(&all_ids),
            &format!("{tag} t{t} membranes"),
        )?;
        let (rc, sc) = (reference.cost(&energy), sut.cost(&energy));
        ptest::prop_assert_eq(sc.events, rc.events, &format!("{tag} t{t} events"))?;
        ptest::prop_assert_eq(sc.hbm_rows, rc.hbm_rows, &format!("{tag} t{t} hbm rows"))?;
        ptest::prop_assert_eq(sc.cycles, rc.cycles, &format!("{tag} t{t} cycles"))?;
    }
    Ok(())
}

/// THE route-parity property: random CSR nets x chunk sizes x worker
/// counts (1..=8, including pools oversubscribed far beyond the chunk
/// count) — the chunk-parallel route is bit-identical to the serial
/// `phase_route` reference.
#[test]
fn prop_chunked_route_matches_serial() {
    ptest::check("chunked_route_vs_serial", 18, |rng| {
        let n = 30 + rng.below(260) as usize;
        let a = 2 + rng.below(8) as usize;
        let net = random_net(rng, n, a);
        let chunk = [1usize, 2, 5, 16, 64][rng.below(5) as usize];
        let workers = 1 + rng.below(8) as usize; // 1..=8
        let mut reference =
            SimConfig::new(net.clone()).backend(Backend::Rust).build().map_err(|e| e.to_string())?;
        let mut pool = SimConfig::new(net)
            .backend(Backend::Pool)
            .workers(workers)
            .route_chunk_ptrs(chunk)
            .build()
            .map_err(|e| e.to_string())?;
        let tag = format!("k={chunk} w={workers}");
        assert_lockstep(&tag, &mut *reference, &mut *pool, 10, rng)
    });
}

/// Exhaustive corner grid on one fixed net: every worker count 1..=8
/// (the net's pointer queues are tiny, so most of these pools are
/// oversubscribed), maximal chunking (one pointer per chunk), and both
/// routing granularities.
#[test]
fn route_worker_grid_and_both_granularities_match_serial() {
    let mut seed_rng = Xorshift32::new(0x0507);
    let net = random_net(&mut seed_rng, 150, 5);
    for workers in 1..=8usize {
        for route in [RouteGranularity::Core, RouteGranularity::Chunk] {
            let mut reference =
                SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
            let mut pool = SimConfig::new(net.clone())
                .backend(Backend::Pool)
                .workers(workers)
                .route_granularity(route)
                .route_chunk_ptrs(1) // maximal split
                .build()
                .unwrap();
            let mut rng = Xorshift32::new(0xFEED);
            assert_lockstep(
                &format!("grid w={workers} {route:?}"),
                &mut *reference,
                &mut *pool,
                12,
                &mut rng,
            )
            .unwrap();
        }
    }
}

/// A dense burst net (every axon hits many targets, every neuron fans
/// out) exercises multi-row regions and many chunks per step; the merge
/// order must still reproduce the serial event stream exactly.
#[test]
fn dense_burst_routing_is_chunk_invariant() {
    let n = 300usize;
    let mut rng = Xorshift32::new(0xB00);
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..24 {
            adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(-8, 12) as i16 });
        }
    }
    let axon_adj: Vec<Vec<Synapse>> = (0..3)
        .map(|_| (0..n as u32).map(|t| Synapse { target: t, weight: 9 }).collect())
        .collect();
    let net = Network::from_adj(
        vec![NeuronModel::if_neuron(25); n],
        &neuron_adj,
        &axon_adj,
        (0..n as u32).step_by(7).collect(),
        0xC0DE,
    );
    for chunk in [1usize, 3, 37] {
        let mut reference =
            SimConfig::new(net.clone()).backend(Backend::Rust).build().unwrap();
        let mut pool = SimConfig::new(net.clone())
            .backend(Backend::Pool)
            .workers(6)
            .route_chunk_ptrs(chunk)
            .build()
            .unwrap();
        let mut rng = Xorshift32::new(7);
        assert_lockstep(&format!("burst k={chunk}"), &mut *reference, &mut *pool, 8, &mut rng)
            .unwrap();
    }
}
