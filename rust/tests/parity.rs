//! Three-way engine parity: on randomized networks, the dense engine, the
//! event-driven HBM engine with the native backend, and the event-driven
//! engine with the **XLA backend running the AOT Pallas artifacts** must
//! produce identical spike trains and membranes — the system's core
//! correctness claim (software sim == hardware, Table 2).

use std::path::Path;
use std::sync::Arc;

use hiaer_spike::engine::{CoreEngine, DenseEngine, RustBackend};
use hiaer_spike::hbm::SlotStrategy;
use hiaer_spike::runtime::{Runtime, XlaBackend};
use hiaer_spike::snn::{Network, NeuronModel, Synapse};
use hiaer_spike::util::prng::Xorshift32;

fn artifacts() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn random_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
    let models = [
        NeuronModel::if_neuron(rng.range_i32(5, 60)),
        NeuronModel::lif(rng.range_i32(5, 60), -5, 4, true).unwrap(),
        NeuronModel::ann(rng.range_i32(2, 40), -8, true).unwrap(),
    ];
    let params: Vec<NeuronModel> = (0..n).map(|_| models[rng.below(3) as usize]).collect();
    let outputs: Vec<u32> = (0..n as u32).filter(|_| rng.chance(0.2)).collect();
    let base_seed = rng.next_u32();
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        let deg = rng.below(10) as usize;
        for _ in 0..deg {
            adj.push(Synapse {
                target: rng.below(n as u32),
                weight: rng.range_i32(-60, 60) as i16,
            });
        }
    }
    let mut axon_adj: Vec<Vec<Synapse>> = vec![Vec::new(); a];
    for adj in axon_adj.iter_mut() {
        for _ in 0..1 + rng.below(6) as usize {
            adj.push(Synapse {
                target: rng.below(n as u32),
                weight: rng.range_i32(-60, 80) as i16,
            });
        }
    }
    Network::from_adj(params, &neuron_adj, &axon_adj, outputs, base_seed)
}

#[test]
fn xla_engine_matches_rust_engine_and_dense() {
    if !artifacts().join("neuron_update_n1024.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`; skipping");
        return;
    }
    let rt = Arc::new(Runtime::cpu(artifacts()).unwrap());
    let mut rng = Xorshift32::new(0xFEED);
    for case in 0..3 {
        let n = 50 + rng.below(400) as usize;
        let a = 4 + rng.below(12) as usize;
        let net = random_net(&mut rng, n, a);
        let mut dense = DenseEngine::new(&net);
        let mut rust_core =
            CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap();
        let backend = XlaBackend::new(rt.clone(), n).unwrap();
        let mut xla_core = CoreEngine::new(&net, SlotStrategy::Modulo, backend).unwrap();

        for t in 0..10 {
            let axons: Vec<u32> = (0..a as u32).filter(|_| rng.chance(0.4)).collect();
            dense.step(&axons);
            let want = dense.fired();
            let r = rust_core.step(&axons).unwrap().fired.to_vec();
            assert_eq!(r, want, "case {case} step {t}: rust-core vs dense");
            let x = xla_core.step(&axons).unwrap().fired.to_vec();
            assert_eq!(x, want, "case {case} step {t}: xla-core vs dense");
            assert_eq!(xla_core.v, dense.v, "case {case} step {t}: xla membranes");
            assert_eq!(rust_core.v, dense.v, "case {case} step {t}: rust membranes");
        }
    }
}

#[test]
fn xla_engine_handles_large_event_batches() {
    if !artifacts().join("neuron_update_n1024.hlo.txt").exists() {
        return;
    }
    // dense fan-out: one step emits more events than the smallest accum
    // variant capacity forces the chunking path
    let rt = Arc::new(Runtime::cpu(artifacts()).unwrap());
    let n = 900usize;
    // axon hits everyone; every neuron hits 20 targets -> ~18k events when
    // all fire (> 4096 capacity of the n1024 accum variant)
    let axon_adj: Vec<Vec<Synapse>> =
        vec![(0..n as u32).map(|t| Synapse { target: t, weight: 10 }).collect()];
    let mut rng = Xorshift32::new(3);
    let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
    for adj in neuron_adj.iter_mut() {
        for _ in 0..20 {
            adj.push(Synapse {
                target: rng.below(n as u32),
                weight: rng.range_i32(-5, 8) as i16,
            });
        }
    }
    let net = Network::from_adj(
        vec![NeuronModel::if_neuron(1); n],
        &neuron_adj,
        &axon_adj,
        vec![0],
        5,
    );
    let mut dense = DenseEngine::new(&net);
    let backend = XlaBackend::new(rt, n).unwrap();
    let mut xla_core = CoreEngine::new(&net, SlotStrategy::BalanceFanIn, backend).unwrap();
    for t in 0..4 {
        dense.step(&[0]);
        xla_core.step(&[0]).unwrap();
        assert_eq!(xla_core.v, dense.v, "step {t}");
    }
}
