//! Mmap-vs-heap backend parity: the same `.hsn` v2 file loaded through
//! the zero-copy [`NetFile`] mapping and through the owned-heap decoder
//! must drive **bit-identical** runs on every backend (dense, rust,
//! pool, cluster). The borrowed-CSR view is the only thing the engines
//! see, so where the bytes live cannot change a single spike.

use hiaer_spike::energy::EnergyModel;
use hiaer_spike::model_fmt::{hsn_v2_bytes_quantized, open_netfile, read_hsn, write_hsn};
use hiaer_spike::sim::{Backend, NetSource, SimConfig, Simulator};
use hiaer_spike::snn::{Network, NetworkBuilder, NeuronModel};
use hiaer_spike::util::prng::Xorshift32;

fn random_net(seed: u32, n: usize, n_axons: usize) -> Network {
    let mut rng = Xorshift32::new(seed);
    let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
    let mut b = NetworkBuilder::new().seed(seed);
    for (i, key) in keys.iter().enumerate() {
        let model = if i % 3 == 2 {
            NeuronModel::ann(4 + (i as i32 % 5), 0, rng.chance(0.3)).unwrap()
        } else {
            NeuronModel::lif(3 + (i as i32 % 7), 0, 63, rng.chance(0.2)).unwrap()
        };
        let syns: Vec<(String, i32)> = (0..rng.below(6))
            .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-8, 8)))
            .collect();
        let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
        b.add_neuron(key, model, &refs).unwrap();
    }
    for a in 0..n_axons {
        let syns: Vec<(String, i32)> = (0..1 + rng.below(4))
            .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-8, 8)))
            .collect();
        let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
        b.add_axon(&format!("a{a}"), &refs).unwrap();
    }
    for key in keys.iter().step_by(3) {
        b.add_output(key);
    }
    b.build().unwrap().0
}

fn schedule(seed: u32, n_axons: u32, steps: usize) -> Vec<Vec<u32>> {
    let mut rng = Xorshift32::new(seed);
    (0..steps).map(|_| (0..n_axons).filter(|_| rng.chance(0.35)).collect()).collect()
}

fn temp_path(tag: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("hiaer_netfile_parity_{}_{tag}.hsn", std::process::id()));
    p
}

fn build(src: NetSource, which: usize) -> Box<dyn Simulator> {
    match which {
        0 => SimConfig::new(src).backend(Backend::Dense).build().unwrap(),
        1 => SimConfig::new(src).backend(Backend::Rust).build().unwrap(),
        2 => SimConfig::new(src).backend(Backend::Pool).workers(3).build().unwrap(),
        // multi-core topology -> the partitioned cluster engine
        _ => SimConfig::new(src).topology(1, 1, 3).build().unwrap(),
    }
}

#[test]
fn mmap_and_heap_runs_are_bit_identical_on_every_backend() {
    let net = random_net(11, 60, 12);
    let path = temp_path("plain");
    write_hsn(&net, &path).unwrap();

    let heap = read_hsn(&path).unwrap();
    let file = open_netfile(&path).unwrap();
    assert_eq!(file.view().to_network().syn_targets, heap.syn_targets);

    let stim = schedule(99, heap.n_axons() as u32, 40);
    let energy = EnergyModel::default();
    let all_ids: Vec<u32> = (0..heap.n_neurons() as u32).collect();
    for which in 0..4 {
        let mut h = build(NetSource::Owned(heap.clone()), which);
        let mut m = build(NetSource::Mapped(file.clone()), which);
        assert_eq!(h.backend_name(), m.backend_name());
        let rh = h.run(&stim, &energy).unwrap();
        let rm = m.run(&stim, &energy).unwrap();
        assert_eq!(rh.steps, rm.steps);
        assert_eq!(
            rh.spikes,
            rm.spikes,
            "backend {}: mmap and heap sources must spike identically",
            h.backend_name()
        );
        assert_eq!(rh.fired_total, rm.fired_total, "backend {}", h.backend_name());
        assert_eq!(
            h.read_membrane(&all_ids),
            m.read_membrane(&all_ids),
            "backend {}: final membranes",
            h.backend_name()
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn quantized_v2_mmap_matches_heap_decode() {
    let net = random_net(7, 40, 8);
    let path = temp_path("quant");
    std::fs::write(&path, hsn_v2_bytes_quantized(&net, 8).unwrap()).unwrap();

    // both loaders dequantize to the same i16 weights...
    let heap = read_hsn(&path).unwrap();
    let file = open_netfile(&path).unwrap();
    assert_eq!(file.view().syn_weights, &heap.syn_weights[..]);

    // ...and runs stay bit-identical across the two sources
    let stim = schedule(5, heap.n_axons() as u32, 25);
    let energy = EnergyModel::default();
    let mut h = SimConfig::new(heap).backend(Backend::Dense).build().unwrap();
    let mut m = SimConfig::new(file).backend(Backend::Dense).build().unwrap();
    let rh = h.run(&stim, &energy).unwrap();
    let rm = m.run(&stim, &energy).unwrap();
    assert_eq!(rh.spikes, rm.spikes);
    assert_eq!(rh.fired_total, rm.fired_total);
    std::fs::remove_file(&path).ok();
}

#[test]
fn seed_override_applies_to_mapped_sources_without_copying() {
    // the seed override rides on the Copy view, so a mapped (read-only)
    // source accepts it exactly like an owned one
    let net = random_net(23, 30, 6);
    let path = temp_path("seed");
    write_hsn(&net, &path).unwrap();
    let file = open_netfile(&path).unwrap();
    let heap = read_hsn(&path).unwrap();

    let stim = schedule(17, heap.n_axons() as u32, 20);
    let energy = EnergyModel::default();
    let mut a = SimConfig::new(file.clone()).seed(1234).build().unwrap();
    let mut b = SimConfig::new(heap).seed(1234).build().unwrap();
    assert_eq!(
        a.run(&stim, &energy).unwrap().spikes,
        b.run(&stim, &energy).unwrap().spikes
    );
    // the mapping itself is untouched: re-opening yields the original seed
    assert_eq!(open_netfile(&path).unwrap().view().base_seed, net.base_seed);
    std::fs::remove_file(&path).ok();
}
