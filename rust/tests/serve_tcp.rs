//! Fault-injection and concurrency tests for the TCP serving tier
//! (`sim::serve`): many concurrent sessions must stay bit-identical to
//! serial runs, and every hostile-client scenario — killed mid-batch,
//! partial-line disconnect, malformed/oversized floods, panicking
//! simulators, idle squatters — must evict (at most) the offending
//! session while the server keeps serving everyone else.
//!
//! The server runs in-process on an ephemeral 127.0.0.1 port;
//! fault-injecting simulators are installed through the layered test
//! seams (`serve_tcp_with_factory` -> `set_sim_factory_for_tests`).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use hiaer_spike::cluster::{CorePool, PoolOptions};
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::engine::{sweep_chunk, CoreParams, UpdateBackend};
use hiaer_spike::hbm::{HbmImage, Pointer};
use hiaer_spike::model_fmt::write_hsn;
use hiaer_spike::sim::frames;
use hiaer_spike::sim::serve::{serve_tcp_with_factory, ServeLimits, SessionFactory};
use hiaer_spike::sim::session::Session;
use hiaer_spike::sim::{CostSummary, SimConfig, SimError, SimOptions, Simulator, StepResult};
use hiaer_spike::snn::{Network, NetworkBuilder, NeuronModel, Synapse};
use hiaer_spike::util::json::Json;

// ---------------------------------------------------------------- nets

fn fig6_net() -> Network {
    let lif = NeuronModel::lif(3, 0, 63, false).unwrap();
    let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
    let ann_d = NeuronModel::ann(5, 0, true).unwrap();
    let mut b = NetworkBuilder::new().seed(7);
    b.add_neuron("a", lif, &[("b", 1), ("d", 2)]).unwrap();
    b.add_neuron("b", lif, &[]).unwrap();
    b.add_neuron("c", lif_c, &[]).unwrap();
    b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
    b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
    b.add_axon("beta", &[("b", 3)]).unwrap();
    b.add_output("a");
    b.add_output("b");
    b.build().unwrap().0
}

fn tiny_net() -> Network {
    Network::from_adj(
        vec![NeuronModel::if_neuron(0); 3],
        &[vec![Synapse { target: 1, weight: 1 }], vec![], vec![]],
        &[vec![Synapse { target: 0, weight: 1 }]],
        vec![1],
        0,
    )
}

fn temp_hsn(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("hiaer_serve_{}_{tag}.hsn", std::process::id()))
}

// ------------------------------------------------------------- harness

struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: thread::JoinHandle<std::io::Result<()>>,
}

fn start_server_with_factory(limits: ServeLimits, factory: SessionFactory) -> TestServer {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let handle = thread::spawn(move || {
        serve_tcp_with_factory(listener, SimOptions::default(), limits, sd, factory)
    });
    TestServer { addr, shutdown, handle }
}

fn start_server(limits: ServeLimits) -> TestServer {
    start_server_with_factory(limits, Arc::new(Session::with_limits))
}

impl TestServer {
    fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.handle.join().expect("server thread").expect("serve_tcp");
    }
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        // a hang becomes a loud failure instead of a stuck test binary
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    /// Next response line, or `None` on EOF (server closed the session).
    fn read_json(&mut self) -> Option<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("reading server response");
        if n == 0 {
            return None;
        }
        Some(Json::parse(line.trim_end()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}")))
    }

    fn hello(&mut self) {
        let j = self.read_json().expect("hello greeting");
        assert_eq!(j.get("op").and_then(Json::as_str), Some("hello"), "{j:?}");
    }

    fn send(&mut self, line: &str) {
        writeln!(self.stream, "{line}").unwrap();
        self.stream.flush().unwrap();
    }

    fn request(&mut self, line: &str) -> Json {
        self.send(line);
        self.read_json().expect("response line")
    }

    /// Send one binary wire-v2 frame (sentinel + length + kind + payload).
    fn send_frame(&mut self, kind: u8, payload: &[u8]) {
        let bytes = frames::encode_wire_frame(kind, payload).unwrap();
        self.stream.write_all(&bytes).unwrap();
        self.stream.flush().unwrap();
    }

    /// Read one binary reply frame; panics on a JSON line (use
    /// `read_json` for those).
    fn read_frame(&mut self) -> (u8, Vec<u8>) {
        use std::io::Read;
        let mut sentinel = [0u8; 1];
        self.reader.read_exact(&mut sentinel).expect("frame sentinel");
        assert_eq!(sentinel[0], frames::WIRE_SENTINEL, "expected a binary frame");
        let mut lenb = [0u8; 4];
        self.reader.read_exact(&mut lenb).expect("frame length");
        let len = u32::from_le_bytes(lenb) as usize;
        assert!(len >= 1, "frame length must count the kind byte");
        let mut kind = [0u8; 1];
        self.reader.read_exact(&mut kind).expect("frame kind");
        let mut payload = vec![0u8; len - 1];
        self.reader.read_exact(&mut payload).expect("frame payload");
        (kind[0], payload)
    }
}

fn ok(j: &Json) -> bool {
    j.get("ok") == Some(&Json::Bool(true))
}

fn code(j: &Json) -> Option<&str> {
    j.get("code").and_then(Json::as_str)
}

fn configure_line(p: &std::path::Path) -> String {
    format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display())
}

fn configure_binary_line(p: &std::path::Path) -> String {
    format!("{{\"op\":\"configure\",\"net\":\"{}\",\"wire\":\"binary\"}}", p.display())
}

fn step_line(axons: &[u32]) -> String {
    let ids: Vec<String> = axons.iter().map(|a| a.to_string()).collect();
    format!("{{\"op\":\"step\",\"axons\":[{}]}}", ids.join(","))
}

fn step_many_line(batch: &[Vec<u32>]) -> String {
    let rows: Vec<String> = batch
        .iter()
        .map(|r| {
            let ids: Vec<String> = r.iter().map(|a| a.to_string()).collect();
            format!("[{}]", ids.join(","))
        })
        .collect();
    format!("{{\"op\":\"step_many\",\"batch\":[{}]}}", rows.join(","))
}

/// Poll `metrics` until `key` reaches `at_least` (counters race with the
/// evicted session's connection thread winding down).
fn wait_for_metric(c: &mut Client, key: &str, at_least: i64) -> i64 {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = c.request("{\"op\":\"metrics\"}");
        let got = m.get(key).and_then(Json::as_i64).unwrap_or(-1);
        if got >= at_least {
            return got;
        }
        assert!(
            Instant::now() < deadline,
            "metric {key} stuck at {got}, wanted >= {at_least}: {m:?}"
        );
        thread::sleep(Duration::from_millis(50));
    }
}

// ---------------------------------------------------- injected engines

/// Hand-rolled no-op engine that panics when the trigger axon fires —
/// drives the catch_unwind eviction path end to end.
#[derive(Default)]
struct PanicSim {
    fired: Vec<u32>,
}

const PANIC_AXON: u32 = 7;

impl Simulator for PanicSim {
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        if axon_in.contains(&PANIC_AXON) {
            panic!("injected simulator panic");
        }
        Ok(StepResult { fired: &self.fired, output_spikes: &self.fired })
    }
    fn fired(&self) -> &[u32] {
        &self.fired
    }
    fn output_spikes(&self) -> &[u32] {
        &self.fired
    }
    fn reset(&mut self) {}
    fn reset_cost(&mut self) {}
    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        vec![0; ids.len()]
    }
    fn cost(&self, _model: &EnergyModel) -> CostSummary {
        CostSummary::default()
    }
    fn backend_name(&self) -> &'static str {
        "panic-test"
    }
    fn n_neurons(&self) -> usize {
        4
    }
    fn n_axons(&self) -> usize {
        8
    }
}

/// Engine whose every step stalls — saturates the shared compute pool so
/// a second session's permit wait times out (`deadline`).
struct SlowSim {
    delay: Duration,
    fired: Vec<u32>,
}

impl Simulator for SlowSim {
    fn step(&mut self, _axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        thread::sleep(self.delay);
        Ok(StepResult { fired: &self.fired, output_spikes: &self.fired })
    }
    fn fired(&self) -> &[u32] {
        &self.fired
    }
    fn output_spikes(&self) -> &[u32] {
        &self.fired
    }
    fn reset(&mut self) {}
    fn reset_cost(&mut self) {}
    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        vec![0; ids.len()]
    }
    fn cost(&self, _model: &EnergyModel) -> CostSummary {
        CostSummary::default()
    }
    fn backend_name(&self) -> &'static str {
        "slow-test"
    }
    fn n_neurons(&self) -> usize {
        1
    }
    fn n_axons(&self) -> usize {
        4
    }
}

/// The honest pure sweep kernel with a booby-trapped route `gather` —
/// the same shape as the pool failure-injection suite. The pool catches
/// the worker panic and surfaces a phase *error*, so through the session
/// this must come back as an `engine` error WITHOUT eviction.
#[derive(Clone, Copy)]
struct GatherPanicBackend;

impl UpdateBackend for GatherPanicBackend {
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> anyhow::Result<()> {
        let n = v.len();
        sweep_chunk(v, params.slice(0, n), step_seed, spikes, 0);
        Ok(())
    }
    fn gather(&self, _image: &HbmImage, _ptr: Pointer, _out: &mut Vec<(u32, i32)>) {
        panic!("injected gather panic");
    }
    fn accumulate(&mut self, _v: &mut [i32], _events: &[(u32, i32)]) -> anyhow::Result<()> {
        Ok(())
    }
    fn chunkable(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "gather-panic"
    }
}

/// Adapter driving a `CorePool<GatherPanicBackend>` (built through the
/// existing `with_backend_for_tests` hook) behind the `Simulator` trait,
/// mirroring `PoolSim`'s update-then-route step.
struct PoolBackedSim {
    pool: CorePool<GatherPanicBackend>,
    inputs: Vec<Vec<u32>>,
    n_axons: usize,
    n_neurons: usize,
}

impl Simulator for PoolBackedSim {
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        self.inputs[0].clear();
        self.inputs[0].extend_from_slice(axon_in);
        self.pool.phase_update().map_err(SimError::Engine)?;
        self.pool.phase_route(&self.inputs).map_err(SimError::Engine)?;
        let core = self.pool.core(0);
        Ok(StepResult { fired: core.fired(), output_spikes: core.output_spikes() })
    }
    fn fired(&self) -> &[u32] {
        self.pool.core(0).fired()
    }
    fn output_spikes(&self) -> &[u32] {
        self.pool.core(0).output_spikes()
    }
    fn reset(&mut self) {
        self.pool.core_mut(0).reset();
    }
    fn reset_cost(&mut self) {
        self.pool.core_mut(0).reset_cost();
    }
    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        self.pool.core(0).read_membrane(ids)
    }
    fn cost(&self, model: &EnergyModel) -> CostSummary {
        self.pool.core(0).cost(model).into()
    }
    fn backend_name(&self) -> &'static str {
        "pool-panic-test"
    }
    fn n_neurons(&self) -> usize {
        self.n_neurons
    }
    fn n_axons(&self) -> usize {
        self.n_axons
    }
}

/// Session factory whose `configure` installs `build()`'s result.
fn sim_factory(
    build: impl Fn() -> Box<dyn Simulator> + Send + Sync + Clone + 'static,
) -> SessionFactory {
    Arc::new(move |opts, limits| {
        let mut s = Session::with_limits(opts, limits);
        let build = build.clone();
        s.set_sim_factory_for_tests(Box::new(move |_net, _opts| Ok(build())));
        s
    })
}

// --------------------------------------------------------------- tests

/// N concurrent sessions, each with its own stimulus schedule: every
/// response stream must be bit-identical to a serial facade run of the
/// same schedule — sessions share the compute pool but never state.
#[test]
fn concurrent_sessions_match_serial_runs() {
    let net_path = temp_hsn("parity");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());
    let addr = server.addr;

    let mut clients = Vec::new();
    for i in 0..4u32 {
        let p = net_path.clone();
        clients.push(thread::spawn(move || {
            let mut c = Client::connect(addr);
            c.hello();
            let conf = c.request(&configure_line(&p));
            assert!(ok(&conf), "{conf:?}");

            let stimulus: Vec<Vec<u32>> = (0..8u32)
                .map(|t| if (t + i) % 3 == 0 { vec![0, 1] } else { vec![(t + i) % 2] })
                .collect();
            let mut reference = SimConfig::new(fig6_net()).build().unwrap();

            for axons in &stimulus[..3] {
                let resp = c.request(&step_line(axons));
                assert!(ok(&resp), "{resp:?}");
                let want = reference.step(axons).unwrap();
                let want: Vec<i64> = want.output_spikes.iter().map(|&s| s as i64).collect();
                assert_eq!(resp.get("spikes").and_then(Json::int_vec), Some(want));
            }

            let resp = c.request(&step_many_line(&stimulus[3..]));
            assert!(ok(&resp), "{resp:?}");
            let want = reference.step_many(&stimulus[3..]).unwrap();
            let got: Vec<Vec<i64>> = resp
                .get("spikes")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|r| r.int_vec().unwrap())
                .collect();
            let want_rows: Vec<Vec<i64>> =
                want.spikes.iter().map(|r| r.iter().map(|&s| s as i64).collect()).collect();
            assert_eq!(got, want_rows);

            let resp = c.request("{\"op\":\"read_membrane\",\"ids\":[0,1,2,3]}");
            let want_v: Vec<i64> =
                reference.read_membrane(&[0, 1, 2, 3]).iter().map(|&x| x as i64).collect();
            assert_eq!(resp.get("v").and_then(Json::int_vec), Some(want_v), "{resp:?}");

            let bye = c.request("{\"op\":\"shutdown\"}");
            assert!(ok(&bye), "{bye:?}");
        }));
    }
    for h in clients {
        h.join().unwrap();
    }
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A client killed mid-batch (request sent, socket dropped before the
/// response) must not disturb the session next door.
#[test]
fn killed_client_mid_batch_leaves_server_serving() {
    let net_path = temp_hsn("killed");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut survivor = Client::connect(server.addr);
    survivor.hello();
    assert!(ok(&survivor.request(&configure_line(&net_path))));

    {
        let mut victim = Client::connect(server.addr);
        victim.hello();
        assert!(ok(&victim.request(&configure_line(&net_path))));
        let batch: Vec<Vec<u32>> = vec![vec![0, 1]; 50];
        victim.send(&step_many_line(&batch));
        // dropped here: socket closes with the batch still executing
    }

    assert!(ok(&survivor.request(&step_line(&[0, 1]))));
    wait_for_metric(&mut survivor, "disconnects", 1);
    assert!(ok(&survivor.request(&step_line(&[1]))));
    drop(survivor);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A connection dying in the middle of a request line (no newline ever
/// arrives) is a clean disconnect: nothing executes, nobody else notices.
#[test]
fn partial_line_disconnect_is_a_clean_close() {
    let net_path = temp_hsn("partial");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut survivor = Client::connect(server.addr);
    survivor.hello();
    assert!(ok(&survivor.request(&configure_line(&net_path))));

    {
        let mut half = Client::connect(server.addr);
        half.hello();
        half.stream.write_all(b"{\"op\":\"ste").unwrap();
        half.stream.flush().unwrap();
        // dropped: the partial line must be discarded, not executed
    }

    wait_for_metric(&mut survivor, "disconnects", 1);
    let m = survivor.request("{\"op\":\"metrics\"}");
    assert_eq!(m.get("steps_total").and_then(Json::as_i64), Some(0), "{m:?}");
    assert!(ok(&survivor.request(&step_line(&[0]))));
    drop(survivor);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// Oversized + malformed floods answer `malformed_request` with the
/// offending bytes never buffered, and `max_errors` consecutive protocol
/// errors evict the flooding session — only that session.
#[test]
fn error_flood_evicts_only_the_flooding_session() {
    let net_path = temp_hsn("flood");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let limits = ServeLimits { max_errors: 3, max_line_bytes: 128, ..ServeLimits::default() };
    let server = start_server(limits);

    let mut survivor = Client::connect(server.addr);
    survivor.hello();
    assert!(ok(&survivor.request(&configure_line(&net_path))));

    let mut flooder = Client::connect(server.addr);
    flooder.hello();
    let r1 = flooder.request("this is not json");
    assert_eq!(code(&r1), Some("malformed_request"), "{r1:?}");
    let oversized = "x".repeat(512); // > max_line_bytes, valid UTF-8
    let r2 = flooder.request(&oversized);
    assert_eq!(code(&r2), Some("malformed_request"), "{r2:?}");
    // third consecutive error trips the flood eviction
    let r3 = flooder.request("{\"op\":\"nope\"}");
    assert_eq!(code(&r3), Some("unknown_op"), "{r3:?}");
    let notice = flooder.read_json().expect("eviction notice");
    assert_eq!(code(&notice), Some("evicted"), "{notice:?}");
    assert_eq!(flooder.read_json(), None, "EOF after eviction");

    wait_for_metric(&mut survivor, "evicted_flood", 1);
    assert!(ok(&survivor.request(&step_line(&[0, 1]))));
    drop(survivor);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A simulator panic is caught per-request: the panicking session gets
/// an `engine` error plus an `evicted` notice and is closed; concurrent
/// sessions (and the server) keep running.
#[test]
fn simulator_panic_evicts_session_and_peers_survive() {
    let net_path = temp_hsn("panic");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let factory = sim_factory(|| Box::new(PanicSim::default()));
    let server = start_server_with_factory(ServeLimits::default(), factory);

    let mut survivor = Client::connect(server.addr);
    survivor.hello();
    assert!(ok(&survivor.request(&configure_line(&net_path))));
    assert!(ok(&survivor.request(&step_line(&[0]))));

    let mut victim = Client::connect(server.addr);
    victim.hello();
    assert!(ok(&victim.request(&configure_line(&net_path))));
    victim.send(&step_line(&[PANIC_AXON]));
    let engine = victim.read_json().expect("engine error line");
    assert_eq!(code(&engine), Some("engine"), "{engine:?}");
    let msg = engine.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(msg.contains("panicked"), "{engine:?}");
    let notice = victim.read_json().expect("eviction notice");
    assert_eq!(code(&notice), Some("evicted"), "{notice:?}");
    assert_eq!(victim.read_json(), None, "EOF after panic eviction");

    wait_for_metric(&mut survivor, "evicted_panic", 1);
    assert!(ok(&survivor.request(&step_line(&[0]))));
    drop(survivor);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A panic *inside the worker pool* (injected through the existing
/// `with_backend_for_tests` hook) is already caught by the pool and
/// surfaces as a phase error — through the server that is an `engine`
/// error response and the session survives, un-evicted.
#[test]
fn pool_backend_panic_is_engine_error_without_eviction() {
    let net_path = temp_hsn("poolpanic");
    write_hsn(&tiny_net(), &net_path).unwrap();
    let factory = sim_factory(|| {
        let net = tiny_net();
        let (n_axons, n_neurons) = (net.n_axons(), net.n_neurons());
        let pool = CorePool::with_backend_for_tests(
            std::slice::from_ref(&net),
            GatherPanicBackend,
            PoolOptions::default(),
        )
        .expect("pool construction");
        Box::new(PoolBackedSim { pool, inputs: vec![Vec::new()], n_axons, n_neurons })
    });
    let server = start_server_with_factory(ServeLimits::default(), factory);

    let mut c = Client::connect(server.addr);
    c.hello();
    assert!(ok(&c.request(&configure_line(&net_path))));
    // quiet step: no fired sources -> no gather chunks -> no panic
    assert!(ok(&c.request(&step_line(&[]))));
    // axon 0 fires -> gather chunk -> injected worker panic -> pool
    // surfaces a phase error -> engine response, session kept
    let resp = c.request(&step_line(&[0]));
    assert_eq!(code(&resp), Some("engine"), "{resp:?}");
    let msg = resp.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(msg.contains("panicked"), "{resp:?}");
    // the session (and its pool) survives for a following quiet step
    assert!(ok(&c.request(&step_line(&[]))));
    let m = c.request("{\"op\":\"metrics\"}");
    assert_eq!(m.get("evicted_panic").and_then(Json::as_i64), Some(0), "{m:?}");
    drop(c);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// Over `max_sessions`, a connection gets one `server_busy` line instead
/// of `hello`; a slot freed by a closing session is reusable.
#[test]
fn admission_rejects_over_capacity_with_server_busy() {
    let limits = ServeLimits { max_sessions: 1, ..ServeLimits::default() };
    let server = start_server(limits);

    let first = {
        let mut c = Client::connect(server.addr);
        c.hello();
        c
    };

    let mut rejected = Client::connect(server.addr);
    let busy = rejected.read_json().expect("server_busy line");
    assert_eq!(code(&busy), Some("server_busy"), "{busy:?}");
    assert_eq!(rejected.read_json(), None, "EOF after rejection");

    drop(first); // frees the one slot (server side notices the EOF)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut retry = Client::connect(server.addr);
        match retry.read_json() {
            Some(j) if j.get("op").and_then(Json::as_str) == Some("hello") => break,
            Some(j) => assert_eq!(code(&j), Some("server_busy"), "{j:?}"),
            None => {}
        }
        assert!(Instant::now() < deadline, "slot never freed for a new session");
        thread::sleep(Duration::from_millis(50));
    }
    server.stop();
}

/// Session quotas reject with the stable `quota` code and leave the
/// session usable: an over-quota net, then a within-quota net, then an
/// over-quota batch, then a within-quota batch.
#[test]
fn session_quotas_answer_quota_and_session_survives() {
    let big = temp_hsn("quota_big");
    write_hsn(&fig6_net(), &big).unwrap(); // 4 neurons
    let small = temp_hsn("quota_small");
    write_hsn(&tiny_net(), &small).unwrap(); // 3 neurons
    let limits = ServeLimits { max_neurons: 3, max_batch_steps: 2, ..ServeLimits::default() };
    let server = start_server(limits);

    let mut c = Client::connect(server.addr);
    c.hello();
    let r = c.request(&configure_line(&big));
    assert_eq!(code(&r), Some("quota"), "{r:?}");
    assert!(ok(&c.request(&configure_line(&small))));
    let r = c.request(&step_many_line(&[vec![], vec![], vec![]]));
    assert_eq!(code(&r), Some("quota"), "{r:?}");
    assert!(ok(&c.request(&step_many_line(&[vec![0], vec![]]))));
    assert!(ok(&c.request(&step_line(&[0]))));
    drop(c);
    server.stop();
    std::fs::remove_file(&big).ok();
    std::fs::remove_file(&small).ok();
}

/// Sessions silent past the idle TTL are evicted with a notice, so
/// abandoned connections cannot pin server capacity.
#[test]
fn idle_sessions_are_evicted_after_ttl() {
    let limits = ServeLimits { idle_timeout_ms: 200, ..ServeLimits::default() };
    let server = start_server(limits);

    let mut c = Client::connect(server.addr);
    c.hello();
    let t0 = Instant::now();
    let notice = c.read_json().expect("idle eviction notice");
    assert_eq!(code(&notice), Some("evicted"), "{notice:?}");
    assert_eq!(c.read_json(), None, "EOF after idle eviction");
    assert!(t0.elapsed() >= Duration::from_millis(150), "evicted too eagerly");
    server.stop();
}

/// `health` and `metrics` are served without a compute permit and report
/// live occupancy / lifetime counters.
#[test]
fn health_and_metrics_report_server_state() {
    let net_path = temp_hsn("health");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let limits = ServeLimits { max_sessions: 5, ..ServeLimits::default() };
    let server = start_server(limits);

    let mut c = Client::connect(server.addr);
    c.hello();
    let h = c.request("{\"op\":\"health\"}");
    assert!(ok(&h), "{h:?}");
    assert_eq!(h.get("sessions").and_then(Json::as_i64), Some(1));
    assert_eq!(h.get("max_sessions").and_then(Json::as_i64), Some(5));
    assert_eq!(h.get("draining"), Some(&Json::Bool(false)));

    assert!(ok(&c.request(&configure_line(&net_path))));
    assert!(ok(&c.request(&step_many_line(&[vec![0], vec![1], vec![]]))));
    let m = c.request("{\"op\":\"metrics\"}");
    assert!(ok(&m), "{m:?}");
    assert_eq!(m.get("steps_total").and_then(Json::as_i64), Some(3), "{m:?}");
    assert_eq!(m.get("sessions_total").and_then(Json::as_i64), Some(1));
    // the snapshot is taken before the metrics request itself is
    // counted: health + configure + step_many have been recorded
    assert!(m.get("requests_total").and_then(Json::as_i64).unwrap_or(0) >= 3, "{m:?}");
    assert!(m.get("execute_us").and_then(Json::as_i64).unwrap_or(-1) >= 0, "{m:?}");
    drop(c);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// Satellite (PR 8): sessions configured from the same `.hsn` v2 path
/// share one mmap through the server-wide `NetCache` — the second
/// configure is a cache hit (visible in `metrics`) and both sessions
/// still step bit-identically.
#[test]
fn sessions_share_one_net_mapping_per_path() {
    let net_path = temp_hsn("netcache");
    write_hsn(&fig6_net(), &net_path).unwrap(); // write_hsn emits v2
    let server = start_server(ServeLimits::default());

    let mut a = Client::connect(server.addr);
    a.hello();
    assert!(ok(&a.request(&configure_line(&net_path))));
    let mut b = Client::connect(server.addr);
    b.hello();
    assert!(ok(&b.request(&configure_line(&net_path))));

    // first configure mapped the file (miss), second reused it (hit)
    wait_for_metric(&mut a, "net_cache_hits", 1);
    let m = a.request("{\"op\":\"metrics\"}");
    assert!(m.get("net_cache_misses").and_then(Json::as_i64).unwrap_or(0) >= 1, "{m:?}");

    // the shared mapping is invisible to execution: both sessions step
    // identically (each owns its simulator, only the bytes are shared)
    let ra = a.request(&step_line(&[0, 1]));
    let rb = b.request(&step_line(&[0, 1]));
    assert!(ok(&ra), "{ra:?}");
    assert_eq!(ra.get("spikes"), rb.get("spikes"), "{ra:?} vs {rb:?}");
    drop(a);
    drop(b);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// With the compute pool saturated by a slow session, a second session's
/// permit wait times out with a retryable `deadline` error — and the
/// waiting session survives to issue more requests.
#[test]
fn saturated_pool_times_out_with_deadline() {
    let net_path = temp_hsn("deadline");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let factory = sim_factory(|| {
        Box::new(SlowSim { delay: Duration::from_millis(250), fired: Vec::new() })
    });
    let limits =
        ServeLimits { concurrency: 1, request_timeout_ms: 50, ..ServeLimits::default() };
    let server = start_server_with_factory(limits, factory);

    let mut hog = Client::connect(server.addr);
    hog.hello();
    assert!(ok(&hog.request(&configure_line(&net_path))));
    let mut waiter = Client::connect(server.addr);
    waiter.hello();
    assert!(ok(&waiter.request(&configure_line(&net_path))));

    // 4 steps x 250 ms: the hog holds the one permit for ~1 s
    hog.send(&step_many_line(&[vec![], vec![], vec![], vec![]]));
    thread::sleep(Duration::from_millis(150)); // hog surely holds it now
    let r = waiter.request(&step_line(&[]));
    assert_eq!(code(&r), Some("deadline"), "{r:?}");
    // the timed-out session survives; the hog's batch completes
    let done = hog.read_json().expect("hog batch response");
    assert!(ok(&done), "{done:?}");
    assert!(ok(&waiter.request(&step_line(&[]))));
    drop(hog);
    drop(waiter);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// Graceful drain: in-flight work finishes and its response is
/// delivered, then every session gets an `evicted` notice and EOF, and
/// `serve_tcp` returns.
#[test]
fn graceful_drain_finishes_in_flight_and_notifies() {
    let net_path = temp_hsn("drain");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut c = Client::connect(server.addr);
    c.hello();
    assert!(ok(&c.request(&configure_line(&net_path))));

    // put a batch in flight, then request the drain
    let batch: Vec<Vec<u32>> = vec![vec![0, 1]; 200];
    c.send(&step_many_line(&batch));
    server.shutdown.store(true, Ordering::Relaxed);

    // the in-flight batch's response arrives before the drain notice
    let resp = c.read_json().expect("in-flight response");
    assert!(ok(&resp), "{resp:?}");
    assert_eq!(resp.get("spikes").and_then(Json::as_arr).map(|v| v.len()), Some(200), "{resp:?}");
    let notice = c.read_json().expect("drain notice");
    assert_eq!(code(&notice), Some("evicted"), "{notice:?}");
    assert_eq!(c.read_json(), None, "EOF after drain");

    server.handle.join().expect("server thread").expect("serve_tcp drain");
    std::fs::remove_file(&net_path).ok();
}

// ------------------------------------------------- binary wire (PR 10)

/// Tentpole parity pin (TCP): the same `step_many` schedule over the
/// JSON wire and over binary STIM/SPIKES frames must produce
/// bit-identical spike trains — the binary wire is an encoding, never a
/// semantic fork.
#[test]
fn binary_wire_matches_json_wire_over_tcp() {
    let net_path = temp_hsn("binparity");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let schedule: Vec<Vec<u32>> =
        (0..16u32).map(|t| if t % 3 == 0 { vec![0, 1] } else { vec![t % 2] }).collect();

    // reference run over the JSON wire
    let mut json_c = Client::connect(server.addr);
    json_c.hello();
    assert!(ok(&json_c.request(&configure_line(&net_path))));
    let json_resp = json_c.request(&step_many_line(&schedule));
    assert!(ok(&json_resp), "{json_resp:?}");
    let json_rows: Vec<Vec<i64>> = json_resp
        .get("spikes")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|r| r.int_vec().unwrap())
        .collect();

    // same schedule over the binary wire
    let mut bin_c = Client::connect(server.addr);
    bin_c.hello();
    let conf = bin_c.request(&configure_binary_line(&net_path));
    assert!(ok(&conf), "{conf:?}");
    assert_eq!(conf.get("wire").and_then(Json::as_str), Some("binary"), "{conf:?}");
    bin_c.send_frame(frames::FRAME_STIM, &frames::encode_stim(&schedule));
    let (kind, payload) = bin_c.read_frame();
    assert_eq!(kind, frames::FRAME_SPIKES);
    let (bin_rows, fired_total) = frames::decode_spikes(&payload).unwrap();

    let bin_rows_i64: Vec<Vec<i64>> =
        bin_rows.iter().map(|r| r.iter().map(|&s| s as i64).collect()).collect();
    assert_eq!(bin_rows_i64, json_rows, "binary and JSON wires must be bit-identical");
    assert_eq!(
        json_resp.get("fired_total").and_then(Json::as_i64),
        Some(fired_total as i64),
        "{json_resp:?}"
    );

    drop(json_c);
    drop(bin_c);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// An unknown frame kind answers `malformed_request` as a JSON line and
/// the session survives to serve a good frame right after.
#[test]
fn binary_bad_kind_answers_malformed_and_session_survives() {
    let net_path = temp_hsn("binbadkind");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut c = Client::connect(server.addr);
    c.hello();
    assert!(ok(&c.request(&configure_binary_line(&net_path))));

    c.send_frame(0x77, &[1, 2, 3]);
    let r = c.read_json().expect("malformed line for bad kind");
    assert_eq!(code(&r), Some("malformed_request"), "{r:?}");

    // undecodable STIM payload: also malformed, also survivable
    c.send_frame(frames::FRAME_STIM, &[9, 9]);
    let r = c.read_json().expect("malformed line for truncated payload");
    assert_eq!(code(&r), Some("malformed_request"), "{r:?}");

    c.send_frame(frames::FRAME_STIM, &frames::encode_stim(&[vec![0, 1], vec![]]));
    let (kind, payload) = c.read_frame();
    assert_eq!(kind, frames::FRAME_SPIKES);
    let (rows, _) = frames::decode_spikes(&payload).unwrap();
    assert_eq!(rows.len(), 2, "session must still step after frame faults");
    drop(c);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A corrupt length prefix cannot be resynchronised: the server answers
/// one `malformed_request` line and closes that connection — and only
/// that connection.
#[test]
fn oversized_binary_length_prefix_closes_only_that_connection() {
    let net_path = temp_hsn("binlen");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut survivor = Client::connect(server.addr);
    survivor.hello();
    assert!(ok(&survivor.request(&configure_line(&net_path))));

    let mut victim = Client::connect(server.addr);
    victim.hello();
    assert!(ok(&victim.request(&configure_binary_line(&net_path))));
    let mut bad = vec![frames::WIRE_SENTINEL];
    bad.extend_from_slice(&u32::MAX.to_le_bytes());
    victim.stream.write_all(&bad).unwrap();
    victim.stream.flush().unwrap();
    let r = victim.read_json().expect("malformed line before close");
    assert_eq!(code(&r), Some("malformed_request"), "{r:?}");
    assert_eq!(victim.read_json(), None, "EOF after corrupt length prefix");

    wait_for_metric(&mut survivor, "disconnects", 1);
    assert!(ok(&survivor.request(&step_line(&[0, 1]))));
    drop(survivor);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A client dropping mid-frame (length promised, bytes never sent) is a
/// clean disconnect: nothing executes, peers keep serving.
#[test]
fn truncated_binary_frame_disconnect_is_clean() {
    let net_path = temp_hsn("bintrunc");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut survivor = Client::connect(server.addr);
    survivor.hello();
    assert!(ok(&survivor.request(&configure_line(&net_path))));

    {
        let mut half = Client::connect(server.addr);
        half.hello();
        assert!(ok(&half.request(&configure_binary_line(&net_path))));
        // promise a 100-byte frame, deliver 5, vanish
        let mut partial = vec![frames::WIRE_SENTINEL];
        partial.extend_from_slice(&100u32.to_le_bytes());
        partial.extend_from_slice(&[frames::FRAME_STIM, 1, 2, 3, 4]);
        half.stream.write_all(&partial).unwrap();
        half.stream.flush().unwrap();
    }

    wait_for_metric(&mut survivor, "disconnects", 1);
    let m = survivor.request("{\"op\":\"metrics\"}");
    assert_eq!(m.get("steps_total").and_then(Json::as_i64), Some(0), "{m:?}");
    assert!(ok(&survivor.request(&step_line(&[0]))));
    drop(survivor);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}

/// A binary frame before `"wire":"binary"` was negotiated answers
/// `malformed_request`; the session stays on the JSON wire and keeps
/// working.
#[test]
fn frame_before_negotiation_is_malformed_and_json_still_works() {
    let net_path = temp_hsn("binnoneg");
    write_hsn(&fig6_net(), &net_path).unwrap();
    let server = start_server(ServeLimits::default());

    let mut c = Client::connect(server.addr);
    c.hello();
    // plain JSON configure: binary was never negotiated
    let conf = c.request(&configure_line(&net_path));
    assert!(ok(&conf), "{conf:?}");
    assert_eq!(conf.get("wire").and_then(Json::as_str), Some("json"), "{conf:?}");

    c.send_frame(frames::FRAME_STIM, &frames::encode_stim(&[vec![0]]));
    let r = c.read_json().expect("malformed line for unnegotiated frame");
    assert_eq!(code(&r), Some("malformed_request"), "{r:?}");

    assert!(ok(&c.request(&step_line(&[0, 1]))));
    drop(c);
    server.stop();
    std::fs::remove_file(&net_path).ok();
}
