//! Failure-injection and robustness tests: corrupted inputs, capacity
//! violations, malformed files and job-level fault isolation must produce
//! errors, never wrong results or panics.

use hiaer_spike::cluster::{parse_stimulus, run_job, CorePool, Job, JobQueue, JobStatus, PoolOptions};
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::engine::{sweep_chunk, CoreParams, UpdateBackend};
use hiaer_spike::hbm::{HbmImage, Pointer, SlotStrategy};
use hiaer_spike::model_fmt::{hsl::read_hsl, read_hsd, read_hsn, write_hsn};
use hiaer_spike::partition::{ClusterTopology, CoreCapacity, Partition};
use hiaer_spike::runtime::{ArtifactRegistry, Runtime};
use hiaer_spike::sim::SimOptions;
use hiaer_spike::snn::{Network, NeuronModel, Synapse};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("hiaer_fi_{}_{name}", std::process::id()))
}

fn tiny_net() -> Network {
    Network::from_adj(
        vec![NeuronModel::if_neuron(0); 3],
        &[vec![Synapse { target: 1, weight: 1 }], vec![], vec![]],
        &[vec![Synapse { target: 0, weight: 1 }]],
        vec![1],
        0,
    )
}

#[test]
fn truncated_hsn_rejected() {
    let p = tmp("trunc.hsn");
    write_hsn(&tiny_net(), &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    for cut in [4usize, 9, 20, bytes.len() - 3] {
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(read_hsn(&p).is_err(), "truncation at {cut} must fail");
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn random_garbage_files_rejected_not_panicking() {
    let p = tmp("garbage");
    for seed in 0..20u8 {
        let blob: Vec<u8> = (0..200).map(|i| (i as u8).wrapping_mul(seed + 7)).collect();
        std::fs::write(&p, &blob).unwrap();
        assert!(read_hsn(&p).is_err());
        assert!(read_hsl(&p).is_err());
        assert!(read_hsd(&p).is_err());
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn invalid_network_rejected_by_hbm_compiler() {
    let mut net = tiny_net();
    net.syn_targets[0] = 99; // OOB target in the CSR array
    assert!(HbmImage::compile(&net, SlotStrategy::Modulo).is_err());
}

#[test]
fn partitioner_rejects_impossible_capacity() {
    let net = tiny_net();
    let cap = CoreCapacity { max_neurons: 1, max_synapses: usize::MAX };
    let topo = ClusterTopology::single_core();
    assert!(Partition::compute(&net, topo, cap).is_err());
}

#[test]
fn job_failure_is_isolated_and_reported() {
    let good = tmp("good.hsn");
    write_hsn(&tiny_net(), &good).unwrap();
    let q = JobQueue::start(2, EnergyModel::default());
    // interleave good and bad jobs
    for id in 0..8 {
        q.submit(Job {
            id,
            net_path: if id % 2 == 0 { good.clone() } else { tmp("missing.hsn") },
            stimulus: vec![vec![0], vec![]],
            options: SimOptions::default(),
        });
    }
    let results = q.drain();
    q.shutdown();
    std::fs::remove_file(&good).ok();
    assert_eq!(results.len(), 8);
    for r in results {
        if r.id % 2 == 0 {
            assert_eq!(r.status, JobStatus::Done, "good job {} must succeed", r.id);
        } else {
            assert!(matches!(r.status, JobStatus::Failed(_)));
        }
    }
}

#[test]
fn stimulus_parser_rejects_bad_tokens_and_handles_comments() {
    assert!(parse_stimulus("1 2 x").is_err());
    assert!(parse_stimulus("-4").is_err());
    let s = parse_stimulus("# header\n3 3 1\n").unwrap();
    assert_eq!(s, vec![vec![1, 3]]); // sorted + deduped
}

#[test]
fn stimulus_axon_out_of_range_fails_job() {
    let p = tmp("oorjob.hsn");
    write_hsn(&tiny_net(), &p).unwrap();
    let job = Job {
        id: 0,
        net_path: p.clone(),
        stimulus: vec![vec![42]], // only 1 axon exists
        options: SimOptions::default(),
    };
    let r = run_job(&job, &EnergyModel::default());
    std::fs::remove_file(&p).ok();
    assert!(matches!(r.status, JobStatus::Failed(_)) || r.spikes.is_empty());
}

/// A backend whose membrane sweep is the honest pure reference kernel
/// (so the pool takes the chunk-parallel paths) but whose route phase
/// is booby-trapped: `gather` or `accumulate` panics on demand.
#[derive(Clone, Copy, Debug)]
struct RoutePanicBackend {
    panic_in_gather: bool,
    panic_in_accumulate: bool,
}

impl UpdateBackend for RoutePanicBackend {
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> anyhow::Result<()> {
        let n = v.len();
        sweep_chunk(v, params.slice(0, n), step_seed, spikes, 0);
        Ok(())
    }

    fn gather(&self, image: &HbmImage, ptr: Pointer, out: &mut Vec<(u32, i32)>) {
        if self.panic_in_gather {
            panic!("injected gather panic");
        }
        image.scan_region(ptr, |e| out.push((e.target, e.weight as i32)));
    }

    fn accumulate(&mut self, _v: &mut [i32], _events: &[(u32, i32)]) -> anyhow::Result<()> {
        if self.panic_in_accumulate {
            panic!("injected accumulate panic");
        }
        Ok(())
    }

    fn chunkable(&self) -> bool {
        true // update IS the pure sweep_chunk reference kernel
    }

    fn name(&self) -> &'static str {
        "route-panic"
    }
}

/// Drive a two-core pool of `RoutePanicBackend`s through one poisoned
/// step and assert the PR-2 panic guarantee now extends to the
/// chunk-parallel Route phase: the phase error is surfaced (not a
/// hang), the pool stays usable for a following quiet step, and `Drop`
/// terminates cleanly.
fn route_panic_scenario(backend: RoutePanicBackend, expect: &str) {
    let nets: Vec<Network> = (0..2).map(|_| tiny_net()).collect();
    let mut pool = CorePool::with_backend_for_tests(&nets, backend, PoolOptions::default())
        .expect("pool construction");
    pool.phase_update().unwrap();
    // axon 0 fires into both cores -> at least one gather chunk each ->
    // the injected panic trips inside the parallel route machinery
    let err = pool
        .phase_route(&[vec![0u32], vec![0u32]])
        .expect_err("injected panic must surface as a phase error")
        .to_string();
    assert!(err.contains("panicked"), "{err}");
    assert!(err.contains(expect), "{err}");
    // the pool survives: a quiet step (no fired sources -> no gather
    // chunks, empty accumulate input) completes normally
    pool.phase_update().unwrap();
    pool.phase_route(&[vec![], vec![]]).unwrap();
    drop(pool); // must not hang on a dead worker
}

#[test]
fn route_gather_panic_is_surfaced_and_pool_survives() {
    route_panic_scenario(
        RoutePanicBackend { panic_in_gather: true, panic_in_accumulate: false },
        "injected gather panic",
    );
}

#[test]
fn route_accumulate_panic_is_surfaced_and_pool_survives() {
    route_panic_scenario(
        RoutePanicBackend { panic_in_gather: false, panic_in_accumulate: true },
        "injected accumulate panic",
    );
}

#[test]
fn runtime_missing_artifact_is_clean_error() {
    let dir = tmp("no_artifacts_dir");
    std::fs::create_dir_all(&dir).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    match rt.load("neuron_update_n1024") {
        Ok(_) => panic!("loading a missing artifact must fail"),
        Err(err) => assert!(format!("{err:#}").contains("neuron_update_n1024")),
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_refuses_oversized_core() {
    assert!(ArtifactRegistry::for_core(10_000_000).is_none());
}

#[test]
fn corrupted_hlo_text_is_clean_error() {
    let dir = tmp("bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule not really hlo {{{").unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    assert!(rt.load("broken").is_err());
    std::fs::remove_dir_all(&dir).ok();
}
