//! HiAER-Spike: a software/hardware reconfigurable platform for event-driven
//! neuromorphic computing at scale — full-system reproduction on a simulated
//! FPGA substrate.
//!
//! **Start at [`sim`]** — the hardware-agnostic facade. A network is
//! executed by building a [`sim::SimConfig`] (topology, HBM strategy,
//! backend, seed) and driving the boxed [`sim::Simulator`] it returns;
//! every engine below is reached through it and their constructors are
//! crate-private.
//!
//! The crate is organised as the paper's stack:
//!
//! * [`sim`] — the unified `Simulator` session API: one backend-neutral
//!   `step`/`step_many`/`run`/`run_many` surface over dense /
//!   event-driven / pooled / clustered / XLA execution (paper §5's
//!   "interface agnostic to hardware-level detail"), plus
//!   [`sim::session`], the line-delimited JSON protocol that the Python
//!   `hs_api` front end (`backend="rust"`) speaks to it via
//!   `hiaer-spike serve-session`.
//! * [`snn`] — network model primitives (axons, neurons, neuron models,
//!   synapses) mirroring the `hs_api` Python interface; connectivity is
//!   stored CSR (flat target/weight arrays + offset tables).
//! * [`hbm`] — the per-core HBM synaptic routing table simulator
//!   (16-slot segments, alignment-aware packing, access counting).
//! * [`engine`] — single-core execution engines ("grey matter"): the
//!   two-phase event-driven core and the dense-matrix golden model,
//!   plus the pluggable membrane-update backend kernels.
//! * [`plasticity`] — the opt-in pair-based STDP learning kernel
//!   (eligibility traces as a branch-free extension of the membrane
//!   sweep, weight updates in the route epilogue) — runtime plasticity
//!   with bit-identical results across worker/shard counts.
//! * [`router`] — hierarchical address-event routing between cores, FPGAs
//!   and servers ("white matter", HiAER levels: NoC / FireFly / Ethernet).
//! * [`partition`] — network partitioning and resource allocation across
//!   the cluster.
//! * [`convert`] — PyTorch-style layer-graph → HiAER-Spike network
//!   conversion (Supplementary A.2) and the inference runner.
//! * [`runtime`] — PJRT/XLA runtime that loads the AOT-compiled JAX/Pallas
//!   artifacts (behind the `pjrt` cargo feature; default builds compile
//!   an offline stub).
//! * [`cluster`] — multi-core / multi-FPGA / multi-server orchestration,
//!   the persistent worker pool, job queue and NSG-portal-like front end.
//! * [`harness`] — trained-model manifest loading and Table-2 style
//!   evaluation on top of the facade.
//! * [`energy`] — HBM-access energy and clock-cycle latency model.
//! * [`util`] — substrate utilities written in-repo because the build is
//!   fully offline (PRNG, JSON, CLI parsing, property testing).

pub mod cluster;
pub mod convert;
pub mod harness;
pub mod energy;
pub mod engine;
pub mod hbm;
pub mod metrics;
pub mod model_fmt;
pub mod partition;
pub mod plasticity;
pub mod router;
pub mod runtime;
pub mod sim;
pub mod snn;
pub mod util;
