//! HiAER-Spike: a software/hardware reconfigurable platform for event-driven
//! neuromorphic computing at scale — full-system reproduction on a simulated
//! FPGA substrate.
//!
//! The crate is organised as the paper's stack:
//!
//! * [`snn`] — network model primitives (axons, neurons, neuron models,
//!   synapses) mirroring the `hs_api` Python interface; connectivity is
//!   stored CSR (flat target/weight arrays + offset tables).
//! * [`hbm`] — the per-core HBM synaptic routing table simulator
//!   (16-slot segments, alignment-aware packing, access counting).
//! * [`engine`] — single-core two-phase event-driven execution engine
//!   ("grey matter").
//! * [`router`] — hierarchical address-event routing between cores, FPGAs
//!   and servers ("white matter", HiAER levels: NoC / FireFly / Ethernet).
//! * [`partition`] — network partitioning and resource allocation across
//!   the cluster.
//! * [`convert`] — PyTorch-style layer-graph → HiAER-Spike network
//!   conversion (Supplementary A.2).
//! * [`runtime`] — PJRT/XLA runtime that loads the AOT-compiled JAX/Pallas
//!   artifacts and executes the neuron-update hot path.
//! * [`cluster`] — multi-core / multi-FPGA / multi-server orchestration,
//!   job queue and NSG-portal-like front end.
//! * [`energy`] — HBM-access energy and clock-cycle latency model.
//! * [`util`] — substrate utilities written in-repo because the build is
//!   fully offline (PRNG, JSON, CLI parsing, property testing).

pub mod cluster;
pub mod convert;
pub mod harness;
pub mod energy;
pub mod engine;
pub mod hbm;
pub mod metrics;
pub mod model_fmt;
pub mod partition;
pub mod router;
pub mod runtime;
pub mod snn;
pub mod util;
