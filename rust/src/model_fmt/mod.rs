//! Serialized model/network formats shared with the Python build path.
//!
//! * [`hsn`] — flattened networks (`.hsn`): written by
//!   `hs_api.network.CRI_network.export_hsn` (and by Rust for
//!   round-trips), compiled by the coordinator into HBM images.
//! * [`hsl`] — trained layer graphs (`.hsl`): written by the Python
//!   training pipeline (`python/train/export.py`); converted to networks
//!   by [`crate::convert`] (Supp A.2).
//! * [`golden`] — loaders for the `artifacts/golden/*.json` cross-language
//!   test vectors.

pub mod golden;
pub mod hsl;
pub mod hsn;
pub mod netfile;

pub use hsl::{Layer, LayerGraph, NeuronKind};
pub use hsn::{
    hsn_v2_bytes, hsn_v2_bytes_quantized, read_hsn, write_hsn, write_hsn_v1, HsnError,
    HSN_MAGIC, HSN_MAGIC_V2,
};
pub use netfile::{open_netfile, NetCache, NetFile};

use std::io::{self, Read};

/// Little-endian primitive readers over any `Read`.
pub(crate) struct Reader<R: Read> {
    inner: R,
}

impl<R: Read> Reader<R> {
    pub fn new(inner: R) -> Self {
        Self { inner }
    }

    pub fn u8(&mut self) -> io::Result<u8> {
        let mut b = [0u8; 1];
        self.inner.read_exact(&mut b)?;
        Ok(b[0])
    }

    pub fn u32(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn i32(&mut self) -> io::Result<i32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(i32::from_le_bytes(b))
    }

    pub fn i16(&mut self) -> io::Result<i16> {
        let mut b = [0u8; 2];
        self.inner.read_exact(&mut b)?;
        Ok(i16::from_le_bytes(b))
    }

    pub fn magic(&mut self, expect: &[u8; 8]) -> io::Result<()> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        if &b != expect {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad magic {:?}, expected {:?}", b, expect),
            ));
        }
        Ok(())
    }

    /// Bulk-read `count` i16 values.
    pub fn i16_vec(&mut self, count: usize) -> io::Result<Vec<i16>> {
        let mut bytes = vec![0u8; count * 2];
        self.inner.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    pub fn i32_vec(&mut self, count: usize) -> io::Result<Vec<i32>> {
        let mut bytes = vec![0u8; count * 4];
        self.inner.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Little-endian primitive writers.
pub(crate) struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    #[allow(dead_code)] // used by the format tests' handwritten blobs
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i16(&mut self, v: i16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}
pub mod testset;
pub use testset::{read_hsd, Sample, TestSet};
