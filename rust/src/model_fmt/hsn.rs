//! `.hsn` flattened-network format.
//!
//! Layout (little-endian), mirrored by `hs_api.network.export_hsn`:
//!
//! ```text
//! magic    8B  "HSNET1\0\0"
//! header   u32 n_axons, u32 n_neurons, u32 n_outputs, u32 reserved,
//!          i32 base_seed
//! params   n_neurons x (i32 theta, i32 nu, i32 lam, i32 flags)
//! neurons  per neuron: u32 count, count x (u32 target, i16 weight)
//! axons    per axon:   u32 count, count x (u32 target, i16 weight)
//! outputs  n_outputs x u32
//! ```
//!
//! Both writers emit each per-source region in **canonical
//! target-sorted order** (`Network::sort_synapses` here, the sorted
//! `pack_adj` in `hs_api.network.export_hsn`), so the same network
//! produces identical bytes from either language —
//! `testdata/fig6_golden.hsn` pins this cross-language
//! (`rust/tests/hsn_golden.rs` / `python/tests/test_golden_hsn.py`).

use std::fs::File;
use std::io::{BufReader, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Reader, Writer};
use crate::snn::{Network, NeuronModel};

pub const HSN_MAGIC: &[u8; 8] = b"HSNET1\x00\x00";

pub fn read_hsn<P: AsRef<Path>>(path: P) -> Result<Network> {
    let f = File::open(&path)
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = Reader::new(BufReader::new(f));
    r.magic(HSN_MAGIC)?;
    let a = r.u32()? as usize;
    let n = r.u32()? as usize;
    let n_out = r.u32()? as usize;
    let _reserved = r.u32()?;
    let base_seed = r.i32()? as u32;

    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let theta = r.i32()?;
        let nu = r.i32()?;
        let lam = r.i32()?;
        let flags = r.i32()?;
        params.push(NeuronModel { theta, nu, lam, flags: flags as u32 });
    }

    // The on-disk order (per-neuron regions, then per-axon regions, each
    // prefixed with its count) is exactly the CSR layout — stream the
    // synapse entries straight into the flat arrays, no nested Vecs.
    let mut syn_targets: Vec<u32> = Vec::new();
    let mut syn_weights: Vec<i16> = Vec::new();
    let mut neuron_off: Vec<u32> = Vec::with_capacity(n + 1);
    let mut axon_off: Vec<u32> = Vec::with_capacity(a + 1);
    neuron_off.push(0);
    if n == 0 {
        axon_off.push(0); // empty neuron section: axon regions start at 0
    }
    for source in 0..n + a {
        let deg = r.u32()? as usize;
        for _ in 0..deg {
            let target = r.u32()?;
            let weight = r.i16()?;
            if target as usize >= n {
                bail!("synapse target {target} out of range ({n} neurons)");
            }
            syn_targets.push(target);
            syn_weights.push(weight);
        }
        let end = syn_targets.len() as u32;
        if source < n {
            neuron_off.push(end);
            if source + 1 == n {
                axon_off.push(end); // axon regions start where neurons end
            }
        } else {
            axon_off.push(end);
        }
    }

    let mut outputs = Vec::with_capacity(n_out);
    for _ in 0..n_out {
        let o = r.u32()?;
        if o as usize >= n {
            bail!("output {o} out of range");
        }
        outputs.push(o);
    }

    let mut net =
        Network { params, syn_targets, syn_weights, neuron_off, axon_off, outputs, base_seed };
    net.sort_synapses();
    net.validate().map_err(|e| anyhow::anyhow!("invalid .hsn: {e}"))?;
    Ok(net)
}

pub fn write_hsn<P: AsRef<Path>>(net: &Network, path: P) -> Result<()> {
    let mut w = Writer::new();
    w.buf.extend_from_slice(HSN_MAGIC);
    w.u32(net.n_axons() as u32);
    w.u32(net.n_neurons() as u32);
    w.u32(net.outputs.len() as u32);
    w.u32(0);
    w.i32(net.base_seed as i32);
    for p in &net.params {
        w.i32(p.theta);
        w.i32(p.nu);
        w.i32(p.lam);
        w.i32(p.flags as i32);
    }
    for source in 0..net.n_neurons() + net.n_axons() {
        let (tg, wt) = if source < net.n_neurons() {
            net.neuron_syns(source)
        } else {
            net.axon_syns(source - net.n_neurons())
        };
        w.u32(tg.len() as u32);
        for (&t, &wgt) in tg.iter().zip(wt) {
            w.u32(t);
            w.i16(wgt);
        }
    }
    for &o in &net.outputs {
        w.u32(o);
    }
    let mut f = File::create(&path)
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(&w.buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::NetworkBuilder;
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn temp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hiaer_test_{}_{name}", std::process::id()));
        p
    }

    fn sample_net(seed: u32) -> Network {
        let mut rng = Xorshift32::new(seed);
        let m1 = NeuronModel::if_neuron(rng.range_i32(1, 100));
        let m2 = NeuronModel::ann(rng.range_i32(1, 50), -3, true).unwrap();
        let mut b = NetworkBuilder::new().seed(seed);
        let n = 20 + rng.below(40) as usize;
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let deg = rng.below(8) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-99, 99)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], if i % 2 == 0 { m1 } else { m2 }, &refs).unwrap();
        }
        b.add_axon("in0", &[("n0", 4), ("n1", -4)]).unwrap();
        b.add_output("n0");
        b.build().unwrap().0
    }

    #[test]
    fn roundtrip_exact() {
        let net = sample_net(42);
        let p = temp_path("roundtrip.hsn");
        write_hsn(&net, &p).unwrap();
        let got = read_hsn(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(got.params, net.params);
        assert_eq!(got.syn_targets, net.syn_targets);
        assert_eq!(got.syn_weights, net.syn_weights);
        assert_eq!(got.neuron_off, net.neuron_off);
        assert_eq!(got.axon_off, net.axon_off);
        assert_eq!(got.outputs, net.outputs);
        assert_eq!(got.base_seed, net.base_seed);
    }

    #[test]
    fn prop_roundtrip_random_networks() {
        ptest::check("hsn_roundtrip", 20, |rng| {
            let net = sample_net(rng.next_u32());
            let p = temp_path(&format!("prop_{}.hsn", rng.next_u32()));
            write_hsn(&net, &p).map_err(|e| e.to_string())?;
            let got = read_hsn(&p).map_err(|e| e.to_string())?;
            std::fs::remove_file(&p).ok();
            ptest::prop_assert_eq(got.params, net.params, "params")?;
            ptest::prop_assert_eq(got.syn_targets, net.syn_targets, "syn_targets")?;
            ptest::prop_assert_eq(got.syn_weights, net.syn_weights, "syn_weights")?;
            ptest::prop_assert_eq(got.neuron_off, net.neuron_off, "neuron_off")?;
            ptest::prop_assert_eq(got.axon_off, net.axon_off, "axon_off")?;
            Ok(())
        });
    }

    #[test]
    fn rejects_bad_magic() {
        let p = temp_path("bad.hsn");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(read_hsn(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_out_of_range_target() {
        let net = sample_net(1);
        let p = temp_path("oor.hsn");
        write_hsn(&net, &p).unwrap();
        // corrupt a synapse target beyond n
        let mut bytes = std::fs::read(&p).unwrap();
        // first adjacency count is at 8 + 20 + 16n; find first nonzero count
        let n = net.n_neurons();
        let mut off = 28 + 16 * n;
        loop {
            let cnt = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
            if cnt > 0 {
                bytes[off..off + 4].copy_from_slice(&(n as u32 + 9).to_le_bytes());
                break;
            }
        }
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_hsn(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
