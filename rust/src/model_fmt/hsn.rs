//! `.hsn` flattened-network format: v1 (streamed, count-prefixed) and
//! v2 (sectioned, mmap-able, zero-copy).
//!
//! Both versions are little-endian and mirrored byte-for-byte by
//! `hs_api.network.export_hsn`; `testdata/fig6_golden.hsn` (v1) and
//! `testdata/fig6_golden_v2.hsn` pin the cross-language contract
//! (`rust/tests/hsn_golden.rs` / `python/tests/test_golden_hsn.py`).
//!
//! # v2 on-disk layout (`HSNET2`) — the default write format
//!
//! A 32-byte header, a table of contents, then the CSR arrays stored
//! contiguously in file order. Loading is `mmap` + bounds/alignment
//! validation + reinterpret: zero per-synapse parsing, and a shard can
//! map only the byte range its offset-table slice covers
//! (see [`crate::model_fmt::NetFile`]).
//!
//! ```text
//! offset  size  field
//! 0       8     magic "HSNET2\0\0"
//! 8       4     u32 n_axons
//! 12      4     u32 n_neurons
//! 16      4     u32 n_outputs
//! 20      4     u32 n_sections
//! 24      4     i32 base_seed
//! 28      4     u32 reserved (0)
//! 32      24*k  table of contents: k = n_sections entries
//! ...           section payloads, each starting at the next 8-byte
//!               boundary (zero padding between), in TOC order
//! ```
//!
//! Each TOC entry is 24 bytes: `u32 id, u32 aux, u64 offset, u64 len`
//! (`offset` absolute from the file start, `len` exact payload bytes,
//! `aux` section-specific — 0 unless noted). Entries are listed in
//! ascending file order; every `offset` is a multiple of 8; payloads
//! never overlap. Unknown section ids are skipped by readers (forward
//! compatibility); the canonical writer emits ids in ascending order:
//!
//! | id | section     | payload                                        |
//! |----|-------------|------------------------------------------------|
//! | 1  | PARAMS      | n_neurons x (i32 theta, i32 nu, i32 lam, u32 flags) — `[NeuronModel]` verbatim |
//! | 2  | NEURON_OFF  | (n_neurons + 1) x u32 CSR offsets              |
//! | 3  | AXON_OFF    | (n_axons + 1) x u32 CSR offsets                |
//! | 4  | SYN_TARGETS | E x u32 flat synapse targets                   |
//! | 5  | SYN_WEIGHTS | E x i16 flat synapse weights                   |
//! | 6  | OUTPUTS     | n_outputs x u32 monitored neuron ids           |
//! | 7  | QWEIGHTS    | f32 scale, then E x i8 quantized codes; `aux` = bits (2..=8). Replaces SYN_WEIGHTS. |
//!
//! `E` (the synapse count) is `SYN_TARGETS.len / 4` and must equal the
//! last `AXON_OFF` entry. Exactly one of SYN_WEIGHTS / QWEIGHTS is
//! present. Per-source regions must already be in canonical
//! target-sorted order — v2 readers **validate** sortedness and reject
//! unsorted files ([`HsnError::Unsorted`]) instead of re-sorting.
//!
//! ## Quantized weights (QWEIGHTS)
//!
//! Weights quantized to `bits`-bit signed codes with one global scale
//! (the dynamic-alpha scheme of `python/train/qat.py`):
//! `scale = max|w| / (2^(bits-1) - 1)` (1.0 for an all-zero net),
//! `code = round(w / scale)`, stored as one i8 each. Readers
//! reconstruct `w = clamp(round(code * scale))` into an owned i16
//! buffer (offsets/targets stay zero-copy). Lossy by design — the
//! fig5 accuracy-vs-bits sweep measures the cost.
//!
//! # v1 layout (`HSNET1`) — legacy, still read
//!
//! ```text
//! magic    8B  "HSNET1\0\0"
//! header   u32 n_axons, u32 n_neurons, u32 n_outputs, u32 reserved,
//!          i32 base_seed
//! params   n_neurons x (i32 theta, i32 nu, i32 lam, i32 flags)
//! neurons  per neuron: u32 count, count x (u32 target, i16 weight)
//! axons    per axon:   u32 count, count x (u32 target, i16 weight)
//! outputs  n_outputs x u32
//! ```
//!
//! v1 requires a full streaming parse into freshly allocated CSR
//! arrays. Writers of either version emit canonical target-sorted
//! per-source regions; the v1 reader validates sortedness and falls
//! back to re-sorting only for legacy files that predate the canonical
//! contract.

use std::fs::File;
use std::io::{BufReader, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};
use thiserror::Error;

use super::{Reader, Writer};
use crate::snn::{NetView, Network, NeuronModel};

pub const HSN_MAGIC: &[u8; 8] = b"HSNET1\x00\x00";
pub const HSN_MAGIC_V2: &[u8; 8] = b"HSNET2\x00\x00";

/// v2 section ids (see the module docs' section table).
pub mod sec {
    pub const PARAMS: u32 = 1;
    pub const NEURON_OFF: u32 = 2;
    pub const AXON_OFF: u32 = 3;
    pub const SYN_TARGETS: u32 = 4;
    pub const SYN_WEIGHTS: u32 = 5;
    pub const OUTPUTS: u32 = 6;
    pub const QWEIGHTS: u32 = 7;
}

/// Header + TOC sizes (bytes).
pub(crate) const V2_HEADER_BYTES: usize = 32;
pub(crate) const V2_TOC_ENTRY_BYTES: usize = 24;
/// TOC sanity cap — far above any defined section count, low enough
/// that a corrupt header cannot demand a huge TOC read.
const V2_MAX_SECTIONS: u32 = 64;

/// Typed `.hsn` v2 validation errors. Every malformed input maps to one
/// of these — never a panic or an out-of-bounds reinterpret.
#[derive(Debug, Error)]
pub enum HsnError {
    #[error("I/O error on .hsn file: {0}")]
    Io(#[from] std::io::Error),
    #[error("bad .hsn magic {found:?} (expected HSNET1/HSNET2)")]
    BadMagic { found: [u8; 8] },
    #[error(".hsn truncated: need {need} bytes, file has {have}")]
    Truncated { need: u64, have: u64 },
    #[error("malformed .hsn header: {0}")]
    BadHeader(String),
    #[error("section {id}: offset {offset} not 8-byte aligned")]
    Misaligned { id: u32, offset: u64 },
    #[error("section {id} at offset {offset} overlaps the previous section or is out of TOC order")]
    Overlap { id: u32, offset: u64 },
    #[error("duplicate section id {0}")]
    DuplicateSection(u32),
    #[error("missing required section id {0}")]
    MissingSection(u32),
    #[error("section {id}: length {got} bytes, expected {expect}")]
    BadSectionLen { id: u32, expect: u64, got: u64 },
    #[error("bad quantized-weight encoding: {0}")]
    BadQuant(String),
    #[error("invalid network structure: {0}")]
    Invalid(String),
    #[error("per-source synapse regions not target-sorted (v2 requires canonical order)")]
    Unsorted,
}

// ---- v1 ------------------------------------------------------------------

fn read_hsn_v1<P: AsRef<Path>>(path: P) -> Result<Network> {
    let f = File::open(&path)
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = Reader::new(BufReader::new(f));
    r.magic(HSN_MAGIC)?;
    let a = r.u32()? as usize;
    let n = r.u32()? as usize;
    let n_out = r.u32()? as usize;
    let _reserved = r.u32()?;
    let base_seed = r.i32()? as u32;

    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let theta = r.i32()?;
        let nu = r.i32()?;
        let lam = r.i32()?;
        let flags = r.i32()?;
        params.push(NeuronModel { theta, nu, lam, flags: flags as u32 });
    }

    // The on-disk order (per-neuron regions, then per-axon regions, each
    // prefixed with its count) is exactly the CSR layout — stream the
    // synapse entries straight into the flat arrays, no nested Vecs.
    let mut syn_targets: Vec<u32> = Vec::new();
    let mut syn_weights: Vec<i16> = Vec::new();
    let mut neuron_off: Vec<u32> = Vec::with_capacity(n + 1);
    let mut axon_off: Vec<u32> = Vec::with_capacity(a + 1);
    neuron_off.push(0);
    if n == 0 {
        axon_off.push(0); // empty neuron section: axon regions start at 0
    }
    for source in 0..n + a {
        let deg = r.u32()? as usize;
        for _ in 0..deg {
            let target = r.u32()?;
            let weight = r.i16()?;
            if target as usize >= n {
                bail!("synapse target {target} out of range ({n} neurons)");
            }
            syn_targets.push(target);
            syn_weights.push(weight);
        }
        let end = syn_targets.len() as u32;
        if source < n {
            neuron_off.push(end);
            if source + 1 == n {
                axon_off.push(end); // axon regions start where neurons end
            }
        } else {
            axon_off.push(end);
        }
    }

    let mut outputs = Vec::with_capacity(n_out);
    for _ in 0..n_out {
        let o = r.u32()?;
        if o as usize >= n {
            bail!("output {o} out of range");
        }
        outputs.push(o);
    }

    let mut net =
        Network { params, syn_targets, syn_weights, neuron_off, axon_off, outputs, base_seed };
    // Writers emit canonical target-sorted regions; validate instead of
    // unconditionally re-sorting (O(E) scan vs O(E log E) sort on every
    // cold start). The sort survives only as the legacy fallback for
    // pre-canonical v1 files.
    if !net.view().is_sorted() {
        net.sort_synapses();
    }
    net.validate().map_err(|e| anyhow::anyhow!("invalid .hsn: {e}"))?;
    Ok(net)
}

/// Write `net` in the **v1** format (legacy interchange; see module
/// docs). New code should prefer [`write_hsn`] (v2).
pub fn write_hsn_v1<'a, P: AsRef<Path>>(net: impl Into<NetView<'a>>, path: P) -> Result<()> {
    let net: NetView<'_> = net.into();
    let mut w = Writer::new();
    w.buf.extend_from_slice(HSN_MAGIC);
    w.u32(net.n_axons() as u32);
    w.u32(net.n_neurons() as u32);
    w.u32(net.outputs.len() as u32);
    w.u32(0);
    w.i32(net.base_seed as i32);
    for p in net.params {
        w.i32(p.theta);
        w.i32(p.nu);
        w.i32(p.lam);
        w.i32(p.flags as i32);
    }
    for source in 0..net.n_neurons() + net.n_axons() {
        let (tg, wt) = if source < net.n_neurons() {
            net.neuron_syns(source)
        } else {
            net.axon_syns(source - net.n_neurons())
        };
        w.u32(tg.len() as u32);
        for (&t, &wgt) in tg.iter().zip(wt) {
            w.u32(t);
            w.i16(wgt);
        }
    }
    for &o in net.outputs {
        w.u32(o);
    }
    let mut f = File::create(&path)
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(&w.buf)?;
    Ok(())
}

// ---- v2 writer -----------------------------------------------------------

fn align8(off: usize) -> usize {
    off.next_multiple_of(8)
}

/// Serialize a network to the canonical v2 byte image (see module docs;
/// identical to `hs_api.network.export_hsn(version=2)`).
pub fn hsn_v2_bytes<'a>(net: impl Into<NetView<'a>>) -> Vec<u8> {
    v2_bytes_with_weights(net.into(), None)
}

/// v2 bytes with the weights quantized to `bits`-bit codes (QWEIGHTS
/// section, lossy — module docs). `bits` must be in `2..=8`.
pub fn hsn_v2_bytes_quantized<'a>(
    net: impl Into<NetView<'a>>,
    bits: u32,
) -> Result<Vec<u8>, HsnError> {
    if !(2..=8).contains(&bits) {
        return Err(HsnError::BadQuant(format!("bits {bits} outside 2..=8")));
    }
    let net: NetView<'_> = net.into();
    let (scale, codes) = quantize_weights(net.syn_weights, bits);
    Ok(v2_bytes_with_weights(net, Some((bits, scale, codes))))
}

/// One global scale + per-synapse signed codes for `bits`-bit storage.
pub(crate) fn quantize_weights(weights: &[i16], bits: u32) -> (f32, Vec<i8>) {
    let qmax = (1i32 << (bits - 1)) - 1;
    let wmax = weights.iter().map(|&w| (w as i32).abs()).max().unwrap_or(0);
    let scale = if wmax == 0 { 1.0f32 } else { wmax as f32 / qmax as f32 };
    let codes = weights
        .iter()
        .map(|&w| (w as f32 / scale).round().clamp(-(qmax as f32), qmax as f32) as i8)
        .collect();
    (scale, codes)
}

/// Reconstruct i16 weights from quantized codes (reader side).
pub(crate) fn dequantize_weights(codes: &[i8], scale: f32) -> Vec<i16> {
    codes
        .iter()
        .map(|&q| (q as f32 * scale).round().clamp(i16::MIN as f32, i16::MAX as f32) as i16)
        .collect()
}

fn v2_bytes_with_weights(net: NetView<'_>, quant: Option<(u32, f32, Vec<i8>)>) -> Vec<u8> {
    // payloads in canonical (ascending-id) order
    let mut params_bytes = Vec::with_capacity(net.params.len() * 16);
    for p in net.params {
        params_bytes.extend_from_slice(&p.theta.to_le_bytes());
        params_bytes.extend_from_slice(&p.nu.to_le_bytes());
        params_bytes.extend_from_slice(&p.lam.to_le_bytes());
        params_bytes.extend_from_slice(&p.flags.to_le_bytes());
    }
    let u32_bytes = |v: &[u32]| -> Vec<u8> { v.iter().flat_map(|x| x.to_le_bytes()).collect() };
    let (weights_id, weights_aux, weights_bytes) = match &quant {
        None => {
            let b: Vec<u8> = net.syn_weights.iter().flat_map(|w| w.to_le_bytes()).collect();
            (sec::SYN_WEIGHTS, 0u32, b)
        }
        Some((bits, scale, codes)) => {
            let mut b = Vec::with_capacity(4 + codes.len());
            b.extend_from_slice(&scale.to_le_bytes());
            b.extend(codes.iter().map(|&c| c as u8));
            (sec::QWEIGHTS, *bits, b)
        }
    };
    let sections: [(u32, u32, Vec<u8>); 6] = [
        (sec::PARAMS, 0, params_bytes),
        (sec::NEURON_OFF, 0, u32_bytes(net.neuron_off)),
        (sec::AXON_OFF, 0, u32_bytes(net.axon_off)),
        (sec::SYN_TARGETS, 0, u32_bytes(net.syn_targets)),
        (weights_id, weights_aux, weights_bytes),
        (sec::OUTPUTS, 0, u32_bytes(net.outputs)),
    ];

    let mut out = Vec::new();
    out.extend_from_slice(HSN_MAGIC_V2);
    out.extend_from_slice(&(net.n_axons() as u32).to_le_bytes());
    out.extend_from_slice(&(net.n_neurons() as u32).to_le_bytes());
    out.extend_from_slice(&(net.outputs.len() as u32).to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    out.extend_from_slice(&(net.base_seed as i32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    debug_assert_eq!(out.len(), V2_HEADER_BYTES);

    // TOC: offsets assigned section-by-section with 8-byte alignment
    let mut off = V2_HEADER_BYTES + sections.len() * V2_TOC_ENTRY_BYTES;
    for (id, aux, payload) in &sections {
        off = align8(off);
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&aux.to_le_bytes());
        out.extend_from_slice(&(off as u64).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        off += payload.len();
    }
    for (_, _, payload) in &sections {
        out.resize(align8(out.len()), 0); // zero padding to the 8B boundary
        out.extend_from_slice(payload);
    }
    out
}

// ---- v2 layout parsing (shared by read_hsn and NetFile) ------------------

/// One resolved section: byte range into the file image.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct SecRange {
    pub off: usize,
    pub len: usize,
}

/// How the weights are stored on disk.
#[derive(Clone, Copy, Debug)]
pub(crate) enum WeightsSec {
    /// SYN_WEIGHTS: plain i16 array (zero-copy eligible).
    Plain(SecRange),
    /// QWEIGHTS: codes byte range (after the leading f32 scale).
    Quant { bits: u32, scale: f32, codes: SecRange },
}

/// Fully validated v2 file layout: header counts + resolved, size- and
/// alignment-checked section ranges. Produced by [`parse_v2`]; the
/// structural CSR checks (offset monotonicity, target ranges,
/// sortedness) run afterwards on the reinterpreted arrays.
#[derive(Clone, Debug)]
pub(crate) struct V2Layout {
    pub n_axons: usize,
    pub n_neurons: usize,
    pub n_outputs: usize,
    pub n_syn: usize,
    pub base_seed: u32,
    pub params: SecRange,
    pub neuron_off: SecRange,
    pub axon_off: SecRange,
    pub syn_targets: SecRange,
    pub weights: WeightsSec,
    pub outputs: SecRange,
}

fn need(bytes: &[u8], upto: usize) -> Result<(), HsnError> {
    if bytes.len() < upto {
        return Err(HsnError::Truncated { need: upto as u64, have: bytes.len() as u64 });
    }
    Ok(())
}

fn le_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
}

fn le_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

/// Parse + validate the v2 header and TOC of a complete file image.
/// Guarantees on success: every returned range is in-bounds, 8-byte
/// aligned at its start, non-overlapping, and its length matches the
/// header counts exactly — so reinterpreting the ranges as typed arrays
/// is safe (no OOB, no misalignment).
pub(crate) fn parse_v2(bytes: &[u8]) -> Result<V2Layout, HsnError> {
    need(bytes, V2_HEADER_BYTES)?;
    if &bytes[..8] != HSN_MAGIC_V2 {
        return Err(HsnError::BadMagic { found: bytes[..8].try_into().unwrap() });
    }
    let n_axons = le_u32(bytes, 8) as usize;
    let n_neurons = le_u32(bytes, 12) as usize;
    let n_outputs = le_u32(bytes, 16) as usize;
    let n_sections = le_u32(bytes, 20);
    let base_seed = le_u32(bytes, 24); // i32 on disk, stored as the bit pattern
    if n_sections == 0 || n_sections > V2_MAX_SECTIONS {
        return Err(HsnError::BadHeader(format!(
            "n_sections {n_sections} outside 1..={V2_MAX_SECTIONS}"
        )));
    }
    let toc_end = V2_HEADER_BYTES + n_sections as usize * V2_TOC_ENTRY_BYTES;
    need(bytes, toc_end)?;

    // walk the TOC: ascending file order, aligned, in-bounds, no overlap
    let mut found: Vec<(u32, u32, SecRange)> = Vec::with_capacity(n_sections as usize);
    let mut prev_end = toc_end as u64;
    for k in 0..n_sections as usize {
        let e = V2_HEADER_BYTES + k * V2_TOC_ENTRY_BYTES;
        let id = le_u32(bytes, e);
        let aux = le_u32(bytes, e + 4);
        let off = le_u64(bytes, e + 8);
        let len = le_u64(bytes, e + 16);
        if off % 8 != 0 {
            return Err(HsnError::Misaligned { id, offset: off });
        }
        if off < prev_end {
            return Err(HsnError::Overlap { id, offset: off });
        }
        let end = off.checked_add(len).ok_or(HsnError::Overlap { id, offset: off })?;
        if end > bytes.len() as u64 {
            return Err(HsnError::Truncated { need: end, have: bytes.len() as u64 });
        }
        prev_end = end;
        if found.iter().any(|&(fid, _, _)| fid == id) {
            return Err(HsnError::DuplicateSection(id));
        }
        found.push((id, aux, SecRange { off: off as usize, len: len as usize }));
    }
    let get = |id: u32| found.iter().find(|&&(fid, _, _)| fid == id).map(|&(_, aux, r)| (aux, r));
    let require = |id: u32| get(id).ok_or(HsnError::MissingSection(id));
    let sized = |id: u32, r: SecRange, expect: usize| -> Result<SecRange, HsnError> {
        if r.len != expect {
            return Err(HsnError::BadSectionLen {
                id,
                expect: expect as u64,
                got: r.len as u64,
            });
        }
        Ok(r)
    };

    let (_, params) = require(sec::PARAMS)?;
    let params = sized(sec::PARAMS, params, n_neurons * 16)?;
    let (_, neuron_off) = require(sec::NEURON_OFF)?;
    let neuron_off = sized(sec::NEURON_OFF, neuron_off, (n_neurons + 1) * 4)?;
    let (_, axon_off) = require(sec::AXON_OFF)?;
    let axon_off = sized(sec::AXON_OFF, axon_off, (n_axons + 1) * 4)?;
    let (_, syn_targets) = require(sec::SYN_TARGETS)?;
    if syn_targets.len % 4 != 0 {
        return Err(HsnError::BadSectionLen {
            id: sec::SYN_TARGETS,
            expect: (syn_targets.len / 4 * 4) as u64,
            got: syn_targets.len as u64,
        });
    }
    let n_syn = syn_targets.len / 4;
    if n_syn > u32::MAX as usize {
        return Err(HsnError::BadHeader(format!("{n_syn} synapses exceed u32 offsets")));
    }
    let (_, outputs) = require(sec::OUTPUTS)?;
    let outputs = sized(sec::OUTPUTS, outputs, n_outputs * 4)?;

    let weights = match (get(sec::SYN_WEIGHTS), get(sec::QWEIGHTS)) {
        (Some(_), Some(_)) => return Err(HsnError::DuplicateSection(sec::QWEIGHTS)),
        (None, None) => return Err(HsnError::MissingSection(sec::SYN_WEIGHTS)),
        (Some((_, r)), None) => WeightsSec::Plain(sized(sec::SYN_WEIGHTS, r, n_syn * 2)?),
        (None, Some((bits, r))) => {
            if !(2..=8).contains(&bits) {
                return Err(HsnError::BadQuant(format!("bits {bits} outside 2..=8")));
            }
            let r = sized(sec::QWEIGHTS, r, 4 + n_syn)?;
            let scale = f32::from_le_bytes(bytes[r.off..r.off + 4].try_into().unwrap());
            if !scale.is_finite() || scale <= 0.0 {
                return Err(HsnError::BadQuant(format!("scale {scale} not finite positive")));
            }
            WeightsSec::Quant { bits, scale, codes: SecRange { off: r.off + 4, len: n_syn } }
        }
    };

    Ok(V2Layout {
        n_axons,
        n_neurons,
        n_outputs,
        n_syn,
        base_seed,
        params,
        neuron_off,
        axon_off,
        syn_targets,
        weights,
        outputs,
    })
}

/// Structural CSR validation shared by both v2 load paths (mmap view and
/// owned decode): [`NetView::validate`] plus the sortedness contract.
pub(crate) fn validate_v2_view(view: &NetView<'_>) -> Result<(), HsnError> {
    view.validate().map_err(HsnError::Invalid)?;
    if !view.is_sorted() {
        return Err(HsnError::Unsorted);
    }
    Ok(())
}

/// Decode a v2 image into an owned [`Network`] (endian-safe byte copy —
/// the explicitly-heap path; [`crate::model_fmt::NetFile`] is the
/// zero-copy one).
pub(crate) fn v2_decode_network(bytes: &[u8]) -> Result<Network, HsnError> {
    let lay = parse_v2(bytes)?;
    let u32s = |r: SecRange| -> Vec<u32> {
        bytes[r.off..r.off + r.len]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    let params: Vec<NeuronModel> = bytes[lay.params.off..lay.params.off + lay.params.len]
        .chunks_exact(16)
        .map(|c| NeuronModel {
            theta: i32::from_le_bytes(c[0..4].try_into().unwrap()),
            nu: i32::from_le_bytes(c[4..8].try_into().unwrap()),
            lam: i32::from_le_bytes(c[8..12].try_into().unwrap()),
            flags: u32::from_le_bytes(c[12..16].try_into().unwrap()),
        })
        .collect();
    let syn_weights = match lay.weights {
        WeightsSec::Plain(r) => bytes[r.off..r.off + r.len]
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        WeightsSec::Quant { scale, codes, .. } => {
            let q: Vec<i8> = bytes[codes.off..codes.off + codes.len]
                .iter()
                .map(|&b| b as i8)
                .collect();
            dequantize_weights(&q, scale)
        }
    };
    let net = Network {
        params,
        syn_targets: u32s(lay.syn_targets),
        syn_weights,
        neuron_off: u32s(lay.neuron_off),
        axon_off: u32s(lay.axon_off),
        outputs: u32s(lay.outputs),
        base_seed: lay.base_seed,
    };
    validate_v2_view(&net.view())?;
    Ok(net)
}

// ---- public entry points -------------------------------------------------

/// Load any `.hsn` file (v1 or v2, sniffed by magic) into an owned
/// [`Network`]. For the zero-copy mmap path use
/// [`crate::model_fmt::NetFile::open`] (v2 only) or the
/// [`crate::sim::SimConfig::from_path`] facade entry.
pub fn read_hsn<P: AsRef<Path>>(path: P) -> Result<Network> {
    let mut magic = [0u8; 8];
    {
        use std::io::Read as _;
        let mut f = File::open(&path)
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let got = f.read(&mut magic)?;
        if got < 8 {
            bail!(HsnError::Truncated { need: 8, have: got as u64 });
        }
    }
    if &magic == HSN_MAGIC_V2 {
        let bytes = std::fs::read(&path)?;
        return v2_decode_network(&bytes).map_err(anyhow::Error::from);
    }
    read_hsn_v1(path) // reports BadMagic itself for unknown magics
}

/// Write `net` as `.hsn` — the **v2** sectioned format (module docs).
/// [`write_hsn_v1`] keeps emitting the legacy stream.
pub fn write_hsn<'a, P: AsRef<Path>>(net: impl Into<NetView<'a>>, path: P) -> Result<()> {
    let bytes = hsn_v2_bytes(net);
    std::fs::write(&path, bytes)
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::snn::NetworkBuilder;
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    pub(crate) fn temp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hiaer_test_{}_{name}", std::process::id()));
        p
    }

    pub(crate) fn sample_net(seed: u32) -> Network {
        let mut rng = Xorshift32::new(seed);
        let m1 = NeuronModel::if_neuron(rng.range_i32(1, 100));
        let m2 = NeuronModel::ann(rng.range_i32(1, 50), -3, true).unwrap();
        let mut b = NetworkBuilder::new().seed(seed);
        let n = 20 + rng.below(40) as usize;
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let deg = rng.below(8) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-99, 99)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], if i % 2 == 0 { m1 } else { m2 }, &refs).unwrap();
        }
        b.add_axon("in0", &[("n0", 4), ("n1", -4)]).unwrap();
        b.add_output("n0");
        b.build().unwrap().0
    }

    fn assert_net_eq(got: &Network, want: &Network) {
        assert_eq!(got.params, want.params);
        assert_eq!(got.syn_targets, want.syn_targets);
        assert_eq!(got.syn_weights, want.syn_weights);
        assert_eq!(got.neuron_off, want.neuron_off);
        assert_eq!(got.axon_off, want.axon_off);
        assert_eq!(got.outputs, want.outputs);
        assert_eq!(got.base_seed, want.base_seed);
    }

    #[test]
    fn roundtrip_exact_v2_default() {
        let net = sample_net(42);
        let p = temp_path("roundtrip.hsn");
        write_hsn(&net, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], HSN_MAGIC_V2, "write_hsn emits v2 by default");
        let got = read_hsn(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_net_eq(&got, &net);
    }

    #[test]
    fn roundtrip_exact_v1() {
        let net = sample_net(43);
        let p = temp_path("roundtrip_v1.hsn");
        write_hsn_v1(&net, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], HSN_MAGIC);
        let got = read_hsn(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_net_eq(&got, &net);
    }

    /// v1 and v2 encode the same networks: reading either file yields the
    /// identical `Network`, and re-encoding is byte-stable per version.
    #[test]
    fn prop_v1_v2_cross_roundtrip() {
        ptest::check("hsn_roundtrip", 20, |rng| {
            let net = sample_net(rng.next_u32());
            let tag = rng.next_u32();
            let p1 = temp_path(&format!("prop_v1_{tag}.hsn"));
            let p2 = temp_path(&format!("prop_v2_{tag}.hsn"));
            write_hsn_v1(&net, &p1).map_err(|e| e.to_string())?;
            write_hsn(&net, &p2).map_err(|e| e.to_string())?;
            let from_v1 = read_hsn(&p1).map_err(|e| e.to_string())?;
            let from_v2 = read_hsn(&p2).map_err(|e| e.to_string())?;
            // Network-level equality across versions
            ptest::prop_assert_eq(from_v1.params.clone(), from_v2.params.clone(), "params")?;
            ptest::prop_assert_eq(from_v1.syn_targets.clone(), from_v2.syn_targets.clone(), "syn_targets")?;
            ptest::prop_assert_eq(from_v1.syn_weights.clone(), from_v2.syn_weights.clone(), "syn_weights")?;
            ptest::prop_assert_eq(from_v1.neuron_off.clone(), from_v2.neuron_off.clone(), "neuron_off")?;
            ptest::prop_assert_eq(from_v1.axon_off.clone(), from_v2.axon_off.clone(), "axon_off")?;
            ptest::prop_assert_eq(from_v1.outputs.clone(), from_v2.outputs.clone(), "outputs")?;
            // byte-level: re-encoding each load reproduces each file
            let v1_bytes = std::fs::read(&p1).unwrap();
            let v2_bytes = std::fs::read(&p2).unwrap();
            let p1b = temp_path(&format!("prop_v1b_{tag}.hsn"));
            write_hsn_v1(&from_v2, &p1b).map_err(|e| e.to_string())?;
            ptest::prop_assert_eq(std::fs::read(&p1b).unwrap(), v1_bytes, "v1 bytes stable")?;
            ptest::prop_assert_eq(hsn_v2_bytes(&from_v1), v2_bytes, "v2 bytes stable")?;
            for p in [&p1, &p2, &p1b] {
                std::fs::remove_file(p).ok();
            }
            Ok(())
        });
    }

    #[test]
    fn v2_sections_are_aligned_and_ordered() {
        let net = sample_net(7);
        let bytes = hsn_v2_bytes(&net);
        let lay = parse_v2(&bytes).unwrap();
        for r in [lay.params, lay.neuron_off, lay.axon_off, lay.syn_targets, lay.outputs] {
            assert_eq!(r.off % 8, 0, "section offset {} must be 8-aligned", r.off);
        }
        assert_eq!(lay.n_neurons, net.n_neurons());
        assert_eq!(lay.n_axons, net.n_axons());
        assert_eq!(lay.n_syn, net.n_synapses());
        assert_eq!(lay.base_seed, net.base_seed);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = temp_path("bad.hsn");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(read_hsn(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_out_of_range_target_v1() {
        let net = sample_net(1);
        let p = temp_path("oor.hsn");
        write_hsn_v1(&net, &p).unwrap();
        // corrupt a synapse target beyond n
        let mut bytes = std::fs::read(&p).unwrap();
        // first adjacency count is at 8 + 20 + 16n; find first nonzero count
        let n = net.n_neurons();
        let mut off = 28 + 16 * n;
        loop {
            let cnt = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
            if cnt > 0 {
                bytes[off..off + 4].copy_from_slice(&(n as u32 + 9).to_le_bytes());
                break;
            }
        }
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_hsn(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// The v1 reader accepts legacy unsorted files by falling back to the
    /// canonicalising sort (the v2 reader rejects them — see netfile tests).
    #[test]
    fn v1_unsorted_legacy_fallback_sorts() {
        let mut net = sample_net(5);
        // axon "in0" targets two distinct neurons (n0, n1) — reversing its
        // region guarantees an unsorted on-disk order
        let r = net.axon_range(0);
        assert!(r.len() >= 2 && net.syn_targets[r.start] != net.syn_targets[r.end - 1]);
        net.syn_targets[r.clone()].reverse();
        net.syn_weights[r].reverse();
        let p = temp_path("unsorted_v1.hsn");
        write_hsn_v1(&net, &p).unwrap();
        let got = read_hsn(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert!(got.view().is_sorted(), "legacy fallback must canonicalise");
        net.sort_synapses();
        assert_net_eq(&got, &net);
    }

    #[test]
    fn quantized_roundtrip_bounded_error() {
        let net = sample_net(11);
        let p = temp_path("quant.hsn");
        let bytes = hsn_v2_bytes_quantized(&net, 8).unwrap();
        std::fs::write(&p, &bytes).unwrap();
        let got = read_hsn(&p).unwrap();
        std::fs::remove_file(&p).ok();
        // lossless fields
        assert_eq!(got.params, net.params);
        assert_eq!(got.syn_targets, net.syn_targets);
        assert_eq!(got.neuron_off, net.neuron_off);
        assert_eq!(got.axon_off, net.axon_off);
        // weights: |round(q*scale) - w| <= scale/2 + 0.5
        let lay = parse_v2(&bytes).unwrap();
        let scale = match lay.weights {
            WeightsSec::Quant { scale, .. } => scale,
            _ => panic!("expected QWEIGHTS"),
        };
        for (&got_w, &want_w) in got.syn_weights.iter().zip(&net.syn_weights) {
            let err = (got_w as f64 - want_w as f64).abs();
            assert!(
                err <= scale as f64 / 2.0 + 0.5,
                "weight {want_w} -> {got_w}: error {err} > half-step at scale {scale}"
            );
        }
    }

    #[test]
    fn quantize_rejects_bad_bits() {
        let net = sample_net(2);
        assert!(matches!(hsn_v2_bytes_quantized(&net, 1), Err(HsnError::BadQuant(_))));
        assert!(matches!(hsn_v2_bytes_quantized(&net, 9), Err(HsnError::BadQuant(_))));
    }

    #[test]
    fn empty_network_round_trips_both_versions() {
        let net = Network {
            params: vec![],
            syn_targets: vec![],
            syn_weights: vec![],
            neuron_off: vec![0],
            axon_off: vec![0],
            outputs: vec![],
            base_seed: 0,
        };
        let writers: [(&str, fn(&Network, &std::path::Path) -> Result<()>); 2] = [
            ("empty_v1.hsn", |n, p| write_hsn_v1(n, p)),
            ("empty_v2.hsn", |n, p| write_hsn(n, p)),
        ];
        for (name, write) in writers {
            let p = temp_path(name);
            write(&net, &p).unwrap();
            let got = read_hsn(&p).unwrap();
            std::fs::remove_file(&p).ok();
            assert_eq!(got.n_neurons(), 0, "{name}");
            assert_eq!(got.n_axons(), 0, "{name}");
            assert_eq!(got.n_synapses(), 0, "{name}");
        }
    }
}
