//! Mmap-backed `.hsn` v2 network file: the zero-copy load path.
//!
//! [`NetFile::open`] maps the file read-only, runs the full structural
//! validation ([`parse_v2`] header/TOC checks, then CSR semantics and
//! the sortedness contract), and afterwards hands out
//! [`NetView`]s whose slices point **straight into the mapping** — no
//! per-synapse parsing, no heap copy of the CSR arrays. Compile,
//! partition, and split all consume the view generically, so cold-start
//! cost is `mmap(2)` + an O(E) validation scan + HBM compile.
//!
//! Portability and fallbacks, in order:
//! * non-Unix targets, or an `mmap` failure (e.g. a pseudo-filesystem):
//!   the file is read into an 8-byte-aligned heap buffer — identical
//!   zero-parse reinterpret, just backed by anonymous memory;
//! * big-endian hosts: sections cannot be reinterpreted, so the image is
//!   decoded into an owned [`Network`] (endian-safe byte swap);
//! * QWEIGHTS files: targets/offsets/params stay zero-copy; only the
//!   dequantized i16 weights are materialized (E×2 bytes).
//!
//! Safety argument for the reinterpret: every section range returned by
//! [`parse_v2`] is bounds-checked against the image, starts on an
//! 8-byte boundary, and has a length that is an exact multiple of the
//! element size; the mapping base is page-aligned (or `Vec<u64>`-backed,
//! 8-aligned), the mapping is private/read-only and outlives the views
//! (slices borrow from `self`), and every element type
//! (`u32`/`i16`/[`NeuronModel`] with `repr(C)`) is valid for all bit
//! patterns. Semantic validity (offsets monotonic and covering, targets
//! in range) is established once at `open` before any view escapes.

use std::path::Path;
use std::sync::Arc;

use crate::snn::{NetView, Network, NeuronModel};

use super::hsn::{
    dequantize_weights, parse_v2, validate_v2_view, HsnError, SecRange, V2Layout, WeightsSec,
};

#[cfg(unix)]
mod sys {
    //! Minimal mmap(2)/munmap(2) FFI — libc is not a dependency, so bind
    //! the two calls directly (precedent: the raw `signal(2)` binding in
    //! `sim/serve.rs`). Constants are the POSIX-mandated values shared
    //! by Linux and the BSDs.
    use std::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// The raw byte image backing a [`NetFile`]: a private read-only file
/// mapping when available, else an 8-aligned heap buffer.
enum Mapping {
    #[cfg(unix)]
    Mmap { ptr: *const u8, len: usize },
    /// `Vec<u64>` guarantees 8-byte base alignment for the reinterpret.
    Heap { buf: Vec<u64>, len: usize },
}

// The mapping is immutable (PROT_READ, MAP_PRIVATE) for its whole
// lifetime, so shared references from any thread are fine.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Mapping::Mmap { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Mapping::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len)
            },
        }
    }

    fn is_mmap(&self) -> bool {
        match self {
            #[cfg(unix)]
            Mapping::Mmap { .. } => true,
            Mapping::Heap { .. } => false,
        }
    }

    fn heap_read<P: AsRef<Path>>(path: P) -> Result<Self, HsnError> {
        let bytes = std::fs::read(path)?;
        let len = bytes.len();
        let mut buf = vec![0u64; len.div_ceil(8)];
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), buf.as_mut_ptr() as *mut u8, len);
        }
        Ok(Mapping::Heap { buf, len })
    }

    fn open<P: AsRef<Path>>(path: P) -> Result<Self, HsnError> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let f = std::fs::File::open(&path)?;
            let len = f.metadata()?.len();
            if len == 0 {
                // mmap(len = 0) is EINVAL; an empty file is handled (and
                // rejected as truncated) through the heap path.
                return Self::heap_read(path);
            }
            if len > usize::MAX as u64 {
                return Err(HsnError::BadHeader(format!("file length {len} exceeds usize")));
            }
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len as usize,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    f.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::map_failed() {
                // some filesystems refuse mmap — fall back, same semantics
                return Self::heap_read(path);
            }
            Ok(Mapping::Mmap { ptr: ptr as *const u8, len: len as usize })
        }
        #[cfg(not(unix))]
        {
            Self::heap_read(path)
        }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Mapping::Mmap { ptr, len } = self {
            unsafe {
                sys::munmap(*ptr as *mut std::ffi::c_void, *len);
            }
        }
    }
}

/// How the loaded image serves views.
enum Backing {
    /// Little-endian host: slices reinterpret the mapped/heap image in
    /// place. `qweights` holds the dequantized weights for QWEIGHTS
    /// files (the only materialized array); `None` means SYN_WEIGHTS is
    /// served zero-copy too.
    #[cfg(target_endian = "little")]
    Zero { mapping: Mapping, lay: V2Layout, qweights: Option<Vec<i16>> },
    /// Big-endian host: full endian-safe decode into an owned network.
    #[allow(dead_code)] // constructed only on big-endian targets
    Owned(Network),
}

/// An open, validated `.hsn` v2 file serving borrowed-CSR views
/// (module docs). Cheap to share: wrap in an [`Arc`] and call
/// [`NetFile::view`] wherever a `&Network` used to be passed.
pub struct NetFile {
    backing: Backing,
    byte_len: usize,
    /// Where the image was opened from — lets multi-process consumers
    /// (the sharded backend) hand the same file to subprocesses.
    path: Option<std::path::PathBuf>,
}

/// Reinterpret a validated section range as a typed slice.
///
/// # Safety
/// `r` must come from [`parse_v2`] over `bytes` (in-bounds, 8-aligned
/// offset, exact multiple of `size_of::<T>()`), `bytes` must be 8-byte
/// aligned at its base, and `T` must be valid for all bit patterns.
unsafe fn sec_slice<T>(bytes: &[u8], r: SecRange) -> &[T] {
    debug_assert_eq!(bytes.as_ptr() as usize % 8, 0, "image base must be 8-aligned");
    debug_assert_eq!(r.off % 8, 0);
    debug_assert_eq!(r.len % std::mem::size_of::<T>(), 0);
    std::slice::from_raw_parts(
        bytes.as_ptr().add(r.off) as *const T,
        r.len / std::mem::size_of::<T>(),
    )
}

/// Build the zero-copy view over a validated layout. Free function (not
/// a method) so `open` can validate the view before `NetFile` exists.
#[cfg(target_endian = "little")]
fn zero_view<'a>(bytes: &'a [u8], lay: &V2Layout, qweights: Option<&'a [i16]>) -> NetView<'a> {
    let syn_weights: &[i16] = match (lay.weights, qweights) {
        (WeightsSec::Plain(r), _) => unsafe { sec_slice(bytes, r) },
        (WeightsSec::Quant { .. }, Some(q)) => q,
        (WeightsSec::Quant { .. }, None) => unreachable!("quantized file without decoded weights"),
    };
    NetView {
        params: unsafe { sec_slice::<NeuronModel>(bytes, lay.params) },
        syn_targets: unsafe { sec_slice(bytes, lay.syn_targets) },
        syn_weights,
        neuron_off: unsafe { sec_slice(bytes, lay.neuron_off) },
        axon_off: unsafe { sec_slice(bytes, lay.axon_off) },
        outputs: unsafe { sec_slice(bytes, lay.outputs) },
        base_seed: lay.base_seed,
    }
}

impl NetFile {
    /// Map and validate a `.hsn` v2 file. Every malformed input returns
    /// a typed [`HsnError`]; no view escapes before validation passes.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self, HsnError> {
        let mapping = Mapping::open(&path)?;
        let byte_len = mapping.bytes().len();
        let src_path = Some(path.as_ref().to_path_buf());
        #[cfg(target_endian = "little")]
        {
            let lay = parse_v2(mapping.bytes())?;
            let qweights = match lay.weights {
                WeightsSec::Plain(_) => None,
                WeightsSec::Quant { scale, codes, .. } => {
                    let raw = &mapping.bytes()[codes.off..codes.off + codes.len];
                    // i8 from u8 bytes: same bit patterns
                    let q: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
                    Some(dequantize_weights(&q, scale))
                }
            };
            validate_v2_view(&zero_view(mapping.bytes(), &lay, qweights.as_deref()))?;
            Ok(NetFile {
                backing: Backing::Zero { mapping, lay, qweights },
                byte_len,
                path: src_path,
            })
        }
        #[cfg(not(target_endian = "little"))]
        {
            let net = super::hsn::v2_decode_network(mapping.bytes())?;
            Ok(NetFile { backing: Backing::Owned(net), byte_len, path: src_path })
        }
    }

    /// The path this image was opened from (`None` only for future
    /// non-file constructions; [`NetFile::open`] always records it).
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// The borrowed-CSR view into this file — on little-endian hosts the
    /// slices point into the mapping itself.
    pub fn view(&self) -> NetView<'_> {
        match &self.backing {
            #[cfg(target_endian = "little")]
            Backing::Zero { mapping, lay, qweights } => {
                zero_view(mapping.bytes(), lay, qweights.as_deref())
            }
            Backing::Owned(net) => net.view(),
        }
    }

    /// Materialize an owned [`Network`] (the explicit copy point for
    /// consumers that must own, e.g. the session `SimFactory` seam).
    pub fn to_network(&self) -> Network {
        match &self.backing {
            #[cfg(target_endian = "little")]
            Backing::Zero { .. } => self.view().to_network(),
            Backing::Owned(net) => net.clone(),
        }
    }

    /// Total on-disk image size in bytes (header + TOC + sections).
    pub fn byte_len(&self) -> usize {
        self.byte_len
    }

    /// True when the image is an actual file mapping (false after the
    /// heap fallback or an owned big-endian decode).
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(target_endian = "little")]
            Backing::Zero { mapping, .. } => mapping.is_mmap(),
            Backing::Owned(_) => false,
        }
    }

    /// True when `ptr` points inside this file's byte image — the
    /// zero-copy assertion hook used by tests: a borrowed CSR slice's
    /// data pointer must land inside the mapping.
    pub fn contains(&self, ptr: *const u8) -> bool {
        match &self.backing {
            #[cfg(target_endian = "little")]
            Backing::Zero { mapping, .. } => {
                let base = mapping.bytes().as_ptr() as usize;
                let p = ptr as usize;
                p >= base && p < base + self.byte_len
            }
            Backing::Owned(_) => false,
        }
    }
}

/// Open a `.hsn` v2 file as a shareable mapped handle.
pub fn open_netfile<P: AsRef<Path>>(path: P) -> Result<Arc<NetFile>, HsnError> {
    Ok(Arc::new(NetFile::open(path)?))
}

/// Shared-mapping cache for `.hsn` v2 files: sessions configuring from
/// the same canonical path (and file identity) get the same
/// [`Arc<NetFile>`] instead of re-mapping per session — N sessions ≈
/// one validation scan and one logical copy of the net (the serve tier
/// holds one of these; `metrics` exposes the hit counter).
///
/// Entries are [`Weak`]: the cache never keeps a mapping alive on its
/// own, so dropping every session releases the file. The key is the
/// canonical path plus the file's identity — mtime, byte length and
/// (on unix) inode — so an overwritten net is re-validated instead of
/// served stale. mtime alone was not enough: a rename-over rewrite
/// that lands within the filesystem's timestamp granularity (or with
/// a deliberately restored mtime) used to hit the old mapping and
/// serve stale bytes; the inode catches the rename, the length catches
/// in-place growth.
pub struct NetCache {
    map: std::sync::Mutex<std::collections::HashMap<CacheKey, std::sync::Weak<NetFile>>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

/// On-disk identity of a `.hsn` file at open time; see [`NetCache`].
#[derive(Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    path: std::path::PathBuf,
    mtime: Option<std::time::SystemTime>,
    len: u64,
    /// unix inode; 0 on platforms without one (the other fields still key)
    ino: u64,
}

impl CacheKey {
    fn for_path(canon: std::path::PathBuf) -> CacheKey {
        let (mtime, len, ino) = match std::fs::metadata(&canon) {
            Ok(m) => {
                #[cfg(unix)]
                let ino = std::os::unix::fs::MetadataExt::ino(&m);
                #[cfg(not(unix))]
                let ino = 0u64;
                (m.modified().ok(), m.len(), ino)
            }
            Err(_) => (None, 0, 0),
        };
        CacheKey { path: canon, mtime, len, ino }
    }
}

impl Default for NetCache {
    fn default() -> Self {
        Self::new()
    }
}

impl NetCache {
    pub fn new() -> Self {
        NetCache {
            map: std::sync::Mutex::new(std::collections::HashMap::new()),
            hits: std::sync::atomic::AtomicU64::new(0),
            misses: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Open through the cache: an upgradable entry for (canonical path,
    /// mtime, length, inode) is a hit; otherwise the file is mapped,
    /// validated and inserted. Dead entries are pruned on every miss.
    pub fn open<P: AsRef<Path>>(&self, path: P) -> Result<Arc<NetFile>, HsnError> {
        use std::sync::atomic::Ordering;
        let canon = std::fs::canonicalize(&path)
            .unwrap_or_else(|_| path.as_ref().to_path_buf());
        let key = CacheKey::for_path(canon);
        let mut map = self.map.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(file) = map.get(&key).and_then(std::sync::Weak::upgrade) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(file);
        }
        let file = Arc::new(NetFile::open(&key.path)?);
        map.retain(|_, w| w.strong_count() > 0);
        map.insert(key, Arc::downgrade(&file));
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(file)
    }

    /// Opens served from a live cached mapping.
    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Opens that had to map (first open, expired entry, or changed
    /// file identity — mtime, length or inode).
    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::super::hsn::{
        hsn_v2_bytes, hsn_v2_bytes_quantized, sec, write_hsn, HsnError, V2_HEADER_BYTES,
        V2_TOC_ENTRY_BYTES,
    };
    use super::super::hsn::tests::{sample_net, temp_path};
    use super::*;

    fn write_bytes(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = temp_path(name);
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn mmap_view_matches_heap_network() {
        let net = sample_net(77);
        let p = temp_path("netfile_basic.hsn");
        write_hsn(&net, &p).unwrap();
        let nf = NetFile::open(&p).unwrap();
        let v = nf.view();
        assert_eq!(v.params, &net.params[..]);
        assert_eq!(v.syn_targets, &net.syn_targets[..]);
        assert_eq!(v.syn_weights, &net.syn_weights[..]);
        assert_eq!(v.neuron_off, &net.neuron_off[..]);
        assert_eq!(v.axon_off, &net.axon_off[..]);
        assert_eq!(v.outputs, &net.outputs[..]);
        assert_eq!(v.base_seed, net.base_seed);
        assert_eq!(nf.byte_len(), std::fs::metadata(&p).unwrap().len() as usize);
        std::fs::remove_file(&p).ok();
    }

    /// The headline zero-copy claim: on a little-endian unix host the CSR
    /// slices returned by `view()` point into the file mapping itself.
    #[cfg(all(unix, target_endian = "little"))]
    #[test]
    fn view_slices_borrow_the_mapping() {
        let net = sample_net(78);
        let p = temp_path("netfile_zerocopy.hsn");
        write_hsn(&net, &p).unwrap();
        let nf = NetFile::open(&p).unwrap();
        assert!(nf.is_mapped(), "regular tmpfile must mmap");
        let v = nf.view();
        assert!(nf.contains(v.syn_targets.as_ptr() as *const u8));
        assert!(nf.contains(v.syn_weights.as_ptr() as *const u8));
        assert!(nf.contains(v.neuron_off.as_ptr() as *const u8));
        assert!(nf.contains(v.axon_off.as_ptr() as *const u8));
        assert!(nf.contains(v.params.as_ptr() as *const u8));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn quantized_weights_are_materialized_rest_zero_copy() {
        let net = sample_net(79);
        let bytes = hsn_v2_bytes_quantized(&net, 6).unwrap();
        let p = write_bytes("netfile_quant.hsn", &bytes);
        let nf = NetFile::open(&p).unwrap();
        let v = nf.view();
        assert_eq!(v.syn_targets, &net.syn_targets[..]);
        // weights decoded, not borrowed from the file
        assert!(!nf.contains(v.syn_weights.as_ptr() as *const u8) || v.syn_weights.is_empty());
        std::fs::remove_file(&p).ok();
    }

    // ---- corrupted-input coverage: typed errors, never panics --------

    #[test]
    fn truncated_file_is_typed_error() {
        let net = sample_net(80);
        let bytes = hsn_v2_bytes(&net);
        // every prefix must fail cleanly (never panic); short prefixes
        // specifically as Truncated
        for cut in [0, 4, 8, 20, V2_HEADER_BYTES, V2_HEADER_BYTES + 30, bytes.len() - 1] {
            let p = write_bytes(&format!("netfile_trunc_{cut}.hsn"), &bytes[..cut]);
            let err = NetFile::open(&p).unwrap_err();
            assert!(
                matches!(err, HsnError::Truncated { .. } | HsnError::BadMagic { .. }),
                "cut at {cut}: got {err:?}"
            );
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn bad_magic_is_typed_error() {
        let net = sample_net(81);
        let mut bytes = hsn_v2_bytes(&net);
        bytes[..8].copy_from_slice(b"HSNET9\x00\x00");
        let p = write_bytes("netfile_magic.hsn", &bytes);
        assert!(matches!(NetFile::open(&p).unwrap_err(), HsnError::BadMagic { .. }));
        std::fs::remove_file(&p).ok();
    }

    fn toc_entry(k: usize) -> usize {
        V2_HEADER_BYTES + k * V2_TOC_ENTRY_BYTES
    }

    #[test]
    fn misaligned_section_offset_is_typed_error() {
        let net = sample_net(82);
        let mut bytes = hsn_v2_bytes(&net);
        // PARAMS is TOC entry 0; knock its offset off the 8B boundary
        let e = toc_entry(0);
        let off = u64::from_le_bytes(bytes[e + 8..e + 16].try_into().unwrap());
        bytes[e + 8..e + 16].copy_from_slice(&(off + 4).to_le_bytes());
        let p = write_bytes("netfile_misaligned.hsn", &bytes);
        assert!(matches!(
            NetFile::open(&p).unwrap_err(),
            HsnError::Misaligned { id: sec::PARAMS, .. } | HsnError::Overlap { .. }
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn overlapping_sections_is_typed_error() {
        let net = sample_net(83);
        let mut bytes = hsn_v2_bytes(&net);
        // rewind entry 1 (NEURON_OFF) onto entry 0's payload
        let e0 = toc_entry(0);
        let off0 = u64::from_le_bytes(bytes[e0 + 8..e0 + 16].try_into().unwrap());
        let e1 = toc_entry(1);
        bytes[e1 + 8..e1 + 16].copy_from_slice(&off0.to_le_bytes());
        let p = write_bytes("netfile_overlap.hsn", &bytes);
        assert!(matches!(
            NetFile::open(&p).unwrap_err(),
            HsnError::Overlap { id: sec::NEURON_OFF, .. }
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn out_of_range_target_is_typed_error() {
        let net = sample_net(84);
        assert!(net.n_synapses() > 0);
        let mut bytes = hsn_v2_bytes(&net);
        let lay = super::super::hsn::parse_v2(&bytes).unwrap();
        let t = lay.syn_targets.off; // first synapse target
        bytes[t..t + 4].copy_from_slice(&(net.n_neurons() as u32 + 5).to_le_bytes());
        let p = write_bytes("netfile_oor.hsn", &bytes);
        assert!(matches!(NetFile::open(&p).unwrap_err(), HsnError::Invalid(_)));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unsorted_v2_is_rejected_not_resorted() {
        let net = sample_net(85);
        let mut bytes = hsn_v2_bytes(&net);
        let lay = super::super::hsn::parse_v2(&bytes).unwrap();
        // axon "in0" targets two distinct neurons (n0, n1): swapping its
        // first and last target guarantees an out-of-order region
        let r = net.axon_range(0);
        assert!(r.len() >= 2 && net.syn_targets[r.start] != net.syn_targets[r.end - 1]);
        let a = lay.syn_targets.off + r.start * 4;
        let b = lay.syn_targets.off + (r.end - 1) * 4;
        let (ta, tb) = (bytes[a..a + 4].to_vec(), bytes[b..b + 4].to_vec());
        bytes[a..a + 4].copy_from_slice(&tb);
        bytes[b..b + 4].copy_from_slice(&ta);
        let p = write_bytes("netfile_unsorted.hsn", &bytes);
        assert!(matches!(NetFile::open(&p).unwrap_err(), HsnError::Unsorted));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn duplicate_and_missing_sections_are_typed_errors() {
        let net = sample_net(86);
        let mut bytes = hsn_v2_bytes(&net);
        // relabel NEURON_OFF's TOC id as PARAMS -> duplicate + missing
        let e1 = toc_entry(1);
        bytes[e1..e1 + 4].copy_from_slice(&sec::PARAMS.to_le_bytes());
        let p = write_bytes("netfile_dup.hsn", &bytes);
        assert!(matches!(NetFile::open(&p).unwrap_err(), HsnError::DuplicateSection(_)));
        std::fs::remove_file(&p).ok();

        let mut bytes = hsn_v2_bytes(&net);
        // unknown id: reader must skip it, then miss the required section
        let e3 = toc_entry(3); // SYN_TARGETS
        bytes[e3..e3 + 4].copy_from_slice(&999u32.to_le_bytes());
        let p = write_bytes("netfile_missing.hsn", &bytes);
        assert!(matches!(
            NetFile::open(&p).unwrap_err(),
            HsnError::MissingSection(sec::SYN_TARGETS)
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn net_cache_shares_one_mapping_per_path() {
        let net = sample_net(91);
        let p = temp_path("netfile_cache.hsn");
        write_hsn(&net, &p).unwrap();
        let cache = NetCache::new();
        let a = cache.open(&p).unwrap();
        let b = cache.open(&p).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same path must share one mapping");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // weak entries: dropping every handle releases the mapping, and
        // the next open is a fresh (validated) miss
        drop(a);
        drop(b);
        let c = cache.open(&p).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 2));
        assert!(c.path().is_some());
        std::fs::remove_file(&p).ok();
    }

    /// Regression (PR 10): a rename-over rewrite of the same byte
    /// length with a restored mtime used to hit the (path, mtime)
    /// cache entry and serve stale bytes. The inode/length key fields
    /// must force a re-map.
    #[cfg(unix)]
    #[test]
    fn net_cache_misses_on_same_size_rewrite_with_pinned_mtime() {
        let net = sample_net(101);
        // same structure, one weight flipped: identical serialized length
        let mut net2 = sample_net(101);
        net2.syn_weights[0] = net2.syn_weights[0].wrapping_add(1);

        let p = temp_path("netfile_cache_stale.hsn");
        write_hsn(&net, &p).unwrap();
        let cache = NetCache::new();
        let a = cache.open(&p).unwrap();
        let w0 = a.view().syn_weights[0];
        let mtime0 = std::fs::metadata(&p).unwrap().modified().unwrap();

        // rewrite via rename (new inode), then pin the mtime back so
        // (path, mtime) alone cannot tell the files apart
        let tmp = temp_path("netfile_cache_stale.hsn.tmp");
        write_hsn(&net2, &tmp).unwrap();
        assert_eq!(
            std::fs::metadata(&tmp).unwrap().len(),
            std::fs::metadata(&p).unwrap().len(),
            "rewrite must be same-size for this regression to mean anything"
        );
        let times = std::fs::FileTimes::new().set_modified(mtime0);
        std::fs::File::options()
            .append(true)
            .open(&tmp)
            .unwrap()
            .set_times(times)
            .unwrap();
        std::fs::rename(&tmp, &p).unwrap();
        assert_eq!(std::fs::metadata(&p).unwrap().modified().unwrap(), mtime0);

        // `a` is still live, so a (path, mtime)-keyed cache would hit
        let b = cache.open(&p).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "rewritten file must get a fresh mapping");
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert_eq!(a.view().syn_weights[0], w0);
        assert_eq!(b.view().syn_weights[0], w0.wrapping_add(1), "must see the new bytes");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn net_file_records_its_path() {
        let net = sample_net(92);
        let p = temp_path("netfile_path.hsn");
        write_hsn(&net, &p).unwrap();
        let nf = NetFile::open(&p).unwrap();
        assert_eq!(nf.path(), Some(p.as_path()));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_is_truncated_error() {
        let p = write_bytes("netfile_empty.hsn", b"");
        assert!(matches!(NetFile::open(&p).unwrap_err(), HsnError::Truncated { .. }));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_section_len_is_typed_error() {
        let net = sample_net(87);
        let mut bytes = hsn_v2_bytes(&net);
        // shrink OUTPUTS (entry 5) length below n_outputs * 4
        let e5 = toc_entry(5);
        let len = u64::from_le_bytes(bytes[e5 + 16..e5 + 24].try_into().unwrap());
        assert!(len >= 4);
        bytes[e5 + 16..e5 + 24].copy_from_slice(&(len - 4).to_le_bytes());
        let p = write_bytes("netfile_badlen.hsn", &bytes);
        assert!(matches!(
            NetFile::open(&p).unwrap_err(),
            HsnError::BadSectionLen { id: sec::OUTPUTS, .. }
        ));
        std::fs::remove_file(&p).ok();
    }
}
