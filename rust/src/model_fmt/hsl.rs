//! `.hsl` trained layer-graph format.
//!
//! Written by the Python training pipeline (`python/train/export.py`)
//! after quantization-aware training: a feed-forward stack of conv /
//! fully-connected / max-pool layers with int16 weights and int32 biases,
//! plus the input shape and the rate-coding timestep count. The Rust
//! converter ([`crate::convert`]) turns this into a HiAER-Spike network
//! following Supplementary A.2.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic     8B "HSLAY1\0\0"
//! header    u32 version, u8 neuron_kind (0=ANN binary, 1=IF),
//!           u32 in_c, u32 in_h, u32 in_w, u32 timesteps, u32 n_layers
//! layer     u8 kind:
//!   0 conv: u32 out_c, kh, kw, stride, pad; i32 theta; u8 has_bias;
//!           i16 w[out_c][in_c][kh][kw]; (i32 bias[out_c])
//!   1 fc:   u32 out_features; i32 theta; u8 has_bias;
//!           i16 w[out][in]; (i32 bias[out])
//!   2 pool: u32 k, u32 stride           (max pool, threshold-OR neurons)
//! ```

use std::fs::File;
use std::io::BufReader;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Reader;

pub const HSL_MAGIC: &[u8; 8] = b"HSLAY1\x00\x00";

/// Neuron class used for every layer of the converted model (paper §6:
/// MNIST models use ANN binary neurons; spiking CNNs use IF neurons,
/// i.e. LIF with membrane time constant 2^63).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeuronKind {
    AnnBinary,
    IntegrateFire,
}

#[derive(Clone, Debug)]
pub enum Layer {
    Conv {
        out_c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        theta: i32,
        /// [out_c][in_c][kh][kw], row-major
        weights: Vec<i16>,
        bias: Option<Vec<i32>>,
    },
    Fc {
        out_features: usize,
        theta: i32,
        /// [out][in], row-major
        weights: Vec<i16>,
        bias: Option<Vec<i32>>,
    },
    MaxPool {
        k: usize,
        stride: usize,
    },
}

#[derive(Clone, Debug)]
pub struct LayerGraph {
    pub neuron_kind: NeuronKind,
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub timesteps: usize,
    pub layers: Vec<Layer>,
}

impl LayerGraph {
    /// Output (c, h, w) after each layer; `usize::MAX` height/width marks
    /// post-flatten FC stages (c = features).
    pub fn shapes(&self) -> Result<Vec<(usize, usize, usize)>> {
        let mut shapes = vec![(self.in_c, self.in_h, self.in_w)];
        for (li, layer) in self.layers.iter().enumerate() {
            let (c, h, w) = *shapes.last().unwrap();
            let next = match layer {
                Layer::Conv { out_c, kh, kw, stride, pad, weights, .. } => {
                    if h == usize::MAX {
                        bail!("layer {li}: conv after flatten");
                    }
                    if weights.len() != out_c * c * kh * kw {
                        bail!(
                            "layer {li}: weight count {} != {out_c}x{c}x{kh}x{kw}",
                            weights.len()
                        );
                    }
                    let oh = (h + 2 * pad).checked_sub(*kh).map(|x| x / stride + 1);
                    let ow = (w + 2 * pad).checked_sub(*kw).map(|x| x / stride + 1);
                    match (oh, ow) {
                        (Some(oh), Some(ow)) if oh > 0 && ow > 0 => (*out_c, oh, ow),
                        _ => bail!("layer {li}: kernel larger than input"),
                    }
                }
                Layer::Fc { out_features, weights, .. } => {
                    let in_features = if h == usize::MAX { c } else { c * h * w };
                    if weights.len() != out_features * in_features {
                        bail!(
                            "layer {li}: weight count {} != {out_features}x{in_features}",
                            weights.len()
                        );
                    }
                    (*out_features, usize::MAX, usize::MAX)
                }
                Layer::MaxPool { k, stride } => {
                    if h == usize::MAX {
                        bail!("layer {li}: pool after flatten");
                    }
                    if *k > h || *k > w {
                        bail!("layer {li}: pool window larger than input");
                    }
                    (c, (h - k) / stride + 1, (w - k) / stride + 1)
                }
            };
            shapes.push(next);
        }
        Ok(shapes)
    }

    pub fn n_inputs(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }
}

pub fn read_hsl<P: AsRef<Path>>(path: P) -> Result<LayerGraph> {
    let f = File::open(&path)
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = Reader::new(BufReader::new(f));
    r.magic(HSL_MAGIC)?;
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported .hsl version {version}");
    }
    let neuron_kind = match r.u8()? {
        0 => NeuronKind::AnnBinary,
        1 => NeuronKind::IntegrateFire,
        k => bail!("unknown neuron kind {k}"),
    };
    let in_c = r.u32()? as usize;
    let in_h = r.u32()? as usize;
    let in_w = r.u32()? as usize;
    let timesteps = r.u32()? as usize;
    let n_layers = r.u32()? as usize;

    let mut layers = Vec::with_capacity(n_layers);
    // track input features for weight-count reads
    let (mut c, mut h, mut w) = (in_c, in_h, in_w);
    for li in 0..n_layers {
        match r.u8()? {
            0 => {
                let out_c = r.u32()? as usize;
                let kh = r.u32()? as usize;
                let kw = r.u32()? as usize;
                let stride = r.u32()? as usize;
                let pad = r.u32()? as usize;
                let theta = r.i32()?;
                let has_bias = r.u8()? != 0;
                if stride == 0 {
                    bail!("layer {li}: zero stride");
                }
                let weights = r.i16_vec(out_c * c * kh * kw)?;
                let bias = if has_bias { Some(r.i32_vec(out_c)?) } else { None };
                layers.push(Layer::Conv { out_c, kh, kw, stride, pad, theta, weights, bias });
                h = (h + 2 * pad - kh) / stride + 1;
                w = (w + 2 * pad - kw) / stride + 1;
                c = out_c;
            }
            1 => {
                let out_features = r.u32()? as usize;
                let theta = r.i32()?;
                let has_bias = r.u8()? != 0;
                let in_features = if h == usize::MAX { c } else { c * h * w };
                let weights = r.i16_vec(out_features * in_features)?;
                let bias = if has_bias { Some(r.i32_vec(out_features)?) } else { None };
                layers.push(Layer::Fc { out_features, theta, weights, bias });
                c = out_features;
                h = usize::MAX;
                w = usize::MAX;
            }
            2 => {
                let k = r.u32()? as usize;
                let stride = r.u32()? as usize;
                if stride == 0 || k == 0 {
                    bail!("layer {li}: zero pool params");
                }
                layers.push(Layer::MaxPool { k, stride });
                h = (h - k) / stride + 1;
                w = (w - k) / stride + 1;
            }
            k => bail!("layer {li}: unknown layer kind {k}"),
        }
    }
    let g = LayerGraph { neuron_kind, in_c, in_h, in_w, timesteps, layers };
    g.shapes()?; // validate
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_fmt::Writer;

    fn write_test_hsl(path: &Path) {
        let mut w = Writer::new();
        w.buf.extend_from_slice(HSL_MAGIC);
        w.u32(1); // version
        w.u8(1); // IF
        w.u32(1); // in_c
        w.u32(6); // in_h
        w.u32(6); // in_w
        w.u32(4); // timesteps
        w.u32(3); // layers
        // conv: 2 filters 3x3 stride 1 pad 0 -> (2,4,4)
        w.u8(0);
        w.u32(2);
        w.u32(3);
        w.u32(3);
        w.u32(1);
        w.u32(0);
        w.i32(10); // theta
        w.u8(0); // no bias
        for i in 0..(2 * 1 * 3 * 3) {
            w.i16(i as i16 - 9);
        }
        // pool 2x2 stride 2 -> (2,2,2)
        w.u8(2);
        w.u32(2);
        w.u32(2);
        // fc: 8 -> 3
        w.u8(1);
        w.u32(3);
        w.i32(5);
        w.u8(1); // bias
        for i in 0..(3 * 8) {
            w.i16(i as i16);
        }
        for i in 0..3 {
            w.i32(i * 100);
        }
        std::fs::write(path, &w.buf).unwrap();
    }

    #[test]
    fn read_and_shape_propagation() {
        let p = std::env::temp_dir().join(format!("t_{}.hsl", std::process::id()));
        write_test_hsl(&p);
        let g = read_hsl(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(g.neuron_kind, NeuronKind::IntegrateFire);
        assert_eq!(g.timesteps, 4);
        let shapes = g.shapes().unwrap();
        assert_eq!(shapes[0], (1, 6, 6));
        assert_eq!(shapes[1], (2, 4, 4));
        assert_eq!(shapes[2], (2, 2, 2));
        assert_eq!(shapes[3], (3, usize::MAX, usize::MAX));
        match &g.layers[2] {
            Layer::Fc { bias: Some(b), .. } => assert_eq!(b, &vec![0, 100, 200]),
            other => panic!("expected fc with bias, got {other:?}"),
        }
    }

    #[test]
    fn shape_validation_errors() {
        let g = LayerGraph {
            neuron_kind: NeuronKind::AnnBinary,
            in_c: 1,
            in_h: 2,
            in_w: 2,
            timesteps: 1,
            layers: vec![Layer::Conv {
                out_c: 1,
                kh: 5,
                kw: 5,
                stride: 1,
                pad: 0,
                theta: 0,
                weights: vec![0; 25],
                bias: None,
            }],
        };
        assert!(g.shapes().is_err()); // kernel larger than input
    }
}
