//! Loaders for `artifacts/golden/*.json` — the cross-language test
//! vectors emitted by `python/compile/aot.py`. Checked bit-exactly by
//! `rust/tests/golden.rs`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

fn load(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

fn field<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    j.get(k).ok_or_else(|| anyhow!("missing field {k}"))
}

fn i32s(j: &Json, k: &str) -> Result<Vec<i32>> {
    field(j, k)?.i32_vec().ok_or_else(|| anyhow!("field {k} not an int array"))
}

/// prng.json: pinned mix_seed / noise17 samples.
pub struct PrngGolden {
    /// (base_seed, step, expected)
    pub mix_seed: Vec<(u32, u32, u32)>,
    /// (seed, idx, expected)
    pub noise17: Vec<(u32, u32, i32)>,
}

pub fn load_prng(path: &Path) -> Result<PrngGolden> {
    let j = load(path)?;
    let tri = |k: &str| -> Result<Vec<(i64, i64, i64)>> {
        field(&j, k)?
            .as_arr()
            .ok_or_else(|| anyhow!("{k} not array"))?
            .iter()
            .map(|row| {
                let v = row.int_vec().ok_or_else(|| anyhow!("{k} row not ints"))?;
                Ok((v[0], v[1], v[2]))
            })
            .collect()
    };
    Ok(PrngGolden {
        mix_seed: tri("mix_seed")?
            .into_iter()
            .map(|(a, b, c)| (a as u32, b as u32, c as u32))
            .collect(),
        noise17: tri("noise17")?
            .into_iter()
            .map(|(a, b, c)| (a as u32, b as u32, c as i32))
            .collect(),
    })
}

/// neuron_update.json: one randomized phase-1..3 update.
pub struct NeuronUpdateGolden {
    pub step_seed: u32,
    pub v: Vec<i32>,
    pub theta: Vec<i32>,
    pub nu: Vec<i32>,
    pub lam: Vec<i32>,
    pub flags: Vec<i32>,
    pub v_out: Vec<i32>,
    pub spikes: Vec<i32>,
}

pub fn load_neuron_update(path: &Path) -> Result<NeuronUpdateGolden> {
    let j = load(path)?;
    Ok(NeuronUpdateGolden {
        step_seed: field(&j, "step_seed")?.as_i64().unwrap_or(0) as u32,
        v: i32s(&j, "v")?,
        theta: i32s(&j, "theta")?,
        nu: i32s(&j, "nu")?,
        lam: i32s(&j, "lam")?,
        flags: i32s(&j, "flags")?,
        v_out: i32s(&j, "v_out")?,
        spikes: i32s(&j, "spikes")?,
    })
}

/// synapse_accum.json.
pub struct SynapseAccumGolden {
    pub n: usize,
    pub v: Vec<i32>,
    pub targets: Vec<i32>,
    pub weights: Vec<i32>,
    pub v_out: Vec<i32>,
}

pub fn load_synapse_accum(path: &Path) -> Result<SynapseAccumGolden> {
    let j = load(path)?;
    Ok(SynapseAccumGolden {
        n: field(&j, "n")?.as_i64().unwrap_or(0) as usize,
        v: i32s(&j, "v")?,
        targets: i32s(&j, "targets")?,
        weights: i32s(&j, "weights")?,
        v_out: i32s(&j, "v_out")?,
    })
}

/// dense_net.json: a 12-step dense-network trace.
pub struct DenseNetGolden {
    pub n: usize,
    pub a: usize,
    pub steps: usize,
    pub base_seed: u32,
    pub w_neuron: Vec<Vec<i32>>,
    pub w_axon: Vec<Vec<i32>>,
    pub theta: Vec<i32>,
    pub nu: Vec<i32>,
    pub lam: Vec<i32>,
    pub flags: Vec<i32>,
    pub axon_seq: Vec<Vec<i32>>,
    pub spikes: Vec<Vec<i32>>,
    pub v: Vec<Vec<i32>>,
}

pub fn load_dense_net(path: &Path) -> Result<DenseNetGolden> {
    let j = load(path)?;
    let mat = |k: &str| -> Result<Vec<Vec<i32>>> {
        field(&j, k)?
            .as_arr()
            .ok_or_else(|| anyhow!("{k} not array"))?
            .iter()
            .map(|row| row.i32_vec().ok_or_else(|| anyhow!("{k} row not ints")))
            .collect()
    };
    Ok(DenseNetGolden {
        n: field(&j, "n")?.as_i64().unwrap_or(0) as usize,
        a: field(&j, "a")?.as_i64().unwrap_or(0) as usize,
        steps: field(&j, "steps")?.as_i64().unwrap_or(0) as usize,
        base_seed: field(&j, "base_seed")?.as_i64().unwrap_or(0) as u32,
        w_neuron: mat("w_neuron")?,
        w_axon: mat("w_axon")?,
        theta: i32s(&j, "theta")?,
        nu: i32s(&j, "nu")?,
        lam: i32s(&j, "lam")?,
        flags: i32s(&j, "flags")?,
        axon_seq: mat("axon_seq")?,
        spikes: mat("spikes")?,
        v: mat("v")?,
    })
}
