//! `.hsd` test-set format: labelled spike-frame samples written by
//! `python/train/export.py::write_hsd`.
//!
//! ```text
//! magic  8B "HSDATA1\0"
//! header u32 n_samples, u32 frames_per_sample, u32 n_axons
//! sample u8 label, then frames_per_sample x (u32 k, k x u32 axon ids)
//! ```

use std::fs::File;
use std::io::BufReader;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Reader;

pub const HSD_MAGIC: &[u8; 8] = b"HSDATA1\x00";

#[derive(Clone, Debug)]
pub struct Sample {
    pub label: u8,
    /// active axon ids per frame, ascending
    pub frames: Vec<Vec<u32>>,
}

#[derive(Clone, Debug)]
pub struct TestSet {
    pub n_axons: usize,
    pub frames_per_sample: usize,
    pub samples: Vec<Sample>,
}

pub fn read_hsd<P: AsRef<Path>>(path: P) -> Result<TestSet> {
    let f = File::open(&path)
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = Reader::new(BufReader::new(f));
    r.magic(HSD_MAGIC)?;
    let n_samples = r.u32()? as usize;
    let frames_per_sample = r.u32()? as usize;
    let n_axons = r.u32()? as usize;
    let mut samples = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let label = r.u8()?;
        let mut frames = Vec::with_capacity(frames_per_sample);
        for _ in 0..frames_per_sample {
            let k = r.u32()? as usize;
            let mut ids = Vec::with_capacity(k);
            for _ in 0..k {
                let id = r.u32()?;
                if id as usize >= n_axons {
                    bail!("axon id {id} out of range ({n_axons})");
                }
                ids.push(id);
            }
            ids.sort_unstable();
            ids.dedup();
            frames.push(ids);
        }
        samples.push(Sample { label, frames });
    }
    Ok(TestSet { n_axons, frames_per_sample, samples })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_handwritten_blob() {
        let mut b = Vec::new();
        b.extend_from_slice(HSD_MAGIC);
        b.extend_from_slice(&2u32.to_le_bytes()); // samples
        b.extend_from_slice(&1u32.to_le_bytes()); // frames
        b.extend_from_slice(&10u32.to_le_bytes()); // axons
        // sample 0: label 3, frame [2, 5]
        b.push(3);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&5u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        // sample 1: label 7, empty frame
        b.push(7);
        b.extend_from_slice(&0u32.to_le_bytes());
        let p = std::env::temp_dir().join(format!("t_{}.hsd", std::process::id()));
        std::fs::write(&p, &b).unwrap();
        let ts = read_hsd(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ts.n_axons, 10);
        assert_eq!(ts.samples.len(), 2);
        assert_eq!(ts.samples[0].label, 3);
        assert_eq!(ts.samples[0].frames[0], vec![2, 5]); // sorted
        assert_eq!(ts.samples[1].frames[0], Vec::<u32>::new());
    }

    #[test]
    fn rejects_out_of_range_axon() {
        let mut b = Vec::new();
        b.extend_from_slice(HSD_MAGIC);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&4u32.to_le_bytes());
        b.push(0);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&9u32.to_le_bytes()); // >= 4
        let p = std::env::temp_dir().join(format!("bad_{}.hsd", std::process::id()));
        std::fs::write(&p, &b).unwrap();
        assert!(read_hsd(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
