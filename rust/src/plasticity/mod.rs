//! Runtime plasticity: the pair-based STDP learning kernel (ROADMAP
//! "runtime plasticity and live reconfiguration"; SpiNNaker2-style
//! event-based learning in PAPERS.md).
//!
//! This module is the **learning-kernel half** of the plasticity
//! subsystem; the other half — the [`crate::snn::EditJournal`] overlay
//! for explicit `write_synapse`/`add_synapse`/`remove_synapse` edits —
//! lives with the network primitives it edits. Both surface through
//! [`crate::sim::Simulator`] (`write_synapse`/`apply_edits`) and the
//! session protocol (`write_synapse`, `configure` with `"learning"`).
//!
//! # The rule
//!
//! Opt-in pair-based STDP with per-neuron eligibility traces, all in the
//! same fixed-point integer arithmetic as the membrane kernel:
//!
//! * every neuron keeps a **pre trace** and a **post trace**; every axon
//!   keeps a pre trace. A trace decays exponentially by shift
//!   (`tr -= tr >> tau`, the FLAG_LIF leak idiom) and is bumped by
//!   [`TRACE_ONE`] (saturating at [`TRACE_CEIL`]) when its source fires;
//! * when a source fires, every **outgoing** plastic synapse is
//!   *depressed* by `(a_minus * trace_post[target]) >> TRACE_SHIFT`;
//! * when a neuron fires, every **incoming** plastic synapse is
//!   *potentiated* by `(a_plus * pre_trace[source]) >> TRACE_SHIFT`;
//! * every delta is applied per-slot and clamped to
//!   `[w_min, w_max]`. Deltas are **additive** (independent of the
//!   current weight), so the order in which distinct slots are updated
//!   can never change any weight's value.
//!
//! A synapse is **plastic** iff it participates in delivery — i.e. its
//! HBM `row_mask` bit is set (valid entry, non-zero weight at compile
//! time or set non-zero by a live edit). Learning never clears a mask
//! bit: a weight driven to zero stays plastic and can recover.
//!
//! # Trace/update ordering contract
//!
//! Per timestep `t` (one `step()` = membrane sweep + route), in this
//! exact order — every execution path (serial engine, chunk-parallel
//! `CorePool`, multi-core cluster, sharded multi-process) implements
//! the same sequence, which is why learning runs are bit-identical
//! across worker counts, chunk sizes, route granularities and shard
//! counts:
//!
//! 1. **sweep** — membranes update and the spike bitmask for step `t`
//!    is written (weights play no part here);
//! 2. **neuron traces** — every neuron's pre and post trace decays,
//!    then fired neurons' traces are bumped ([`trace_chunk`], run over
//!    the same word-aligned chunks as `sweep_chunk`; per-lane
//!    independent, so chunking/order is irrelevant);
//! 3. **axon traces** — every axon trace decays, then axons delivered
//!    this step (`axon_in`, which in the cluster includes the dedicated
//!    local axon of each remote source — delivery is same-step, so the
//!    local trace mirrors the remote neuron's trace exactly) are
//!    bumped;
//! 4. **deliveries accumulate** — phase-4 consumes events gathered in
//!    phase 2, i.e. with the weights as of the **end of step `t-1`**;
//! 5. **depression** — for every source that fired/arrived at step `t`,
//!    each outgoing plastic slot gets `-(a_minus * trace_post[target])
//!    >> TRACE_SHIFT` (post traces already include step-`t` bumps:
//!    same-step pre/post pairing counts);
//! 6. **potentiation** — for every neuron that fired at step `t`, each
//!    incoming plastic slot gets `+(a_plus * pre_trace[src]) >>
//!    TRACE_SHIFT` (pre traces likewise include step-`t` bumps). A slot
//!    whose source **and** target both fired is depressed first, then
//!    potentiated, each step clamped at application.
//!
//! All weight mutation happens in the serial RouteAccum epilogue
//! (`route_finish`), after the ordered buffer merge — the chunk-merge
//! determinism contract of the route phase is untouched. Stochastic
//! neurons keep their counter-based `noise17(mix_seed(base_seed, t), i)`
//! schedule, so a learning run is a pure function of (network, seed,
//! stimulus): re-running reproduces every spike, membrane and final
//! weight bit-for-bit.
//!
//! Not modelled (ROADMAP follow-ups): reward-modulated (three-factor)
//! STDP, and structural plasticity — learning never creates or removes
//! synapses; that is the edit journal's job.

mod stdp;

pub use stdp::{
    apply_delta, decay_trace, stdp_delta, trace_chunk, InEdge, PlasticState, TRACE_CEIL, TRACE_ONE,
    TRACE_SHIFT,
};

/// STDP rule parameters (the `SimConfig` / session `configure.learning`
/// surface). Amplitudes are non-negative fixed-point factors applied as
/// `(a * trace) >> TRACE_SHIFT`: with the trace freshly bumped
/// ([`TRACE_ONE`] = `1 << TRACE_SHIFT`), a same-step pairing moves the
/// weight by exactly `a_plus` (or `-a_minus`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlasticityConfig {
    /// Potentiation amplitude (post fires after/with pre), >= 0.
    pub a_plus: i32,
    /// Depression amplitude (pre fires after/with post), >= 0.
    pub a_minus: i32,
    /// Pre-trace decay shift: `tr -= tr >> tau_pre` per step (window
    /// ~`2^tau_pre` steps). 0 = traces survive only within the step.
    pub tau_pre: u32,
    /// Post-trace decay shift.
    pub tau_post: u32,
    /// Weight clamp floor (inclusive).
    pub w_min: i16,
    /// Weight clamp ceiling (inclusive).
    pub w_max: i16,
}

impl Default for PlasticityConfig {
    fn default() -> Self {
        Self {
            a_plus: 8,
            a_minus: 9,
            tau_pre: 3,
            tau_post: 3,
            w_min: crate::snn::WEIGHT_MIN as i16,
            w_max: crate::snn::WEIGHT_MAX as i16,
        }
    }
}

impl PlasticityConfig {
    /// Reject configurations the fixed-point kernel cannot honour.
    pub fn validate(&self) -> Result<(), String> {
        if self.a_plus < 0 || self.a_minus < 0 {
            return Err(format!(
                "learning amplitudes must be >= 0 (a_plus={}, a_minus={})",
                self.a_plus, self.a_minus
            ));
        }
        if self.a_plus > 1 << 20 || self.a_minus > 1 << 20 {
            return Err("learning amplitudes must be <= 2^20".into());
        }
        if self.tau_pre > 31 || self.tau_post > 31 {
            return Err(format!(
                "tau shifts must be <= 31 (tau_pre={}, tau_post={})",
                self.tau_pre, self.tau_post
            ));
        }
        if self.w_min > self.w_max {
            return Err(format!("w_min {} > w_max {}", self.w_min, self.w_max));
        }
        Ok(())
    }
}
