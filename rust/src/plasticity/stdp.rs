//! The STDP trace kernel and per-engine learning state.
//!
//! [`trace_chunk`] is the branch-free extension of
//! [`crate::engine::backend::sweep_chunk`]: it runs over the same
//! word-aligned chunks, right after the sweep wrote the chunk's spike
//! words, and is per-lane independent — chunking, chunk order and
//! worker interleaving cannot change any trace. [`PlasticState`] holds
//! the traces plus the reverse (incoming-synapse) index over the HBM
//! image that the potentiation pass walks; weight mutation itself
//! happens in the engine's RouteAccum epilogue (see the module docs'
//! ordering contract).

use super::PlasticityConfig;
use crate::engine::mask_words;
use crate::hbm::HbmImage;

/// Trace value added when a source fires; one "unit" of coincidence.
pub const TRACE_ONE: i32 = 1 << TRACE_SHIFT;
/// Saturation ceiling for traces (bounds `a * trace` well inside i64).
pub const TRACE_CEIL: i32 = 1 << 20;
/// Fixed-point shift applied to `amplitude * trace` products.
pub const TRACE_SHIFT: u32 = 10;

/// One decay step: `tr - (tr >> tau)`, the FLAG_LIF leak idiom. Traces
/// are non-negative, so the shift is a floor division and the result
/// stays in `[0, tr]`.
#[inline(always)]
pub fn decay_trace(tr: i32, tau: u32) -> i32 {
    tr - (tr >> tau.min(31))
}

/// Fixed-point STDP delta: `(a * trace) >> TRACE_SHIFT`, widened to
/// i64 so saturated traces times large amplitudes cannot overflow.
#[inline(always)]
pub fn stdp_delta(a: i32, trace: i32) -> i32 {
    ((a as i64 * trace as i64) >> TRACE_SHIFT) as i32
}

/// Apply one clamped additive delta to a weight.
#[inline(always)]
pub fn apply_delta(w: i16, delta: i32, cfg: &PlasticityConfig) -> i16 {
    (w as i32).saturating_add(delta).clamp(cfg.w_min as i32, cfg.w_max as i32) as i16
}

/// Decay-then-bump both neuron traces over one word-aligned chunk.
///
/// `pre`/`post` cover the same neurons as `spikes` (`mask_words` words
/// for `pre.len()` lanes); the chunk's first neuron must sit on a word
/// boundary, exactly like `sweep_chunk`. Branch-free per lane: the
/// fired bit multiplies the bump in, and saturation is a `min`.
pub fn trace_chunk(spikes: &[u64], pre: &mut [i32], post: &mut [i32], tau_pre: u32, tau_post: u32) {
    let n = pre.len();
    debug_assert_eq!(post.len(), n);
    debug_assert_eq!(spikes.len(), mask_words(n));
    for (w, &word) in spikes.iter().enumerate() {
        let base = w * 64;
        let valid = 64.min(n - base);
        for lane in 0..valid {
            let i = base + lane;
            let fired = ((word >> lane) & 1) as i32;
            pre[i] = (decay_trace(pre[i], tau_pre) + fired * TRACE_ONE).min(TRACE_CEIL);
            post[i] = (decay_trace(post[i], tau_post) + fired * TRACE_ONE).min(TRACE_CEIL);
        }
    }
}

/// Address of one plastic synapse slot in the HBM image, as seen from
/// its **target** (the potentiation pass walks these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InEdge {
    /// Synapse-section row holding the slot.
    pub row: u32,
    /// Slot within the row (== `slot_of[target]`).
    pub slot: u8,
    /// Source is an axon (true) or a local neuron (false).
    pub axon_src: bool,
    /// Source id in its own namespace.
    pub src: u32,
}

/// Per-engine learning state: the rule parameters, the eligibility
/// traces, and the reverse in-edge index over the compiled image.
///
/// The in-edge index covers exactly the plastic slots (row-mask bits at
/// construction) and is kept in sync by the engine's live-edit path
/// ([`PlasticState::note_install`] / [`PlasticState::note_remove`]).
/// `reset()` clears the traces but **keeps** learned weights — they
/// live in the image, and resetting a session back to quiescent
/// membranes must not undo learning.
pub struct PlasticState {
    pub cfg: PlasticityConfig,
    /// Per-neuron presynaptic trace (for the neuron's outgoing slots).
    pub trace_pre: Vec<i32>,
    /// Per-neuron postsynaptic trace (for the neuron's incoming slots).
    pub trace_post: Vec<i32>,
    /// Per-axon presynaptic trace, advanced with the route phase.
    pub trace_axon: Vec<i32>,
    /// Incoming plastic slots per target neuron.
    pub in_edges: Vec<Vec<InEdge>>,
    /// Weight deltas applied since construction/`reset_cost`-style
    /// clears (diagnostics; not part of the determinism contract).
    pub events: u64,
}

impl PlasticState {
    /// Build the learning state for a compiled image: zero traces plus
    /// the reverse index of every masked (plastic) slot, axon regions
    /// first, then neuron regions — construction order only affects the
    /// order slots are visited, never any weight value (deltas are
    /// per-slot and additive).
    pub fn from_image(image: &HbmImage, cfg: PlasticityConfig) -> Self {
        let n = image.n_neurons;
        let mut in_edges: Vec<Vec<InEdge>> = vec![Vec::new(); n];
        let mut index_region = |ptr: crate::hbm::Pointer, axon_src: bool, src: u32| {
            for r in ptr.start_row..ptr.start_row + ptr.rows {
                let mut m = image.row_mask[r as usize];
                while m != 0 {
                    let slot = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let t = image.syn_rows[r as usize][slot].target as usize;
                    in_edges[t].push(InEdge { row: r, slot: slot as u8, axon_src, src });
                }
            }
        };
        for (a, &p) in image.axon_ptr.iter().enumerate() {
            index_region(p, true, a as u32);
        }
        for (i, &p) in image.neuron_ptr.iter().enumerate() {
            index_region(p, false, i as u32);
        }
        Self {
            cfg,
            trace_pre: vec![0; n],
            trace_post: vec![0; n],
            trace_axon: vec![0; image.n_axons],
            in_edges,
            events: 0,
        }
    }

    /// Clear all traces (session reset). Learned weights stay.
    pub fn reset(&mut self) {
        self.trace_pre.iter_mut().for_each(|t| *t = 0);
        self.trace_post.iter_mut().for_each(|t| *t = 0);
        self.trace_axon.iter_mut().for_each(|t| *t = 0);
    }

    /// Presynaptic trace of a source in either namespace.
    #[inline]
    pub fn pre_trace(&self, axon_src: bool, src: u32) -> i32 {
        if axon_src {
            self.trace_axon[src as usize]
        } else {
            self.trace_pre[src as usize]
        }
    }

    /// A live edit installed (or re-armed) a plastic slot: index it.
    /// Idempotent per (row, slot) — re-writing an already-plastic slot
    /// must not duplicate its in-edge.
    pub fn note_install(&mut self, row: u32, slot: u8, axon_src: bool, src: u32, target: u32) {
        let list = &mut self.in_edges[target as usize];
        if !list.iter().any(|e| e.row == row && e.slot == slot) {
            list.push(InEdge { row, slot, axon_src, src });
        }
    }

    /// A live edit removed a slot: drop it from the reverse index.
    pub fn note_remove(&mut self, row: u32, slot: u8, target: u32) {
        self.in_edges[target as usize].retain(|e| !(e.row == row && e.slot == slot));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_decay_and_bump() {
        // tau=1 halves; bump adds TRACE_ONE; ceiling saturates
        let mut pre = vec![0i32, 1024, TRACE_CEIL];
        let mut post = vec![0i32, 0, 0];
        // neurons 0 and 2 fire
        trace_chunk(&[0b101], &mut pre, &mut post, 1, 2);
        assert_eq!(pre[0], TRACE_ONE);
        assert_eq!(pre[1], 512); // decayed, no bump
        assert_eq!(pre[2], TRACE_CEIL); // saturated
        assert_eq!(post[0], TRACE_ONE);
        assert_eq!(post[1], 0);
        assert_eq!(post[2], TRACE_ONE);
    }

    #[test]
    fn trace_chunking_is_order_invariant() {
        let n = 130;
        let spikes: Vec<u64> = vec![0xDEADBEEF, u64::MAX, 0b11];
        let mut pre_a = (0..n as i32).map(|i| i * 7).collect::<Vec<_>>();
        let mut post_a = (0..n as i32).map(|i| i * 3).collect::<Vec<_>>();
        let mut pre_b = pre_a.clone();
        let mut post_b = post_a.clone();
        trace_chunk(&spikes, &mut pre_a, &mut post_a, 2, 4);
        // word-by-word, reversed order
        for w in (0..3usize).rev() {
            let lo = w * 64;
            let hi = (lo + 64).min(n);
            trace_chunk(
                &spikes[w..w + 1],
                &mut pre_b[lo..hi],
                &mut post_b[lo..hi],
                2,
                4,
            );
        }
        assert_eq!(pre_a, pre_b);
        assert_eq!(post_a, post_b);
    }

    #[test]
    fn delta_clamps_and_saturates() {
        let cfg = PlasticityConfig { w_min: -4, w_max: 7, ..PlasticityConfig::default() };
        assert_eq!(stdp_delta(8, TRACE_ONE), 8);
        assert_eq!(stdp_delta(1 << 20, TRACE_CEIL), 1 << 30); // no overflow
        assert_eq!(apply_delta(5, 100, &cfg), 7);
        assert_eq!(apply_delta(5, -100, &cfg), -4);
        assert_eq!(apply_delta(0, 3, &cfg), 3);
    }

    #[test]
    fn config_validation() {
        assert!(PlasticityConfig::default().validate().is_ok());
        assert!(PlasticityConfig { a_plus: -1, ..Default::default() }.validate().is_err());
        assert!(PlasticityConfig { tau_pre: 32, ..Default::default() }.validate().is_err());
        assert!(PlasticityConfig { w_min: 5, w_max: 4, ..Default::default() }
            .validate()
            .is_err());
    }
}
