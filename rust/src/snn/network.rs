//! The flattened network representation and its keyed builder.
//!
//! # CSR memory layout
//!
//! Connectivity is stored as one compressed-sparse-row (CSR) structure
//! shared by neurons and axons — the in-memory mirror of the HBM synapse
//! section (contiguous region per source):
//!
//! ```text
//! syn_targets : [ n0 syns | n1 syns | ... | a0 syns | a1 syns | ... ]  u32
//! syn_weights : [    parallel to syn_targets                       ]  i16
//! neuron_off  : n_neurons + 1 offsets into the flat arrays
//! axon_off    : n_axons + 1 offsets; axon_off[0] == neuron_off[n]
//! ```
//!
//! Neuron `i`'s outgoing synapses occupy
//! `syn_targets[neuron_off[i] .. neuron_off[i+1]]` (axons analogously,
//! after all neuron regions). Compared to the seed's
//! `Vec<Vec<Synapse>>` this removes one heap allocation + pointer chase
//! per source, makes whole-network sweeps (fan-in, HBM compile,
//! partition cuts) a single linear scan, and lets `split_network`
//! extract sub-networks by offset arithmetic. Offsets are `u32`: a
//! single in-memory `Network` holds < 2^32 synapses (the per-core HBM
//! budget is 32M; cluster-scale networks are partitioned before they
//! are materialised per core).
//!
//! Every per-source slice is sorted by target id
//! ([`Network::sort_synapses`] runs at the end of every construction
//! path), which enables the binary-search `read_synapse` /
//! `write_synapse` and gives all builders one canonical form.
//! Duplicate (source, target) pairs are allowed (weights accumulate at
//! delivery); lookups resolve to one of the duplicates.

use std::collections::HashMap;
use std::ops::Range;

use thiserror::Error;

use super::neuron::NeuronModel;

/// Synaptic weights are 16-bit signed integers in HBM.
pub const WEIGHT_MIN: i32 = -(1 << 15);
pub const WEIGHT_MAX: i32 = (1 << 15) - 1;

/// One synapse: postsynaptic neuron index + int16 weight. Construction
/// currency only — the stored form is the CSR arrays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Synapse {
    pub target: u32,
    pub weight: i16,
}

#[derive(Debug, Error)]
pub enum NetError {
    #[error("duplicate key {0:?}")]
    DuplicateKey(String),
    #[error("neuron {pre:?} synapse targets unknown neuron {target:?}")]
    UnknownNeuronTarget { pre: String, target: String },
    #[error("axon {pre:?} synapse targets unknown neuron {target:?}")]
    UnknownAxonTarget { pre: String, target: String },
    #[error("weight {0} outside int16 range")]
    BadWeight(i32),
    #[error("output {0:?} is not a neuron")]
    BadOutput(String),
}

/// Flattened, index-based network — the form consumed by the HBM
/// compiler, the engines and the partitioner. Axons and neurons are
/// contiguous 0-based index spaces; connectivity is CSR (module docs).
#[derive(Clone, Debug)]
pub struct Network {
    /// Per-neuron model parameters.
    pub params: Vec<NeuronModel>,
    /// Flat synapse targets (neuron regions, then axon regions).
    pub syn_targets: Vec<u32>,
    /// Flat synapse weights, parallel to `syn_targets`.
    pub syn_weights: Vec<i16>,
    /// Per-neuron region offsets (`n_neurons + 1` entries).
    pub neuron_off: Vec<u32>,
    /// Per-axon region offsets (`n_axons + 1`; first == last neuron_off).
    pub axon_off: Vec<u32>,
    /// Indices of monitored output neurons.
    pub outputs: Vec<u32>,
    /// Base RNG seed for the stochastic neuron noise.
    pub base_seed: u32,
}

impl Default for Network {
    fn default() -> Self {
        Network {
            params: Vec::new(),
            syn_targets: Vec::new(),
            syn_weights: Vec::new(),
            neuron_off: vec![0],
            axon_off: vec![0],
            outputs: Vec::new(),
            base_seed: 0,
        }
    }
}

impl Network {
    pub fn n_neurons(&self) -> usize {
        self.params.len()
    }

    pub fn n_axons(&self) -> usize {
        self.axon_off.len() - 1
    }

    pub fn n_synapses(&self) -> usize {
        self.syn_targets.len()
    }

    /// Flat-array range of neuron `i`'s outgoing synapses.
    #[inline]
    pub fn neuron_range(&self, i: usize) -> Range<usize> {
        self.neuron_off[i] as usize..self.neuron_off[i + 1] as usize
    }

    /// Flat-array range of axon `i`'s outgoing synapses.
    #[inline]
    pub fn axon_range(&self, i: usize) -> Range<usize> {
        self.axon_off[i] as usize..self.axon_off[i + 1] as usize
    }

    /// Contiguous (targets, weights) slices of neuron `i`.
    #[inline]
    pub fn neuron_syns(&self, i: usize) -> (&[u32], &[i16]) {
        let r = self.neuron_range(i);
        (&self.syn_targets[r.clone()], &self.syn_weights[r])
    }

    /// Contiguous (targets, weights) slices of axon `i`.
    #[inline]
    pub fn axon_syns(&self, i: usize) -> (&[u32], &[i16]) {
        let r = self.axon_range(i);
        (&self.syn_targets[r.clone()], &self.syn_weights[r])
    }

    /// Target ids of neuron `i`'s outgoing synapses.
    #[inline]
    pub fn neuron_targets(&self, i: usize) -> &[u32] {
        &self.syn_targets[self.neuron_range(i)]
    }

    /// Target ids of axon `i`'s outgoing synapses.
    #[inline]
    pub fn axon_targets(&self, i: usize) -> &[u32] {
        &self.syn_targets[self.axon_range(i)]
    }

    /// Out-degree of neuron `i`.
    #[inline]
    pub fn neuron_degree(&self, i: usize) -> usize {
        self.neuron_range(i).len()
    }

    /// Out-degree of axon `i`.
    #[inline]
    pub fn axon_degree(&self, i: usize) -> usize {
        self.axon_range(i).len()
    }

    /// Allocate a CSR skeleton from per-source out-degrees (zeroed
    /// synapse arrays). Fill `syn_targets` / `syn_weights` through the
    /// offset tables, then call [`Self::sort_synapses`].
    pub fn with_degrees(
        params: Vec<NeuronModel>,
        neuron_deg: &[u32],
        axon_deg: &[u32],
        outputs: Vec<u32>,
        base_seed: u32,
    ) -> Network {
        debug_assert_eq!(params.len(), neuron_deg.len());
        // u32 offsets cap one materialised Network at 2^32 synapses; a
        // silent wrap would alias regions undetectably, so fail loudly.
        let grow = |off: u32, d: u32| -> u32 {
            off.checked_add(d)
                .expect("network exceeds u32 CSR offset capacity (2^32 synapses); partition first")
        };
        let mut off = 0u32;
        let mut neuron_off = Vec::with_capacity(neuron_deg.len() + 1);
        neuron_off.push(0);
        for &d in neuron_deg {
            off = grow(off, d);
            neuron_off.push(off);
        }
        let mut axon_off = Vec::with_capacity(axon_deg.len() + 1);
        axon_off.push(off);
        for &d in axon_deg {
            off = grow(off, d);
            axon_off.push(off);
        }
        Network {
            params,
            syn_targets: vec![0; off as usize],
            syn_weights: vec![0; off as usize],
            neuron_off,
            axon_off,
            outputs,
            base_seed,
        }
    }

    /// Build from per-source nested synapse lists — the reference
    /// construction path (tests, format readers, small hand-built nets).
    pub fn from_adj(
        params: Vec<NeuronModel>,
        neuron_adj: &[Vec<Synapse>],
        axon_adj: &[Vec<Synapse>],
        outputs: Vec<u32>,
        base_seed: u32,
    ) -> Network {
        let ndeg: Vec<u32> = neuron_adj.iter().map(|l| l.len() as u32).collect();
        let adeg: Vec<u32> = axon_adj.iter().map(|l| l.len() as u32).collect();
        let mut net = Network::with_degrees(params, &ndeg, &adeg, outputs, base_seed);
        let mut k = 0usize;
        for list in neuron_adj.iter().chain(axon_adj.iter()) {
            for s in list {
                net.syn_targets[k] = s.target;
                net.syn_weights[k] = s.weight;
                k += 1;
            }
        }
        net.sort_synapses();
        net
    }

    /// Canonicalize: sort every per-source slice by target (stable, so
    /// duplicate targets keep insertion order). Required by the
    /// binary-search synapse lookup; every construction path ends here.
    pub fn sort_synapses(&mut self) {
        let n = self.n_neurons();
        let a = self.n_axons();
        let mut scratch: Vec<(u32, i16)> = Vec::new();
        for s in 0..n + a {
            let r = if s < n { self.neuron_range(s) } else { self.axon_range(s - n) };
            if r.len() < 2 {
                continue;
            }
            if self.syn_targets[r.clone()].windows(2).all(|w| w[0] <= w[1]) {
                continue;
            }
            scratch.clear();
            scratch.extend(
                self.syn_targets[r.clone()]
                    .iter()
                    .copied()
                    .zip(self.syn_weights[r.clone()].iter().copied()),
            );
            scratch.sort_by_key(|&(t, _)| t);
            for (k, &(t, w)) in scratch.iter().enumerate() {
                self.syn_targets[r.start + k] = t;
                self.syn_weights[r.start + k] = w;
            }
        }
    }

    /// Total fan-in per neuron (used by the partitioner's traffic model).
    /// One linear pass over the flat target array.
    pub fn fan_in(&self) -> Vec<u32> {
        let mut f = vec![0u32; self.n_neurons()];
        for &t in &self.syn_targets {
            f[t as usize] += 1;
        }
        f
    }

    /// Structural validation: offsets consistent, every synapse target in
    /// range, outputs valid.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_neurons() as u32;
        if self.neuron_off.len() != self.params.len() + 1 {
            return Err("params/neuron_off length mismatch".into());
        }
        if self.neuron_off[0] != 0 {
            return Err("neuron_off must start at 0".into());
        }
        if self.axon_off.is_empty() || self.axon_off[0] != *self.neuron_off.last().unwrap() {
            return Err("axon_off must continue neuron_off".into());
        }
        if self.syn_targets.len() != self.syn_weights.len() {
            return Err("syn_targets/syn_weights length mismatch".into());
        }
        if *self.axon_off.last().unwrap() as usize != self.syn_targets.len() {
            return Err("offset tables do not cover the synapse arrays".into());
        }
        if self.neuron_off.windows(2).any(|w| w[0] > w[1])
            || self.axon_off.windows(2).any(|w| w[0] > w[1])
        {
            return Err("offsets not monotonic".into());
        }
        for (k, &t) in self.syn_targets.iter().enumerate() {
            if t >= n {
                return Err(format!("synapse {k} target {t} out of range"));
            }
        }
        for &o in &self.outputs {
            if o >= n {
                return Err(format!("output {o} out of range"));
            }
        }
        Ok(())
    }
}

/// Flat edge-list construction scratch: O(1) pushes in any source order
/// (the converter visits sources non-sequentially), one counting sort
/// into CSR at the end. No per-source heap allocations.
#[derive(Clone, Debug)]
pub struct EdgeList {
    n_neurons: usize,
    n_axons: usize,
    /// (source slot, target, weight); neurons occupy slots `0..n`,
    /// axons `n..n+a`.
    edges: Vec<(u32, u32, i16)>,
}

impl EdgeList {
    pub fn new(n_neurons: usize, n_axons: usize) -> Self {
        EdgeList { n_neurons, n_axons, edges: Vec::new() }
    }

    pub fn with_capacity(n_neurons: usize, n_axons: usize, cap: usize) -> Self {
        EdgeList { n_neurons, n_axons, edges: Vec::with_capacity(cap) }
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    #[inline]
    pub fn push_neuron(&mut self, pre: u32, target: u32, weight: i16) {
        debug_assert!((pre as usize) < self.n_neurons);
        self.edges.push((pre, target, weight));
    }

    #[inline]
    pub fn push_axon(&mut self, pre: u32, target: u32, weight: i16) {
        debug_assert!((pre as usize) < self.n_axons);
        self.edges.push((self.n_neurons as u32 + pre, target, weight));
    }

    /// Counting-sort the edges into a CSR [`Network`] (stable within a
    /// source, then canonically sorted by target).
    pub fn into_network(
        self,
        params: Vec<NeuronModel>,
        outputs: Vec<u32>,
        base_seed: u32,
    ) -> Network {
        let (n, a) = (self.n_neurons, self.n_axons);
        debug_assert_eq!(params.len(), n);
        let mut deg = vec![0u32; n + a];
        for &(s, _, _) in &self.edges {
            deg[s as usize] += 1;
        }
        let mut net = Network::with_degrees(params, &deg[..n], &deg[n..], outputs, base_seed);
        // scatter with per-source cursors (reuse `deg` as the cursor table)
        for (s, cur) in deg.iter_mut().enumerate() {
            *cur = if s < n { net.neuron_off[s] } else { net.axon_off[s - n] };
        }
        for &(s, t, w) in &self.edges {
            let k = deg[s as usize] as usize;
            net.syn_targets[k] = t;
            net.syn_weights[k] = w;
            deg[s as usize] += 1;
        }
        net.sort_synapses();
        net
    }
}

/// Keyed builder mirroring the `hs_api` dictionaries: axon/neuron keys are
/// strings; `build()` flattens to index space (insertion order).
#[derive(Debug, Default)]
pub struct NetworkBuilder {
    axon_keys: Vec<String>,
    axon_index: HashMap<String, u32>,
    neuron_keys: Vec<String>,
    neuron_index: HashMap<String, u32>,
    models: Vec<NeuronModel>,
    // synapses recorded with string targets, resolved at build()
    neuron_syn: Vec<Vec<(String, i32)>>,
    axon_syn: Vec<Vec<(String, i32)>>,
    outputs: Vec<String>,
    base_seed: u32,
}

impl NetworkBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn seed(mut self, seed: u32) -> Self {
        self.base_seed = seed;
        self
    }

    pub fn add_axon(
        &mut self,
        key: &str,
        synapses: &[(&str, i32)],
    ) -> Result<(), NetError> {
        if self.axon_index.contains_key(key) {
            return Err(NetError::DuplicateKey(key.into()));
        }
        self.axon_index.insert(key.into(), self.axon_keys.len() as u32);
        self.axon_keys.push(key.into());
        self.axon_syn
            .push(synapses.iter().map(|&(t, w)| (t.to_string(), w)).collect());
        Ok(())
    }

    pub fn add_neuron(
        &mut self,
        key: &str,
        model: NeuronModel,
        synapses: &[(&str, i32)],
    ) -> Result<(), NetError> {
        if self.neuron_index.contains_key(key) {
            return Err(NetError::DuplicateKey(key.into()));
        }
        self.neuron_index.insert(key.into(), self.neuron_keys.len() as u32);
        self.neuron_keys.push(key.into());
        self.models.push(model);
        self.neuron_syn
            .push(synapses.iter().map(|&(t, w)| (t.to_string(), w)).collect());
        Ok(())
    }

    pub fn add_output(&mut self, key: &str) {
        self.outputs.push(key.into());
    }

    pub fn neuron_id(&self, key: &str) -> Option<u32> {
        self.neuron_index.get(key).copied()
    }

    pub fn axon_id(&self, key: &str) -> Option<u32> {
        self.axon_index.get(key).copied()
    }

    /// Resolve one source's synapse list. Errors name the presynaptic
    /// source and its kind, so a bad target in a 10M-synapse build is
    /// traceable to the exact axon/neuron that referenced it.
    fn resolve(
        &self,
        pre_key: &str,
        pre_is_axon: bool,
        list: &[(String, i32)],
    ) -> Result<Vec<Synapse>, NetError> {
        list.iter()
            .map(|(t, w)| {
                let target = *self.neuron_index.get(t).ok_or_else(|| {
                    if pre_is_axon {
                        NetError::UnknownAxonTarget { pre: pre_key.into(), target: t.clone() }
                    } else {
                        NetError::UnknownNeuronTarget { pre: pre_key.into(), target: t.clone() }
                    }
                })?;
                if !(WEIGHT_MIN..=WEIGHT_MAX).contains(w) {
                    return Err(NetError::BadWeight(*w));
                }
                Ok(Synapse { target, weight: *w as i16 })
            })
            .collect()
    }

    pub fn build(self) -> Result<(Network, KeyMap), NetError> {
        let neuron_adj = self
            .neuron_syn
            .iter()
            .enumerate()
            .map(|(i, l)| self.resolve(&self.neuron_keys[i], false, l))
            .collect::<Result<Vec<_>, _>>()?;
        let axon_adj = self
            .axon_syn
            .iter()
            .enumerate()
            .map(|(i, l)| self.resolve(&self.axon_keys[i], true, l))
            .collect::<Result<Vec<_>, _>>()?;
        let outputs = self
            .outputs
            .iter()
            .map(|k| {
                self.neuron_index
                    .get(k)
                    .copied()
                    .ok_or_else(|| NetError::BadOutput(k.clone()))
            })
            .collect::<Result<Vec<_>, _>>()?;
        let net =
            Network::from_adj(self.models, &neuron_adj, &axon_adj, outputs, self.base_seed);
        let keys = KeyMap {
            axon_keys: self.axon_keys,
            neuron_keys: self.neuron_keys,
            axon_index: self.axon_index,
            neuron_index: self.neuron_index,
        };
        Ok((net, keys))
    }
}

/// Key <-> index maps retained from the builder for user-facing lookups
/// (`read_synapse("a", "b")` etc.).
#[derive(Clone, Debug, Default)]
pub struct KeyMap {
    pub axon_keys: Vec<String>,
    pub neuron_keys: Vec<String>,
    pub axon_index: HashMap<String, u32>,
    pub neuron_index: HashMap<String, u32>,
}

impl KeyMap {
    pub fn neuron(&self, key: &str) -> Option<u32> {
        self.neuron_index.get(key).copied()
    }

    pub fn axon(&self, key: &str) -> Option<u32> {
        self.axon_index.get(key).copied()
    }
}

/// Mutable synapse access on the flattened network (paper API
/// `read_synapse` / `write_synapse`). Binary search over the per-source
/// CSR slice (sorted by target at build time): O(log deg) instead of the
/// seed's linear scan.
impl Network {
    fn find_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Option<usize> {
        let r = if pre_is_axon {
            self.axon_range(pre as usize)
        } else {
            self.neuron_range(pre as usize)
        };
        self.syn_targets[r.clone()]
            .binary_search(&post)
            .ok()
            .map(|k| r.start + k)
    }

    /// Flat-index range of every `(pre, post)` duplicate (contiguous,
    /// because per-source slices are sorted by target). Empty if absent.
    fn synapse_run(&self, pre_is_axon: bool, pre: u32, post: u32) -> Range<usize> {
        let r = if pre_is_axon {
            self.axon_range(pre as usize)
        } else {
            self.neuron_range(pre as usize)
        };
        let s = self.syn_targets[r.clone()].partition_point(|&t| t < post);
        let e = self.syn_targets[r.clone()].partition_point(|&t| t <= post);
        r.start + s..r.start + e
    }

    /// Weight of the first `(pre, post)` duplicate (they are adjacent;
    /// after any `write_synapse` all duplicates hold the same weight).
    pub fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Option<i16> {
        let run = self.synapse_run(pre_is_axon, pre, post);
        if run.is_empty() {
            None
        } else {
            Some(self.syn_weights[run.start])
        }
    }

    /// Set the weight of `(pre, post)`. Every duplicate slot is written
    /// (delivery sums duplicates, so partial writes would make the
    /// effective weight depend on which duplicate a lookup resolved to).
    /// Returns `false` if no such synapse exists — use
    /// [`Network::add_synapse`] to create one.
    pub fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> bool {
        let run = self.synapse_run(pre_is_axon, pre, post);
        if run.is_empty() {
            return false;
        }
        for k in run {
            self.syn_weights[k] = weight;
        }
        true
    }

    /// Upsert a synapse: overwrite `(pre, post)` if present (all
    /// duplicates, as [`Network::write_synapse`]), else splice a new slot
    /// into the sorted per-source slice and shift the offset tables.
    /// Returns `true` if a new synapse was created. O(n_synapses) on
    /// insert — live engines buffer edits in [`super::EditJournal`] and
    /// compact instead of calling this per edit.
    pub fn add_synapse(&mut self, pre_is_axon: bool, pre: u32, post: u32, weight: i16) -> bool {
        if self.write_synapse(pre_is_axon, pre, post, weight) {
            return false;
        }
        let run = self.synapse_run(pre_is_axon, pre, post);
        self.syn_targets.insert(run.start, post);
        self.syn_weights.insert(run.start, weight);
        self.shift_offsets(pre_is_axon, pre, 1);
        true
    }

    /// Remove every `(pre, post)` duplicate. Returns the number removed.
    pub fn remove_synapse(&mut self, pre_is_axon: bool, pre: u32, post: u32) -> usize {
        let run = self.synapse_run(pre_is_axon, pre, post);
        let count = run.len();
        if count > 0 {
            self.syn_targets.drain(run.clone());
            self.syn_weights.drain(run);
            self.shift_offsets(pre_is_axon, pre, -(count as i64));
        }
        count
    }

    /// Shift every offset after source `pre`'s region by `delta` slots.
    fn shift_offsets(&mut self, pre_is_axon: bool, pre: u32, delta: i64) {
        let apply = |o: &mut u32| *o = (*o as i64 + delta) as u32;
        if !pre_is_axon {
            for o in &mut self.neuron_off[pre as usize + 1..] {
                apply(o);
            }
            for o in &mut self.axon_off {
                apply(o);
            }
        } else {
            for o in &mut self.axon_off[pre as usize + 1..] {
                apply(o);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    /// The Fig-6 / Supplementary-A.1 example network.
    pub fn fig6() -> (Network, KeyMap) {
        let lif_ab = NeuronModel::lif(3, 0, 63, false).unwrap();
        let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
        let ann_d = NeuronModel::ann(5, 0, true).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("a", lif_ab, &[("b", 1), ("d", 2)]).unwrap();
        b.add_neuron("b", lif_ab, &[]).unwrap();
        b.add_neuron("c", lif_c, &[]).unwrap();
        b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
        b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
        b.add_axon("beta", &[("b", 3)]).unwrap();
        b.add_output("a");
        b.add_output("b");
        b.build().unwrap()
    }

    #[test]
    fn fig6_structure() {
        let (net, keys) = fig6();
        assert_eq!(net.n_neurons(), 4);
        assert_eq!(net.n_axons(), 2);
        assert_eq!(net.n_synapses(), 6);
        assert_eq!(net.outputs.len(), 2);
        let a = keys.neuron("a").unwrap();
        let b = keys.neuron("b").unwrap();
        assert_eq!(net.read_synapse(false, a, b), Some(1));
        let alpha = keys.axon("alpha").unwrap();
        assert_eq!(net.read_synapse(true, alpha, a), Some(3));
        net.validate().unwrap();
    }

    #[test]
    fn csr_offsets_and_slices() {
        let (net, keys) = fig6();
        // neuron a has 2 synapses, b and c none, d one; axons 2 + 1
        assert_eq!(net.neuron_off, vec![0, 2, 2, 2, 3]);
        assert_eq!(net.axon_off, vec![3, 5, 6]);
        let a = keys.neuron("a").unwrap() as usize;
        let (tg, wt) = net.neuron_syns(a);
        assert_eq!(tg, &[1, 3]); // sorted by target: b(1), d(3)
        assert_eq!(wt, &[1, 2]);
        let (tg, wt) = net.axon_syns(keys.axon("alpha").unwrap() as usize);
        assert_eq!(tg, &[0, 2]);
        assert_eq!(wt, &[3, 2]);
        assert_eq!(net.neuron_degree(a), 2);
        assert_eq!(net.axon_degree(1), 1);
    }

    #[test]
    fn write_synapse_updates() {
        let (mut net, keys) = fig6();
        let a = keys.neuron("a").unwrap();
        let b = keys.neuron("b").unwrap();
        assert!(net.write_synapse(false, a, b, 2));
        assert_eq!(net.read_synapse(false, a, b), Some(2));
        let c = keys.neuron("c").unwrap();
        assert!(!net.write_synapse(false, b, c, 1)); // no such synapse
    }

    #[test]
    fn add_remove_synapse_splice_csr() {
        let (mut net, keys) = fig6();
        let b = keys.neuron("b").unwrap();
        let c = keys.neuron("c").unwrap();
        let before = net.n_synapses();
        // b has no outgoing synapses; create b -> c
        assert!(net.add_synapse(false, b, c, 7));
        assert_eq!(net.n_synapses(), before + 1);
        assert_eq!(net.read_synapse(false, b, c), Some(7));
        net.validate().unwrap();
        // upsert on an existing synapse overwrites in place
        assert!(!net.add_synapse(false, b, c, 9));
        assert_eq!(net.n_synapses(), before + 1);
        assert_eq!(net.read_synapse(false, b, c), Some(9));
        // axon-sourced splice
        let beta = keys.axon("beta").unwrap();
        assert!(net.add_synapse(true, beta, c, -3));
        assert_eq!(net.read_synapse(true, beta, c), Some(-3));
        net.validate().unwrap();
        // removals restore the original counts
        assert_eq!(net.remove_synapse(false, b, c), 1);
        assert_eq!(net.remove_synapse(true, beta, c), 1);
        assert_eq!(net.remove_synapse(false, b, c), 0);
        assert_eq!(net.n_synapses(), before);
        assert_eq!(net.read_synapse(false, b, c), None);
        net.validate().unwrap();
    }

    #[test]
    fn write_and_remove_cover_duplicates() {
        // duplicate (0 -> 1) synapses built through from_adj
        let m = NeuronModel::if_neuron(5);
        let adj = vec![
            vec![Synapse { target: 1, weight: 2 }, Synapse { target: 1, weight: 3 }],
            vec![],
        ];
        let mut net = Network::from_adj(vec![m; 2], &adj, &[], vec![], 0);
        assert_eq!(net.read_synapse(false, 0, 1), Some(2)); // first duplicate
        assert!(net.write_synapse(false, 0, 1, 5));
        assert_eq!(net.neuron_syns(0).1, &[5, 5]); // both slots written
        assert_eq!(net.remove_synapse(false, 0, 1), 2);
        assert_eq!(net.n_synapses(), 0);
        net.validate().unwrap();
    }

    #[test]
    fn synapse_lookup_hit_and_miss_both_source_kinds() {
        let m = NeuronModel::if_neuron(5);
        let keys: Vec<String> = (0..20).map(|i| format!("n{i}")).collect();
        // neuron 0 -> {3, 7, 11}, axon -> {2, 7, 19}
        let mut b = NetworkBuilder::new();
        for (i, k) in keys.iter().enumerate() {
            let syns: Vec<(&str, i32)> = if i == 0 {
                vec![("n3", 30), ("n7", 70), ("n11", 110)]
            } else {
                vec![]
            };
            b.add_neuron(k, m, &syns).unwrap();
        }
        b.add_axon("ax", &[("n2", 2), ("n7", 7), ("n19", 19)]).unwrap();
        let (mut net, _) = b.build().unwrap();
        // neuron-source hits
        assert_eq!(net.read_synapse(false, 0, 3), Some(30));
        assert_eq!(net.read_synapse(false, 0, 7), Some(70));
        assert_eq!(net.read_synapse(false, 0, 11), Some(110));
        // neuron-source misses (below, between, above the slice)
        assert_eq!(net.read_synapse(false, 0, 2), None);
        assert_eq!(net.read_synapse(false, 0, 8), None);
        assert_eq!(net.read_synapse(false, 0, 12), None);
        assert_eq!(net.read_synapse(false, 5, 3), None); // empty source
        // axon-source hits + misses
        assert_eq!(net.read_synapse(true, 0, 7), Some(7));
        assert_eq!(net.read_synapse(true, 0, 19), Some(19));
        assert_eq!(net.read_synapse(true, 0, 0), None);
        assert_eq!(net.read_synapse(true, 0, 18), None);
        // write through both kinds
        assert!(net.write_synapse(true, 0, 2, -9));
        assert_eq!(net.read_synapse(true, 0, 2), Some(-9));
        assert!(!net.write_synapse(true, 0, 4, 1));
    }

    #[test]
    fn prop_lookup_matches_linear_scan() {
        ptest::check("synapse_lookup_vs_linear", 30, |rng| {
            let n = 4 + rng.below(40) as usize;
            let m = NeuronModel::if_neuron(1);
            let mut b = NetworkBuilder::new();
            let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
            for key in &keys {
                let deg = rng.below(12) as usize;
                let syns: Vec<(String, i32)> = (0..deg)
                    .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-99, 99)))
                    .collect();
                let refs: Vec<(&str, i32)> =
                    syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
                b.add_neuron(key, m, &refs).unwrap();
            }
            let (net, _) = b.build().unwrap();
            for pre in 0..n as u32 {
                for post in 0..n as u32 {
                    let (tg, wt) = net.neuron_syns(pre as usize);
                    let linear =
                        tg.iter().position(|&t| t == post).map(|k| wt[k]);
                    let got = net.read_synapse(false, pre, post);
                    ptest::prop_assert_eq(
                        got.is_some(),
                        linear.is_some(),
                        &format!("hit/miss {pre}->{post}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn duplicate_and_unknown_keys() {
        let m = NeuronModel::ann(1, 0, false).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("x", m, &[]).unwrap();
        assert!(matches!(b.add_neuron("x", m, &[]), Err(NetError::DuplicateKey(_))));
        let mut b2 = NetworkBuilder::new();
        b2.add_neuron("x", m, &[("ghost", 1)]).unwrap();
        match b2.build() {
            Err(NetError::UnknownNeuronTarget { pre, target }) => {
                assert_eq!(pre, "x");
                assert_eq!(target, "ghost");
            }
            other => panic!("expected UnknownNeuronTarget, got {other:?}"),
        }
        let mut b3 = NetworkBuilder::new();
        b3.add_neuron("x", m, &[]).unwrap();
        b3.add_axon("in", &[("ghost", 1)]).unwrap();
        match b3.build() {
            Err(NetError::UnknownAxonTarget { pre, target }) => {
                assert_eq!(pre, "in");
                assert_eq!(target, "ghost");
            }
            other => panic!("expected UnknownAxonTarget, got {other:?}"),
        }
    }

    #[test]
    fn weight_range_checked() {
        let m = NeuronModel::ann(1, 0, false).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("x", m, &[]).unwrap();
        b.add_axon("in", &[("x", 1 << 15)]).unwrap();
        assert!(matches!(b.build(), Err(NetError::BadWeight(_))));
    }

    #[test]
    fn fan_in_counts() {
        let (net, keys) = fig6();
        let f = net.fan_in();
        assert_eq!(f[keys.neuron("c").unwrap() as usize], 2); // from d and alpha
        assert_eq!(f[keys.neuron("a").unwrap() as usize], 1); // from alpha
    }

    #[test]
    fn validate_catches_bad_target() {
        let (mut net, _) = fig6();
        net.syn_targets[0] = 99;
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_catches_broken_offsets() {
        let (mut net, _) = fig6();
        net.neuron_off[1] = 5; // > neuron_off[4] region end, non-monotonic later
        assert!(net.validate().is_err());
    }

    /// Satellite: CSR build from `NetworkBuilder` round-trips against a
    /// reference nested-Vec construction — same `n_synapses`, `fan_in`,
    /// and per-source slices.
    #[test]
    fn prop_csr_build_matches_reference_nested_vec() {
        ptest::check("csr_vs_nested_reference", 40, |rng| {
            let n = 1 + rng.below(60) as usize;
            let a = rng.below(8) as usize;
            let models = [
                NeuronModel::if_neuron(rng.range_i32(1, 50)),
                NeuronModel::ann(rng.range_i32(1, 30), 0, false).unwrap(),
            ];
            // one spec, two construction paths
            let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
            let mut b = NetworkBuilder::new().seed(rng.next_u32());
            let mut params = Vec::new();
            let mut neuron_adj: Vec<Vec<Synapse>> = Vec::new();
            let mut axon_adj: Vec<Vec<Synapse>> = Vec::new();
            for i in 0..n {
                let m = models[rng.below(2) as usize];
                let deg = rng.below(10) as usize;
                let syns: Vec<(u32, i32)> = (0..deg)
                    .map(|_| (rng.below(n as u32), rng.range_i32(-80, 80)))
                    .collect();
                let named: Vec<(String, i32)> =
                    syns.iter().map(|&(t, w)| (keys[t as usize].clone(), w)).collect();
                let refs: Vec<(&str, i32)> =
                    named.iter().map(|(k, w)| (k.as_str(), *w)).collect();
                b.add_neuron(&keys[i], m, &refs).unwrap();
                params.push(m);
                neuron_adj.push(
                    syns.iter()
                        .map(|&(t, w)| Synapse { target: t, weight: w as i16 })
                        .collect(),
                );
            }
            for j in 0..a {
                let deg = rng.below(6) as usize;
                let syns: Vec<(u32, i32)> = (0..deg)
                    .map(|_| (rng.below(n as u32), rng.range_i32(-80, 80)))
                    .collect();
                let named: Vec<(String, i32)> =
                    syns.iter().map(|&(t, w)| (keys[t as usize].clone(), w)).collect();
                let refs: Vec<(&str, i32)> =
                    named.iter().map(|(k, w)| (k.as_str(), *w)).collect();
                b.add_axon(&format!("a{j}"), &refs).unwrap();
                axon_adj.push(
                    syns.iter()
                        .map(|&(t, w)| Synapse { target: t, weight: w as i16 })
                        .collect(),
                );
            }
            let (built, _) = b.build().unwrap();
            let reference =
                Network::from_adj(params, &neuron_adj, &axon_adj, vec![], built.base_seed);

            ptest::prop_assert_eq(built.n_synapses(), reference.n_synapses(), "n_synapses")?;
            ptest::prop_assert_eq(built.fan_in(), reference.fan_in(), "fan_in")?;
            ptest::prop_assert_eq(
                built.neuron_off.clone(),
                reference.neuron_off.clone(),
                "neuron_off",
            )?;
            ptest::prop_assert_eq(
                built.axon_off.clone(),
                reference.axon_off.clone(),
                "axon_off",
            )?;
            for i in 0..n {
                ptest::prop_assert_eq(
                    built.neuron_syns(i),
                    reference.neuron_syns(i),
                    &format!("neuron {i} slice"),
                )?;
            }
            for j in 0..a {
                ptest::prop_assert_eq(
                    built.axon_syns(j),
                    reference.axon_syns(j),
                    &format!("axon {j} slice"),
                )?;
            }
            built.validate()?;
            reference.validate()?;
            Ok(())
        });
    }

    #[test]
    fn edge_list_matches_from_adj() {
        let mut rng = Xorshift32::new(77);
        let n = 30usize;
        let a = 3usize;
        let m = NeuronModel::if_neuron(9);
        let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
        let mut axon_adj: Vec<Vec<Synapse>> = vec![Vec::new(); a];
        let mut edges = EdgeList::new(n, a);
        // interleave pushes in scrambled source order
        for _ in 0..200 {
            let pre = rng.below(n as u32);
            let t = rng.below(n as u32);
            let w = rng.range_i32(-50, 50) as i16;
            neuron_adj[pre as usize].push(Synapse { target: t, weight: w });
            edges.push_neuron(pre, t, w);
        }
        for _ in 0..20 {
            let pre = rng.below(a as u32);
            let t = rng.below(n as u32);
            let w = rng.range_i32(-50, 50) as i16;
            axon_adj[pre as usize].push(Synapse { target: t, weight: w });
            edges.push_axon(pre, t, w);
        }
        let x = Network::from_adj(vec![m; n], &neuron_adj, &axon_adj, vec![0], 5);
        let y = edges.into_network(vec![m; n], vec![0], 5);
        assert_eq!(x.syn_targets, y.syn_targets);
        assert_eq!(x.syn_weights, y.syn_weights);
        assert_eq!(x.neuron_off, y.neuron_off);
        assert_eq!(x.axon_off, y.axon_off);
    }
}
