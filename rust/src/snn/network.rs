//! The flattened network representation and its keyed builder.

use std::collections::HashMap;

use thiserror::Error;

use super::neuron::NeuronModel;

/// Synaptic weights are 16-bit signed integers in HBM.
pub const WEIGHT_MIN: i32 = -(1 << 15);
pub const WEIGHT_MAX: i32 = (1 << 15) - 1;

/// One synapse: postsynaptic neuron index + int16 weight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Synapse {
    pub target: u32,
    pub weight: i16,
}

#[derive(Debug, Error)]
pub enum NetError {
    #[error("duplicate key {0:?}")]
    DuplicateKey(String),
    #[error("unknown neuron key {0:?}")]
    UnknownNeuron(String),
    #[error("unknown presynaptic key {0:?}")]
    UnknownPre(String),
    #[error("weight {0} outside int16 range")]
    BadWeight(i32),
    #[error("no synapse {0:?} -> {1:?}")]
    NoSynapse(String, String),
    #[error("output {0:?} is not a neuron")]
    BadOutput(String),
}

/// Flattened, index-based network — the form consumed by the HBM
/// compiler, the engines and the partitioner. Axons and neurons are
/// contiguous 0-based index spaces.
#[derive(Clone, Debug, Default)]
pub struct Network {
    /// Per-neuron model parameters.
    pub params: Vec<NeuronModel>,
    /// Outgoing synapses per neuron (pre-major adjacency).
    pub neuron_adj: Vec<Vec<Synapse>>,
    /// Outgoing synapses per axon.
    pub axon_adj: Vec<Vec<Synapse>>,
    /// Indices of monitored output neurons.
    pub outputs: Vec<u32>,
    /// Base RNG seed for the stochastic neuron noise.
    pub base_seed: u32,
}

impl Network {
    pub fn n_neurons(&self) -> usize {
        self.params.len()
    }

    pub fn n_axons(&self) -> usize {
        self.axon_adj.len()
    }

    pub fn n_synapses(&self) -> usize {
        self.neuron_adj.iter().map(Vec::len).sum::<usize>()
            + self.axon_adj.iter().map(Vec::len).sum::<usize>()
    }

    /// Total fan-in per neuron (used by the partitioner's traffic model).
    pub fn fan_in(&self) -> Vec<u32> {
        let mut f = vec![0u32; self.n_neurons()];
        for adj in self.neuron_adj.iter().chain(self.axon_adj.iter()) {
            for s in adj {
                f[s.target as usize] += 1;
            }
        }
        f
    }

    /// Structural validation: every synapse target in range, outputs valid.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_neurons() as u32;
        for (i, adj) in self.neuron_adj.iter().enumerate() {
            for s in adj {
                if s.target >= n {
                    return Err(format!("neuron {i} synapse target {} out of range", s.target));
                }
            }
        }
        for (i, adj) in self.axon_adj.iter().enumerate() {
            for s in adj {
                if s.target >= n {
                    return Err(format!("axon {i} synapse target {} out of range", s.target));
                }
            }
        }
        for &o in &self.outputs {
            if o >= n {
                return Err(format!("output {o} out of range"));
            }
        }
        if self.neuron_adj.len() != self.params.len() {
            return Err("params/adjacency length mismatch".into());
        }
        Ok(())
    }
}

/// Keyed builder mirroring the `hs_api` dictionaries: axon/neuron keys are
/// strings; `build()` flattens to index space (insertion order).
#[derive(Debug, Default)]
pub struct NetworkBuilder {
    axon_keys: Vec<String>,
    axon_index: HashMap<String, u32>,
    neuron_keys: Vec<String>,
    neuron_index: HashMap<String, u32>,
    models: Vec<NeuronModel>,
    // synapses recorded with string targets, resolved at build()
    neuron_syn: Vec<Vec<(String, i32)>>,
    axon_syn: Vec<Vec<(String, i32)>>,
    outputs: Vec<String>,
    base_seed: u32,
}

impl NetworkBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn seed(mut self, seed: u32) -> Self {
        self.base_seed = seed;
        self
    }

    pub fn add_axon(
        &mut self,
        key: &str,
        synapses: &[(&str, i32)],
    ) -> Result<(), NetError> {
        if self.axon_index.contains_key(key) {
            return Err(NetError::DuplicateKey(key.into()));
        }
        self.axon_index.insert(key.into(), self.axon_keys.len() as u32);
        self.axon_keys.push(key.into());
        self.axon_syn
            .push(synapses.iter().map(|&(t, w)| (t.to_string(), w)).collect());
        Ok(())
    }

    pub fn add_neuron(
        &mut self,
        key: &str,
        model: NeuronModel,
        synapses: &[(&str, i32)],
    ) -> Result<(), NetError> {
        if self.neuron_index.contains_key(key) {
            return Err(NetError::DuplicateKey(key.into()));
        }
        self.neuron_index.insert(key.into(), self.neuron_keys.len() as u32);
        self.neuron_keys.push(key.into());
        self.models.push(model);
        self.neuron_syn
            .push(synapses.iter().map(|&(t, w)| (t.to_string(), w)).collect());
        Ok(())
    }

    pub fn add_output(&mut self, key: &str) {
        self.outputs.push(key.into());
    }

    pub fn neuron_id(&self, key: &str) -> Option<u32> {
        self.neuron_index.get(key).copied()
    }

    pub fn axon_id(&self, key: &str) -> Option<u32> {
        self.axon_index.get(key).copied()
    }

    fn resolve(&self, list: &[(String, i32)]) -> Result<Vec<Synapse>, NetError> {
        list.iter()
            .map(|(t, w)| {
                let target = *self
                    .neuron_index
                    .get(t)
                    .ok_or_else(|| NetError::UnknownNeuron(t.clone()))?;
                if !(WEIGHT_MIN..=WEIGHT_MAX).contains(w) {
                    return Err(NetError::BadWeight(*w));
                }
                Ok(Synapse { target, weight: *w as i16 })
            })
            .collect()
    }

    pub fn build(self) -> Result<(Network, KeyMap), NetError> {
        let neuron_adj = self
            .neuron_syn
            .iter()
            .map(|l| self.resolve(l))
            .collect::<Result<Vec<_>, _>>()?;
        let axon_adj = self
            .axon_syn
            .iter()
            .map(|l| self.resolve(l))
            .collect::<Result<Vec<_>, _>>()?;
        let outputs = self
            .outputs
            .iter()
            .map(|k| {
                self.neuron_index
                    .get(k)
                    .copied()
                    .ok_or_else(|| NetError::BadOutput(k.clone()))
            })
            .collect::<Result<Vec<_>, _>>()?;
        let net = Network {
            params: self.models,
            neuron_adj,
            axon_adj,
            outputs,
            base_seed: self.base_seed,
        };
        let keys = KeyMap {
            axon_keys: self.axon_keys,
            neuron_keys: self.neuron_keys,
            axon_index: self.axon_index,
            neuron_index: self.neuron_index,
        };
        Ok((net, keys))
    }
}

/// Key <-> index maps retained from the builder for user-facing lookups
/// (`read_synapse("a", "b")` etc.).
#[derive(Clone, Debug, Default)]
pub struct KeyMap {
    pub axon_keys: Vec<String>,
    pub neuron_keys: Vec<String>,
    pub axon_index: HashMap<String, u32>,
    pub neuron_index: HashMap<String, u32>,
}

impl KeyMap {
    pub fn neuron(&self, key: &str) -> Option<u32> {
        self.neuron_index.get(key).copied()
    }

    pub fn axon(&self, key: &str) -> Option<u32> {
        self.axon_index.get(key).copied()
    }
}

/// Mutable synapse access on the flattened network (paper API
/// `read_synapse` / `write_synapse`).
impl Network {
    pub fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Option<i16> {
        let adj = if pre_is_axon {
            &self.axon_adj[pre as usize]
        } else {
            &self.neuron_adj[pre as usize]
        };
        adj.iter().find(|s| s.target == post).map(|s| s.weight)
    }

    pub fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> bool {
        let adj = if pre_is_axon {
            &mut self.axon_adj[pre as usize]
        } else {
            &mut self.neuron_adj[pre as usize]
        };
        for s in adj.iter_mut() {
            if s.target == post {
                s.weight = weight;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig-6 / Supplementary-A.1 example network.
    pub fn fig6() -> (Network, KeyMap) {
        let lif_ab = NeuronModel::lif(3, 0, 63, false).unwrap();
        let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
        let ann_d = NeuronModel::ann(5, 0, true).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("a", lif_ab, &[("b", 1), ("d", 2)]).unwrap();
        b.add_neuron("b", lif_ab, &[]).unwrap();
        b.add_neuron("c", lif_c, &[]).unwrap();
        b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
        b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
        b.add_axon("beta", &[("b", 3)]).unwrap();
        b.add_output("a");
        b.add_output("b");
        b.build().unwrap()
    }

    #[test]
    fn fig6_structure() {
        let (net, keys) = fig6();
        assert_eq!(net.n_neurons(), 4);
        assert_eq!(net.n_axons(), 2);
        assert_eq!(net.n_synapses(), 6);
        assert_eq!(net.outputs.len(), 2);
        let a = keys.neuron("a").unwrap();
        let b = keys.neuron("b").unwrap();
        assert_eq!(net.read_synapse(false, a, b), Some(1));
        let alpha = keys.axon("alpha").unwrap();
        assert_eq!(net.read_synapse(true, alpha, a), Some(3));
        net.validate().unwrap();
    }

    #[test]
    fn write_synapse_updates() {
        let (mut net, keys) = fig6();
        let a = keys.neuron("a").unwrap();
        let b = keys.neuron("b").unwrap();
        assert!(net.write_synapse(false, a, b, 2));
        assert_eq!(net.read_synapse(false, a, b), Some(2));
        let c = keys.neuron("c").unwrap();
        assert!(!net.write_synapse(false, b, c, 1)); // no such synapse
    }

    #[test]
    fn duplicate_and_unknown_keys() {
        let m = NeuronModel::ann(1, 0, false).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("x", m, &[]).unwrap();
        assert!(matches!(b.add_neuron("x", m, &[]), Err(NetError::DuplicateKey(_))));
        let mut b2 = NetworkBuilder::new();
        b2.add_neuron("x", m, &[("ghost", 1)]).unwrap();
        assert!(matches!(b2.build(), Err(NetError::UnknownNeuron(_))));
    }

    #[test]
    fn weight_range_checked() {
        let m = NeuronModel::ann(1, 0, false).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("x", m, &[]).unwrap();
        b.add_axon("in", &[("x", 1 << 15)]).unwrap();
        assert!(matches!(b.build(), Err(NetError::BadWeight(_))));
    }

    #[test]
    fn fan_in_counts() {
        let (net, keys) = fig6();
        let f = net.fan_in();
        assert_eq!(f[keys.neuron("c").unwrap() as usize], 2); // from d and alpha
        assert_eq!(f[keys.neuron("a").unwrap() as usize], 1); // from alpha
    }

    #[test]
    fn validate_catches_bad_target() {
        let (mut net, _) = fig6();
        net.neuron_adj[0].push(Synapse { target: 99, weight: 1 });
        assert!(net.validate().is_err());
    }
}
