//! Network model primitives — the Rust-side equivalent of the `hs_api`
//! Python interface (paper §5.2, Supplementary A.1).
//!
//! A network is defined by axons (external inputs), neurons (each with a
//! neuron model and an outgoing synapse list) and an outputs list. The
//! [`NetworkBuilder`] offers the keyed dictionary-style API of the paper;
//! [`Network`] is the flattened index-based form every other subsystem
//! (HBM compiler, engines, partitioner) consumes. Connectivity is stored
//! CSR (flat `syn_targets`/`syn_weights` plus offset tables — see the
//! `network` module docs); [`EdgeList`] is the flat construction scratch
//! for callers that discover synapses in arbitrary source order.

mod journal;
mod network;
mod neuron;
mod view;

pub use journal::{EditJournal, EditKey, EditState, JournaledView, SynEdit};
pub use network::{
    EdgeList, KeyMap, NetError, Network, NetworkBuilder, Synapse, WEIGHT_MAX, WEIGHT_MIN,
};
pub use neuron::{NeuronModel, FLAG_LIF, FLAG_NOISE, LAM_MAX, NU_MAX, NU_MIN};
pub use view::NetView;
