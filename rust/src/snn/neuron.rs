//! Neuron models (paper §5.1, Table 1).
//!
//! Two classes: LIF (theta, nu, lambda) and ANN/binary (theta, nu), each
//! optionally stochastic (the noise update). The flag bits here are the
//! single source of truth shared with `python/compile/kernels/ref.py` and
//! the Pallas kernel.

use thiserror::Error;

/// bit0: 1 = LIF membrane update (leak), 0 = ANN (cleared every step).
pub const FLAG_LIF: u32 = 1;
/// bit1: 1 = stochastic (apply the 17-bit noise update each step).
pub const FLAG_NOISE: u32 = 2;

/// lambda is a 6-bit leak exponent.
pub const LAM_MAX: i32 = 63;
/// nu is a 6-bit *signed* noise shift.
pub const NU_MIN: i32 = -32;
pub const NU_MAX: i32 = 31;

#[derive(Debug, Error, PartialEq)]
pub enum ModelError {
    #[error("nu={0} outside 6-bit signed range [{NU_MIN}, {NU_MAX}]")]
    BadNu(i32),
    #[error("lam={0} outside [0, {LAM_MAX}]")]
    BadLam(i32),
}

/// A neuron model: the per-neuron parameter tuple programmed into the
/// neuron-model section of HBM and applied by the membrane-update kernel.
///
/// `repr(C)` pins the layout to four consecutive 32-bit words
/// (`theta, nu, lam, flags` — 16 bytes, no padding): the `.hsn` PARAMS
/// section stores exactly this struct, and the mmap loader reinterprets
/// the section bytes as `[NeuronModel]` without a copy.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NeuronModel {
    pub theta: i32,
    pub nu: i32,
    pub lam: i32,
    pub flags: u32,
}

impl NeuronModel {
    /// Leaky-integrate-and-fire: `V -= V >> lam` each step.
    /// `lam = 63` approximates an integrate-and-fire neuron.
    pub fn lif(theta: i32, nu: i32, lam: i32, stochastic: bool) -> Result<Self, ModelError> {
        validate_nu(nu)?;
        if !(0..=LAM_MAX).contains(&lam) {
            return Err(ModelError::BadLam(lam));
        }
        Ok(Self {
            theta,
            nu,
            lam,
            flags: FLAG_LIF | if stochastic { FLAG_NOISE } else { 0 },
        })
    }

    /// Binary (memoryless) neuron; with `stochastic` and nu > -17 it is a
    /// Boltzmann-like stochastic binary neuron (Table 1 note).
    pub fn ann(theta: i32, nu: i32, stochastic: bool) -> Result<Self, ModelError> {
        validate_nu(nu)?;
        Ok(Self { theta, nu, lam: 0, flags: if stochastic { FLAG_NOISE } else { 0 } })
    }

    /// Deterministic integrate-and-fire (the converted-model workhorse:
    /// the paper uses membrane time constant 2^63 ≈ no leak).
    pub fn if_neuron(theta: i32) -> Self {
        Self::lif(theta, 0, LAM_MAX, false).expect("static params valid")
    }

    pub fn is_lif(&self) -> bool {
        self.flags & FLAG_LIF != 0
    }

    pub fn is_stochastic(&self) -> bool {
        self.flags & FLAG_NOISE != 0
    }
}

fn validate_nu(nu: i32) -> Result<(), ModelError> {
    if !(NU_MIN..=NU_MAX).contains(&nu) {
        return Err(ModelError::BadNu(nu));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lif_flags() {
        let m = NeuronModel::lif(3, 0, 63, false).unwrap();
        assert!(m.is_lif() && !m.is_stochastic());
        let m = NeuronModel::lif(3, -4, 2, true).unwrap();
        assert!(m.is_lif() && m.is_stochastic());
    }

    #[test]
    fn ann_flags() {
        let m = NeuronModel::ann(5, 0, true).unwrap();
        assert!(!m.is_lif() && m.is_stochastic());
        assert_eq!(m.lam, 0);
    }

    #[test]
    fn param_validation() {
        assert_eq!(NeuronModel::lif(1, 99, 63, false), Err(ModelError::BadNu(99)));
        assert_eq!(NeuronModel::lif(1, 0, 64, false), Err(ModelError::BadLam(64)));
        assert_eq!(NeuronModel::ann(1, -33, false), Err(ModelError::BadNu(-33)));
        assert!(NeuronModel::lif(1, NU_MIN, LAM_MAX, false).is_ok());
    }

    #[test]
    fn if_neuron_is_max_lam() {
        let m = NeuronModel::if_neuron(100);
        assert_eq!(m.lam, LAM_MAX);
        assert!(m.is_lif());
    }
}
