//! Borrowed-CSR network view — the read-only slice-of-arrays subset of
//! [`Network`]'s API that every consumer (HBM compile, partitioner,
//! router, engines) actually needs.
//!
//! [`NetView`] is a `Copy` bundle of borrowed slices, so the same
//! compile/partition/split code runs over
//!
//! * an owned heap [`Network`] (`(&net).into()` / [`Network::view`]), or
//! * an mmap-backed [`crate::model_fmt::NetFile`] (`file.view()`), whose
//!   slices point straight into the mapped `.hsn` v2 bytes — zero
//!   per-synapse copying between file and engine compilation.
//!
//! Consumer entry points take `impl Into<NetView<'a>>`, so existing
//! `&Network` call sites keep compiling unchanged while genuinely
//! threading the view. The field invariants are exactly [`Network`]'s
//! (see its module docs): `neuron_off` has `n_neurons + 1` entries
//! starting at 0, `axon_off` continues it, per-source slices are sorted
//! by target. [`NetView::validate`] checks them; both construction paths
//! (builder / format readers) guarantee them.

use std::ops::Range;

use super::network::Network;
use super::neuron::NeuronModel;

/// Borrowed read-only CSR view of a network (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct NetView<'a> {
    /// Per-neuron model parameters.
    pub params: &'a [NeuronModel],
    /// Flat synapse targets (neuron regions, then axon regions).
    pub syn_targets: &'a [u32],
    /// Flat synapse weights, parallel to `syn_targets`.
    pub syn_weights: &'a [i16],
    /// Per-neuron region offsets (`n_neurons + 1` entries).
    pub neuron_off: &'a [u32],
    /// Per-axon region offsets (`n_axons + 1`; first == last neuron_off).
    pub axon_off: &'a [u32],
    /// Indices of monitored output neurons.
    pub outputs: &'a [u32],
    /// Base RNG seed for the stochastic neuron noise.
    pub base_seed: u32,
}

impl<'a> From<&'a Network> for NetView<'a> {
    fn from(net: &'a Network) -> Self {
        NetView {
            params: &net.params,
            syn_targets: &net.syn_targets,
            syn_weights: &net.syn_weights,
            neuron_off: &net.neuron_off,
            axon_off: &net.axon_off,
            outputs: &net.outputs,
            base_seed: net.base_seed,
        }
    }
}

impl<'a> From<&NetView<'a>> for NetView<'a> {
    fn from(v: &NetView<'a>) -> Self {
        *v
    }
}

impl Network {
    /// Borrow this network as a [`NetView`].
    pub fn view(&self) -> NetView<'_> {
        self.into()
    }
}

impl<'a> NetView<'a> {
    pub fn n_neurons(&self) -> usize {
        self.params.len()
    }

    pub fn n_axons(&self) -> usize {
        self.axon_off.len() - 1
    }

    pub fn n_synapses(&self) -> usize {
        self.syn_targets.len()
    }

    /// Flat-array range of neuron `i`'s outgoing synapses.
    #[inline]
    pub fn neuron_range(&self, i: usize) -> Range<usize> {
        self.neuron_off[i] as usize..self.neuron_off[i + 1] as usize
    }

    /// Flat-array range of axon `i`'s outgoing synapses.
    #[inline]
    pub fn axon_range(&self, i: usize) -> Range<usize> {
        self.axon_off[i] as usize..self.axon_off[i + 1] as usize
    }

    /// Contiguous (targets, weights) slices of neuron `i`.
    #[inline]
    pub fn neuron_syns(&self, i: usize) -> (&'a [u32], &'a [i16]) {
        let r = self.neuron_range(i);
        (&self.syn_targets[r.clone()], &self.syn_weights[r])
    }

    /// Contiguous (targets, weights) slices of axon `i`.
    #[inline]
    pub fn axon_syns(&self, i: usize) -> (&'a [u32], &'a [i16]) {
        let r = self.axon_range(i);
        (&self.syn_targets[r.clone()], &self.syn_weights[r])
    }

    /// Target ids of neuron `i`'s outgoing synapses.
    #[inline]
    pub fn neuron_targets(&self, i: usize) -> &'a [u32] {
        &self.syn_targets[self.neuron_range(i)]
    }

    /// Target ids of axon `i`'s outgoing synapses.
    #[inline]
    pub fn axon_targets(&self, i: usize) -> &'a [u32] {
        &self.syn_targets[self.axon_range(i)]
    }

    /// Out-degree of neuron `i`.
    #[inline]
    pub fn neuron_degree(&self, i: usize) -> usize {
        self.neuron_range(i).len()
    }

    /// Out-degree of axon `i`.
    #[inline]
    pub fn axon_degree(&self, i: usize) -> usize {
        self.axon_range(i).len()
    }

    /// Total fan-in per neuron — one linear pass over the flat targets.
    pub fn fan_in(&self) -> Vec<u32> {
        let mut f = vec![0u32; self.n_neurons()];
        for &t in self.syn_targets {
            f[t as usize] += 1;
        }
        f
    }

    /// True when every per-source slice is sorted ascending by target —
    /// the canonical form all writers emit (duplicates allowed).
    pub fn is_sorted(&self) -> bool {
        let n = self.n_neurons();
        (0..n + self.n_axons()).all(|s| {
            let r = if s < n { self.neuron_range(s) } else { self.axon_range(s - n) };
            self.syn_targets[r].windows(2).all(|w| w[0] <= w[1])
        })
    }

    /// Structural validation — the same checks as [`Network::validate`].
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_neurons() as u32;
        if self.neuron_off.len() != self.params.len() + 1 {
            return Err("params/neuron_off length mismatch".into());
        }
        if self.neuron_off[0] != 0 {
            return Err("neuron_off must start at 0".into());
        }
        if self.axon_off.is_empty() || self.axon_off[0] != *self.neuron_off.last().unwrap() {
            return Err("axon_off must continue neuron_off".into());
        }
        if self.syn_targets.len() != self.syn_weights.len() {
            return Err("syn_targets/syn_weights length mismatch".into());
        }
        if *self.axon_off.last().unwrap() as usize != self.syn_targets.len() {
            return Err("offset tables do not cover the synapse arrays".into());
        }
        if self.neuron_off.windows(2).any(|w| w[0] > w[1])
            || self.axon_off.windows(2).any(|w| w[0] > w[1])
        {
            return Err("offsets not monotonic".into());
        }
        for (k, &t) in self.syn_targets.iter().enumerate() {
            if t >= n {
                return Err(format!("synapse {k} target {t} out of range"));
            }
        }
        for &o in self.outputs {
            if o >= n {
                return Err(format!("output {o} out of range"));
            }
        }
        Ok(())
    }

    /// Deep-copy the view into an owned [`Network`] (the explicit
    /// materialisation point — nothing else on the load path copies CSR).
    pub fn to_network(&self) -> Network {
        Network {
            params: self.params.to_vec(),
            syn_targets: self.syn_targets.to_vec(),
            syn_weights: self.syn_weights.to_vec(),
            neuron_off: self.neuron_off.to_vec(),
            axon_off: self.axon_off.to_vec(),
            outputs: self.outputs.to_vec(),
            base_seed: self.base_seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::network::NetworkBuilder;
    use super::super::neuron::NeuronModel;
    use super::*;

    fn sample() -> Network {
        let m = NeuronModel::if_neuron(5);
        let mut b = NetworkBuilder::new().seed(42);
        b.add_neuron("a", m, &[("b", 1), ("c", -2)]).unwrap();
        b.add_neuron("b", m, &[("a", 3)]).unwrap();
        b.add_neuron("c", m, &[]).unwrap();
        b.add_axon("in", &[("a", 7), ("b", 1)]).unwrap();
        b.add_output("a");
        b.add_output("c");
        b.build().unwrap().0
    }

    #[test]
    fn view_mirrors_network_accessors() {
        let net = sample();
        let v = net.view();
        assert_eq!(v.n_neurons(), net.n_neurons());
        assert_eq!(v.n_axons(), net.n_axons());
        assert_eq!(v.n_synapses(), net.n_synapses());
        assert_eq!(v.base_seed, net.base_seed);
        for i in 0..net.n_neurons() {
            assert_eq!(v.neuron_range(i), net.neuron_range(i));
            assert_eq!(v.neuron_syns(i), net.neuron_syns(i));
            assert_eq!(v.neuron_targets(i), net.neuron_targets(i));
            assert_eq!(v.neuron_degree(i), net.neuron_degree(i));
        }
        for i in 0..net.n_axons() {
            assert_eq!(v.axon_range(i), net.axon_range(i));
            assert_eq!(v.axon_syns(i), net.axon_syns(i));
            assert_eq!(v.axon_targets(i), net.axon_targets(i));
            assert_eq!(v.axon_degree(i), net.axon_degree(i));
        }
        assert_eq!(v.fan_in(), net.fan_in());
        assert!(v.is_sorted());
        v.validate().unwrap();
    }

    #[test]
    fn to_network_round_trips() {
        let net = sample();
        let copy = net.view().to_network();
        assert_eq!(copy.params, net.params);
        assert_eq!(copy.syn_targets, net.syn_targets);
        assert_eq!(copy.syn_weights, net.syn_weights);
        assert_eq!(copy.neuron_off, net.neuron_off);
        assert_eq!(copy.axon_off, net.axon_off);
        assert_eq!(copy.outputs, net.outputs);
        assert_eq!(copy.base_seed, net.base_seed);
    }

    #[test]
    fn is_sorted_detects_violations() {
        let mut net = sample();
        assert!(net.view().is_sorted());
        // neuron "a" has two synapses; swap them out of order
        net.syn_targets.swap(0, 1);
        net.syn_weights.swap(0, 1);
        assert!(!net.view().is_sorted());
    }
}
