//! `EditJournal` — a sorted overlay of pending synapse edits over a
//! borrowed CSR, plus compaction into a fresh [`Network`].
//!
//! Live engines and sessions cannot afford an O(n_synapses) CSR splice
//! per `add_synapse`, and an mmap-backed `.hsn` v2 [`NetView`] is
//! read-only, so *no* in-place edit is even legal there. The journal
//! makes both cases cheap: edits land in a `BTreeMap` keyed by
//! `(pre_is_axon, pre, post)` (neurons order before axons — the CSR
//! source order), reads consult the overlay first
//! ([`JournaledView::read_synapse`]), and a periodic
//! [`EditJournal::compact`] materialises base + overlay into a fresh
//! owned CSR in one linear merge pass.
//!
//! # Edit semantics (the overlay contract)
//!
//! The journal holds **at most one pending state per key**: `Set(w)`
//! (the synapse exists with weight `w`) or `Removed`. Consequences:
//!
//! * `write_synapse` targets an *existing* synapse (base or pending
//!   `Set`); it returns `false` for a miss rather than creating one.
//! * `add_synapse` is an upsert: it records `Set(w)` whether or not the
//!   base has the synapse, and reports whether it created one.
//! * Base **duplicate** `(pre, post)` slots (legal in the CSR; delivery
//!   sums them) are treated as one logical synapse by the overlay: a
//!   `Set` collapses them to a single slot at compaction, `Removed`
//!   drops them all — mirroring [`Network::write_synapse`] /
//!   [`Network::remove_synapse`] whole-run semantics.
//! * Untouched base entries are copied verbatim (duplicates preserved),
//!   so compacting an empty journal reproduces the base CSR
//!   bit-identically.
//!
//! The property suite (`rust/tests/plasticity.rs`) pins overlay reads
//! and the compacted CSR against an eagerly rebuilt `Network` across
//! random edit sequences.

use std::collections::BTreeMap;

use super::network::Network;
use super::view::NetView;

/// Identity of one logical synapse. Derived `Ord` sorts neurons
/// (`pre_is_axon == false`) before axons, then by `(pre, post)` — the
/// flat CSR source order, which is what lets compaction merge the
/// journal against the base arrays in one forward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct EditKey {
    pub pre_is_axon: bool,
    pub pre: u32,
    pub post: u32,
}

/// Pending overlay state of one key (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EditState {
    /// Synapse exists with this weight.
    Set(i16),
    /// Synapse does not exist.
    Removed,
}

/// One recorded edit, as consumed by engines applying a journal live
/// (`Simulator::apply_edits`) and by the session wire protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SynEdit {
    pub key: EditKey,
    pub state: EditState,
}

/// Sorted overlay of pending synapse edits (see module docs).
#[derive(Clone, Debug, Default)]
pub struct EditJournal {
    pending: BTreeMap<EditKey, EditState>,
    /// Total edit operations recorded since construction/`clear` —
    /// monotonic even when edits coalesce onto one key (serving-tier
    /// quota accounting wants operations, not distinct keys).
    recorded: u64,
}

impl EditJournal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Distinct keys with pending state.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total edit operations recorded (monotonic until [`Self::clear`]).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Drop all pending state (after a compaction consumed it).
    pub fn clear(&mut self) {
        self.pending.clear();
        self.recorded = 0;
    }

    /// Pending edits in key order.
    pub fn iter(&self) -> impl Iterator<Item = SynEdit> + '_ {
        self.pending.iter().map(|(&key, &state)| SynEdit { key, state })
    }

    fn base_has(base: NetView<'_>, key: EditKey) -> bool {
        let (tg, _) = if key.pre_is_axon {
            base.axon_syns(key.pre as usize)
        } else {
            base.neuron_syns(key.pre as usize)
        };
        tg.binary_search(&key.post).is_ok()
    }

    /// True if `key` resolves to a synapse through the overlay.
    pub fn exists(&self, base: NetView<'_>, key: EditKey) -> bool {
        match self.pending.get(&key) {
            Some(EditState::Set(_)) => true,
            Some(EditState::Removed) => false,
            None => Self::base_has(base, key),
        }
    }

    /// Record a weight write. Returns `false` (and records nothing) if
    /// the synapse does not exist through the overlay.
    pub fn write_synapse(&mut self, base: NetView<'_>, key: EditKey, weight: i16) -> bool {
        if !self.exists(base, key) {
            return false;
        }
        self.pending.insert(key, EditState::Set(weight));
        self.recorded += 1;
        true
    }

    /// Record an upsert. Returns `true` if the synapse did not exist
    /// through the overlay (i.e. this edit creates it).
    pub fn add_synapse(&mut self, base: NetView<'_>, key: EditKey, weight: i16) -> bool {
        let created = !self.exists(base, key);
        self.pending.insert(key, EditState::Set(weight));
        self.recorded += 1;
        created
    }

    /// Record a removal. Returns `false` if already absent.
    pub fn remove_synapse(&mut self, base: NetView<'_>, key: EditKey) -> bool {
        if !self.exists(base, key) {
            return false;
        }
        if Self::base_has(base, key) {
            self.pending.insert(key, EditState::Removed);
        } else {
            // journal-only synapse: the add and the remove annihilate
            self.pending.remove(&key);
        }
        self.recorded += 1;
        true
    }

    /// Effective (targets, weights) of one source under the overlay —
    /// the per-source merge step compaction runs for every source.
    /// Sorted by target; base duplicates of an edited target collapse.
    fn effective_syns(
        &self,
        base: NetView<'_>,
        pre_is_axon: bool,
        pre: u32,
        out: &mut Vec<(u32, i16)>,
    ) {
        out.clear();
        let (tg, wt) = if pre_is_axon {
            base.axon_syns(pre as usize)
        } else {
            base.neuron_syns(pre as usize)
        };
        let lo = EditKey { pre_is_axon, pre, post: 0 };
        let hi = EditKey { pre_is_axon, pre, post: u32::MAX };
        let mut edits = self.pending.range(lo..=hi).peekable();
        let mut k = 0usize;
        while k < tg.len() || edits.peek().is_some() {
            match edits.peek() {
                Some((&ekey, &state)) if k >= tg.len() || ekey.post <= tg[k] => {
                    // emit the edit, skipping any base duplicates of it
                    if let EditState::Set(w) = state {
                        out.push((ekey.post, w));
                    }
                    while k < tg.len() && tg[k] == ekey.post {
                        k += 1;
                    }
                    edits.next();
                }
                _ => {
                    out.push((tg[k], wt[k]));
                    k += 1;
                }
            }
        }
    }

    /// Materialise base + overlay into a fresh owned [`Network`] (same
    /// params/outputs/base_seed). One linear merge pass per source; the
    /// result is sorted/canonical, ready for recompilation. The journal
    /// is not consumed — callers [`Self::clear`] after swapping the new
    /// CSR in.
    pub fn compact<'a>(&self, base: impl Into<NetView<'a>>) -> Network {
        let base: NetView<'_> = base.into();
        let n = base.n_neurons();
        let a = base.n_axons();
        let mut scratch: Vec<(u32, i16)> = Vec::new();
        let mut neuron_deg = vec![0u32; n];
        let mut axon_deg = vec![0u32; a];
        for i in 0..n {
            self.effective_syns(base, false, i as u32, &mut scratch);
            neuron_deg[i] = scratch.len() as u32;
        }
        for i in 0..a {
            self.effective_syns(base, true, i as u32, &mut scratch);
            axon_deg[i] = scratch.len() as u32;
        }
        let mut net = Network::with_degrees(
            base.params.to_vec(),
            &neuron_deg,
            &axon_deg,
            base.outputs.to_vec(),
            base.base_seed,
        );
        let mut k = 0usize;
        for (pre_is_axon, count) in [(false, n), (true, a)] {
            for i in 0..count {
                self.effective_syns(base, pre_is_axon, i as u32, &mut scratch);
                for &(t, w) in &scratch {
                    net.syn_targets[k] = t;
                    net.syn_weights[k] = w;
                    k += 1;
                }
            }
        }
        debug_assert_eq!(k, net.n_synapses());
        net
    }

    /// Borrow base + journal as an overlay reader.
    pub fn view<'a>(&'a self, base: NetView<'a>) -> JournaledView<'a> {
        JournaledView { base, journal: self }
    }
}

/// The thin overlay reader over a borrowed CSR: pending journal state
/// wins, otherwise the base answers. This is what makes `write_synapse`
/// legal on a read-only mmap-backed `NetFile` — the mapped bytes are
/// never touched.
#[derive(Clone, Copy)]
pub struct JournaledView<'a> {
    pub base: NetView<'a>,
    pub journal: &'a EditJournal,
}

impl<'a> JournaledView<'a> {
    /// Effective weight of `(pre, post)` (first base duplicate when the
    /// key is untouched, matching [`Network::read_synapse`]).
    pub fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Option<i16> {
        let key = EditKey { pre_is_axon, pre, post };
        match self.journal.pending.get(&key) {
            Some(EditState::Set(w)) => Some(*w),
            Some(EditState::Removed) => None,
            None => {
                let (tg, wt) = if pre_is_axon {
                    self.base.axon_syns(pre as usize)
                } else {
                    self.base.neuron_syns(pre as usize)
                };
                let s = tg.partition_point(|&t| t < post);
                (s < tg.len() && tg[s] == post).then(|| wt[s])
            }
        }
    }

    /// Effective out-degree of one source under the overlay.
    pub fn degree(&self, pre_is_axon: bool, pre: u32) -> usize {
        let mut scratch = Vec::new();
        self.journal.effective_syns(self.base, pre_is_axon, pre, &mut scratch);
        scratch.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{NetworkBuilder, NeuronModel};

    fn toy() -> Network {
        let m = NeuronModel::if_neuron(5);
        let mut b = NetworkBuilder::new();
        for i in 0..4 {
            let key = format!("n{i}");
            if i == 0 {
                b.add_neuron(&key, m, &[("n1", 10), ("n3", 30)]).unwrap();
            } else {
                b.add_neuron(&key, m, &[]).unwrap();
            }
        }
        b.add_axon("a0", &[("n0", 1), ("n2", 2)]).unwrap();
        b.build().unwrap().0
    }

    #[test]
    fn overlay_reads_and_states() {
        let net = toy();
        let mut j = EditJournal::new();
        let k01 = EditKey { pre_is_axon: false, pre: 0, post: 1 };
        let k02 = EditKey { pre_is_axon: false, pre: 0, post: 2 };
        // write hits an existing synapse, misses an absent one
        assert!(j.write_synapse(net.view(), k01, 11));
        assert!(!j.write_synapse(net.view(), k02, 5));
        // add is an upsert; remove needs existence
        assert!(j.add_synapse(net.view(), k02, 5));
        assert!(!j.add_synapse(net.view(), k02, 6));
        let v = j.view(net.view());
        assert_eq!(v.read_synapse(false, 0, 1), Some(11));
        assert_eq!(v.read_synapse(false, 0, 2), Some(6));
        assert_eq!(v.read_synapse(false, 0, 3), Some(30)); // untouched base
        assert_eq!(v.read_synapse(true, 0, 0), Some(1));
        assert!(j.remove_synapse(net.view(), k01));
        assert!(!j.remove_synapse(net.view(), k01));
        assert_eq!(j.view(net.view()).read_synapse(false, 0, 1), None);
        // journal-only add + remove annihilate to no pending state
        let before = j.len();
        let k13 = EditKey { pre_is_axon: false, pre: 1, post: 3 };
        assert!(j.add_synapse(net.view(), k13, 4));
        assert!(j.remove_synapse(net.view(), k13));
        assert_eq!(j.len(), before);
        assert_eq!(j.recorded(), 7);
    }

    #[test]
    fn compact_empty_journal_is_identity() {
        let net = toy();
        let j = EditJournal::new();
        let out = j.compact(&net);
        assert_eq!(out.syn_targets, net.syn_targets);
        assert_eq!(out.syn_weights, net.syn_weights);
        assert_eq!(out.neuron_off, net.neuron_off);
        assert_eq!(out.axon_off, net.axon_off);
    }

    #[test]
    fn compact_matches_eager_network_edits() {
        let net = toy();
        let mut j = EditJournal::new();
        let mut eager = net.clone();
        let edits: [(bool, u32, u32, Option<i16>); 5] = [
            (false, 0, 1, Some(-4)), // write existing
            (false, 2, 3, Some(8)),  // add new
            (true, 0, 2, None),      // remove axon synapse
            (true, 0, 3, Some(6)),   // add axon synapse
            (false, 0, 3, None),     // remove existing
        ];
        for (ax, pre, post, w) in edits {
            let key = EditKey { pre_is_axon: ax, pre, post };
            match w {
                Some(w) => {
                    j.add_synapse(net.view(), key, w);
                    eager.add_synapse(ax, pre, post, w);
                }
                None => {
                    j.remove_synapse(net.view(), key);
                    eager.remove_synapse(ax, pre, post);
                }
            }
        }
        let out = j.compact(&net);
        assert_eq!(out.syn_targets, eager.syn_targets);
        assert_eq!(out.syn_weights, eager.syn_weights);
        assert_eq!(out.neuron_off, eager.neuron_off);
        assert_eq!(out.axon_off, eager.axon_off);
        out.validate().unwrap();
    }

    #[test]
    fn edited_duplicates_collapse_at_compaction() {
        use crate::snn::Synapse;
        let m = NeuronModel::if_neuron(5);
        let adj = vec![
            vec![Synapse { target: 1, weight: 2 }, Synapse { target: 1, weight: 3 }],
            vec![],
        ];
        let net = Network::from_adj(vec![m; 2], &adj, &[], vec![], 0);
        let mut j = EditJournal::new();
        let key = EditKey { pre_is_axon: false, pre: 0, post: 1 };
        assert!(j.write_synapse(net.view(), key, 9));
        let out = j.compact(&net);
        assert_eq!(out.neuron_syns(0), (&[1u32][..], &[9i16][..]));
    }
}
