//! The unified simulation facade — the paper's "programming interface
//! agnostic to hardware-level detail" (§5).
//!
//! Every way of executing a spiking network in this crate — the dense
//! software baseline, the event-driven HBM core, the chunk-parallel
//! worker pool, the partitioned multi-core cluster and the AOT-Pallas
//! XLA path — is reached through one pair of types:
//!
//! * [`SimConfig`] — a builder that owns the network plus every
//!   deployment decision (topology, per-core capacity, HBM slot
//!   strategy, compute backend, noise seed, artifact directory, sweep
//!   chunk granularity, route granularity, worker count).
//!   [`SimConfig::build`] performs partitioning, HBM image compilation
//!   and worker-pool spin-up, and returns a boxed [`Simulator`]. All
//!   parallelism knobs are bit-exactness-preserving: the same network
//!   and seed produce identical spike trains for every `workers` /
//!   `chunk_words` / `route_granularity` setting.
//! * [`Simulator`] — the backend-neutral session: [`Simulator::step`]
//!   advances one 1 ms tick, [`Simulator::step_many`] advances a whole
//!   stimulus batch with one up-front marshalling pass,
//!   [`Simulator::run`] drives a schedule into a [`RunRecord`],
//!   [`Simulator::run_many`] reuses the same engine (pool workers kept
//!   warm, buffers retained) across a batch of samples with a reset in
//!   between.
//!
//! Out-of-process callers (the `hs_api` Python front end, the portal)
//! reach the same trait through the line-delimited JSON protocol in
//! [`session`] (`hiaer-spike serve-session`).
//!
//! # Config lifecycle
//!
//! ```text
//! SimConfig::new(net)                 // or SimConfig::from_args(net, &args)
//!     .topology(servers, fpgas, cores)
//!     .strategy(SlotStrategy::BalanceFanIn)
//!     .backend(Backend::Rust)
//!     .seed(42)
//!     .build()?                       // -> Box<dyn Simulator>
//! ```
//!
//! `build` consumes the config: the network moves into the engine, the
//! chosen backend decides which engine is instantiated (see
//! [`Backend`]), and all engine-specific constructors stay `pub(crate)`
//! — the facade is the only public way to execute a network.
//!
//! # Trait contract
//!
//! * `step(axon_in)` takes **ascending, in-range** global axon ids;
//!   out-of-range ids are a [`SimError::Stimulus`] error, never a panic.
//! * Spike trains are **bit-identical across backends** on the same
//!   network and seed (single-core backends; a multi-core cluster
//!   matches on deterministic networks — per-core noise seeds differ).
//!   `rust/tests/sim_facade.rs` pins this matrix.
//! * Cost counters accumulate monotonically until [`Simulator::reset`] /
//!   [`Simulator::reset_cost`]; [`Simulator::run`] reports per-run cost
//!   (it clears the counters first), mirroring the paper's
//!   per-inference accounting.
//!
//! # Which backend to pick
//!
//! | backend          | engine                       | when                                        |
//! |------------------|------------------------------|---------------------------------------------|
//! | [`Backend::Dense`] | dense-matrix software sim  | golden model, tiny nets, debugging          |
//! | [`Backend::Rust`]  | event-driven HBM core      | default; becomes the cluster at >1 core     |
//! | [`Backend::Pool`]  | chunk-parallel `CorePool`  | one big core, sweep spread over all workers |
//! | [`Backend::Xla`]   | AOT Pallas artifacts, PJRT | needs the `pjrt` cargo feature + artifacts  |
//! | [`Backend::Sharded`] | multi-process shard cluster | paper-scale nets, `--shards` subprocesses |

mod config;
pub mod frames;
pub mod serve;
pub mod session;

pub use config::{Backend, NetSource, SimConfig, SimOptions};
pub(crate) use config::parse_learning;
pub use crate::cluster::RouteGranularity;

use crate::energy::{CostReport, EnergyModel};
use crate::hbm::LayoutStats;
use crate::partition::Partition;
use crate::router::RouterStats;
use crate::snn::{EditJournal, EditState};

/// Errors surfaced by the facade (configuration and execution).
#[derive(Debug, thiserror::Error)]
pub enum SimError {
    /// The requested backend cannot run in this build/environment.
    #[error("backend `{backend}` is unavailable: {reason}")]
    BackendUnavailable { backend: &'static str, reason: String },
    /// The configuration itself is inconsistent (bad flag value,
    /// unsupported topology for the chosen backend, ...).
    #[error("invalid simulator configuration: {0}")]
    Config(String),
    /// Malformed stimulus handed to a running simulator.
    #[error("bad stimulus: {0}")]
    Stimulus(String),
    /// An engine-level failure (HBM compilation, worker pool, PJRT ...).
    #[error(transparent)]
    Engine(#[from] anyhow::Error),
}

/// Shared stimulus validation: every backend rejects out-of-range axon
/// ids with the same [`SimError::Stimulus`] error (the facade contract —
/// one place, so backends cannot diverge).
pub(crate) fn check_axons(axon_in: &[u32], n_axons: usize) -> Result<(), SimError> {
    match axon_in.iter().find(|&&a| a as usize >= n_axons) {
        Some(&bad) => Err(SimError::Stimulus(format!(
            "axon id {bad} out of range ({n_axons} axons)"
        ))),
        None => Ok(()),
    }
}

/// Result of one [`Simulator::step`]: borrowed views into the
/// simulator's reusable buffers (copy out what you need to keep).
#[derive(Debug)]
pub struct StepResult<'a> {
    /// Fired neuron ids this step, ascending (global ids).
    pub fired: &'a [u32],
    /// Fired output neurons (subset of `fired`), ascending.
    pub output_spikes: &'a [u32],
}

/// Backend-neutral cost summary — the union of the single-core
/// [`CostReport`] and the cluster cost (which adds router statistics).
#[derive(Clone, Debug, Default)]
pub struct CostSummary {
    pub energy_uj: f64,
    pub latency_us: f64,
    /// HBM row accesses (pointer + synapse rows).
    pub hbm_rows: u64,
    /// Synaptic events routed.
    pub events: u64,
    /// Simulated clock cycles (slowest core + fabric for a cluster).
    pub cycles: u64,
    /// HiAER fabric statistics; `None` for single-core backends.
    pub router: Option<RouterStats>,
}

impl From<CostReport> for CostSummary {
    fn from(r: CostReport) -> Self {
        CostSummary {
            energy_uj: r.energy_uj,
            latency_us: r.latency_us,
            hbm_rows: r.hbm_rows,
            events: r.events,
            cycles: r.cycles,
            router: None,
        }
    }
}

/// Owned result of one [`Simulator::step_many`] batch.
#[derive(Clone, Debug, Default)]
pub struct BatchResult {
    /// Output-neuron spikes per step (global ids, ascending).
    pub spikes: Vec<Vec<u32>>,
    /// Total fired neurons across the batch (activity measure).
    pub fired_total: u64,
}

/// Outcome of one [`Simulator::apply_edits`] batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EditReport {
    /// Existing synapses whose weight was set.
    pub updated: u64,
    /// Synapses newly created.
    pub created: u64,
    /// Synapses removed.
    pub removed: u64,
}

impl EditReport {
    /// Total edits that changed the live network.
    pub fn applied(&self) -> u64 {
        self.updated + self.created + self.removed
    }
}

/// Record of one [`Simulator::run`] over a stimulus schedule.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    /// Steps executed (== stimulus length).
    pub steps: usize,
    /// Output-neuron spikes per step (global ids, ascending).
    pub spikes: Vec<Vec<u32>>,
    /// Total fired neurons across the run (activity measure).
    pub fired_total: u64,
    /// Aggregated cost of the run (counters cleared at run start).
    pub cost: CostSummary,
}

/// A live, hardware-agnostic simulation session over one network.
///
/// Obtained from [`SimConfig::build`]; see the module docs for the
/// contract. All implementations keep their hot-path buffers warm
/// between steps and across [`Simulator::reset`], so one session can be
/// reused for many samples ([`Simulator::run_many`]).
pub trait Simulator {
    /// Advance one timestep. `axon_in` lists fired global axon ids,
    /// ascending; ids out of range are a [`SimError::Stimulus`] error.
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError>;

    /// Fired neurons from the last completed step (ascending).
    fn fired(&self) -> &[u32];

    /// Fired output neurons from the last completed step (ascending).
    fn output_spikes(&self) -> &[u32];

    /// Restore membranes/step counter to the initial state and clear
    /// cost counters. Keeps buffers and worker pools warm.
    fn reset(&mut self);

    /// Clear the access/cycle counters only (per-inference accounting).
    fn reset_cost(&mut self);

    /// Read membrane potentials for the given (global) neuron ids.
    fn read_membrane(&self, ids: &[u32]) -> Vec<i32>;

    /// Aggregate cost since the last reset, under the given model.
    fn cost(&self, model: &EnergyModel) -> CostSummary;

    /// Short backend identifier ("dense", "rust", "pool", "xla",
    /// "cluster").
    fn backend_name(&self) -> &'static str;

    /// Total neurons simulated (global).
    fn n_neurons(&self) -> usize;

    /// Global axons accepted by [`Simulator::step`].
    fn n_axons(&self) -> usize;

    /// Execution cores behind this session (1 for single-core backends).
    fn n_cores(&self) -> usize {
        1
    }

    /// Neuron-to-core placement, when the backend partitions the
    /// network (`None` for single-core backends).
    fn placement(&self) -> Option<&Partition> {
        None
    }

    /// HBM routing-table layout statistics of the compiled image
    /// (`None` for the dense software baseline, which has no HBM, and
    /// for clusters, which hold one image per core). Saves callers a
    /// second `HbmImage::compile` when they only want the stats.
    fn hbm_stats(&self) -> Option<LayoutStats> {
        None
    }

    /// Live weight edit between steps: set **every** duplicate slot of
    /// the synapse `pre -> post` to `weight`, in place — membranes,
    /// traces and all other weights survive (the paper's
    /// `write_synapse`, no re-export/reconfigure round trip). Returns
    /// Ok(false) when the synapse does not exist (use
    /// [`Simulator::add_synapse`] / [`Simulator::apply_edits`] to
    /// create one). Backends without live-edit support return a
    /// [`SimError::Config`] error.
    fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        let _ = (pre_is_axon, pre, post, weight);
        Err(SimError::Config(format!(
            "backend `{}` does not support live synapse edits",
            self.backend_name()
        )))
    }

    /// Read one live synapse weight (first duplicate slot), `Ok(None)`
    /// when absent. Reads through the same live state `write_synapse`
    /// mutates, so an edit is immediately visible.
    fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Result<Option<i16>, SimError> {
        let _ = (pre_is_axon, pre, post);
        Err(SimError::Config(format!(
            "backend `{}` does not support live synapse edits",
            self.backend_name()
        )))
    }

    /// Live structural edit: create the synapse `pre -> post` (upsert —
    /// an existing synapse is re-weighted instead). Returns Ok(true)
    /// when a synapse was created. May fail with a config error when
    /// the backend's compiled layout has no room left; compact the
    /// session's [`EditJournal`] into a fresh network and rebuild.
    fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        let _ = (pre_is_axon, pre, post, weight);
        Err(SimError::Config(format!(
            "backend `{}` does not support live synapse edits",
            self.backend_name()
        )))
    }

    /// Live structural edit: remove every duplicate slot of
    /// `pre -> post`. Returns the number of slots removed (0 = absent).
    fn remove_synapse(&mut self, pre_is_axon: bool, pre: u32, post: u32) -> Result<usize, SimError> {
        let _ = (pre_is_axon, pre, post);
        Err(SimError::Config(format!(
            "backend `{}` does not support live synapse edits",
            self.backend_name()
        )))
    }

    /// Apply a canonicalized [`EditJournal`] batch (at most one pending
    /// state per synapse) to the live session, in the journal's
    /// deterministic key order. Default implementation dispatches each
    /// edit through the per-synapse methods above; all-or-nothing is
    /// NOT guaranteed — on error a prefix may be applied (the journal
    /// stays intact for compaction/rebuild recovery).
    fn apply_edits(&mut self, journal: &EditJournal) -> Result<EditReport, SimError> {
        let mut rep = EditReport::default();
        for edit in journal.iter() {
            let k = edit.key;
            match edit.state {
                EditState::Set(w) => {
                    if self.write_synapse(k.pre_is_axon, k.pre, k.post, w)? {
                        rep.updated += 1;
                    } else if self.add_synapse(k.pre_is_axon, k.pre, k.post, w)? {
                        rep.created += 1;
                    } else {
                        rep.updated += 1;
                    }
                }
                EditState::Removed => {
                    rep.removed +=
                        (self.remove_synapse(k.pre_is_axon, k.pre, k.post)? > 0) as u64;
                }
            }
        }
        Ok(rep)
    }

    /// Batched stepping: advance one step per `batch` entry and collect
    /// the per-step output spikes into an owned [`BatchResult`].
    ///
    /// The whole stimulus batch is validated **up-front in one
    /// marshalling pass** — a [`SimError::Stimulus`] error is returned
    /// before any step executes, leaving membranes, counters and the
    /// last-step [`Simulator::fired`] views untouched. (Engine-level
    /// failures mid-batch may still leave a prefix executed.) On `Ok`,
    /// the result is bit-identical to the equivalent [`Simulator::step`]
    /// loop on every backend; engines may override this to amortise
    /// per-step stimulus marshalling, never to change semantics.
    fn step_many(&mut self, batch: &[Vec<u32>]) -> Result<BatchResult, SimError> {
        let n_axons = self.n_axons();
        for axons in batch {
            check_axons(axons, n_axons)?;
        }
        let mut result = BatchResult { spikes: Vec::with_capacity(batch.len()), fired_total: 0 };
        for axons in batch {
            let out = self.step(axons)?;
            result.fired_total += out.fired.len() as u64;
            result.spikes.push(out.output_spikes.to_vec());
        }
        Ok(result)
    }

    /// Drive a whole stimulus schedule (`stimulus[t]` = axon ids fired
    /// at step `t`). Clears cost counters first, so the returned
    /// [`RunRecord`] carries per-run cost — the paper's per-inference
    /// accounting. Does NOT reset membranes; call [`Simulator::reset`]
    /// (or use [`Simulator::run_many`]) for independent samples.
    /// Executes through [`Simulator::step_many`], so the whole schedule
    /// is marshalled once.
    fn run(&mut self, stimulus: &[Vec<u32>], energy: &EnergyModel) -> Result<RunRecord, SimError> {
        self.reset_cost();
        let batch = self.step_many(stimulus)?;
        Ok(RunRecord {
            steps: stimulus.len(),
            spikes: batch.spikes,
            fired_total: batch.fired_total,
            cost: self.cost(energy),
        })
    }

    /// Batched execution: run every sample through **this same engine**
    /// with a full reset in between — pool workers stay warm and no
    /// per-sample engine construction happens. Returns one
    /// [`RunRecord`] per sample.
    fn run_many(
        &mut self,
        samples: &[Vec<Vec<u32>>],
        energy: &EnergyModel,
    ) -> Result<Vec<RunRecord>, SimError> {
        samples
            .iter()
            .map(|s| {
                self.reset();
                self.run(s, energy)
            })
            .collect()
    }
}
