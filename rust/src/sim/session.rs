//! Line-delimited JSON session protocol over a [`Simulator`] — the wire
//! format behind `hiaer-spike serve-session` and the Python
//! `hs_api` `backend="rust"` front end (paper §5.2: one network
//! definition, hardware-agnostic execution).
//!
//! # Framing
//!
//! One JSON object per line in each direction; the server answers every
//! request line with exactly one response line, in order, and flushes
//! after each. On startup the server emits a greeting line before any
//! request is read:
//!
//! ```text
//! {"backend":"rust","ok":true,"op":"hello","protocol":1}
//! ```
//!
//! Successful responses carry `"ok": true` plus the echoed `"op"`;
//! failures carry `"ok": false`, a **stable machine-readable `"code"`**
//! (see [Error codes](#error-codes)) and a human-readable `"error"`.
//! A failed request never tears the session down: the simulator state is
//! untouched (stimulus batches are validated before any step executes)
//! and the next line is processed normally.
//!
//! # Ops (one request/response example each)
//!
//! `configure` — load a `.hsn` network and (re)build the simulator from
//! the session's deployment options; an existing simulator is replaced.
//! Optional fields override the CLI options: `seed` (noise base seed),
//! `workers` (worker-thread count for the pooled backends, >= 1 —
//! bit-exactness is worker-count-invariant, so this only tunes
//! throughput) and `shards` (shard-subprocess count: implies
//! `backend=sharded`, >= 1 and <= the topology's core count —
//! spike trains are shard-count-invariant, see
//! [`crate::cluster::shard`]) and `learning` (an object switching on
//! pair-based STDP for this session — integer fields `a_plus`,
//! `a_minus`, `tau_pre`, `tau_post`, `w_min`, `w_max`, each optional
//! over [`PlasticityConfig::default`]; mistyped fields answer
//! `malformed_request`, invalid combinations and unsupported backends
//! answer `config`; see [`crate::plasticity`]). The response breaks the cold start down: `load_ms`
//! (network load — mmap + validate for `.hsn` v2, full heap parse for
//! v1), `compile_ms` (partition + HBM compile + worker pools) and
//! `net_bytes` (on-disk file size):
//!
//! ```text
//! -> {"op":"configure","net":"mnist.hsn","seed":7,"workers":4}
//! <- {"axons":64,"backend":"rust","compile_ms":41.7,"load_ms":0.3,"net_bytes":6400512,"neurons":100000,"ok":true,"op":"configure","outputs":10,"protocol":1}
//! ```
//!
//! `step` — advance one tick; `axons` lists fired global axon ids (the
//! server sorts + dedups). `spikes` are fired output-neuron ids
//! (ascending global ids), `fired` counts all fired neurons:
//!
//! ```text
//! -> {"op":"step","axons":[0,3]}
//! <- {"fired":2,"ok":true,"op":"step","spikes":[1]}
//! ```
//!
//! `step_many` — advance one tick per `batch` entry in a single
//! request/response round trip (the batched-stimulus amortisation of
//! [`Simulator::step_many`]); at most [`MAX_BATCH_STEPS`] steps:
//!
//! ```text
//! -> {"op":"step_many","batch":[[0],[],[1]]}
//! <- {"fired_total":5,"ok":true,"op":"step_many","spikes":[[],[1],[0,1]]}
//! ```
//!
//! `read_membrane` — membrane potentials for global neuron ids:
//!
//! ```text
//! -> {"op":"read_membrane","ids":[0,1,2]}
//! <- {"ok":true,"op":"read_membrane","v":[3,-1,0]}
//! ```
//!
//! `write_synapse` — upsert one synapse weight live, between steps.
//! `pre` names the source (`"pre_is_axon": true` selects the axon id
//! space; default `false` = neuron source), `post` the target neuron,
//! `weight` an i16. The engine slot is patched in place — membranes,
//! step counter and accumulated cost are untouched (the
//! online-learning fast path) — and the edit is also recorded in the
//! session's [`EditJournal`]. When the in-place patch is structurally
//! impossible (full HBM row, a source with no HiAER route to the
//! target's core, an edit-less backend), the journal is compacted into
//! a fresh CSR and the simulator rebuilt from it: `"compacted": true`,
//! and membranes reset on that path only. `created` reports whether
//! the edit created the synapse (`false` = overwrote an existing one):
//!
//! ```text
//! -> {"op":"write_synapse","pre":0,"post":2,"weight":7}
//! <- {"compacted":false,"created":true,"ok":true,"op":"write_synapse"}
//! ```
//!
//! `reset` — restore membranes/step counter and clear cost counters
//! (learned/edited weights persist — see [`crate::plasticity`]):
//!
//! ```text
//! -> {"op":"reset"}
//! <- {"ok":true,"op":"reset"}
//! ```
//!
//! `cost` — aggregate cost counters since the last reset, under the
//! default energy model:
//!
//! ```text
//! -> {"op":"cost"}
//! <- {"backend":"rust","cycles":410,"energy_uj":1.2,"events":96,"hbm_rows":14,"latency_us":0.4,"ok":true,"op":"cost"}
//! ```
//!
//! `health` — liveness probe, answered even before `configure`. Over
//! stdio it reports the single session; the shared TCP server
//! ([`crate::sim::serve`]) intercepts it and reports server-wide state
//! (active sessions, queue depth, draining flag):
//!
//! ```text
//! -> {"op":"health"}
//! <- {"configured":true,"ok":true,"op":"health","protocol":1}
//! ```
//!
//! `metrics` — counters since the session started: requests served,
//! error responses, simulation steps executed, synapse edits applied
//! (`edits_applied`) and edit-journal compactions (rebuilds —
//! `journal_compactions`), plus the most recent `configure`'s
//! cold-start breakdown. The TCP server again intercepts
//! this op and adds server-wide totals (sessions, evictions, queue
//! depth, step rates — see [`crate::sim::serve`]):
//!
//! ```text
//! -> {"op":"metrics"}
//! <- {"edits_applied":3,"errors":0,"journal_compactions":0,"last_compile_ms":41.7,"last_load_ms":0.3,"net_bytes":6400512,"ok":true,"op":"metrics","requests":5,"steps":12}
//! ```
//!
//! `shutdown` — acknowledge, drop the simulator and end the serve loop.
//! The codec itself stays usable: a later `configure` on the same
//! [`Session`] starts a fresh simulator (mid-session shutdown is
//! recoverable for embedding callers):
//!
//! ```text
//! -> {"op":"shutdown"}
//! <- {"ok":true,"op":"shutdown"}
//! ```
//!
//! # Binary wire (wire v2, PR 10)
//!
//! JSON lines are the default and remain the *control channel* forever
//! — `configure`, errors, `health`, `metrics`, eviction notices are
//! always JSON lines. What the binary wire replaces is the marshalling
//! hot path: `step_many` batches and their spike responses.
//!
//! **Negotiation.** A client opts in per session by sending
//! `"wire":"binary"` in a `configure` request; the response echoes
//! `"wire":"binary"` back (`"wire":"json"` otherwise). An old server
//! ignores the unknown field and echoes nothing — that missing echo is
//! how clients detect negotiation failure. The mode applies from the
//! next request after the successful `configure` and is re-negotiated
//! (default: JSON) by every later `configure`.
//!
//! **Framing.** After negotiation the client may send stimulus batches
//! as binary frames interleaved with JSON lines on the same stream:
//!
//! ```text
//! 0x00 sentinel | u32 len (LE) | u8 kind | payload
//! ```
//!
//! The one-byte `0x00` sentinel can never begin a JSON line (`{` is
//! 0x7B), so the server routes on a single peeked byte. `len` counts
//! the kind byte plus payload (codec shared with the shard AER pipes —
//! [`crate::sim::frames`]) and is capped at
//! [`frames::MAX_FRAME_BYTES`](crate::sim::frames::MAX_FRAME_BYTES):
//! a corrupt prefix can never OOM the server, and because a binary
//! stream cannot be resynchronised after a bad length, the server
//! answers one `malformed_request` line and closes the connection (the
//! only binary-wire fault that ends the session; every in-frame fault
//! below keeps it alive).
//!
//! Frame kinds (payload ids all u32-LE; see [`crate::sim::frames`]):
//!
//! | kind | name   | dir             | payload                                     |
//! |------|--------|-----------------|---------------------------------------------|
//! | 0x10 | STIM   | client → server | `u32 n_steps, n×{u32 n, n×u32 axon_id}`     |
//! | 0x90 | SPIKES | server → client | `u64 fired_total, u32 n_steps, n×{u32 n, n×u32 output_neuron_id}` |
//!
//! A STIM frame is exactly a `step_many` request: same
//! [`MAX_BATCH_STEPS`] / quota caps, same server-side sort+dedup
//! marshalling, same atomic validation — the same schedule produces a
//! **bit-identical** spike train over either wire (pinned by parity
//! tests). Errors are *always* JSON lines, so error handling is
//! wire-independent: a frame before negotiation, an unknown kind or an
//! undecodable payload answers `malformed_request`; oversized batches,
//! quotas, `no_session` and engine errors answer their usual codes; in
//! all those cases the session survives and the next request (either
//! wire) is served normally. [`PROTOCOL_VERSION`] stays 1 — the binary
//! wire is opt-in and fully backward compatible.
//!
//! # Error codes
//!
//! | code                  | meaning                                            |
//! |-----------------------|----------------------------------------------------|
//! | `malformed_request`   | line is not JSON / missing or mistyped fields /    |
//! |                       | line longer than the transport's byte cap          |
//! | `unknown_op`          | `op` is not one of the ten ops                     |
//! | `no_session`          | execution op before a successful `configure`       |
//! | `oversized_batch`     | `step_many` batch exceeds [`MAX_BATCH_STEPS`]      |
//! | `quota`               | a per-session quota ([`SessionLimits`]) exceeded:  |
//! |                       | net too large, batch over the session's step cap,  |
//! |                       | synapse edits over the per-step edit cap           |
//! | `server_busy`         | shared server at capacity / draining; reconnect    |
//! |                       | later (emitted instead of `hello`, then closed)    |
//! | `deadline`            | request waited too long for shared-server capacity |
//! | `evicted`             | session removed: idle TTL, error flood, panic or   |
//! |                       | server drain (best-effort notice, then close)      |
//! | `backend_unavailable` | [`SimError::BackendUnavailable`] (e.g. no pjrt)    |
//! | `config`              | bad network file / [`SimError::Config`]            |
//! | `stimulus`            | out-of-range axon or neuron id                     |
//! | `engine`              | engine-level failure ([`SimError::Engine`]) or a   |
//! |                       | panic caught by the shared server's isolation      |
//!
//! The Python client maps these to typed exceptions
//! (`hs_api.exceptions`: `stimulus` → `HsStimulusError`,
//! `backend_unavailable` → `HsBackendUnavailable`, `quota` →
//! `HsQuotaError`, `server_busy`/`deadline` → `HsServerBusy`, ...).
//! Codes are part of the wire contract — add new ones, never rename
//! existing ones.
//!
//! # Quotas, deadlines, eviction
//!
//! A [`Session`] can carry [`SessionLimits`] (a shared server sets them
//! from its CLI flags): `max_neurons` bounds the network a `configure`
//! may load, `max_batch_steps` tightens the global
//! [`MAX_BATCH_STEPS`] cap per session, `max_edits_per_step` bounds
//! `write_synapse` ops between two step intervals (a learning client
//! must keep stepping, not mutate weights unboundedly — the serving
//! tier's `--max-edits-per-step`). All violations answer `quota`
//! and leave the session alive. Deadlines (`deadline`) and eviction
//! (`evicted`) only exist on the shared server — the stdio transport
//! has one client and no contention; see [`crate::sim::serve`] for
//! those semantics. Per-request concurrency quota is structural: the
//! protocol is strictly request/response per connection, so a session
//! can never have more than one request in flight.

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::Instant;

use crate::energy::EnergyModel;
use crate::model_fmt::NetCache;
use crate::plasticity::PlasticityConfig;
use crate::sim::frames;
use crate::sim::{NetSource, SimError, SimOptions, Simulator};
use crate::snn::{EditJournal, EditKey};
use crate::util::json::{arr_i64, obj, Json};

/// Protocol revision announced in the `hello` greeting and `configure`
/// responses. Bump only on a breaking wire change.
pub const PROTOCOL_VERSION: i64 = 1;

/// Hard cap on `step_many` batch length: bounds per-request memory and
/// keeps one request from wedging the session for minutes. Oversized
/// batches are rejected with `oversized_batch` before any step runs.
pub const MAX_BATCH_STEPS: usize = 65_536;

pub const CODE_MALFORMED: &str = "malformed_request";
pub const CODE_UNKNOWN_OP: &str = "unknown_op";
pub const CODE_NO_SESSION: &str = "no_session";
pub const CODE_OVERSIZED_BATCH: &str = "oversized_batch";
pub const CODE_BACKEND_UNAVAILABLE: &str = "backend_unavailable";
pub const CODE_CONFIG: &str = "config";
pub const CODE_STIMULUS: &str = "stimulus";
pub const CODE_ENGINE: &str = "engine";
/// A per-session quota ([`SessionLimits`]) was exceeded.
pub const CODE_QUOTA: &str = "quota";
/// Shared server at capacity or draining; sent instead of `hello`.
pub const CODE_SERVER_BUSY: &str = "server_busy";
/// Request waited past its deadline for shared-server capacity.
pub const CODE_DEADLINE: &str = "deadline";
/// Session removed by the shared server (idle TTL, error flood, panic,
/// drain); best-effort notice before the connection closes.
pub const CODE_EVICTED: &str = "evicted";

/// Byte cap on one request line over the stdio transport. Lines longer
/// than this are answered with `malformed_request` — and crucially are
/// *consumed without buffering*, so an oversized line cannot OOM the
/// server. Generous because a max-size `step_many` batch is a legitimate
/// multi-megabyte line; the TCP server defaults tighter (per-connection
/// memory is multiplied by the session count).
pub const MAX_LINE_BYTES_STDIO: usize = 64 << 20;

/// Stable protocol error code for a facade error. Every [`SimError`]
/// variant maps to exactly one code (the wire contract the Python
/// exception types are built on).
pub fn error_code(e: &SimError) -> &'static str {
    match e {
        SimError::BackendUnavailable { .. } => CODE_BACKEND_UNAVAILABLE,
        SimError::Config(_) => CODE_CONFIG,
        SimError::Stimulus(_) => CODE_STIMULUS,
        SimError::Engine(_) => CODE_ENGINE,
    }
}

/// One parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Configure {
        net: String,
        seed: Option<u32>,
        workers: Option<usize>,
        shards: Option<usize>,
        learning: Option<PlasticityConfig>,
        /// `"wire":"binary"` negotiation (wire v2): `true` switches the
        /// session's `step_many` hot path to binary STIM/SPIKES frames
        /// once this configure succeeds.
        wire_binary: bool,
    },
    Step { axons: Vec<u32> },
    StepMany { batch: Vec<Vec<u32>> },
    ReadMembrane { ids: Vec<u32> },
    WriteSynapse { pre_is_axon: bool, pre: u32, post: u32, weight: i16 },
    Reset,
    Cost,
    Health,
    Metrics,
    Shutdown,
}

impl Request {
    /// Simulation steps this request would execute if it succeeds (what
    /// per-session step quotas and server step-rate metrics count).
    pub fn steps_requested(&self) -> usize {
        match self {
            Request::Step { .. } => 1,
            Request::StepMany { batch } => batch.len(),
            _ => 0,
        }
    }
}

/// Protocol-level parse/validation failure: stable code + message.
#[derive(Clone, Debug)]
pub struct ProtoError {
    pub code: &'static str,
    pub message: String,
}

fn perr(code: &'static str, message: impl Into<String>) -> ProtoError {
    ProtoError { code, message: message.into() }
}

fn id_value(v: &Json, key: &str) -> Result<u32, ProtoError> {
    match v.as_i64() {
        Some(x) if (0..=u32::MAX as i64).contains(&x) => Ok(x as u32),
        _ => Err(perr(CODE_MALFORMED, format!("`{key}` entries must be u32 ids"))),
    }
}

fn ids_field(j: &Json, key: &str, op: &str) -> Result<Vec<u32>, ProtoError> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| perr(CODE_MALFORMED, format!("{op}: missing array field `{key}`")))?;
    arr.iter().map(|v| id_value(v, key)).collect()
}

fn u32_field(j: &Json, key: &str, op: &str) -> Result<u32, ProtoError> {
    match j.get(key) {
        Some(v) => id_value(v, key),
        None => Err(perr(CODE_MALFORMED, format!("{op}: missing u32 field `{key}`"))),
    }
}

/// Parse a `configure.learning` object into a [`PlasticityConfig`].
/// Every field is optional over [`PlasticityConfig::default`]; mistyped
/// or out-of-range fields answer `malformed_request`. Cross-field
/// validity (`w_min <= w_max`, backend support) stays in
/// [`SimConfig::build`](crate::sim::SimConfig::build) — one validation
/// point, answered as `config`.
fn learning_field(v: &Json) -> Result<PlasticityConfig, ProtoError> {
    if !matches!(v, Json::Obj(_)) {
        return Err(perr(
            CODE_MALFORMED,
            "configure: `learning` must be an object like \
             {\"a_plus\":8,\"a_minus\":9,\"tau_pre\":3,\"tau_post\":3,\"w_min\":-128,\"w_max\":127}",
        ));
    }
    fn int(v: &Json, key: &str, lo: i64, hi: i64) -> Result<Option<i64>, ProtoError> {
        match v.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(x) => match x.as_i64() {
                Some(n) if (lo..=hi).contains(&n) => Ok(Some(n)),
                _ => Err(perr(
                    CODE_MALFORMED,
                    format!("learning.{key} must be an integer in [{lo}, {hi}]"),
                )),
            },
        }
    }
    let mut cfg = PlasticityConfig::default();
    if let Some(x) = int(v, "a_plus", i32::MIN as i64, i32::MAX as i64)? {
        cfg.a_plus = x as i32;
    }
    if let Some(x) = int(v, "a_minus", i32::MIN as i64, i32::MAX as i64)? {
        cfg.a_minus = x as i32;
    }
    if let Some(x) = int(v, "tau_pre", 0, u32::MAX as i64)? {
        cfg.tau_pre = x as u32;
    }
    if let Some(x) = int(v, "tau_post", 0, u32::MAX as i64)? {
        cfg.tau_post = x as u32;
    }
    if let Some(x) = int(v, "w_min", i16::MIN as i64, i16::MAX as i64)? {
        cfg.w_min = x as i16;
    }
    if let Some(x) = int(v, "w_max", i16::MIN as i64, i16::MAX as i64)? {
        cfg.w_max = x as i16;
    }
    Ok(cfg)
}

/// Parse one request line. Protocol-level failures (not JSON, bad
/// shape, unknown op, oversized batch) come back as a [`ProtoError`]
/// with the stable code; they never depend on session state.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let j = Json::parse(line).map_err(|e| perr(CODE_MALFORMED, format!("bad JSON: {e}")))?;
    let op = j
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| perr(CODE_MALFORMED, "missing string field `op`"))?;
    match op {
        "configure" => {
            let net = j
                .get("net")
                .and_then(Json::as_str)
                .ok_or_else(|| perr(CODE_MALFORMED, "configure: missing string field `net`"))?
                .to_string();
            let seed = match j.get("seed") {
                None | Some(Json::Null) => None,
                Some(v) => Some(id_value(v, "seed")?),
            };
            let workers = match j.get("workers") {
                None | Some(Json::Null) => None,
                Some(v) => Some(id_value(v, "workers")? as usize),
            };
            let shards = match j.get("shards") {
                None | Some(Json::Null) => None,
                Some(v) => Some(id_value(v, "shards")? as usize),
            };
            let learning = match j.get("learning") {
                None | Some(Json::Null) => None,
                Some(v) => Some(learning_field(v)?),
            };
            let wire_binary = match j.get("wire") {
                None | Some(Json::Null) => false,
                Some(Json::Str(s)) if s == "json" => false,
                Some(Json::Str(s)) if s == "binary" => true,
                Some(_) => {
                    return Err(perr(
                        CODE_MALFORMED,
                        "configure: `wire` must be \"json\" or \"binary\"",
                    ))
                }
            };
            Ok(Request::Configure { net, seed, workers, shards, learning, wire_binary })
        }
        "step" => Ok(Request::Step { axons: ids_field(&j, "axons", "step")? }),
        "step_many" => {
            let rows = j.get("batch").and_then(Json::as_arr).ok_or_else(|| {
                perr(CODE_MALFORMED, "step_many: missing array field `batch`")
            })?;
            if rows.len() > MAX_BATCH_STEPS {
                return Err(perr(
                    CODE_OVERSIZED_BATCH,
                    format!(
                        "batch of {} steps exceeds the {MAX_BATCH_STEPS}-step limit; split it",
                        rows.len()
                    ),
                ));
            }
            let batch = rows
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| {
                            perr(CODE_MALFORMED, "step_many: `batch` entries must be id arrays")
                        })?
                        .iter()
                        .map(|v| id_value(v, "batch"))
                        .collect()
                })
                .collect::<Result<Vec<Vec<u32>>, ProtoError>>()?;
            Ok(Request::StepMany { batch })
        }
        "read_membrane" => Ok(Request::ReadMembrane { ids: ids_field(&j, "ids", "read_membrane")? }),
        "write_synapse" => {
            let pre = u32_field(&j, "pre", "write_synapse")?;
            let post = u32_field(&j, "post", "write_synapse")?;
            let weight = match j.get("weight").map(Json::as_i64) {
                Some(Some(w)) if (i16::MIN as i64..=i16::MAX as i64).contains(&w) => w as i16,
                Some(Some(w)) => {
                    return Err(perr(
                        CODE_MALFORMED,
                        format!(
                            "write_synapse: `weight` {w} outside the i16 range [{}, {}]",
                            i16::MIN,
                            i16::MAX
                        ),
                    ))
                }
                _ => {
                    return Err(perr(
                        CODE_MALFORMED,
                        "write_synapse: missing integer field `weight`",
                    ))
                }
            };
            let pre_is_axon = match j.get("pre_is_axon") {
                None | Some(Json::Null) => false,
                Some(Json::Bool(b)) => *b,
                Some(_) => {
                    return Err(perr(
                        CODE_MALFORMED,
                        "write_synapse: `pre_is_axon` must be a boolean",
                    ))
                }
            };
            Ok(Request::WriteSynapse { pre_is_axon, pre, post, weight })
        }
        "reset" => Ok(Request::Reset),
        "cost" => Ok(Request::Cost),
        "health" => Ok(Request::Health),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(perr(
            CODE_UNKNOWN_OP,
            format!(
                "unknown op {other:?} (options: configure, step, step_many, read_membrane, \
                 write_synapse, reset, cost, health, metrics, shutdown)"
            ),
        )),
    }
}

fn ok_response(op: &str, mut fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(true)), ("op", Json::Str(op.to_string()))];
    all.append(&mut fields);
    obj(all).to_string()
}

pub(crate) fn err_response(code: &str, message: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::Str(code.to_string())),
        ("error", Json::Str(message.to_string())),
    ])
    .to_string()
}

/// Whether a serialized response line is an error. Error responses are
/// built by [`err_response`] and — keys being BTreeMap-sorted — always
/// serialize as `{"code":...`; no success op emits a `code` field.
pub(crate) fn is_error_response(resp: &str) -> bool {
    resp.starts_with("{\"code\"")
}

fn spikes_json(spikes: &[u32]) -> Json {
    arr_i64(spikes.iter().map(|&s| s as i64))
}

/// Sort + dedup a stimulus row: the engines require ascending unique
/// axon ids; the protocol accepts any order (client marshalling stays
/// trivial, the server canonicalises once per row).
fn marshal_axons(ids: &[u32]) -> Vec<u32> {
    let mut v = ids.to_vec();
    v.sort_unstable();
    v.dedup();
    v
}

/// Per-session quotas, enforced inside the codec so every transport
/// (stdio, TCP) rejects identically with the stable `quota` code.
/// `usize::MAX` (the default) means "no session-specific bound" — the
/// global [`MAX_BATCH_STEPS`] protocol cap still applies.
#[derive(Clone, Copy, Debug)]
pub struct SessionLimits {
    /// Largest network (neuron count) a `configure` may load.
    pub max_neurons: usize,
    /// Per-request `step_many` cap, tightened below [`MAX_BATCH_STEPS`].
    pub max_batch_steps: usize,
    /// `write_synapse` ops allowed between two step intervals (the
    /// serving tier's `--max-edits-per-step`). A successful `step` /
    /// `step_many` / `reset` / `configure` opens a fresh budget.
    pub max_edits_per_step: usize,
}

impl Default for SessionLimits {
    fn default() -> Self {
        SessionLimits {
            max_neurons: usize::MAX,
            max_batch_steps: usize::MAX,
            max_edits_per_step: usize::MAX,
        }
    }
}

/// Counters a session accumulates over its lifetime (the `metrics` op).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Requests handled (including ones answered with an error).
    pub requests: u64,
    /// Error responses produced.
    pub errors: u64,
    /// Simulation steps executed successfully.
    pub steps: u64,
    /// `write_synapse` edits applied (fast path and compaction path).
    pub edits_applied: u64,
    /// Edit-journal compactions: structural edits that forced a CSR
    /// rebuild (each one is a cold start — a high rate relative to
    /// `edits_applied` means the workload wants a different topology).
    pub journal_compactions: u64,
    /// Network-load wall time of the most recent successful `configure`
    /// (mmap + validate for `.hsn` v2; full heap parse for v1).
    pub last_load_ms: f64,
    /// Simulator-build wall time (partition + HBM compile + worker
    /// pools) of the most recent successful `configure`.
    pub last_compile_ms: f64,
    /// On-disk byte size of the most recently configured network file.
    pub net_bytes: u64,
}

/// Test seam: builds the simulator `configure` installs. Production code
/// always goes through [`SimConfig::build`](crate::sim::SimConfig);
/// fault-injection tests substitute panicking/slow simulators here.
#[doc(hidden)]
pub type SimFactory =
    Box<dyn FnMut(crate::snn::Network, SimOptions) -> Result<Box<dyn Simulator>, SimError> + Send>;

/// A protocol session: deployment options fixed at construction (from
/// the `serve-session` CLI flags), simulator built/replaced by
/// `configure`. Drives any [`Simulator`] the facade can build.
pub struct Session {
    opts: SimOptions,
    limits: SessionLimits,
    energy: EnergyModel,
    sim: Option<Box<dyn Simulator>>,
    stats: SessionStats,
    sim_factory: Option<SimFactory>,
    net_cache: Option<Arc<NetCache>>,
    /// Network source of the most recent successful `configure`,
    /// retained as the edit journal's compaction base (`.hsn` v2 is an
    /// `Arc` clone of the shared mapping; owned heap nets are kept by
    /// reference-of-record in the same enum).
    base: Option<NetSource>,
    /// Effective deployment options of the most recent successful
    /// `configure` (CLI opts + per-request overrides) — what a
    /// compaction rebuild must reuse to stay bit-compatible.
    active_opts: Option<SimOptions>,
    /// Pending + applied `write_synapse` edits since the last
    /// compaction, recorded against `base` (see [`EditJournal`]).
    journal: EditJournal,
    /// `write_synapse` ops since the last step interval (the
    /// `max_edits_per_step` quota counter).
    edits_since_step: usize,
    /// Whether the most recent successful `configure` negotiated the
    /// binary wire (`"wire":"binary"`); gates [`Session::handle_frame`].
    wire_binary: bool,
}

impl Session {
    pub fn new(opts: SimOptions) -> Self {
        Self::with_limits(opts, SessionLimits::default())
    }

    /// A session with per-session quotas (the shared server's path).
    pub fn with_limits(opts: SimOptions, limits: SessionLimits) -> Self {
        Session {
            opts,
            limits,
            energy: EnergyModel::default(),
            sim: None,
            stats: SessionStats::default(),
            sim_factory: None,
            net_cache: None,
            base: None,
            active_opts: None,
            journal: EditJournal::new(),
            edits_since_step: 0,
            wire_binary: false,
        }
    }

    /// Whether the session has negotiated the binary wire (wire v2) —
    /// i.e. the most recent successful `configure` carried
    /// `"wire":"binary"`.
    pub fn wire_is_binary(&self) -> bool {
        self.wire_binary
    }

    /// Install a shared network-mapping cache: `configure` ops on this
    /// session then share one mmap per `.hsn` v2 path with every other
    /// session holding the same cache (the TCP server installs one
    /// server-wide cache; stdio sessions have one client and skip it).
    pub fn set_net_cache(&mut self, cache: Arc<NetCache>) {
        self.net_cache = Some(cache);
    }

    /// Test seam: replace the facade build with a custom simulator
    /// factory (panic injection, artificial slowness). Quota checks
    /// still run against whatever the factory returns.
    #[doc(hidden)]
    pub fn set_sim_factory_for_tests(&mut self, f: SimFactory) {
        self.sim_factory = Some(f);
    }

    /// Lifetime counters (served by the `metrics` op; the shared server
    /// aggregates them across sessions).
    pub fn stats(&self) -> SessionStats {
        self.stats
    }

    /// The greeting line emitted before any request is read.
    pub fn hello(&self) -> String {
        ok_response(
            "hello",
            vec![
                ("protocol", Json::Int(PROTOCOL_VERSION)),
                ("backend", Json::Str(self.opts.backend.name().to_string())),
            ],
        )
    }

    /// Whether a `configure` has succeeded (and no shutdown followed).
    pub fn is_configured(&self) -> bool {
        self.sim.is_some()
    }

    /// Handle one raw request line. Returns the response line plus a
    /// `done` flag that is `true` only after a clean `shutdown`. Errors
    /// — protocol-level or simulator-level — always leave the session
    /// in a recoverable state (`done` stays `false`, simulator state
    /// untouched by invalid stimuli).
    pub fn handle_line(&mut self, line: &str) -> (String, bool) {
        match parse_request(line) {
            Err(e) => {
                self.stats.requests += 1;
                self.stats.errors += 1;
                (err_response(e.code, &e.message), false)
            }
            Ok(req) => self.handle_request(req),
        }
    }

    fn sim_or_err(&mut self) -> Result<&mut dyn Simulator, String> {
        self.sim
            .as_deref_mut()
            .ok_or_else(|| err_response(CODE_NO_SESSION, "no simulator: send `configure` first"))
    }

    /// Handle one already-parsed request (what [`Session::handle_line`]
    /// dispatches to, and what the shared server calls after doing its
    /// own parse so it can intercept `health`/`metrics` server-side).
    pub fn handle_request(&mut self, req: Request) -> (String, bool) {
        let steps = req.steps_requested() as u64;
        let (resp, done) = self.dispatch(req);
        self.stats.requests += 1;
        if is_error_response(&resp) {
            self.stats.errors += 1;
        } else {
            self.stats.steps += steps;
            if steps > 0 {
                // a successful step interval opens a fresh edit budget
                self.edits_since_step = 0;
            }
        }
        (resp, done)
    }

    /// Handle one binary-wire frame (wire v2). `Ok` is the complete
    /// sentinel-prefixed SPIKES reply, ready to write to the stream;
    /// `Err` is a JSON error line — errors always travel as JSON, so a
    /// client's error handling is wire-independent. Every error leaves
    /// the session alive and the simulator untouched, exactly like the
    /// JSON `step_many` path.
    pub fn handle_frame(&mut self, kind: u8, payload: &[u8]) -> Result<Vec<u8>, String> {
        let out = self.frame_response(kind, payload);
        self.stats.requests += 1;
        if out.is_err() {
            self.stats.errors += 1;
        }
        out
    }

    fn frame_response(&mut self, kind: u8, payload: &[u8]) -> Result<Vec<u8>, String> {
        if !self.wire_binary {
            return Err(err_response(
                CODE_MALFORMED,
                "binary frame before `\"wire\":\"binary\"` was negotiated at configure",
            ));
        }
        if kind != frames::FRAME_STIM {
            return Err(err_response(
                CODE_MALFORMED,
                &format!("unexpected binary frame kind 0x{kind:02x} (clients send STIM 0x10)"),
            ));
        }
        let batch = frames::decode_stim(payload)
            .map_err(|e| err_response(CODE_MALFORMED, &format!("bad STIM frame: {e}")))?;
        if batch.len() > MAX_BATCH_STEPS {
            return Err(err_response(
                CODE_OVERSIZED_BATCH,
                &format!(
                    "batch of {} steps exceeds the {MAX_BATCH_STEPS}-step limit; split it",
                    batch.len()
                ),
            ));
        }
        if batch.len() > self.limits.max_batch_steps {
            return Err(err_response(
                CODE_QUOTA,
                &format!(
                    "batch of {} steps exceeds this session's {}-step quota",
                    batch.len(),
                    self.limits.max_batch_steps
                ),
            ));
        }
        let sim = self.sim.as_deref_mut().ok_or_else(|| {
            err_response(CODE_NO_SESSION, "no simulator: send `configure` first")
        })?;
        // same server-side canonicalisation as the JSON path — this is
        // what keeps the two wires bit-identical on the same schedule
        let batch: Vec<Vec<u32>> = batch.iter().map(|row| marshal_axons(row)).collect();
        match sim.step_many(&batch) {
            Ok(r) => {
                self.stats.steps += batch.len() as u64;
                self.edits_since_step = 0;
                let payload = frames::encode_spikes(&r.spikes, r.fired_total);
                frames::encode_wire_frame(frames::FRAME_SPIKES, &payload).map_err(|e| {
                    err_response(CODE_ENGINE, &format!("encoding SPIKES frame: {e}"))
                })
            }
            Err(e) => Err(err_response(error_code(&e), &e.to_string())),
        }
    }

    fn dispatch(&mut self, req: Request) -> (String, bool) {
        match req {
            Request::Configure { net, seed, workers, shards, learning, wire_binary } => {
                (self.configure(&net, seed, workers, shards, learning, wire_binary), false)
            }
            Request::Step { axons } => {
                let sim = match self.sim_or_err() {
                    Ok(s) => s,
                    Err(resp) => return (resp, false),
                };
                let axons = marshal_axons(&axons);
                match sim.step(&axons) {
                    Ok(out) => {
                        let fired = out.fired.len() as i64;
                        let spikes = spikes_json(out.output_spikes);
                        (
                            ok_response(
                                "step",
                                vec![("spikes", spikes), ("fired", Json::Int(fired))],
                            ),
                            false,
                        )
                    }
                    Err(e) => (err_response(error_code(&e), &e.to_string()), false),
                }
            }
            Request::StepMany { batch } => {
                if batch.len() > self.limits.max_batch_steps {
                    return (
                        err_response(
                            CODE_QUOTA,
                            &format!(
                                "batch of {} steps exceeds this session's {}-step quota",
                                batch.len(),
                                self.limits.max_batch_steps
                            ),
                        ),
                        false,
                    );
                }
                let sim = match self.sim_or_err() {
                    Ok(s) => s,
                    Err(resp) => return (resp, false),
                };
                // one marshalling pass for the whole batch (the protocol
                // mirror of Simulator::step_many's up-front validation)
                let batch: Vec<Vec<u32>> = batch.iter().map(|row| marshal_axons(row)).collect();
                match sim.step_many(&batch) {
                    Ok(r) => {
                        let spikes = Json::Arr(r.spikes.iter().map(|s| spikes_json(s)).collect());
                        (
                            ok_response(
                                "step_many",
                                vec![
                                    ("spikes", spikes),
                                    ("fired_total", Json::Int(r.fired_total as i64)),
                                ],
                            ),
                            false,
                        )
                    }
                    Err(e) => (err_response(error_code(&e), &e.to_string()), false),
                }
            }
            Request::ReadMembrane { ids } => {
                let sim = match self.sim_or_err() {
                    Ok(s) => s,
                    Err(resp) => return (resp, false),
                };
                let n = sim.n_neurons();
                if let Some(&bad) = ids.iter().find(|&&i| i as usize >= n) {
                    return (
                        err_response(
                            CODE_STIMULUS,
                            &format!("neuron id {bad} out of range ({n} neurons)"),
                        ),
                        false,
                    );
                }
                let v = sim.read_membrane(&ids);
                (
                    ok_response(
                        "read_membrane",
                        vec![("v", arr_i64(v.iter().map(|&x| x as i64)))],
                    ),
                    false,
                )
            }
            Request::WriteSynapse { pre_is_axon, pre, post, weight } => {
                (self.write_synapse_op(pre_is_axon, pre, post, weight), false)
            }
            Request::Reset => {
                let sim = match self.sim_or_err() {
                    Ok(s) => s,
                    Err(resp) => return (resp, false),
                };
                sim.reset();
                self.edits_since_step = 0;
                (ok_response("reset", vec![]), false)
            }
            Request::Cost => {
                let energy = self.energy;
                let sim = match self.sim_or_err() {
                    Ok(s) => s,
                    Err(resp) => return (resp, false),
                };
                let c = sim.cost(&energy);
                (
                    ok_response(
                        "cost",
                        vec![
                            ("energy_uj", Json::Num(c.energy_uj)),
                            ("latency_us", Json::Num(c.latency_us)),
                            ("hbm_rows", Json::Int(c.hbm_rows as i64)),
                            ("events", Json::Int(c.events as i64)),
                            ("cycles", Json::Int(c.cycles as i64)),
                            ("backend", Json::Str(sim.backend_name().to_string())),
                        ],
                    ),
                    false,
                )
            }
            Request::Health => (
                ok_response(
                    "health",
                    vec![
                        ("protocol", Json::Int(PROTOCOL_VERSION)),
                        ("configured", Json::Bool(self.sim.is_some())),
                    ],
                ),
                false,
            ),
            Request::Metrics => (
                ok_response(
                    "metrics",
                    vec![
                        ("requests", Json::Int(self.stats.requests as i64)),
                        ("errors", Json::Int(self.stats.errors as i64)),
                        ("steps", Json::Int(self.stats.steps as i64)),
                        ("edits_applied", Json::Int(self.stats.edits_applied as i64)),
                        (
                            "journal_compactions",
                            Json::Int(self.stats.journal_compactions as i64),
                        ),
                        ("last_load_ms", Json::Num(self.stats.last_load_ms)),
                        ("last_compile_ms", Json::Num(self.stats.last_compile_ms)),
                        ("net_bytes", Json::Int(self.stats.net_bytes as i64)),
                    ],
                ),
                false,
            ),
            Request::Shutdown => {
                self.sim = None;
                (ok_response("shutdown", vec![]), true)
            }
        }
    }

    /// The `write_synapse` op: quota + range checks, journal record,
    /// then the in-place engine patch — falling back to a journal
    /// compaction + rebuild when the patch is structurally impossible.
    fn write_synapse_op(&mut self, pre_is_axon: bool, pre: u32, post: u32, weight: i16) -> String {
        let (n, a) = match self.sim.as_deref() {
            Some(sim) => (sim.n_neurons(), sim.n_axons()),
            None => {
                return err_response(CODE_NO_SESSION, "no simulator: send `configure` first")
            }
        };
        if self.edits_since_step >= self.limits.max_edits_per_step {
            return err_response(
                CODE_QUOTA,
                &format!(
                    "{} synapse edits since the last step reach this session's {}-edit \
                     quota; step before editing further",
                    self.edits_since_step, self.limits.max_edits_per_step
                ),
            );
        }
        if post as usize >= n {
            return err_response(
                CODE_STIMULUS,
                &format!("neuron id {post} out of range ({n} neurons)"),
            );
        }
        let (space, bound) = if pre_is_axon { ("axon", a) } else { ("neuron", n) };
        if pre as usize >= bound {
            return err_response(
                CODE_STIMULUS,
                &format!("{space} id {pre} out of range ({bound} {space}s)"),
            );
        }
        // Record in the journal first: the journal is the compaction
        // source of truth, so the structural fallback below already
        // sees this edit when it rebuilds.
        let key = EditKey { pre_is_axon, pre, post };
        let journal_created = match self.base.as_ref() {
            Some(base) => Some(self.journal.add_synapse(base.view(), key, weight)),
            // test-factory sessions retain no base; fast path only
            None => None,
        };
        // Fast path: patch the engine slot in place — membranes, step
        // counter and cost counters untouched.
        let sim = self.sim.as_deref_mut().expect("checked above");
        let patched = match sim.write_synapse(pre_is_axon, pre, post, weight) {
            Ok(true) => Ok(false), // overwrote an existing synapse
            Ok(false) => sim.add_synapse(pre_is_axon, pre, post, weight).map(|_| true),
            Err(e) => Err(e),
        };
        let (created, compacted) = match patched {
            Ok(created) => (created, false),
            // Structurally impossible in place (full HBM row, a source
            // with no HiAER route to the target's core, an edit-less
            // backend): compact base + journal into a fresh CSR and
            // rebuild — the slow path the journal exists to make rare.
            Err(_) => match self.compact_and_rebuild() {
                Ok(()) => (journal_created.unwrap_or(true), true),
                Err(e) => return err_response(error_code(&e), &e.to_string()),
            },
        };
        self.edits_since_step += 1;
        self.stats.edits_applied += 1;
        ok_response(
            "write_synapse",
            vec![("created", Json::Bool(created)), ("compacted", Json::Bool(compacted))],
        )
    }

    /// Slow-path edit application: materialise the retained base CSR +
    /// journal into a fresh [`crate::snn::Network`] and rebuild the
    /// simulator with the session's active deployment options. The
    /// rebuild is a cold start (membranes/step counter reset). On error
    /// nothing is swapped — the old simulator, base and journal all
    /// survive, so the pending edit lands at the next successful
    /// compaction.
    fn compact_and_rebuild(&mut self) -> Result<(), SimError> {
        let base = self.base.as_ref().ok_or_else(|| {
            SimError::Config(
                "this session retains no base network; reconfigure before structural edits"
                    .into(),
            )
        })?;
        let fresh = self.journal.compact(base.view());
        let opts = self.active_opts.clone().unwrap_or_else(|| self.opts.clone());
        let sim = match self.sim_factory.as_mut() {
            Some(factory) => factory(fresh.clone(), opts)?,
            None => opts.into_config(fresh.clone()).build()?,
        };
        self.sim = Some(sim);
        self.base = Some(NetSource::Owned(fresh));
        self.journal.clear();
        self.stats.journal_compactions += 1;
        Ok(())
    }

    fn configure(
        &mut self,
        net_path: &str,
        seed: Option<u32>,
        workers: Option<usize>,
        shards: Option<usize>,
        learning: Option<PlasticityConfig>,
        wire_binary: bool,
    ) -> String {
        // Cold-start phase 1 — load: `.hsn` v2 is mmap + validate
        // (zero-copy), v1 a full heap parse. Timed separately from the
        // build so the response exposes where a slow configure went.
        let t_load = Instant::now();
        let src = match NetSource::from_path_cached(net_path, self.net_cache.as_deref()) {
            Ok(s) => s,
            Err(SimError::Engine(e)) => {
                return err_response(CODE_CONFIG, &format!("loading {net_path}: {e:#}"))
            }
            Err(e) => return err_response(CODE_CONFIG, &format!("loading {net_path}: {e}")),
        };
        let load_ms = t_load.elapsed().as_secs_f64() * 1e3;
        let net_bytes = src
            .file_bytes()
            .or_else(|| std::fs::metadata(net_path).ok().map(|m| m.len()))
            .unwrap_or(0);
        let view = src.view();
        if view.n_neurons() > self.limits.max_neurons {
            // checked before the (expensive) HBM compile: an over-quota
            // net must not cost the server the work of building it
            return err_response(
                CODE_QUOTA,
                &format!(
                    "network has {} neurons, over this session's {}-neuron quota",
                    view.n_neurons(),
                    self.limits.max_neurons
                ),
            );
        }
        let n_outputs = view.outputs.len();
        let mut opts = self.opts.clone();
        if seed.is_some() {
            opts.seed = seed;
        }
        if workers.is_some() {
            // workers: 0 flows into SimConfig::build, which rejects it
            // with a `config` error (one validation point, not two)
            opts.workers = workers;
        }
        if let Some(n) = shards {
            // shards implies the sharded backend, mirroring the CLI's
            // `--shards N`; 0 / over-core-count flow into
            // ShardedSim::build's single validation point
            opts.shards = Some(n);
            opts.backend = crate::sim::Backend::Sharded;
        }
        if learning.is_some() {
            // per-session STDP switch-on; invalid configs flow into
            // SimConfig::build's single validation point
            opts.learning = learning;
        }
        // Cold-start phase 2 — build: partition + HBM compile + pools.
        let t_compile = Instant::now();
        let active_opts = opts.clone();
        let built = match self.sim_factory.as_mut() {
            // the test seam keeps its owned-Network signature; this is
            // the one materialisation point on the configure path
            Some(factory) => factory(src.view().to_network(), opts),
            None => opts.into_config(src.clone()).build(),
        };
        let compile_ms = t_compile.elapsed().as_secs_f64() * 1e3;
        match built {
            Ok(sim) => {
                let resp = ok_response(
                    "configure",
                    vec![
                        ("protocol", Json::Int(PROTOCOL_VERSION)),
                        ("backend", Json::Str(sim.backend_name().to_string())),
                        ("neurons", Json::Int(sim.n_neurons() as i64)),
                        ("axons", Json::Int(sim.n_axons() as i64)),
                        ("outputs", Json::Int(n_outputs as i64)),
                        ("load_ms", Json::Num(load_ms)),
                        ("compile_ms", Json::Num(compile_ms)),
                        ("net_bytes", Json::Int(net_bytes as i64)),
                        // the negotiation echo (wire v2): an old server
                        // omits this field, which is how clients detect
                        // that `"wire":"binary"` was silently ignored
                        (
                            "wire",
                            Json::Str(if wire_binary { "binary" } else { "json" }.to_string()),
                        ),
                    ],
                );
                self.sim = Some(sim);
                self.wire_binary = wire_binary;
                // fresh network ⇒ stale pending edits die with it; the
                // source + effective opts become the compaction base
                self.base = Some(src);
                self.active_opts = Some(active_opts);
                self.journal.clear();
                self.edits_since_step = 0;
                self.stats.last_load_ms = load_ms;
                self.stats.last_compile_ms = compile_ms;
                self.stats.net_bytes = net_bytes;
                resp
            }
            Err(e) => err_response(error_code(&e), &e.to_string()),
        }
    }
}

/// One read outcome from [`CappedLineReader`].
#[derive(Debug)]
pub(crate) enum LineRead {
    /// A complete line (newline stripped, trailing `\r` dropped).
    Line(String),
    /// The line exceeded the byte cap. Its bytes were consumed and
    /// *discarded* as they streamed in — answer `malformed_request` and
    /// keep serving; memory use stayed bounded throughout.
    TooLong,
    /// Clean end of input (EOF with no buffered partial line, or a
    /// partial line with no terminating newline — a disconnect mid-line
    /// is not a request).
    Eof,
    /// The per-call time budget elapsed mid-line (anti-slow-loris: a
    /// client dripping bytes cannot pin the caller inside `read_line`,
    /// which would starve its idle-TTL and drain checks). State is kept;
    /// call again to resume. Crucially this is *not* activity — only a
    /// completed line resets a session's idle clock.
    Pending,
}

/// Line reader with a hard byte cap — the protocol's anti-OOM /
/// anti-slow-loris guard. Unlike `BufRead::lines`, (a) a line longer
/// than `cap` never accumulates in memory (bytes past the cap are
/// drained and dropped until the newline), and (b) state survives
/// `WouldBlock`/`TimedOut` errors from a read-timeout transport, so the
/// TCP server can poll for idleness mid-line without losing the prefix.
pub(crate) struct CappedLineReader {
    buf: Vec<u8>,
    overflow: bool,
    cap: usize,
}

impl CappedLineReader {
    pub(crate) fn new(cap: usize) -> Self {
        CappedLineReader { buf: Vec::new(), overflow: false, cap }
    }

    /// Whether a partial line is buffered (or being drained as
    /// overflow). While true, [`WireReader`] must keep feeding this
    /// reader instead of sniffing for a frame sentinel — a stray NUL
    /// *inside* a line is line content, not a frame boundary.
    pub(crate) fn is_mid_line(&self) -> bool {
        !self.buf.is_empty() || self.overflow
    }

    pub(crate) fn read_line<R: BufRead>(&mut self, r: &mut R) -> std::io::Result<LineRead> {
        let call_start = std::time::Instant::now();
        loop {
            if call_start.elapsed() > std::time::Duration::from_millis(150) {
                return Ok(LineRead::Pending);
            }
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                // timeouts/interrupts propagate with the partial line
                // intact; the caller retries and we resume mid-line
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                // EOF: a buffered partial line is a disconnect, not a
                // request — drop it (see serve_tcp: partial-line
                // disconnects must not execute anything)
                self.buf.clear();
                return Ok(if std::mem::take(&mut self.overflow) {
                    LineRead::TooLong
                } else {
                    LineRead::Eof
                });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    let too_long =
                        std::mem::take(&mut self.overflow) || self.buf.len() + i > self.cap;
                    if !too_long {
                        self.buf.extend_from_slice(&chunk[..i]);
                    }
                    r.consume(i + 1);
                    if too_long {
                        self.buf.clear();
                        return Ok(LineRead::TooLong);
                    }
                    if self.buf.last() == Some(&b'\r') {
                        self.buf.pop();
                    }
                    let line = String::from_utf8_lossy(&self.buf).into_owned();
                    self.buf.clear();
                    return Ok(LineRead::Line(line));
                }
                None => {
                    let n = chunk.len();
                    if !self.overflow {
                        if self.buf.len() + n > self.cap {
                            self.overflow = true;
                            self.buf = Vec::new(); // release, don't retain capacity
                        } else {
                            self.buf.extend_from_slice(chunk);
                        }
                    }
                    r.consume(n);
                }
            }
        }
    }
}

/// One read outcome from [`WireReader`]: the [`LineRead`] outcomes plus
/// the binary-wire cases.
#[derive(Debug)]
pub(crate) enum WireRead {
    /// A complete JSON line (see [`LineRead::Line`]).
    Line(String),
    /// Line over the byte cap, drained unbuffered ([`LineRead::TooLong`]).
    TooLong,
    /// A complete binary frame: `(kind, payload)`.
    Frame(u8, Vec<u8>),
    /// The frame length prefix was 0 or over the frame cap. The prefix
    /// was consumed but nothing after it — a binary stream cannot be
    /// resynchronised past a corrupt length, so the caller must answer
    /// `malformed_request` and close the connection.
    BadFrameLen(u32),
    /// Clean end of input ([`LineRead::Eof`]).
    Eof,
    /// Time budget elapsed mid-line or mid-frame; state is kept, call
    /// again ([`LineRead::Pending`] — not activity for idle TTLs).
    Pending,
}

/// Resumable mid-frame state of a [`WireReader`].
enum FrameState {
    /// Between requests: the next byte routes (0x00 → frame, else line).
    Idle,
    /// Collecting the 4-byte length prefix.
    Len { buf: [u8; 4], got: usize },
    /// Collecting the kind byte + `need` payload bytes. The payload
    /// buffer grows only as bytes actually arrive, so a hostile length
    /// prefix (already capped) never forces a large up-front allocation.
    Body { kind: Option<u8>, need: usize, payload: Vec<u8> },
}

/// The wire-v2 reader: routes a mixed stream of JSON lines and
/// sentinel-prefixed binary frames ([`crate::sim::frames`]), preserving
/// every [`CappedLineReader`] robustness property — bounded memory
/// (lines capped at `line_cap` bytes, frame lengths at `frame_cap`),
/// state that survives `WouldBlock`/`TimedOut` from a read-timeout
/// transport, and a per-call time budget against byte-drip clients.
/// EOF mid-frame is an `UnexpectedEof` error (a disconnect, like EOF
/// mid-line, executes nothing).
pub(crate) struct WireReader {
    lines: CappedLineReader,
    frame_cap: u32,
    state: FrameState,
}

impl WireReader {
    pub(crate) fn new(line_cap: usize, frame_cap: u32) -> Self {
        WireReader {
            lines: CappedLineReader::new(line_cap),
            frame_cap: frame_cap.min(frames::MAX_FRAME_BYTES),
            state: FrameState::Idle,
        }
    }

    pub(crate) fn read<R: BufRead>(&mut self, r: &mut R) -> std::io::Result<WireRead> {
        let call_start = std::time::Instant::now();
        loop {
            if call_start.elapsed() > std::time::Duration::from_millis(150) {
                return Ok(WireRead::Pending);
            }
            match &mut self.state {
                FrameState::Idle => {
                    if !self.lines.is_mid_line() {
                        let chunk = r.fill_buf()?;
                        if !chunk.is_empty() && chunk[0] == frames::WIRE_SENTINEL {
                            r.consume(1);
                            self.state = FrameState::Len { buf: [0; 4], got: 0 };
                            continue;
                        }
                        // empty chunk (EOF) falls through: the line
                        // reader reports it as a clean Eof
                    }
                    return Ok(match self.lines.read_line(r)? {
                        LineRead::Line(l) => WireRead::Line(l),
                        LineRead::TooLong => WireRead::TooLong,
                        LineRead::Eof => WireRead::Eof,
                        LineRead::Pending => WireRead::Pending,
                    });
                }
                FrameState::Len { buf, got } => {
                    let chunk = r.fill_buf()?;
                    if chunk.is_empty() {
                        self.state = FrameState::Idle;
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "EOF inside a binary frame length prefix",
                        ));
                    }
                    let take = chunk.len().min(4 - *got);
                    buf[*got..*got + take].copy_from_slice(&chunk[..take]);
                    r.consume(take);
                    *got += take;
                    if *got == 4 {
                        let len = u32::from_le_bytes(*buf);
                        if len == 0 || len > self.frame_cap {
                            self.state = FrameState::Idle;
                            return Ok(WireRead::BadFrameLen(len));
                        }
                        self.state = FrameState::Body {
                            kind: None,
                            need: len as usize - 1,
                            payload: Vec::new(),
                        };
                    }
                }
                FrameState::Body { kind, need, payload } => {
                    if kind.is_none() {
                        let chunk = r.fill_buf()?;
                        if chunk.is_empty() {
                            self.state = FrameState::Idle;
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::UnexpectedEof,
                                "EOF inside a binary frame",
                            ));
                        }
                        *kind = Some(chunk[0]);
                        r.consume(1);
                    }
                    if *need > 0 {
                        let chunk = r.fill_buf()?;
                        if chunk.is_empty() {
                            self.state = FrameState::Idle;
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::UnexpectedEof,
                                "EOF inside a binary frame payload",
                            ));
                        }
                        let take = chunk.len().min(*need);
                        payload.extend_from_slice(&chunk[..take]);
                        r.consume(take);
                        *need -= take;
                    }
                    if *need == 0 {
                        let k = kind.expect("kind read before payload");
                        let p = std::mem::take(payload);
                        self.state = FrameState::Idle;
                        return Ok(WireRead::Frame(k, p));
                    }
                }
            }
        }
    }
}

/// The `serve-session` loop: greeting line, then one response line per
/// request line until `shutdown` or EOF. Flushes after every line (the
/// client blocks on each response). Blank lines are ignored.
///
/// Robustness contract (PR 6): request lines longer than
/// [`MAX_LINE_BYTES_STDIO`] are answered with `malformed_request`
/// without ever being buffered whole, and I/O errors on either side end
/// the loop cleanly (`Ok`) — a vanished client is the normal end of a
/// session, not a process error. Binary frames (wire v2) are accepted
/// once negotiated; frame lengths are capped at
/// [`frames::MAX_FRAME_BYTES`](crate::sim::frames::MAX_FRAME_BYTES),
/// and a corrupt length prefix — the one unrecoverable wire fault —
/// answers `malformed_request` and ends the loop.
pub fn serve<R: BufRead, W: Write>(
    opts: SimOptions,
    mut input: R,
    out: &mut W,
) -> std::io::Result<()> {
    let mut session = Session::new(opts);
    if writeln!(out, "{}", session.hello()).and_then(|_| out.flush()).is_err() {
        return Ok(());
    }
    let mut reader = WireReader::new(MAX_LINE_BYTES_STDIO, frames::MAX_FRAME_BYTES);
    loop {
        let (resp, done) = match reader.read(&mut input) {
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Ok(WireRead::Pending) => continue,
            Err(_) | Ok(WireRead::Eof) => break,
            Ok(WireRead::TooLong) => (
                err_response(
                    CODE_MALFORMED,
                    &format!("request line exceeds {MAX_LINE_BYTES_STDIO} bytes"),
                ),
                false,
            ),
            Ok(WireRead::BadFrameLen(len)) => {
                // unrecoverable: the stream cannot be resynchronised
                let resp = err_response(
                    CODE_MALFORMED,
                    &format!(
                        "binary frame length {len} invalid (1..={} allowed); closing",
                        frames::MAX_FRAME_BYTES
                    ),
                );
                let _ = writeln!(out, "{resp}").and_then(|_| out.flush());
                break;
            }
            Ok(WireRead::Frame(kind, payload)) => match session.handle_frame(kind, &payload) {
                Ok(reply) => {
                    if out.write_all(&reply).and_then(|_| out.flush()).is_err() {
                        break;
                    }
                    continue;
                }
                Err(line) => (line, false),
            },
            Ok(WireRead::Line(line)) => {
                if line.trim().is_empty() {
                    continue;
                }
                session.handle_line(&line)
            }
        };
        if writeln!(out, "{resp}").and_then(|_| out.flush()).is_err() || done {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_fmt::{read_hsn, write_hsn};
    use crate::snn::{NetworkBuilder, NeuronModel};

    fn fig6_path(tag: &str) -> std::path::PathBuf {
        let lif = NeuronModel::lif(3, 0, 63, false).unwrap();
        let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
        let ann_d = NeuronModel::ann(5, 0, true).unwrap();
        let mut b = NetworkBuilder::new().seed(7);
        b.add_neuron("a", lif, &[("b", 1), ("d", 2)]).unwrap();
        b.add_neuron("b", lif, &[]).unwrap();
        b.add_neuron("c", lif_c, &[]).unwrap();
        b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
        b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
        b.add_axon("beta", &[("b", 3)]).unwrap();
        b.add_output("a");
        b.add_output("b");
        let (net, _) = b.build().unwrap();
        let mut p = std::env::temp_dir();
        p.push(format!("hiaer_session_{}_{tag}.hsn", std::process::id()));
        write_hsn(&net, &p).unwrap();
        p
    }

    fn parsed(resp: &str) -> Json {
        Json::parse(resp).unwrap_or_else(|e| panic!("unparseable response {resp:?}: {e}"))
    }

    fn assert_err(resp: &str, code: &str) {
        let j = parsed(resp);
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{resp}");
        assert_eq!(j.get("code").and_then(Json::as_str), Some(code), "{resp}");
        assert!(j.get("error").and_then(Json::as_str).is_some(), "{resp}");
    }

    fn configured_session(path: &std::path::Path) -> Session {
        let mut s = Session::new(SimOptions::default());
        let (resp, done) =
            s.handle_line(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", path.display()));
        assert!(!done);
        let j = parsed(&resp);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(j.get("neurons").and_then(Json::as_i64), Some(4));
        assert_eq!(j.get("axons").and_then(Json::as_i64), Some(2));
        assert_eq!(j.get("outputs").and_then(Json::as_i64), Some(2));
        s
    }

    #[test]
    fn hello_announces_protocol_and_backend() {
        let s = Session::new(SimOptions::default());
        let j = parsed(&s.hello());
        assert_eq!(j.get("op").and_then(Json::as_str), Some("hello"));
        assert_eq!(j.get("protocol").and_then(Json::as_i64), Some(PROTOCOL_VERSION));
        assert_eq!(j.get("backend").and_then(Json::as_str), Some("rust"));
    }

    #[test]
    fn step_and_step_many_match_direct_facade() {
        let p = fig6_path("parity");
        let mut s = configured_session(&p);

        // direct facade reference
        let net = read_hsn(&p).unwrap();
        let mut reference = crate::sim::SimConfig::new(net).build().unwrap();
        let stimulus: Vec<Vec<u32>> = vec![vec![0, 1], vec![0], vec![], vec![1], vec![]];

        for axons in &stimulus {
            let want = {
                let r = reference.step(axons).unwrap();
                (r.output_spikes.to_vec(), r.fired.len() as i64)
            };
            let req = obj(vec![
                ("op", Json::Str("step".into())),
                ("axons", arr_i64(axons.iter().map(|&a| a as i64))),
            ]);
            let (resp, done) = s.handle_line(&req.to_string());
            assert!(!done);
            let j = parsed(&resp);
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
            let got: Vec<u32> = j
                .get("spikes")
                .and_then(Json::int_vec)
                .unwrap()
                .into_iter()
                .map(|x| x as u32)
                .collect();
            assert_eq!(got, want.0);
            assert_eq!(j.get("fired").and_then(Json::as_i64), Some(want.1));
        }

        // step_many over a fresh pair must equal the per-step trace
        let mut s2 = configured_session(&p);
        let net = read_hsn(&p).unwrap();
        let mut ref2 = crate::sim::SimConfig::new(net).build().unwrap();
        let want = ref2.step_many(&stimulus).unwrap();
        let rows = Json::Arr(
            stimulus.iter().map(|r| arr_i64(r.iter().map(|&a| a as i64))).collect(),
        );
        let req = obj(vec![("op", Json::Str("step_many".into())), ("batch", rows)]);
        let (resp, _) = s2.handle_line(&req.to_string());
        let j = parsed(&resp);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let got: Vec<Vec<u32>> = j
            .get("spikes")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|r| r.int_vec().unwrap().into_iter().map(|x| x as u32).collect())
            .collect();
        assert_eq!(got, want.spikes);
        assert_eq!(
            j.get("fired_total").and_then(Json::as_i64),
            Some(want.fired_total as i64)
        );

        // membranes agree too
        let ids: Vec<u32> = (0..4).collect();
        let want_v = ref2.read_membrane(&ids);
        let (resp, _) = s2.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        let j = parsed(&resp);
        assert_eq!(j.get("v").and_then(Json::i32_vec), Some(want_v));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn malformed_json_line_is_structured_and_recoverable() {
        let p = fig6_path("malformed");
        let mut s = configured_session(&p);
        let (resp, done) = s.handle_line("{not json!");
        assert!(!done);
        assert_err(&resp, CODE_MALFORMED);
        // wrong field type is also malformed_request
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":"zero"}"#);
        assert_err(&resp, CODE_MALFORMED);
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":[-1]}"#);
        assert_err(&resp, CODE_MALFORMED);
        // session still serves valid requests
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":[0]}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unknown_op_lists_options() {
        let mut s = Session::new(SimOptions::default());
        let (resp, done) = s.handle_line(r#"{"op":"teleport"}"#);
        assert!(!done);
        assert_err(&resp, CODE_UNKNOWN_OP);
        assert!(parsed(&resp).get("error").and_then(Json::as_str).unwrap().contains("step_many"));
    }

    #[test]
    fn oversized_batch_rejected_without_stepping() {
        let p = fig6_path("oversized");
        let mut s = configured_session(&p);
        // build an over-limit batch of empty rows
        let mut req = String::from(r#"{"op":"step_many","batch":["#);
        for i in 0..=MAX_BATCH_STEPS {
            if i > 0 {
                req.push(',');
            }
            req.push_str("[]");
        }
        req.push_str("]}");
        let (resp, done) = s.handle_line(&req);
        assert!(!done);
        assert_err(&resp, CODE_OVERSIZED_BATCH);
        // no steps ran: membranes still at the initial state
        let (resp, _) = s.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        assert_eq!(parsed(&resp).get("v").and_then(Json::i32_vec), Some(vec![0, 0, 0, 0]));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn execution_ops_before_configure_are_no_session() {
        let mut s = Session::new(SimOptions::default());
        for req in [
            r#"{"op":"step","axons":[]}"#,
            r#"{"op":"step_many","batch":[[]]}"#,
            r#"{"op":"read_membrane","ids":[0]}"#,
            r#"{"op":"reset"}"#,
            r#"{"op":"cost"}"#,
        ] {
            let (resp, done) = s.handle_line(req);
            assert!(!done);
            assert_err(&resp, CODE_NO_SESSION);
        }
    }

    #[test]
    fn bad_stimulus_is_stimulus_code_and_state_untouched() {
        let p = fig6_path("stim");
        let mut s = configured_session(&p);
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":[9]}"#);
        assert_err(&resp, CODE_STIMULUS);
        // batch with a bad row mid-way: atomic, nothing executed
        let (resp, _) = s.handle_line(r#"{"op":"step_many","batch":[[0],[7],[1]]}"#);
        assert_err(&resp, CODE_STIMULUS);
        let (resp, _) = s.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        assert_eq!(parsed(&resp).get("v").and_then(Json::i32_vec), Some(vec![0, 0, 0, 0]));
        // out-of-range membrane id reports stimulus too
        let (resp, _) = s.handle_line(r#"{"op":"read_membrane","ids":[99]}"#);
        assert_err(&resp, CODE_STIMULUS);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unsorted_duplicate_axons_are_marshalled_server_side() {
        let p = fig6_path("marshal");
        let mut s = configured_session(&p);
        let mut t = configured_session(&p);
        let (resp_a, _) = s.handle_line(r#"{"op":"step","axons":[1,0,1,0]}"#);
        let (resp_b, _) = t.handle_line(r#"{"op":"step","axons":[0,1]}"#);
        assert_eq!(resp_a, resp_b);
        std::fs::remove_file(&p).ok();
    }

    /// Satellite: the `configure` op threads an explicit worker count
    /// into the deployment options — parsed as an optional u32 field,
    /// `0` rejected by the facade as a `config` error, execution
    /// bit-identical to the CLI-default worker count.
    #[test]
    fn configure_workers_field_parses_and_zero_is_config_error() {
        assert_eq!(
            parse_request(r#"{"op":"configure","net":"x.hsn","workers":4}"#).unwrap(),
            Request::Configure {
                net: "x.hsn".into(),
                seed: None,
                workers: Some(4),
                shards: None,
                learning: None,
                wire_binary: false
            }
        );
        assert_eq!(
            parse_request(r#"{"op":"configure","net":"x.hsn"}"#).unwrap(),
            Request::Configure {
                net: "x.hsn".into(),
                seed: None,
                workers: None,
                shards: None,
                learning: None,
                wire_binary: false
            }
        );
        // mistyped workers is a malformed request, not a silent default
        let e = parse_request(r#"{"op":"configure","net":"x.hsn","workers":"two"}"#).unwrap_err();
        assert_eq!(e.code, CODE_MALFORMED);

        let p = fig6_path("workers");
        let opts = SimOptions { backend: crate::sim::Backend::Pool, ..Default::default() };
        let mut s = Session::new(opts);
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"workers\":0}}",
            p.display()
        ));
        assert_err(&resp, CODE_CONFIG);
        assert!(!s.is_configured());
        // a valid worker count configures and steps bit-identically to
        // the default
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"workers\":3}}",
            p.display()
        ));
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let mut d = configured_session(&p);
        let (a, _) = s.handle_line(r#"{"op":"step","axons":[0,1]}"#);
        let (b, _) = d.handle_line(r#"{"op":"step","axons":[0,1]}"#);
        assert_eq!(a, b, "explicit workers changed the spike train");
        std::fs::remove_file(&p).ok();
    }

    /// Satellite (PR 8): the `configure` op threads a shard-subprocess
    /// count into the deployment options, implying `backend=sharded` —
    /// parsed as an optional u32 field; `0` and over-core-count values
    /// are rejected by [`ShardedSim::build`]'s single validation point
    /// as `config` errors before any worker is spawned.
    #[test]
    fn configure_shards_field_parses_and_invalid_counts_are_config_errors() {
        assert_eq!(
            parse_request(r#"{"op":"configure","net":"x.hsn","shards":2}"#).unwrap(),
            Request::Configure {
                net: "x.hsn".into(),
                seed: None,
                workers: None,
                shards: Some(2),
                learning: None,
                wire_binary: false
            }
        );
        // mistyped shards is a malformed request, not a silent default
        let e = parse_request(r#"{"op":"configure","net":"x.hsn","shards":"two"}"#).unwrap_err();
        assert_eq!(e.code, CODE_MALFORMED);

        let p = fig6_path("shards");
        // shards: 0 flows into ShardedSim::build, which rejects it with
        // a `config` error before spawning any worker
        let mut s = Session::new(SimOptions::default());
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"shards\":0}}",
            p.display()
        ));
        assert_err(&resp, CODE_CONFIG);
        assert!(!s.is_configured());
        // more shards than cores (default topology has one core) is a
        // `config` error too — and the session stays usable
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"shards\":4}}",
            p.display()
        ));
        assert_err(&resp, CODE_CONFIG);
        let (resp, _) =
            s.handle_line(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn configure_missing_file_is_config_error() {
        let mut s = Session::new(SimOptions::default());
        let (resp, done) = s.handle_line(r#"{"op":"configure","net":"/nonexistent/x.hsn"}"#);
        assert!(!done);
        assert_err(&resp, CODE_CONFIG);
        assert!(!s.is_configured());
    }

    /// Satellite: the configure response breaks the cold start down
    /// into `load_ms` / `compile_ms` / `net_bytes`, and `metrics`
    /// remembers the most recent breakdown.
    #[test]
    fn configure_reports_cold_start_breakdown() {
        let p = fig6_path("coldstart");
        let mut s = Session::new(SimOptions::default());
        let (resp, _) =
            s.handle_line(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
        let j = parsed(&resp);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let bytes = std::fs::metadata(&p).unwrap().len() as i64;
        assert_eq!(j.get("net_bytes").and_then(Json::as_i64), Some(bytes));
        assert!(j.get("load_ms").and_then(Json::as_f64).unwrap() >= 0.0, "{resp}");
        assert!(j.get("compile_ms").and_then(Json::as_f64).unwrap() >= 0.0, "{resp}");
        let (m, _) = s.handle_line(r#"{"op":"metrics"}"#);
        let mj = parsed(&m);
        assert_eq!(mj.get("net_bytes").and_then(Json::as_i64), Some(bytes));
        assert!(mj.get("last_load_ms").and_then(Json::as_f64).is_some(), "{m}");
        assert!(mj.get("last_compile_ms").and_then(Json::as_f64).is_some(), "{m}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn shutdown_mid_session_recoverable_by_reconfigure() {
        let p = fig6_path("shutdown");
        let mut s = configured_session(&p);
        s.handle_line(r#"{"op":"step","axons":[0]}"#);
        let (resp, done) = s.handle_line(r#"{"op":"shutdown"}"#);
        assert!(done, "shutdown ends the serve loop");
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)));
        assert!(!s.is_configured(), "simulator dropped on shutdown");
        // the codec object itself is recoverable: configure starts fresh
        let (resp, done) =
            s.handle_line(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
        assert!(!done);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, _) = s.handle_line(r#"{"op":"read_membrane","ids":[0]}"#);
        assert_eq!(parsed(&resp).get("v").and_then(Json::i32_vec), Some(vec![0]));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn health_and_metrics_ops_work_pre_and_post_configure() {
        let p = fig6_path("health");
        let mut s = Session::new(SimOptions::default());
        // health answers before configure (liveness probing must not
        // require a loaded network)
        let (resp, done) = s.handle_line(r#"{"op":"health"}"#);
        assert!(!done);
        let j = parsed(&resp);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(j.get("configured"), Some(&Json::Bool(false)));

        let mut s = configured_session(&p);
        let (resp, _) = s.handle_line(r#"{"op":"health"}"#);
        assert_eq!(parsed(&resp).get("configured"), Some(&Json::Bool(true)));
        s.handle_line(r#"{"op":"step","axons":[0]}"#);
        s.handle_line(r#"{"op":"step_many","batch":[[0],[1]]}"#);
        s.handle_line("{garbage");
        let (resp, _) = s.handle_line(r#"{"op":"metrics"}"#);
        let j = parsed(&resp);
        // configure + health + step + step_many + garbage + this = 6
        assert_eq!(j.get("requests").and_then(Json::as_i64), Some(6), "{resp}");
        assert_eq!(j.get("errors").and_then(Json::as_i64), Some(1), "{resp}");
        assert_eq!(j.get("steps").and_then(Json::as_i64), Some(3), "{resp}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn session_quotas_reject_with_quota_code_and_session_survives() {
        let p = fig6_path("quota");
        // net-size quota: the fig6 net has 4 neurons
        let mut s = Session::new(SimOptions::default());
        let limits =
            SessionLimits { max_neurons: 3, max_batch_steps: 2, ..SessionLimits::default() };
        let mut q = Session::with_limits(SimOptions::default(), limits);
        let conf = format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display());
        let (resp, done) = q.handle_line(&conf);
        assert!(!done);
        assert_err(&resp, CODE_QUOTA);
        assert!(!q.is_configured());

        // batch quota: allowed size passes, over-quota answers `quota`
        // and executes nothing; the global cap still reports
        // `oversized_batch` (distinct codes, distinct remedies)
        let limits =
            SessionLimits { max_neurons: 100, max_batch_steps: 2, ..SessionLimits::default() };
        let mut q = Session::with_limits(SimOptions::default(), limits);
        let (resp, _) = q.handle_line(&conf);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, _) = q.handle_line(r#"{"op":"step_many","batch":[[0],[1],[0]]}"#);
        assert_err(&resp, CODE_QUOTA);
        let (resp, _) = q.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        assert_eq!(parsed(&resp).get("v").and_then(Json::i32_vec), Some(vec![0, 0, 0, 0]));
        let (resp, _) = q.handle_line(r#"{"op":"step_many","batch":[[0],[1]]}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");

        // an unlimited session accepts the same batch the quota refused
        let (resp, _) = s.handle_line(&conf);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, _) = s.handle_line(r#"{"op":"step_many","batch":[[0],[1],[0]]}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn capped_reader_handles_short_long_and_crlf_lines() {
        let mut r = CappedLineReader::new(8);
        let mut input: &[u8] = b"short\r\nwaaaaaaaaay too long\nok\npartial";
        match r.read_line(&mut input).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "short"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(r.read_line(&mut input).unwrap(), LineRead::TooLong));
        match r.read_line(&mut input).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "ok"),
            other => panic!("{other:?}"),
        }
        // a partial line at EOF is a disconnect, not a request
        assert!(matches!(r.read_line(&mut input).unwrap(), LineRead::Eof));
    }

    #[test]
    fn capped_reader_drains_oversized_line_without_buffering_it() {
        // 1 MiB line against a 1 KiB cap: the reader must report
        // TooLong while never holding more than ~cap bytes
        let cap = 1024;
        let mut r = CappedLineReader::new(cap);
        let big = vec![b'x'; 1 << 20];
        let mut input: Vec<u8> = big;
        input.push(b'\n');
        input.extend_from_slice(b"{\"op\":\"health\"}\n");
        let mut cursor = std::io::BufReader::with_capacity(512, &input[..]);
        assert!(matches!(r.read_line(&mut cursor).unwrap(), LineRead::TooLong));
        assert!(r.buf.capacity() <= 2 * cap + 512, "buffered {} bytes", r.buf.capacity());
        match r.read_line(&mut cursor).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "{\"op\":\"health\"}"),
            other => panic!("{other:?}"),
        }
    }

    /// Satellite (PR 6): the stdio loop answers an oversized line with
    /// `malformed_request` and keeps serving the same stream.
    #[test]
    fn serve_loop_survives_oversized_line() {
        let p = fig6_path("oversized_line");
        let mut input = format!("{{\"op\":\"configure\",\"net\":\"{}\"}}\n", p.display());
        input.push_str(&"x".repeat(MAX_LINE_BYTES_STDIO + 1));
        input.push('\n');
        input.push_str("{\"op\":\"step\",\"axons\":[0]}\n{\"op\":\"shutdown\"}\n");
        let mut out = Vec::new();
        serve(SimOptions::default(), input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5, "{text}");
        assert_eq!(parsed(lines[0]).get("op").and_then(Json::as_str), Some("hello"));
        assert_eq!(parsed(lines[1]).get("ok"), Some(&Json::Bool(true)), "{}", lines[1]);
        assert_eq!(
            parsed(lines[2]).get("code").and_then(Json::as_str),
            Some(CODE_MALFORMED),
            "{}",
            lines[2]
        );
        // ...and the step after the flood still executed normally
        assert_eq!(parsed(lines[3]).get("op").and_then(Json::as_str), Some("step"));
        assert_eq!(parsed(lines[4]).get("op").and_then(Json::as_str), Some("shutdown"));
        std::fs::remove_file(&p).ok();
    }

    /// PR 9 tentpole: `write_synapse` request shapes — defaults, id and
    /// weight validation, unknown-field tolerance — all protocol-level
    /// (`malformed_request`), never session-state-dependent.
    #[test]
    fn write_synapse_parses_and_validates() {
        assert_eq!(
            parse_request(r#"{"op":"write_synapse","pre":0,"post":2,"weight":7}"#).unwrap(),
            Request::WriteSynapse { pre_is_axon: false, pre: 0, post: 2, weight: 7 }
        );
        assert_eq!(
            parse_request(
                r#"{"op":"write_synapse","pre_is_axon":true,"pre":1,"post":0,"weight":-3}"#
            )
            .unwrap(),
            Request::WriteSynapse { pre_is_axon: true, pre: 1, post: 0, weight: -3 }
        );
        for bad in [
            r#"{"op":"write_synapse","post":2,"weight":7}"#, // missing pre
            r#"{"op":"write_synapse","pre":0,"weight":7}"#,  // missing post
            r#"{"op":"write_synapse","pre":0,"post":2}"#,    // missing weight
            r#"{"op":"write_synapse","pre":0,"post":2,"weight":40000}"#, // > i16
            r#"{"op":"write_synapse","pre":0,"post":2,"weight":"big"}"#,
            r#"{"op":"write_synapse","pre":-1,"post":2,"weight":7}"#,
            r#"{"op":"write_synapse","pre_is_axon":1,"pre":0,"post":2,"weight":7}"#,
        ] {
            let e = parse_request(bad).unwrap_err();
            assert_eq!(e.code, CODE_MALFORMED, "{bad}");
        }
    }

    /// PR 9 tentpole acceptance: a live `write_synapse` mutates the
    /// next step's behaviour without resetting membranes — the in-place
    /// fast path, `compacted: false`.
    #[test]
    fn write_synapse_mutates_next_step_without_membrane_reset() {
        let p = fig6_path("edit");
        let mut s = configured_session(&p);
        let mut t = configured_session(&p);
        for sess in [&mut s, &mut t] {
            sess.handle_line(r#"{"op":"step","axons":[0]}"#);
        }
        let (v_before, _) = s.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        // flip a→b (pre 0 → post 1, an existing weight-1 synapse) in s
        let (resp, done) =
            s.handle_line(r#"{"op":"write_synapse","pre":0,"post":1,"weight":-63}"#);
        assert!(!done);
        let j = parsed(&resp);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(j.get("created"), Some(&Json::Bool(false)), "{resp}");
        assert_eq!(j.get("compacted"), Some(&Json::Bool(false)), "{resp}");
        // the edit itself left membranes untouched
        let (v_after, _) = s.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        assert_eq!(v_before, v_after, "live edit reset membranes");
        // ...but the sessions diverge once the pre neuron fires again
        let mut diverged = false;
        for _ in 0..8 {
            let (a, _) = s.handle_line(r#"{"op":"step","axons":[0]}"#);
            let (b, _) = t.handle_line(r#"{"op":"step","axons":[0]}"#);
            let (ma, _) = s.handle_line(r#"{"op":"read_membrane","ids":[1]}"#);
            let (mb, _) = t.handle_line(r#"{"op":"read_membrane","ids":[1]}"#);
            if a != b || ma != mb {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "weight edit never changed behaviour");
        // out-of-range ids answer `stimulus`, session stays alive
        let (resp, _) = s.handle_line(r#"{"op":"write_synapse","pre":9,"post":1,"weight":1}"#);
        assert_err(&resp, CODE_STIMULUS);
        let (resp, _) = s.handle_line(r#"{"op":"write_synapse","pre":0,"post":9,"weight":1}"#);
        assert_err(&resp, CODE_STIMULUS);
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":[0]}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        std::fs::remove_file(&p).ok();
    }

    /// Satellite: the per-session edit quota answers the stable `quota`
    /// code between step intervals, a step reopens the budget, and
    /// `metrics` reports `edits_applied` / `journal_compactions`.
    #[test]
    fn edit_quota_and_edit_metrics() {
        let p = fig6_path("editquota");
        let limits = SessionLimits { max_edits_per_step: 2, ..SessionLimits::default() };
        let mut s = Session::with_limits(SimOptions::default(), limits);
        let (resp, _) =
            s.handle_line(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        for _ in 0..2 {
            let (resp, _) =
                s.handle_line(r#"{"op":"write_synapse","pre":0,"post":1,"weight":2}"#);
            assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        }
        let (resp, _) = s.handle_line(r#"{"op":"write_synapse","pre":0,"post":1,"weight":3}"#);
        assert_err(&resp, CODE_QUOTA);
        // a step interval reopens the budget
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":[]}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, _) = s.handle_line(r#"{"op":"write_synapse","pre":0,"post":1,"weight":3}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (m, _) = s.handle_line(r#"{"op":"metrics"}"#);
        let mj = parsed(&m);
        assert_eq!(mj.get("edits_applied").and_then(Json::as_i64), Some(3), "{m}");
        // all three edits overwrote an existing engine slot in place
        assert_eq!(mj.get("journal_compactions").and_then(Json::as_i64), Some(0), "{m}");
        std::fs::remove_file(&p).ok();
    }

    /// PR 9 tentpole: the `configure` op's `learning` field switches on
    /// per-session STDP — mistyped fields are `malformed_request`,
    /// invalid combinations `config` (one validation point in the
    /// facade), and a valid config builds a stepping session.
    #[test]
    fn configure_learning_field_parses_and_validates() {
        match parse_request(
            r#"{"op":"configure","net":"x.hsn","learning":{"a_plus":4,"tau_post":5}}"#,
        )
        .unwrap()
        {
            Request::Configure { learning: Some(cfg), .. } => {
                assert_eq!(cfg.a_plus, 4);
                assert_eq!(cfg.tau_post, 5);
                let d = PlasticityConfig::default();
                assert_eq!(cfg.a_minus, d.a_minus);
                assert_eq!(cfg.tau_pre, d.tau_pre);
            }
            other => panic!("{other:?}"),
        }
        for bad in [
            r#"{"op":"configure","net":"x.hsn","learning":5}"#,
            r#"{"op":"configure","net":"x.hsn","learning":{"a_plus":"big"}}"#,
            r#"{"op":"configure","net":"x.hsn","learning":{"w_min":-40000}}"#,
        ] {
            let e = parse_request(bad).unwrap_err();
            assert_eq!(e.code, CODE_MALFORMED, "{bad}");
        }

        let p = fig6_path("learning");
        let mut s = Session::new(SimOptions::default());
        // w_min > w_max flows into the facade's validation: `config`
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"learning\":{{\"w_min\":10,\"w_max\":-10}}}}",
            p.display()
        ));
        assert_err(&resp, CODE_CONFIG);
        assert!(!s.is_configured());
        // a valid learning config builds and steps
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"learning\":{{\"a_plus\":4,\"a_minus\":5}}}}",
            p.display()
        ));
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, _) = s.handle_line(r#"{"op":"step","axons":[0,1]}"#);
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        std::fs::remove_file(&p).ok();
    }

    /// PR 10 tentpole: `"wire":"binary"` negotiation — parse, echo in
    /// the configure response, re-negotiation by a later configure, and
    /// rejection of unknown wire names.
    #[test]
    fn configure_wire_field_parses_and_is_echoed() {
        match parse_request(r#"{"op":"configure","net":"x.hsn","wire":"binary"}"#).unwrap() {
            Request::Configure { wire_binary, .. } => assert!(wire_binary),
            other => panic!("{other:?}"),
        }
        match parse_request(r#"{"op":"configure","net":"x.hsn","wire":"json"}"#).unwrap() {
            Request::Configure { wire_binary, .. } => assert!(!wire_binary),
            other => panic!("{other:?}"),
        }
        for bad in [
            r#"{"op":"configure","net":"x.hsn","wire":"carrier-pigeon"}"#,
            r#"{"op":"configure","net":"x.hsn","wire":2}"#,
        ] {
            assert_eq!(parse_request(bad).unwrap_err().code, CODE_MALFORMED, "{bad}");
        }

        let p = fig6_path("wirenego");
        let mut s = Session::new(SimOptions::default());
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"wire\":\"binary\"}}",
            p.display()
        ));
        assert_eq!(parsed(&resp).get("wire").and_then(Json::as_str), Some("binary"), "{resp}");
        assert!(s.wire_is_binary());
        // a later configure without the field re-negotiates back to JSON
        let (resp, _) =
            s.handle_line(&format!("{{\"op\":\"configure\",\"net\":\"{}\"}}", p.display()));
        assert_eq!(parsed(&resp).get("wire").and_then(Json::as_str), Some("json"), "{resp}");
        assert!(!s.wire_is_binary());
        std::fs::remove_file(&p).ok();
    }

    /// PR 10 acceptance: the same schedule over the JSON wire and the
    /// binary wire produces a bit-identical spike train (stdio serve
    /// loop; the TCP side is pinned in `tests/serve_tcp.rs`).
    #[test]
    fn binary_wire_matches_json_wire_over_stdio_serve() {
        let p = fig6_path("wireparity");
        let stimulus: Vec<Vec<u32>> = vec![vec![0, 1], vec![0], vec![], vec![1], vec![0]];

        // reference: the JSON wire
        let mut t = configured_session(&p);
        let rows = Json::Arr(
            stimulus.iter().map(|r| arr_i64(r.iter().map(|&a| a as i64))).collect(),
        );
        let req = obj(vec![("op", Json::Str("step_many".into())), ("batch", rows)]);
        let (resp, _) = t.handle_line(&req.to_string());
        let j = parsed(&resp);
        let want: Vec<Vec<u32>> = j
            .get("spikes")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|r| r.int_vec().unwrap().into_iter().map(|x| x as u32).collect())
            .collect();
        let want_fired = j.get("fired_total").and_then(Json::as_i64).unwrap() as u64;

        // binary wire through the full serve loop: a configure line and
        // a STIM frame interleaved on one input stream
        let mut input = format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"wire\":\"binary\"}}\n",
            p.display()
        )
        .into_bytes();
        input.extend_from_slice(
            &frames::encode_wire_frame(frames::FRAME_STIM, &frames::encode_stim(&stimulus))
                .unwrap(),
        );
        let mut out = Vec::new();
        serve(SimOptions::default(), &input[..], &mut out).unwrap();

        // output: hello line, configure line, then one SPIKES frame
        let frame_at = out
            .iter()
            .position(|&b| b == frames::WIRE_SENTINEL)
            .expect("no SPIKES frame in output");
        let text = std::str::from_utf8(&out[..frame_at]).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{text}");
        assert_eq!(parsed(lines[0]).get("op").and_then(Json::as_str), Some("hello"));
        assert_eq!(
            parsed(lines[1]).get("wire").and_then(Json::as_str),
            Some("binary"),
            "{}",
            lines[1]
        );
        let mut r = std::io::Cursor::new(&out[frame_at + 1..]);
        let (kind, payload) = frames::read_frame(&mut r).unwrap().unwrap();
        assert_eq!(kind, frames::FRAME_SPIKES);
        let (got, fired) = frames::decode_spikes(&payload).unwrap();
        assert_eq!(got, want, "binary wire diverged from the JSON wire");
        assert_eq!(fired, want_fired);
        assert_eq!(r.position() as usize, out.len() - frame_at - 1, "trailing output bytes");
        std::fs::remove_file(&p).ok();
    }

    /// Satellite (PR 10): in-frame fault paths answer the same stable
    /// JSON error codes as the JSON wire and leave the session alive.
    #[test]
    fn binary_frame_faults_answer_stable_codes_and_session_survives() {
        let p = fig6_path("wirefaults");
        // frame before negotiation (fresh session, JSON wire)
        let mut s = configured_session(&p);
        let stim = frames::encode_stim(&[vec![0u32]]);
        assert_err(&s.handle_frame(frames::FRAME_STIM, &stim).unwrap_err(), CODE_MALFORMED);

        // negotiate, then: bad kind, undecodable payload, bad stimulus
        let (resp, _) = s.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"wire\":\"binary\"}}",
            p.display()
        ));
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_err(&s.handle_frame(0x77, &stim).unwrap_err(), CODE_MALFORMED);
        assert_err(
            &s.handle_frame(frames::FRAME_STIM, &stim[..stim.len() - 1]).unwrap_err(),
            CODE_MALFORMED,
        );
        let bad_axon = frames::encode_stim(&[vec![0u32], vec![99]]);
        assert_err(&s.handle_frame(frames::FRAME_STIM, &bad_axon).unwrap_err(), CODE_STIMULUS);
        // atomicity held: nothing executed across all those faults
        let (resp, _) = s.handle_line(r#"{"op":"read_membrane","ids":[0,1,2,3]}"#);
        assert_eq!(parsed(&resp).get("v").and_then(Json::i32_vec), Some(vec![0, 0, 0, 0]));
        // and a good frame still works
        let reply = s.handle_frame(frames::FRAME_STIM, &stim).unwrap();
        assert_eq!(reply[0], frames::WIRE_SENTINEL);
        let mut r = std::io::Cursor::new(&reply[1..]);
        let (kind, payload) = frames::read_frame(&mut r).unwrap().unwrap();
        assert_eq!(kind, frames::FRAME_SPIKES);
        let (spikes, _) = frames::decode_spikes(&payload).unwrap();
        assert_eq!(spikes.len(), 1);

        // quota + oversized caps mirror the JSON path
        let limits = SessionLimits { max_batch_steps: 2, ..SessionLimits::default() };
        let mut q = Session::with_limits(SimOptions::default(), limits);
        let (resp, _) = q.handle_line(&format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"wire\":\"binary\"}}",
            p.display()
        ));
        assert_eq!(parsed(&resp).get("ok"), Some(&Json::Bool(true)), "{resp}");
        let big = frames::encode_stim(&vec![Vec::new(); 3]);
        assert_err(&q.handle_frame(frames::FRAME_STIM, &big).unwrap_err(), CODE_QUOTA);
        std::fs::remove_file(&p).ok();
    }

    /// Satellite (PR 10): a corrupt binary length prefix answers one
    /// `malformed_request` line and ends the stdio serve loop — the
    /// stream cannot be resynchronised.
    #[test]
    fn serve_loop_closes_on_bad_frame_length() {
        let p = fig6_path("badframelen");
        let mut input = format!(
            "{{\"op\":\"configure\",\"net\":\"{}\",\"wire\":\"binary\"}}\n",
            p.display()
        )
        .into_bytes();
        input.push(frames::WIRE_SENTINEL);
        input.extend_from_slice(&u32::MAX.to_le_bytes()); // over the cap
        input.extend_from_slice(b"garbage that must never be parsed");
        let mut out = Vec::new();
        serve(SimOptions::default(), &input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert_eq!(
            parsed(lines[2]).get("code").and_then(Json::as_str),
            Some(CODE_MALFORMED),
            "{}",
            lines[2]
        );
        std::fs::remove_file(&p).ok();
    }

    /// The wire reader routes interleaved lines and frames, and never
    /// mistakes a NUL byte *inside* a line for a frame sentinel.
    #[test]
    fn wire_reader_routes_lines_and_frames() {
        let mut input: Vec<u8> = b"{\"op\":\"health\"}\n".to_vec();
        input.extend_from_slice(&frames::encode_wire_frame(frames::FRAME_STIM, &[9, 9]).unwrap());
        input.extend_from_slice(b"tail\x00line\n"); // NUL inside a line
        input.extend_from_slice(&frames::encode_wire_frame(frames::FRAME_STIM, &[]).unwrap());
        let mut r = WireReader::new(1024, frames::MAX_FRAME_BYTES);
        let mut cursor = std::io::BufReader::with_capacity(3, &input[..]); // tiny chunks
        match r.read(&mut cursor).unwrap() {
            WireRead::Line(l) => assert_eq!(l, "{\"op\":\"health\"}"),
            other => panic!("{other:?}"),
        }
        match r.read(&mut cursor).unwrap() {
            WireRead::Frame(k, p) => assert_eq!((k, p.as_slice()), (frames::FRAME_STIM, &[9u8, 9][..])),
            other => panic!("{other:?}"),
        }
        match r.read(&mut cursor).unwrap() {
            WireRead::Line(l) => assert_eq!(l, "tail\x00line"),
            other => panic!("{other:?}"),
        }
        match r.read(&mut cursor).unwrap() {
            WireRead::Frame(k, p) => assert_eq!((k, p.len()), (frames::FRAME_STIM, 0)),
            other => panic!("{other:?}"),
        }
        assert!(matches!(r.read(&mut cursor).unwrap(), WireRead::Eof));

        // EOF mid-frame is an error, not a silent request
        let whole = frames::encode_wire_frame(frames::FRAME_STIM, &[1, 2, 3, 4]).unwrap();
        let cut = &whole[..whole.len() - 2];
        let mut r = WireReader::new(1024, frames::MAX_FRAME_BYTES);
        let mut cursor = std::io::BufReader::new(cut);
        assert!(r.read(&mut cursor).is_err());

        // a corrupt length prefix reports BadFrameLen without reading on
        let mut input = vec![frames::WIRE_SENTINEL];
        input.extend_from_slice(&0u32.to_le_bytes());
        let mut r = WireReader::new(1024, frames::MAX_FRAME_BYTES);
        let mut cursor = std::io::BufReader::new(&input[..]);
        assert!(matches!(r.read(&mut cursor).unwrap(), WireRead::BadFrameLen(0)));
    }

    #[test]
    fn serve_loop_end_to_end_over_buffers() {
        let p = fig6_path("serve");
        let input = format!(
            "{{\"op\":\"configure\",\"net\":\"{}\"}}\n\
             {{\"op\":\"step\",\"axons\":[0,1]}}\n\
             \n\
             {{\"op\":\"cost\"}}\n\
             {{\"op\":\"shutdown\"}}\n\
             {{\"op\":\"step\",\"axons\":[]}}\n",
            p.display()
        );
        let mut out = Vec::new();
        serve(SimOptions::default(), input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // hello + configure + step + cost + shutdown; the post-shutdown
        // step is never answered (loop ended), blank line skipped
        assert_eq!(lines.len(), 5, "{text}");
        assert_eq!(parsed(lines[0]).get("op").and_then(Json::as_str), Some("hello"));
        for l in &lines {
            assert_eq!(parsed(l).get("ok"), Some(&Json::Bool(true)), "{l}");
        }
        assert_eq!(parsed(lines[4]).get("op").and_then(Json::as_str), Some("shutdown"));
        std::fs::remove_file(&p).ok();
    }
}
