//! `hiaer-spike serve --listen <addr>` — the resilient multi-session
//! serving tier (paper §5: the platform is "made easily available over
//! a web portal"; this is the process behind that portal).
//!
//! One TCP connection is one protocol session: the server speaks exactly
//! the line-delimited JSON wire format of [`crate::sim::session`]
//! (greeting, then one response line per request line), so the Python
//! `SessionClient` works unchanged over its TCP transport. Sessions
//! that negotiate `"wire":"binary"` at `configure` (wire v2 — see the
//! session module docs) additionally exchange `step_many` batches as
//! sentinel-prefixed binary STIM/SPIKES frames on the same stream;
//! JSON stays the control channel, every robustness property below
//! applies to both wires, and binary frame lengths are capped at
//! `--max-frame-bytes` (a corrupt prefix answers `malformed_request`
//! and closes that one connection — it can never OOM the server). What
//! this module adds on top of the codec is everything a *shared*
//! service needs to survive hostile or unlucky clients:
//!
//! * **Admission control** — at most `max_sessions` concurrent
//!   connections; a connection over that answers one
//!   `{"ok":false,"code":"server_busy",...}` line instead of `hello`
//!   and is closed. The same line is sent while draining.
//! * **Fair scheduling with deadlines** — simulator work is gated
//!   through a FIFO [`AdmissionGate`] of `concurrency` permits
//!   (grown out of `cluster/jobs.rs`): a session that cannot get a
//!   permit within `request_timeout_ms` gets a `deadline` error and the
//!   session survives; one greedy session cannot starve the rest,
//!   because admission is strictly arrival-ordered.
//! * **Quotas** — `max_neurons` / `max_batch` / `max-edits-per-step`
//!   (the `write_synapse` budget between step intervals) become the
//!   session's
//!   [`SessionLimits`] (code `quota`); the read side caps request lines
//!   at `max_line_bytes` (answered `malformed_request`, bytes past the
//!   cap never buffered). In-flight requests per session are capped at
//!   1 structurally: the protocol is strict request/response.
//! * **Fault isolation** — each request runs under
//!   [`catch_unwind`]; a panicking simulator evicts *that* session
//!   (best-effort `engine` error naming the panic, then `evicted`
//!   notice, then close) while every other session keeps running.
//!   A flood of `max_errors` consecutive protocol errors (malformed /
//!   oversized lines) also evicts.
//! * **Idle TTL** — sessions silent for `idle_timeout_ms` are evicted
//!   (best-effort `evicted` notice) so abandoned connections cannot
//!   pin server capacity.
//! * **Graceful drain** — on SIGTERM/SIGINT (see
//!   [`install_drain_signal_handler`]) or when the shutdown flag is
//!   set: stop accepting, let every session finish its in-flight
//!   request, send each an `evicted` notice, then return once all
//!   connections closed (bounded by `drain_grace_ms`).
//! * **Observability** — `health` and `metrics` are answered by the
//!   *server* (the per-session codec never sees them): `health` reports
//!   active sessions, queue depth and the draining flag; `metrics` adds
//!   lifetime totals (sessions, evictions by cause, requests, errors,
//!   steps) and step rates split by phase — time spent queued for a
//!   permit vs. executing.
//!
//! # Eviction semantics on the wire
//!
//! An evicted session receives (best-effort — the peer may already be
//! gone) one final error line and then EOF. The `code` tells the client
//! what happened: `evicted` (idle TTL, error flood, drain) or `engine`
//! followed by `evicted` (panic isolation). Clients should treat EOF
//! after an `evicted` line as a clean, non-retryable session end;
//! `server_busy`/`deadline` are retryable.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::AdmissionGate;
use crate::model_fmt::NetCache;
use crate::sim::frames;
use crate::sim::session::{
    err_response, is_error_response, parse_request, Request, Session, SessionLimits, WireRead,
    WireReader, CODE_DEADLINE, CODE_ENGINE, CODE_EVICTED, CODE_MALFORMED, CODE_SERVER_BUSY,
};
use crate::sim::SimOptions;
use crate::util::cli::Args;
use crate::util::json::{obj, Json};

/// Serving-tier limits and timeouts; every knob has a `serve` CLI flag
/// (see [`ServeLimits::from_args`]).
#[derive(Clone, Debug)]
pub struct ServeLimits {
    /// Concurrent sessions admitted; further connections get
    /// `server_busy` (`--max-sessions`).
    pub max_sessions: usize,
    /// Simulator-work permits shared by all sessions — the width of the
    /// compute pool behind the admission gate (`--concurrency`).
    pub concurrency: usize,
    /// Per-session cap on loadable network size (`--max-neurons`).
    pub max_neurons: usize,
    /// Per-session `step_many` cap (`--max-batch`).
    pub max_batch_steps: usize,
    /// Per-session `write_synapse` budget between step intervals
    /// (`--max-edits-per-step`) — a learning client must keep stepping,
    /// not mutate weights unboundedly.
    pub max_edits_per_step: usize,
    /// Read-side request-line byte cap (`--max-line-bytes`).
    pub max_line_bytes: usize,
    /// Read-side binary frame-length cap (`--max-frame-bytes`), clamped
    /// to the protocol-wide [`frames::MAX_FRAME_BYTES`]. A length
    /// prefix over this closes the connection with `malformed_request`.
    pub max_frame_bytes: u32,
    /// Max wait for a compute permit before `deadline`
    /// (`--request-timeout-ms`).
    pub request_timeout_ms: u64,
    /// Idle eviction TTL (`--idle-timeout-ms`).
    pub idle_timeout_ms: u64,
    /// Consecutive protocol errors before a flooding session is evicted
    /// (`--max-errors`).
    pub max_errors: u32,
    /// Drain patience: how long to wait for open sessions to finish
    /// in-flight work after shutdown is requested (`--drain-grace-ms`).
    pub drain_grace_ms: u64,
}

impl Default for ServeLimits {
    fn default() -> Self {
        ServeLimits {
            max_sessions: 32,
            concurrency: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            max_neurons: usize::MAX,
            max_batch_steps: usize::MAX,
            max_edits_per_step: usize::MAX,
            max_line_bytes: 8 << 20,
            max_frame_bytes: frames::MAX_FRAME_BYTES,
            request_timeout_ms: 30_000,
            idle_timeout_ms: 300_000,
            max_errors: 64,
            drain_grace_ms: 30_000,
        }
    }
}

impl ServeLimits {
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = ServeLimits::default();
        Ok(ServeLimits {
            max_sessions: args.get_usize("max-sessions", d.max_sessions)?,
            concurrency: args.get_usize("concurrency", d.concurrency)?.max(1),
            max_neurons: args.get_usize("max-neurons", d.max_neurons)?,
            max_batch_steps: args.get_usize("max-batch", d.max_batch_steps)?,
            max_edits_per_step: args.get_usize("max-edits-per-step", d.max_edits_per_step)?,
            max_line_bytes: args.get_usize("max-line-bytes", d.max_line_bytes)?,
            max_frame_bytes: args
                .get_usize("max-frame-bytes", d.max_frame_bytes as usize)?
                .min(frames::MAX_FRAME_BYTES as usize) as u32,
            request_timeout_ms: args.get_usize("request-timeout-ms", d.request_timeout_ms as usize)?
                as u64,
            idle_timeout_ms: args.get_usize("idle-timeout-ms", d.idle_timeout_ms as usize)? as u64,
            max_errors: args.get_u32("max-errors", d.max_errors)?.max(1),
            drain_grace_ms: args.get_usize("drain-grace-ms", d.drain_grace_ms as usize)? as u64,
        })
    }

    fn session_limits(&self) -> SessionLimits {
        SessionLimits {
            max_neurons: self.max_neurons,
            max_batch_steps: self.max_batch_steps,
            max_edits_per_step: self.max_edits_per_step,
        }
    }
}

/// Lifetime counters behind the `metrics` op. All relaxed atomics — the
/// counters are monotonic telemetry, not synchronization.
#[derive(Default)]
struct Counters {
    sessions_total: AtomicU64,
    sessions_rejected: AtomicU64,
    evicted_idle: AtomicU64,
    evicted_panic: AtomicU64,
    evicted_flood: AtomicU64,
    evicted_drain: AtomicU64,
    disconnects: AtomicU64,
    requests_total: AtomicU64,
    errors_total: AtomicU64,
    steps_total: AtomicU64,
    /// `write_synapse` edits applied across all sessions.
    edits_applied: AtomicU64,
    /// Edit-journal compactions (CSR rebuilds) across all sessions.
    journal_compactions: AtomicU64,
    /// Wall time spent waiting for admission-gate permits (µs).
    queue_wait_us: AtomicU64,
    /// Wall time spent executing simulator work under a permit (µs).
    execute_us: AtomicU64,
}

impl Counters {
    fn bump(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }
    fn add(c: &AtomicU64, n: u64) {
        c.fetch_add(n, Ordering::Relaxed);
    }
}

/// State shared by the acceptor and every connection thread.
struct Shared {
    limits: ServeLimits,
    opts: SimOptions,
    gate: AdmissionGate,
    draining: AtomicBool,
    active: AtomicUsize,
    /// Guards `active` transitions for the drain wait (the atomic is
    /// read lock-free on the hot path; the mutex exists only so drain
    /// can condvar-wait for it to reach zero).
    drain_lock: Mutex<()>,
    drain_cv: Condvar,
    counters: Counters,
    started: Instant,
    /// Server-wide `.hsn` v2 mapping cache: sessions configured from the
    /// same canonical path (and mtime) share one `Arc<NetFile>` mmap
    /// instead of mapping the file once per session (PR 8 satellite).
    net_cache: Arc<NetCache>,
}

impl Shared {
    fn health_response(&self) -> String {
        ok_obj(
            "health",
            vec![
                ("sessions", Json::Int(self.active.load(Ordering::Relaxed) as i64)),
                ("max_sessions", Json::Int(self.limits.max_sessions as i64)),
                ("queue_depth", Json::Int(self.gate.queue_depth() as i64)),
                ("draining", Json::Bool(self.draining.load(Ordering::Relaxed))),
                ("uptime_ms", Json::Int(self.started.elapsed().as_millis() as i64)),
            ],
        )
    }

    fn metrics_response(&self) -> String {
        let c = &self.counters;
        let steps = c.steps_total.load(Ordering::Relaxed);
        let exec_us = c.execute_us.load(Ordering::Relaxed);
        // executing-phase step rate: what the compute pool sustains
        // while actually running (queue wait reported separately)
        let steps_per_s =
            if exec_us > 0 { steps as f64 / (exec_us as f64 / 1e6) } else { 0.0 };
        ok_obj(
            "metrics",
            vec![
                ("sessions", Json::Int(self.active.load(Ordering::Relaxed) as i64)),
                ("sessions_total", Json::Int(c.sessions_total.load(Ordering::Relaxed) as i64)),
                (
                    "sessions_rejected",
                    Json::Int(c.sessions_rejected.load(Ordering::Relaxed) as i64),
                ),
                ("evicted_idle", Json::Int(c.evicted_idle.load(Ordering::Relaxed) as i64)),
                ("evicted_panic", Json::Int(c.evicted_panic.load(Ordering::Relaxed) as i64)),
                ("evicted_flood", Json::Int(c.evicted_flood.load(Ordering::Relaxed) as i64)),
                ("evicted_drain", Json::Int(c.evicted_drain.load(Ordering::Relaxed) as i64)),
                ("disconnects", Json::Int(c.disconnects.load(Ordering::Relaxed) as i64)),
                ("requests_total", Json::Int(c.requests_total.load(Ordering::Relaxed) as i64)),
                ("errors_total", Json::Int(c.errors_total.load(Ordering::Relaxed) as i64)),
                ("steps_total", Json::Int(steps as i64)),
                ("edits_applied", Json::Int(c.edits_applied.load(Ordering::Relaxed) as i64)),
                (
                    "journal_compactions",
                    Json::Int(c.journal_compactions.load(Ordering::Relaxed) as i64),
                ),
                ("queue_depth", Json::Int(self.gate.queue_depth() as i64)),
                ("concurrency", Json::Int(self.limits.concurrency as i64)),
                (
                    "queue_wait_us",
                    Json::Int(c.queue_wait_us.load(Ordering::Relaxed) as i64),
                ),
                ("execute_us", Json::Int(exec_us as i64)),
                ("steps_per_s", Json::Num(steps_per_s)),
                ("net_cache_hits", Json::Int(self.net_cache.hits() as i64)),
                ("net_cache_misses", Json::Int(self.net_cache.misses() as i64)),
            ],
        )
    }
}

fn ok_obj(op: &str, mut fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(true)), ("op", Json::Str(op.to_string()))];
    all.append(&mut fields);
    obj(all).to_string()
}

/// Builds each connection's [`Session`]. The production factory is
/// [`Session::with_limits`]; fault-injection tests substitute sessions
/// whose simulators panic or stall.
#[doc(hidden)]
pub type SessionFactory = Arc<dyn Fn(SimOptions, SessionLimits) -> Session + Send + Sync>;

/// Run the serving tier on an already-bound listener until `shutdown`
/// becomes true (or a signal installed by
/// [`install_drain_signal_handler`] arrives), then drain gracefully.
/// Returns once every session has closed (or `drain_grace_ms` elapsed).
/// The listener is polled, so a shutdown request is observed within
/// ~50 ms without any traffic.
pub fn serve_tcp(
    listener: TcpListener,
    opts: SimOptions,
    limits: ServeLimits,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<()> {
    serve_tcp_with_factory(listener, opts, limits, shutdown, Arc::new(Session::with_limits))
}

/// [`serve_tcp`] with a session-factory seam for fault-injection tests.
#[doc(hidden)]
pub fn serve_tcp_with_factory(
    listener: TcpListener,
    opts: SimOptions,
    limits: ServeLimits,
    shutdown: Arc<AtomicBool>,
    factory: SessionFactory,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let shared = Arc::new(Shared {
        gate: AdmissionGate::new(limits.concurrency),
        limits,
        opts,
        draining: AtomicBool::new(false),
        active: AtomicUsize::new(0),
        drain_lock: Mutex::new(()),
        drain_cv: Condvar::new(),
        counters: Counters::default(),
        started: Instant::now(),
        net_cache: Arc::new(NetCache::new()),
    });

    let mut conn_threads = Vec::new();
    while !shutdown.load(Ordering::Relaxed) && !DRAIN_FLAG.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // admission: draining or at capacity -> one busy line
                let admitted = !shared.draining.load(Ordering::Relaxed)
                    && shared.active.load(Ordering::Relaxed) < shared.limits.max_sessions;
                if !admitted {
                    Counters::bump(&shared.counters.sessions_rejected);
                    reject_busy(stream, shared.draining.load(Ordering::Relaxed));
                    continue;
                }
                shared.active.fetch_add(1, Ordering::Relaxed);
                Counters::bump(&shared.counters.sessions_total);
                let shared = Arc::clone(&shared);
                let factory = Arc::clone(&factory);
                conn_threads.push(std::thread::spawn(move || {
                    // the decrement lives in a drop guard so even a
                    // panic escaping the connection machinery (it
                    // shouldn't — requests run under catch_unwind)
                    // cannot leak a session slot or wedge the drain
                    let _slot = ActiveSlot(&shared);
                    // the Session (and its Box<dyn Simulator>) lives
                    // entirely on this thread; only Shared crosses
                    handle_connection(stream, &shared, &factory);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                // transient accept failure (EMFILE, ...): back off, keep
                // serving existing sessions rather than dying
                eprintln!("serve: accept error (backing off): {e}");
                std::thread::sleep(Duration::from_millis(200));
            }
        }
        // opportunistically reap finished connection threads
        conn_threads.retain(|h| !h.is_finished());
    }

    // drain: stop accepting (loop exited), tell sessions to wrap up,
    // wait for them to finish their in-flight request and close
    shared.draining.store(true, Ordering::Relaxed);
    drop(listener);
    let grace = Duration::from_millis(shared.limits.drain_grace_ms);
    let deadline = Instant::now() + grace;
    let mut guard = shared.drain_lock.lock().unwrap_or_else(|e| e.into_inner());
    while shared.active.load(Ordering::Relaxed) > 0 {
        let now = Instant::now();
        if now >= deadline {
            eprintln!(
                "serve: drain grace expired with {} session(s) still open",
                shared.active.load(Ordering::Relaxed)
            );
            break;
        }
        let (g, _) = shared
            .drain_cv
            .wait_timeout(guard, deadline - now)
            .unwrap_or_else(|e| e.into_inner());
        guard = g;
    }
    drop(guard);
    for h in conn_threads {
        if h.is_finished() {
            h.join().ok();
        }
    }
    Ok(())
}

/// Releases one session slot on drop (normal return *and* unwind) and
/// wakes a drain waiting for the session count to reach zero.
struct ActiveSlot<'a>(&'a Shared);

impl Drop for ActiveSlot<'_> {
    fn drop(&mut self) {
        let _g = self.0.drain_lock.lock().unwrap_or_else(|e| e.into_inner());
        self.0.active.fetch_sub(1, Ordering::Relaxed);
        self.0.drain_cv.notify_all();
    }
}

/// Best-effort `server_busy` rejection line in place of `hello`.
fn reject_busy(stream: TcpStream, draining: bool) {
    let why = if draining {
        "server is draining; retry against another instance"
    } else {
        "server at max_sessions capacity; retry later"
    };
    let mut w = BufWriter::new(stream);
    let _ = writeln!(w, "{}", err_response(CODE_SERVER_BUSY, why));
    let _ = w.flush();
}

/// Why a connection's serve loop ended (drives counters + the final
/// best-effort notice line).
enum Exit {
    /// Peer closed / I/O error / clean `shutdown` op: nothing to send.
    Closed,
    /// Evicted with already-formatted final notice line(s) — panic
    /// eviction sends `engine` then `evicted`, the rest one `evicted`.
    Evicted { counter: &'static str, notices: Vec<String> },
}

fn handle_connection(stream: TcpStream, shared: &Shared, factory: &SessionFactory) {
    stream.set_nodelay(true).ok();
    // short read timeout = the poll tick for idle TTL + drain checks;
    // CappedLineReader keeps partial-line state across ticks
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let Ok(write_half) = stream.try_clone() else { return };
    let mut writer = BufWriter::new(write_half);
    let mut reader = BufReader::new(stream);

    let mut session = factory(shared.opts.clone(), shared.limits.session_limits());
    session.set_net_cache(Arc::clone(&shared.net_cache));
    if send_line(&mut writer, &session.hello()).is_err() {
        Counters::bump(&shared.counters.disconnects);
        return;
    }

    let exit = connection_loop(&mut reader, &mut writer, &mut session, shared);
    match exit {
        Exit::Closed => Counters::bump(&shared.counters.disconnects),
        Exit::Evicted { counter, notices } => {
            let c = &shared.counters;
            Counters::bump(match counter {
                "idle" => &c.evicted_idle,
                "panic" => &c.evicted_panic,
                "flood" => &c.evicted_flood,
                _ => &c.evicted_drain,
            });
            for notice in &notices {
                let _ = send_line(&mut writer, notice); // peer may be gone
            }
        }
    }
}

fn send_line<W: Write>(w: &mut W, line: &str) -> std::io::Result<()> {
    writeln!(w, "{line}")?;
    w.flush()
}

fn connection_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    session: &mut Session,
    shared: &Shared,
) -> Exit {
    let mut wire = WireReader::new(shared.limits.max_line_bytes, shared.limits.max_frame_bytes);
    let idle_ttl = Duration::from_millis(shared.limits.idle_timeout_ms);
    let mut last_activity = Instant::now();
    let mut consecutive_errors: u32 = 0;

    loop {
        if shared.draining.load(Ordering::Relaxed) {
            return Exit::Evicted {
                counter: "drain",
                notices: vec![err_response(CODE_EVICTED, "server draining; session closed")],
            };
        }
        let read = match wire.read(reader) {
            // no complete line yet (read timeout tick, or a byte-drip
            // client hit the reader's per-call budget): this is NOT
            // activity — check the idle TTL, then poll again
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if last_activity.elapsed() >= idle_ttl {
                    return Exit::Evicted {
                        counter: "idle",
                        notices: vec![err_response(
                            CODE_EVICTED,
                            &format!(
                                "session idle past the {} ms TTL",
                                shared.limits.idle_timeout_ms
                            ),
                        )],
                    };
                }
                continue;
            }
            Ok(WireRead::Pending) => {
                if last_activity.elapsed() >= idle_ttl {
                    return Exit::Evicted {
                        counter: "idle",
                        notices: vec![err_response(
                            CODE_EVICTED,
                            &format!(
                                "no complete request line within the {} ms TTL",
                                shared.limits.idle_timeout_ms
                            ),
                        )],
                    };
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            // hard I/O error or EOF (incl. a dropped partial line or a
            // disconnect mid-frame): the client is gone — close without
            // executing anything
            Err(_) | Ok(WireRead::Eof) => return Exit::Closed,
            Ok(r) => r,
        };
        last_activity = Instant::now();

        let (resp, done) = match read {
            WireRead::Eof | WireRead::Pending => unreachable!("handled above"),
            WireRead::TooLong => (
                err_response(
                    CODE_MALFORMED,
                    &format!("request line exceeds {} bytes", shared.limits.max_line_bytes),
                ),
                false,
            ),
            // a corrupt binary length prefix: the stream cannot be
            // resynchronised — one best-effort error line, then close
            // (isolated to this connection; the server keeps serving)
            WireRead::BadFrameLen(len) => {
                Counters::bump(&shared.counters.requests_total);
                Counters::bump(&shared.counters.errors_total);
                let _ = send_line(
                    writer,
                    &err_response(
                        CODE_MALFORMED,
                        &format!(
                            "binary frame length {len} invalid (1..={} allowed); closing",
                            shared.limits.max_frame_bytes
                        ),
                    ),
                );
                return Exit::Closed;
            }
            // binary STIM frame: same permit gate, panic isolation and
            // counters as a JSON request; a success reply is raw frame
            // bytes, an error is a JSON line that flows through the
            // shared error-flood accounting below
            WireRead::Frame(kind, payload) => {
                match execute_frame(session, kind, &payload, shared) {
                    Err(exit) => return exit,
                    Ok(Ok(reply)) => {
                        Counters::bump(&shared.counters.requests_total);
                        consecutive_errors = 0;
                        if writer.write_all(&reply).and_then(|_| writer.flush()).is_err() {
                            return Exit::Closed;
                        }
                        continue;
                    }
                    Ok(Err(line)) => (line, false),
                }
            }
            WireRead::Line(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                match parse_request(&line) {
                    Err(e) => (err_response(e.code, &e.message), false),
                    // health/metrics answered server-side, without a
                    // compute permit: probes must work under full load
                    Ok(Request::Health) => (shared.health_response(), false),
                    Ok(Request::Metrics) => (shared.metrics_response(), false),
                    Ok(req) => match execute(session, req, shared) {
                        Ok(pair) => pair,
                        Err(exit) => return exit,
                    },
                }
            }
        };

        Counters::bump(&shared.counters.requests_total);
        if is_error_response(&resp) {
            Counters::bump(&shared.counters.errors_total);
            consecutive_errors += 1;
            if consecutive_errors >= shared.limits.max_errors {
                let _ = send_line(writer, &resp);
                return Exit::Evicted {
                    counter: "flood",
                    notices: vec![err_response(
                        CODE_EVICTED,
                        &format!(
                            "{consecutive_errors} consecutive protocol errors; session evicted"
                        ),
                    )],
                };
            }
        } else {
            consecutive_errors = 0;
        }
        if send_line(writer, &resp).is_err() {
            return Exit::Closed;
        }
        if done {
            return Exit::Closed;
        }
    }
}

/// Run one parsed request through the session under a compute permit,
/// with panic isolation. `Err` means the session must end (panic
/// eviction); the deadline case stays `Ok` — the session survives a
/// timed-out wait.
fn execute(
    session: &mut Session,
    req: Request,
    shared: &Shared,
) -> Result<(String, bool), Exit> {
    let wait0 = Instant::now();
    let permit = shared
        .gate
        .acquire(Duration::from_millis(shared.limits.request_timeout_ms));
    Counters::add(&shared.counters.queue_wait_us, wait0.elapsed().as_micros() as u64);
    let Some(permit) = permit else {
        return Ok((
            err_response(
                CODE_DEADLINE,
                &format!(
                    "no compute capacity within {} ms (queue depth {})",
                    shared.limits.request_timeout_ms,
                    shared.gate.queue_depth()
                ),
            ),
            false,
        ));
    };

    let steps = req.steps_requested() as u64;
    let stats_before = session.stats();
    let exec0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| session.handle_request(req)));
    Counters::add(&shared.counters.execute_us, exec0.elapsed().as_micros() as u64);
    drop(permit);

    match outcome {
        Ok((resp, done)) => {
            if !is_error_response(&resp) {
                Counters::add(&shared.counters.steps_total, steps);
                // fold the session's edit deltas into server totals
                let after = session.stats();
                Counters::add(
                    &shared.counters.edits_applied,
                    after.edits_applied - stats_before.edits_applied,
                );
                Counters::add(
                    &shared.counters.journal_compactions,
                    after.journal_compactions - stats_before.journal_compactions,
                );
            }
            Ok((resp, done))
        }
        Err(panic) => {
            let what = panic_message(&panic);
            Err(Exit::Evicted {
                counter: "panic",
                notices: vec![
                    err_response(CODE_ENGINE, &format!("session panicked: {what}")),
                    err_response(CODE_EVICTED, "session evicted after engine panic"),
                ],
            })
        }
    }
}

/// [`execute`]'s binary-wire twin: one STIM frame through the session
/// under a compute permit with panic isolation. Outer `Err` = eviction
/// (panic); inner `Ok` = raw SPIKES reply bytes; inner `Err` = a JSON
/// error line (deadline, malformed frame, quota, ...) — the session
/// survives those exactly as on the JSON wire.
fn execute_frame(
    session: &mut Session,
    kind: u8,
    payload: &[u8],
    shared: &Shared,
) -> Result<Result<Vec<u8>, String>, Exit> {
    let wait0 = Instant::now();
    let permit = shared
        .gate
        .acquire(Duration::from_millis(shared.limits.request_timeout_ms));
    Counters::add(&shared.counters.queue_wait_us, wait0.elapsed().as_micros() as u64);
    let Some(permit) = permit else {
        return Ok(Err(err_response(
            CODE_DEADLINE,
            &format!(
                "no compute capacity within {} ms (queue depth {})",
                shared.limits.request_timeout_ms,
                shared.gate.queue_depth()
            ),
        )));
    };

    let stats_before = session.stats();
    let exec0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| session.handle_frame(kind, payload)));
    Counters::add(&shared.counters.execute_us, exec0.elapsed().as_micros() as u64);
    drop(permit);

    match outcome {
        Ok(result) => {
            if result.is_ok() {
                // the session counted its executed steps; fold the delta
                // into the server totals
                let after = session.stats();
                Counters::add(&shared.counters.steps_total, after.steps - stats_before.steps);
            }
            Ok(result)
        }
        Err(panic) => {
            let what = panic_message(&panic);
            Err(Exit::Evicted {
                counter: "panic",
                notices: vec![
                    err_response(CODE_ENGINE, &format!("session panicked: {what}")),
                    err_response(CODE_EVICTED, "session evicted after engine panic"),
                ],
            })
        }
    }
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Process-wide drain request, flipped by the signal handler. Every
/// [`serve_tcp`] accept loop honors it in addition to its own `shutdown`
/// flag, so the handler needs no per-server plumbing.
static DRAIN_FLAG: AtomicBool = AtomicBool::new(false);

extern "C" fn drain_on_signal(_signum: i32) {
    // async-signal-safe: a single atomic store
    DRAIN_FLAG.store(true, Ordering::Relaxed);
}

/// Install SIGTERM/SIGINT handlers that request a graceful drain of
/// every running [`serve_tcp`] loop in this process. Uses raw
/// `signal(2)` so no extra dependency is needed; on non-Unix targets
/// this is a no-op (Ctrl-C kills the process as usual).
pub fn install_drain_signal_handler() {
    #[cfg(unix)]
    {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(SIGTERM, drain_on_signal);
            signal(SIGINT, drain_on_signal);
        }
    }
}
