//! Length-prefixed binary frame codec shared by every binary wire in
//! the crate — the shard AER pipes ([`crate::cluster::shard`], PR 8)
//! and the session protocol's opt-in wire v2 ([`crate::sim::session`]).
//!
//! # Frame layout
//!
//! ```text
//! u32 len (LE) | u8 kind | payload
//! ```
//!
//! `len` counts the kind byte plus the payload, so `len >= 1` always;
//! `len == 0` and `len > MAX_FRAME_BYTES` are rejected on read — a
//! corrupted prefix can never drive a multi-GiB allocation. All
//! integers are little-endian.
//!
//! The *session* wire additionally prefixes every frame with a one-byte
//! sentinel ([`WIRE_SENTINEL`], `0x00`) so binary frames can interleave
//! with JSON control lines on one stream: a JSON line always starts
//! with `{` (or whitespace), never NUL, so peeking a single byte routes
//! the parser. The shard pipes carry frames only and skip the sentinel.
//! [`encode_wire_frame`] builds the sentinel-prefixed form.
//!
//! # Session wire v2 frame kinds
//!
//! | kind | name   | dir             | payload                                         |
//! |------|--------|-----------------|-------------------------------------------------|
//! | 0x10 | STIM   | client → server | `u32 n_steps, n×{u32 n_ids, n_ids×u32 axon}`    |
//! | 0x90 | SPIKES | server → client | `u64 fired_total, u32 n_steps, n×{u32 n_ids, n_ids×u32 output_neuron}` |
//!
//! Shard-pipe kinds (`UPDATE`/`DELIVER`/`FIRED`/...) are defined next
//! to their protocol in [`crate::cluster::shard`].
//!
//! # The length-truncation fix
//!
//! `write_frame` previously computed `1u32.checked_add(payload.len()
//! as u32)`: the `as u32` cast truncates *before* the overflow check,
//! so a payload over 4 GiB silently wrapped to a small length prefix
//! and wrote a corrupt frame. [`frame_len`] now validates
//! `payload.len()` as a `usize` against [`MAX_FRAME_BYTES`] before any
//! cast (see `frame_len_rejects_overflow_before_any_cast`).

use std::io::{self, Read, Write};

use anyhow::bail;

/// Upper bound on one frame's `len` field (kind byte + payload) — a
/// corrupted length prefix must not drive a multi-GiB allocation.
/// 256 MiB comfortably fits a whole-net burst (4 bytes/event ≈ 67M
/// events).
pub const MAX_FRAME_BYTES: u32 = 256 * 1024 * 1024;

/// First byte of every *session-wire* binary frame (never of a JSON
/// line): `0x00`. See the module docs.
pub const WIRE_SENTINEL: u8 = 0x00;

/// Session wire v2, client → server: one `step_many` stimulus batch.
pub const FRAME_STIM: u8 = 0x10;

/// Session wire v2, server → client: the batch's per-step output
/// spikes.
pub const FRAME_SPIKES: u8 = 0x90;

/// Validated `len` field for a payload of `payload_len` bytes. The
/// check runs on the untruncated `usize` — `payload_len >=
/// MAX_FRAME_BYTES` (including > 4 GiB values whose `as u32` cast would
/// wrap) is an [`io::ErrorKind::InvalidInput`] error, never a silent
/// wrong prefix.
pub fn frame_len(payload_len: usize) -> io::Result<u32> {
    if payload_len >= MAX_FRAME_BYTES as usize {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame payload of {payload_len} bytes exceeds the {} byte frame cap",
                MAX_FRAME_BYTES - 1
            ),
        ));
    }
    Ok(payload_len as u32 + 1)
}

/// Write one `len | kind | payload` frame. The caller flushes.
pub fn write_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> io::Result<()> {
    let len = frame_len(payload.len())?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)
}

/// Read one frame. `Ok(None)` on clean EOF **at the length prefix**
/// (the peer closed between frames); EOF mid-frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<(u8, Vec<u8>)>> {
    let mut len_buf = [0u8; 4];
    // manual first-byte read so EOF-between-frames is distinguishable
    match r.read(&mut len_buf[..1])? {
        0 => return Ok(None),
        _ => r.read_exact(&mut len_buf[1..])?,
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    let mut payload = vec![0u8; len as usize - 1];
    r.read_exact(&mut payload)?;
    Ok(Some((kind[0], payload)))
}

/// One session-wire frame as raw bytes: `sentinel | len | kind |
/// payload`, ready to write to the stream in one call.
pub fn encode_wire_frame(kind: u8, payload: &[u8]) -> io::Result<Vec<u8>> {
    let len = frame_len(payload.len())?;
    let mut out = Vec::with_capacity(6 + payload.len());
    out.push(WIRE_SENTINEL);
    out.extend_from_slice(&len.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(payload);
    Ok(out)
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i32(buf: &mut Vec<u8>, v: i32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Cursor over a frame payload; every read is bounds-checked so a
/// malformed peer yields a typed error, never a panic.
pub struct Payload<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Payload<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Payload { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => bail!(
                "truncated frame payload (want {n} at {}, have {})",
                self.pos,
                self.buf.len()
            ),
        }
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> anyhow::Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn done(&self) -> anyhow::Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in frame payload", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// ---- session wire v2 STIM / SPIKES payloads -------------------------------

/// Encode a `step_many` batch as a STIM payload:
/// `u32 n_steps, n×{u32 n_ids, n_ids×u32 axon_id}`.
pub fn encode_stim(batch: &[Vec<u32>]) -> Vec<u8> {
    let ids: usize = batch.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(4 + batch.len() * 4 + ids * 4);
    put_u32(&mut out, batch.len() as u32);
    for row in batch {
        put_u32(&mut out, row.len() as u32);
        for &a in row {
            put_u32(&mut out, a);
        }
    }
    out
}

/// Decode a STIM payload. Claimed counts are only trusted up to the
/// bytes actually present (`Payload` bounds-checks every read, and
/// pre-allocation is capped by the remaining byte count), so a hostile
/// header cannot force a huge allocation.
pub fn decode_stim(payload: &[u8]) -> anyhow::Result<Vec<Vec<u32>>> {
    let mut p = Payload::new(payload);
    let n_steps = p.u32()? as usize;
    let mut batch = Vec::with_capacity(n_steps.min(p.remaining() / 4 + 1));
    for _ in 0..n_steps {
        let n = p.u32()? as usize;
        let bytes = p.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("row overflow"))?)?;
        let row: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        batch.push(row);
    }
    p.done()?;
    Ok(batch)
}

/// Encode a `step_many` result as a SPIKES payload:
/// `u64 fired_total, u32 n_steps, n×{u32 n_ids, n_ids×u32 neuron_id}`.
pub fn encode_spikes(spikes: &[Vec<u32>], fired_total: u64) -> Vec<u8> {
    let ids: usize = spikes.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(12 + spikes.len() * 4 + ids * 4);
    put_u64(&mut out, fired_total);
    put_u32(&mut out, spikes.len() as u32);
    for row in spikes {
        put_u32(&mut out, row.len() as u32);
        for &s in row {
            put_u32(&mut out, s);
        }
    }
    out
}

/// Decode a SPIKES payload into `(per-step spikes, fired_total)`.
pub fn decode_spikes(payload: &[u8]) -> anyhow::Result<(Vec<Vec<u32>>, u64)> {
    let mut p = Payload::new(payload);
    let fired_total = p.u64()?;
    let n_steps = p.u32()? as usize;
    let mut spikes = Vec::with_capacity(n_steps.min(p.remaining() / 4 + 1));
    for _ in 0..n_steps {
        let n = p.u32()? as usize;
        let bytes = p.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("row overflow"))?)?;
        let row: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        spikes.push(row);
    }
    p.done()?;
    Ok((spikes, fired_total))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite (PR 10): the pre-fix code computed
    /// `1u32.checked_add(payload.len() as u32)` — for a > 4 GiB payload
    /// the cast wraps first, the checked_add then "succeeds" on the
    /// wrapped value, and a corrupt (small) length prefix is written.
    /// `frame_len` must reject such lengths on the untruncated usize.
    #[test]
    fn frame_len_rejects_overflow_before_any_cast() {
        // boundary: largest legal payload is MAX - 1 (len == MAX)
        assert_eq!(frame_len(MAX_FRAME_BYTES as usize - 1).unwrap(), MAX_FRAME_BYTES);
        assert_eq!(
            frame_len(MAX_FRAME_BYTES as usize).unwrap_err().kind(),
            io::ErrorKind::InvalidInput
        );
        // the truncation trap: 4 GiB + 9 wraps to 9 under `as u32`; the
        // pre-fix check would have accepted it and written len == 10
        #[cfg(target_pointer_width = "64")]
        assert_eq!(
            frame_len((1usize << 32) + 9).unwrap_err().kind(),
            io::ErrorKind::InvalidInput
        );
        assert_eq!(frame_len(0).unwrap(), 1); // empty payload: kind only
    }

    #[test]
    fn wire_frame_has_sentinel_then_frame_bytes() {
        let f = encode_wire_frame(FRAME_STIM, &[1, 2, 3]).unwrap();
        assert_eq!(f[0], WIRE_SENTINEL);
        assert_eq!(&f[1..5], &4u32.to_le_bytes()); // kind + 3 payload bytes
        assert_eq!(f[5], FRAME_STIM);
        assert_eq!(&f[6..], &[1, 2, 3]);
        // the post-sentinel bytes are a plain frame
        let mut r = io::Cursor::new(&f[1..]);
        let (k, p) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!((k, p.as_slice()), (FRAME_STIM, &[1u8, 2, 3][..]));
    }

    #[test]
    fn stim_and_spikes_payloads_roundtrip() {
        let batch = vec![vec![0u32, 3, 7], vec![], vec![2]];
        assert_eq!(decode_stim(&encode_stim(&batch)).unwrap(), batch);
        let spikes = vec![vec![1u32], vec![0, 1], vec![]];
        let (got, fired) = decode_spikes(&encode_spikes(&spikes, 42)).unwrap();
        assert_eq!(got, spikes);
        assert_eq!(fired, 42);
        // empty batch round-trips too
        assert_eq!(decode_stim(&encode_stim(&[])).unwrap(), Vec::<Vec<u32>>::new());
    }

    #[test]
    fn decoders_reject_truncation_trailers_and_hostile_counts() {
        let good = encode_stim(&[vec![1, 2], vec![3]]);
        assert!(decode_stim(&good[..good.len() - 2]).is_err(), "truncated");
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_stim(&trailing).is_err(), "trailing bytes");
        // a header claiming 2^31 steps with no bytes behind it must
        // error cheaply instead of allocating
        let hostile = (1u32 << 31).to_le_bytes().to_vec();
        assert!(decode_stim(&hostile).is_err());
        assert!(decode_spikes(&hostile).is_err());
    }
}
