//! [`SimConfig`]: the one place where a network plus deployment choices
//! become a running [`Simulator`]. Owns backend selection, partitioning
//! parameters, HBM slot strategy, seeding and the CLI flag parsing every
//! subcommand shares ([`SimOptions::from_args`]).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cluster::{MultiCoreEngine, PoolOptions, PoolSim, RouteGranularity};
use crate::engine::{CoreEngine, DenseSim, RustBackend};
use crate::hbm::SlotStrategy;
use crate::model_fmt::{open_netfile, read_hsn, NetFile, HSN_MAGIC_V2};
use crate::partition::{ClusterTopology, CoreCapacity};
use crate::runtime::{pjrt_enabled, Runtime, XlaBackend};
use crate::sim::{SimError, Simulator};
use crate::snn::{NetView, Network};
use crate::util::cli::Args;

/// Which execution engine a [`SimConfig`] instantiates. See the module
/// docs of [`crate::sim`] for a selection guide.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Dense-matrix software simulator (the Fig-8 CPU baseline and
    /// golden model). Single-core only; reports zero hardware cost.
    Dense,
    /// Event-driven HBM core with the native Rust membrane backend.
    /// With a multi-core topology this becomes the partitioned,
    /// HiAER-routed cluster engine.
    Rust,
    /// Chunk-parallel `CorePool` execution of one core: the membrane
    /// sweep spreads across all worker threads. Single-core topologies
    /// only (clusters already pool internally).
    Pool,
    /// AOT-compiled JAX/Pallas artifacts through PJRT. Requires the
    /// `pjrt` cargo feature (and vendored bindings + artifacts);
    /// otherwise [`SimConfig::build`] returns
    /// [`SimError::BackendUnavailable`].
    Xla,
}

impl Backend {
    pub const ALL: [Backend; 4] = [Backend::Dense, Backend::Rust, Backend::Pool, Backend::Xla];

    pub fn name(self) -> &'static str {
        match self {
            Backend::Dense => "dense",
            Backend::Rust => "rust",
            Backend::Pool => "pool",
            Backend::Xla => "xla",
        }
    }

    /// Parse a CLI value; unknown values list the options instead of
    /// silently defaulting.
    pub fn parse(s: &str) -> Result<Backend, SimError> {
        match s {
            "dense" => Ok(Backend::Dense),
            "rust" => Ok(Backend::Rust),
            "pool" => Ok(Backend::Pool),
            "xla" => Ok(Backend::Xla),
            other => Err(SimError::Config(format!(
                "unknown --backend {other:?} (options: dense, rust, pool, xla)"
            ))),
        }
    }

    /// Whether this build can instantiate the backend at all.
    pub fn available(self) -> bool {
        match self {
            Backend::Xla => pjrt_enabled(),
            _ => true,
        }
    }
}

/// Parse a `--strategy` value; unknown values list the options.
pub(crate) fn parse_strategy(s: &str) -> Result<SlotStrategy, SimError> {
    match s {
        "modulo" => Ok(SlotStrategy::Modulo),
        "balance" => Ok(SlotStrategy::BalanceFanIn),
        other => Err(SimError::Config(format!(
            "unknown --strategy {other:?} (options: modulo, balance)"
        ))),
    }
}

/// Parse a `--route` value; unknown values list the options.
pub(crate) fn parse_route(s: &str) -> Result<RouteGranularity, SimError> {
    match s {
        "core" => Ok(RouteGranularity::Core),
        "chunk" => Ok(RouteGranularity::Chunk),
        other => Err(SimError::Config(format!(
            "unknown --route {other:?} (options: core, chunk)"
        ))),
    }
}

/// Network-independent deployment options — everything a [`SimConfig`]
/// holds except the network itself. Jobs and daemons carry this and
/// attach a network per run ([`SimOptions::into_config`]).
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub topology: ClusterTopology,
    pub capacity: CoreCapacity,
    pub strategy: SlotStrategy,
    pub backend: Backend,
    /// Override of the network's noise base seed.
    pub seed: Option<u32>,
    /// AOT artifact directory for [`Backend::Xla`].
    pub artifacts: PathBuf,
    /// Sweep chunk granularity in 64-bit spike words for the pooled
    /// backends (`None` = engine default).
    pub chunk_words: Option<usize>,
    /// Route-phase work-unit granularity for the pooled backends
    /// (chunk-parallel gather by default; `core` = one worker per core).
    pub route: RouteGranularity,
    /// Route gather granularity in pointers per chunk (`None` = engine
    /// default).
    pub route_chunk_ptrs: Option<usize>,
    /// Worker-thread count for the pooled backends (`None` = size to
    /// `available_parallelism`). Must be >= 1; explicit so throughput
    /// and parity tests control parallelism instead of inheriting the
    /// host's. No-op for the serial single-core backends.
    pub workers: Option<usize>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            topology: ClusterTopology::single_core(),
            capacity: CoreCapacity::default(),
            strategy: SlotStrategy::BalanceFanIn,
            backend: Backend::Rust,
            seed: None,
            artifacts: PathBuf::from("artifacts"),
            chunk_words: None,
            route: RouteGranularity::default(),
            route_chunk_ptrs: None,
            workers: None,
        }
    }
}

impl SimOptions {
    /// The shared CLI surface: `--servers/--fpgas/--cores` (topology),
    /// `--strategy modulo|balance`, `--backend dense|rust|pool|xla`
    /// (plus the legacy `--xla` flag), `--seed N`, `--workers N`,
    /// `--route core|chunk`, `--artifacts DIR`. Unknown
    /// `--backend`/`--strategy`/`--route` values (and `--workers 0`)
    /// are listed-options errors, never silent defaults. Used by every
    /// execution subcommand, `serve-session` included — the protocol's
    /// `configure` op supplies the network (and may override
    /// `workers`), these flags fix the deployment.
    pub fn from_args(args: &Args) -> Result<SimOptions, SimError> {
        let topology = ClusterTopology {
            servers: args.get_usize("servers", 1).map_err(SimError::Config)?,
            fpgas_per_server: args.get_usize("fpgas", 1).map_err(SimError::Config)?,
            cores_per_fpga: args.get_usize("cores", 1).map_err(SimError::Config)?,
        };
        let strategy = parse_strategy(args.get_or("strategy", "balance"))?;
        let mut backend = Backend::parse(args.get_or("backend", "rust"))?;
        if args.flag("xla") {
            backend = Backend::Xla;
        }
        let seed = match args.get("seed") {
            None => None,
            Some(_) => Some(args.get_u32("seed", 0).map_err(SimError::Config)?),
        };
        let route = parse_route(args.get_or("route", "chunk"))?;
        let workers = match args.get("workers") {
            None => None,
            Some(_) => Some(args.get_usize("workers", 0).map_err(SimError::Config)?),
        };
        if workers == Some(0) {
            return Err(SimError::Config(
                "--workers must be >= 1 (worker threads for the pooled backends; \
                 omit the flag to size to available parallelism)"
                    .into(),
            ));
        }
        Ok(SimOptions {
            topology,
            strategy,
            backend,
            seed,
            artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
            route,
            workers,
            ..SimOptions::default()
        })
    }

    /// The worker-pool slice of these options (for the pooled engines).
    pub(crate) fn pool_options(&self) -> PoolOptions {
        PoolOptions {
            chunk_words: self.chunk_words,
            route: self.route,
            route_chunk_ptrs: self.route_chunk_ptrs,
            workers: self.workers,
        }
    }

    /// Attach a network (owned [`Network`] or mmap-backed
    /// [`NetSource::Mapped`]), yielding a buildable [`SimConfig`].
    pub fn into_config(self, net: impl Into<NetSource>) -> SimConfig {
        SimConfig { net: net.into(), opts: self }
    }
}

/// The network a [`SimConfig`] builds from. Both variants expose the
/// same borrowed [`NetView`]; [`SimConfig::build`] reads CSR only
/// through that view and never heap-copies it.
#[derive(Clone)]
pub enum NetSource {
    /// Owned heap CSR (builder, converter or `.hsn` v1 reader output).
    Owned(Network),
    /// Shared mmap-backed `.hsn` v2 file — the view's synapse slices
    /// point straight into the mapped bytes (zero-copy cold start).
    Mapped(Arc<NetFile>),
}

impl From<Network> for NetSource {
    fn from(net: Network) -> Self {
        NetSource::Owned(net)
    }
}

impl From<Arc<NetFile>> for NetSource {
    fn from(file: Arc<NetFile>) -> Self {
        NetSource::Mapped(file)
    }
}

impl std::fmt::Debug for NetSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetSource::Owned(net) => f.debug_tuple("Owned").field(net).finish(),
            NetSource::Mapped(file) => f
                .debug_struct("Mapped")
                .field("bytes", &file.byte_len())
                .field("mmap", &file.is_mapped())
                .finish(),
        }
    }
}

impl NetSource {
    /// Open a `.hsn` file as a build source: v2 maps the file zero-copy
    /// ([`NetFile`]); v1 parses into a heap [`Network`]. The cold-start
    /// path behind [`SimConfig::from_path`] and the session protocol's
    /// `configure` op.
    pub fn from_path<P: AsRef<Path>>(path: P) -> Result<NetSource, SimError> {
        let path = path.as_ref();
        let is_v2 = std::fs::File::open(path)
            .and_then(|mut f| {
                use std::io::Read;
                let mut magic = [0u8; 8];
                f.read_exact(&mut magic).map(|_| magic == *HSN_MAGIC_V2)
            })
            // open/short-read failures fall through to the v1 reader,
            // which reports the typed error
            .unwrap_or(false);
        if is_v2 {
            Ok(NetSource::Mapped(
                open_netfile(path).map_err(|e| SimError::Engine(e.into()))?,
            ))
        } else {
            Ok(NetSource::Owned(read_hsn(path)?))
        }
    }

    /// Borrow the CSR view (owned heap arrays or mapped file bytes).
    pub fn view(&self) -> NetView<'_> {
        match self {
            NetSource::Owned(net) => net.view(),
            NetSource::Mapped(file) => file.view(),
        }
    }

    /// On-disk byte size when backed by a file; `None` for owned nets.
    pub fn file_bytes(&self) -> Option<u64> {
        match self {
            NetSource::Owned(_) => None,
            NetSource::Mapped(file) => Some(file.byte_len() as u64),
        }
    }
}

/// Builder for a [`Simulator`] session. See [`crate::sim`] module docs
/// for the lifecycle.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub net: NetSource,
    pub opts: SimOptions,
}

impl SimConfig {
    pub fn new(net: impl Into<NetSource>) -> Self {
        SimOptions::default().into_config(net)
    }

    /// Load a `.hsn` file with default options (v2 → mmap zero-copy,
    /// v1 → heap parse; see [`NetSource::from_path`]).
    pub fn from_path<P: AsRef<Path>>(path: P) -> Result<Self, SimError> {
        Ok(SimConfig { net: NetSource::from_path(path)?, opts: SimOptions::default() })
    }

    /// Build a config straight from parsed CLI args (the deduplicated
    /// topology/strategy/backend/seed flag surface).
    pub fn from_args(net: impl Into<NetSource>, args: &Args) -> Result<Self, SimError> {
        Ok(SimOptions::from_args(args)?.into_config(net))
    }

    /// Cluster topology (servers × FPGAs/server × cores/FPGA).
    pub fn topology(mut self, servers: usize, fpgas: usize, cores: usize) -> Self {
        self.opts.topology =
            ClusterTopology { servers, fpgas_per_server: fpgas, cores_per_fpga: cores };
        self
    }

    /// Per-core capacity bound for the partitioner.
    pub fn capacity(mut self, cap: CoreCapacity) -> Self {
        self.opts.capacity = cap;
        self
    }

    /// HBM slot-assignment strategy.
    pub fn strategy(mut self, strategy: SlotStrategy) -> Self {
        self.opts.strategy = strategy;
        self
    }

    /// Execution backend.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.opts.backend = backend;
        self
    }

    /// Override the network's noise base seed.
    pub fn seed(mut self, seed: u32) -> Self {
        self.opts.seed = Some(seed);
        self
    }

    /// AOT artifact directory for [`Backend::Xla`].
    pub fn artifacts<P: Into<PathBuf>>(mut self, dir: P) -> Self {
        self.opts.artifacts = dir.into();
        self
    }

    /// Sweep chunk granularity (64-bit spike words) for the pooled
    /// backends — exposed for tests and perf experiments.
    pub fn chunk_words(mut self, words: usize) -> Self {
        self.opts.chunk_words = Some(words);
        self
    }

    /// Route-phase work-unit granularity for the pooled backends:
    /// chunk-parallel gather ([`RouteGranularity::Chunk`], the default)
    /// or one worker per core ([`RouteGranularity::Core`]). Both are
    /// bit-identical; the knob exists for parity tests and perf
    /// ablations.
    pub fn route_granularity(mut self, route: RouteGranularity) -> Self {
        self.opts.route = route;
        self
    }

    /// Route gather granularity (pointers per chunk) for the pooled
    /// backends — exposed for tests and perf experiments.
    pub fn route_chunk_ptrs(mut self, ptrs: usize) -> Self {
        self.opts.route_chunk_ptrs = Some(ptrs);
        self
    }

    /// Explicit worker-thread count for the pooled backends (must be
    /// >= 1; [`SimConfig::build`] rejects 0). Makes parallelism a tested
    /// input instead of an `available_parallelism` accident; the pool
    /// still keeps one worker per core for per-core phases.
    pub fn workers(mut self, workers: usize) -> Self {
        self.opts.workers = Some(workers);
        self
    }

    /// Compile and spin up the session: applies the seed override,
    /// partitions the network (multi-core), builds HBM images and
    /// starts worker pools. The returned box is the only public
    /// execution handle.
    pub fn build(self) -> Result<Box<dyn Simulator>, SimError> {
        let SimConfig { net: src, opts } = self;
        // The seed override mutates only the Copy view; the CSR arrays
        // stay borrowed from the source (heap or mapping), never copied.
        let mut net = src.view();
        if let Some(seed) = opts.seed {
            net.base_seed = seed;
        }
        if opts.workers == Some(0) {
            return Err(SimError::Config(
                "workers must be >= 1 (omit to size to available parallelism)".into(),
            ));
        }
        let n_cores = opts.topology.n_cores();
        if n_cores == 0 {
            return Err(SimError::Config("topology has zero cores".into()));
        }
        if n_cores > 1 && opts.backend != Backend::Rust {
            return Err(SimError::Config(format!(
                "backend `{}` is single-core; multi-core topologies ({n_cores} cores) \
                 require backend `rust` (the partitioned cluster engine)",
                opts.backend.name()
            )));
        }
        match opts.backend {
            Backend::Dense => Ok(Box::new(DenseSim::new(net))),
            Backend::Rust if n_cores > 1 => {
                let engine = MultiCoreEngine::new(
                    net,
                    opts.topology,
                    opts.capacity,
                    opts.strategy,
                    opts.pool_options(),
                )?;
                Ok(Box::new(engine))
            }
            Backend::Rust => {
                Ok(Box::new(CoreEngine::new(net, opts.strategy, RustBackend)?))
            }
            Backend::Pool => {
                Ok(Box::new(PoolSim::new(net, opts.strategy, opts.pool_options())?))
            }
            Backend::Xla => {
                if !pjrt_enabled() {
                    return Err(SimError::BackendUnavailable {
                        backend: "xla",
                        reason: "this binary was built without the `pjrt` cargo feature; \
                                 rebuild with `--features pjrt` (plus vendored libxla \
                                 bindings and `make artifacts`) to execute the AOT \
                                 Pallas artifact path"
                            .into(),
                    });
                }
                let rt = Arc::new(Runtime::cpu(&opts.artifacts)?);
                let backend = XlaBackend::new(rt, net.n_neurons())?;
                Ok(Box::new(CoreEngine::new(net, opts.strategy, backend)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn args(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()), &["xla"]).unwrap()
    }

    #[test]
    fn from_args_parses_shared_flags() {
        let a = args(&[
            "--servers", "2", "--fpgas", "3", "--cores", "4", "--strategy", "modulo",
            "--backend", "pool", "--seed", "7",
        ]);
        let o = SimOptions::from_args(&a).unwrap();
        assert_eq!(o.topology.n_cores(), 24);
        assert_eq!(o.strategy, SlotStrategy::Modulo);
        assert_eq!(o.backend, Backend::Pool);
        assert_eq!(o.seed, Some(7));
    }

    #[test]
    fn unknown_backend_lists_options() {
        let err = SimOptions::from_args(&args(&["--backend", "gpu"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("gpu") && msg.contains("dense, rust, pool, xla"), "{msg}");
    }

    #[test]
    fn unknown_strategy_lists_options() {
        let err = SimOptions::from_args(&args(&["--strategy", "zigzag"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("zigzag") && msg.contains("modulo, balance"), "{msg}");
    }

    #[test]
    fn legacy_xla_flag_selects_xla() {
        let o = SimOptions::from_args(&args(&["--xla"])).unwrap();
        assert_eq!(o.backend, Backend::Xla);
    }

    #[test]
    fn workers_flag_is_explicit_and_zero_is_an_error() {
        let o = SimOptions::from_args(&args(&["--workers", "3"])).unwrap();
        assert_eq!(o.workers, Some(3));
        assert_eq!(SimOptions::from_args(&args(&[])).unwrap().workers, None);
        let err = SimOptions::from_args(&args(&["--workers", "0"])).unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        // the builder path rejects 0 at build time too
        let net = crate::snn::Network::from_adj(
            vec![crate::snn::NeuronModel::if_neuron(1); 2],
            &[vec![], vec![]],
            &[vec![crate::snn::Synapse { target: 0, weight: 1 }]],
            vec![0],
            0,
        );
        let err = SimConfig::new(net).backend(Backend::Pool).workers(0).build();
        assert!(matches!(err, Err(SimError::Config(_))));
    }

    #[test]
    fn unknown_route_granularity_lists_options() {
        let err = SimOptions::from_args(&args(&["--route", "warp"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("warp") && msg.contains("core, chunk"), "{msg}");
        let o = SimOptions::from_args(&args(&["--route", "core"])).unwrap();
        assert_eq!(o.route, RouteGranularity::Core);
        assert_eq!(SimOptions::from_args(&args(&[])).unwrap().route, RouteGranularity::Chunk);
    }
}
