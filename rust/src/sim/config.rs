//! [`SimConfig`]: the one place where a network plus deployment choices
//! become a running [`Simulator`]. Owns backend selection, partitioning
//! parameters, HBM slot strategy, seeding and the CLI flag parsing every
//! subcommand shares ([`SimOptions::from_args`]).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cluster::{MultiCoreEngine, PoolOptions, PoolSim, RouteGranularity};
use crate::engine::{CoreEngine, DenseSim, RustBackend};
use crate::hbm::SlotStrategy;
use crate::model_fmt::{open_netfile, read_hsn, NetCache, NetFile, HSN_MAGIC_V2};
use crate::partition::{ClusterTopology, CoreCapacity};
use crate::plasticity::PlasticityConfig;
use crate::runtime::{pjrt_enabled, Runtime, XlaBackend};
use crate::sim::{SimError, Simulator};
use crate::snn::{NetView, Network};
use crate::util::cli::Args;

/// Which execution engine a [`SimConfig`] instantiates. See the module
/// docs of [`crate::sim`] for a selection guide.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Dense-matrix software simulator (the Fig-8 CPU baseline and
    /// golden model). Single-core only; reports zero hardware cost.
    Dense,
    /// Event-driven HBM core with the native Rust membrane backend.
    /// With a multi-core topology this becomes the partitioned,
    /// HiAER-routed cluster engine.
    Rust,
    /// Chunk-parallel `CorePool` execution of one core: the membrane
    /// sweep spreads across all worker threads. Single-core topologies
    /// only (clusters already pool internally).
    Pool,
    /// AOT-compiled JAX/Pallas artifacts through PJRT. Requires the
    /// `pjrt` cargo feature (and vendored bindings + artifacts);
    /// otherwise [`SimConfig::build`] returns
    /// [`SimError::BackendUnavailable`].
    Xla,
    /// Multi-process execution: the partitioned cluster split across
    /// `--shards` worker subprocesses exchanging binary AER frames
    /// through the parent's HiAER tree router. Bit-identical to the
    /// single-process cluster (`rust` on a multi-core topology); see
    /// [`crate::cluster::shard`].
    Sharded,
}

impl Backend {
    pub const ALL: [Backend; 5] =
        [Backend::Dense, Backend::Rust, Backend::Pool, Backend::Xla, Backend::Sharded];

    pub fn name(self) -> &'static str {
        match self {
            Backend::Dense => "dense",
            Backend::Rust => "rust",
            Backend::Pool => "pool",
            Backend::Xla => "xla",
            Backend::Sharded => "sharded",
        }
    }

    /// Parse a CLI value; unknown values list the options instead of
    /// silently defaulting.
    pub fn parse(s: &str) -> Result<Backend, SimError> {
        match s {
            "dense" => Ok(Backend::Dense),
            "rust" => Ok(Backend::Rust),
            "pool" => Ok(Backend::Pool),
            "xla" => Ok(Backend::Xla),
            "sharded" => Ok(Backend::Sharded),
            other => Err(SimError::Config(format!(
                "unknown --backend {other:?} (options: dense, rust, pool, xla, sharded)"
            ))),
        }
    }

    /// Whether this build can instantiate the backend at all.
    pub fn available(self) -> bool {
        match self {
            Backend::Xla => pjrt_enabled(),
            _ => true,
        }
    }
}

/// Parse a `--strategy` value; unknown values list the options.
pub(crate) fn parse_strategy(s: &str) -> Result<SlotStrategy, SimError> {
    match s {
        "modulo" => Ok(SlotStrategy::Modulo),
        "balance" => Ok(SlotStrategy::BalanceFanIn),
        other => Err(SimError::Config(format!(
            "unknown --strategy {other:?} (options: modulo, balance)"
        ))),
    }
}

/// Parse a `--route` value; unknown values list the options.
pub(crate) fn parse_route(s: &str) -> Result<RouteGranularity, SimError> {
    match s {
        "core" => Ok(RouteGranularity::Core),
        "chunk" => Ok(RouteGranularity::Chunk),
        other => Err(SimError::Config(format!(
            "unknown --route {other:?} (options: core, chunk)"
        ))),
    }
}

/// Network-independent deployment options — everything a [`SimConfig`]
/// holds except the network itself. Jobs and daemons carry this and
/// attach a network per run ([`SimOptions::into_config`]).
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub topology: ClusterTopology,
    pub capacity: CoreCapacity,
    pub strategy: SlotStrategy,
    pub backend: Backend,
    /// Override of the network's noise base seed.
    pub seed: Option<u32>,
    /// AOT artifact directory for [`Backend::Xla`].
    pub artifacts: PathBuf,
    /// Sweep chunk granularity in 64-bit spike words for the pooled
    /// backends (`None` = engine default).
    pub chunk_words: Option<usize>,
    /// Route-phase work-unit granularity for the pooled backends
    /// (chunk-parallel gather by default; `core` = one worker per core).
    pub route: RouteGranularity,
    /// Route gather granularity in pointers per chunk (`None` = engine
    /// default).
    pub route_chunk_ptrs: Option<usize>,
    /// Worker-thread count for the pooled backends (`None` = size to
    /// `available_parallelism`). Must be >= 1; explicit so throughput
    /// and parity tests control parallelism instead of inheriting the
    /// host's. No-op for the serial single-core backends.
    pub workers: Option<usize>,
    /// Shard-subprocess count for [`Backend::Sharded`] (`None` =
    /// `min(2, n_cores)`). Must be >= 1 and <= the topology's core
    /// count; spike trains are shard-count-invariant.
    pub shards: Option<usize>,
    /// Path of the `hiaer-spike` binary the shard parent spawns as
    /// `shard-worker` children (`None` = discover: `$HS_BIN`, then the
    /// running executable / its target dir).
    pub shard_bin: Option<PathBuf>,
    /// Deadline in milliseconds for each frame awaited from a shard
    /// subprocess before the step fails with a typed engine error
    /// (`None` = 30 000).
    pub shard_timeout_ms: Option<u64>,
    /// Opt-in pair-based STDP (`None` = frozen weights). Event-driven
    /// backends only (`rust`/`pool`/`xla`/`sharded`); the dense golden
    /// model rejects it at build time. See [`crate::plasticity`].
    pub learning: Option<PlasticityConfig>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            topology: ClusterTopology::single_core(),
            capacity: CoreCapacity::default(),
            strategy: SlotStrategy::BalanceFanIn,
            backend: Backend::Rust,
            seed: None,
            artifacts: PathBuf::from("artifacts"),
            chunk_words: None,
            route: RouteGranularity::default(),
            route_chunk_ptrs: None,
            workers: None,
            shards: None,
            shard_bin: None,
            shard_timeout_ms: None,
            learning: None,
        }
    }
}

/// Parse a `--learn A_PLUS,A_MINUS,TAU_PRE,TAU_POST` value (with an
/// optional `--learn-clamp MIN,MAX` refinement) into a
/// [`PlasticityConfig`]; malformed values name the expected shape.
pub(crate) fn parse_learning(
    learn: &str,
    clamp: Option<&str>,
) -> Result<PlasticityConfig, SimError> {
    fn fields<const N: usize>(flag: &str, s: &str, shape: &str) -> Result<[i64; N], SimError> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.len() != N {
            return Err(SimError::Config(format!("--{flag} expects {shape} (got {s:?})")));
        }
        let mut out = [0i64; N];
        for (slot, p) in out.iter_mut().zip(&parts) {
            *slot = p
                .parse::<i64>()
                .map_err(|_| SimError::Config(format!("--{flag} expects {shape} (got {s:?})")))?;
        }
        Ok(out)
    }
    let mut cfg = PlasticityConfig::default();
    let [a_plus, a_minus, tau_pre, tau_post] =
        fields::<4>("learn", learn, "A_PLUS,A_MINUS,TAU_PRE,TAU_POST")?;
    cfg.a_plus = a_plus as i32;
    cfg.a_minus = a_minus as i32;
    cfg.tau_pre = tau_pre.clamp(0, u32::MAX as i64) as u32;
    cfg.tau_post = tau_post.clamp(0, u32::MAX as i64) as u32;
    if let Some(clamp) = clamp {
        let [lo, hi] = fields::<2>("learn-clamp", clamp, "MIN,MAX")?;
        cfg.w_min = lo.clamp(i16::MIN as i64, i16::MAX as i64) as i16;
        cfg.w_max = hi.clamp(i16::MIN as i64, i16::MAX as i64) as i16;
    }
    cfg.validate().map_err(SimError::Config)?;
    Ok(cfg)
}

impl SimOptions {
    /// The shared CLI surface: `--servers/--fpgas/--cores` (topology),
    /// `--strategy modulo|balance`, `--backend
    /// dense|rust|pool|xla|sharded` (plus the legacy `--xla` flag),
    /// `--seed N`, `--workers N`, `--shards N` (implies `sharded` when
    /// `--backend` is not given), `--shard-timeout-ms N`, `--route
    /// core|chunk`, `--artifacts DIR`. Unknown
    /// `--backend`/`--strategy`/`--route` values (and `--workers 0` /
    /// `--shards 0`) are listed-options errors, never silent defaults.
    /// `--learn A_PLUS,A_MINUS,TAU_PRE,TAU_POST` (with optional
    /// `--learn-clamp MIN,MAX`) switches on STDP.
    /// Used by every execution subcommand, `serve-session` included —
    /// the protocol's `configure` op supplies the network (and may
    /// override `workers`/`shards`), these flags fix the deployment.
    pub fn from_args(args: &Args) -> Result<SimOptions, SimError> {
        let topology = ClusterTopology {
            servers: args.get_usize("servers", 1).map_err(SimError::Config)?,
            fpgas_per_server: args.get_usize("fpgas", 1).map_err(SimError::Config)?,
            cores_per_fpga: args.get_usize("cores", 1).map_err(SimError::Config)?,
        };
        let strategy = parse_strategy(args.get_or("strategy", "balance"))?;
        let mut backend = Backend::parse(args.get_or("backend", "rust"))?;
        if args.flag("xla") {
            backend = Backend::Xla;
        }
        let seed = match args.get("seed") {
            None => None,
            Some(_) => Some(args.get_u32("seed", 0).map_err(SimError::Config)?),
        };
        let route = parse_route(args.get_or("route", "chunk"))?;
        let workers = match args.get("workers") {
            None => None,
            Some(_) => Some(args.get_usize("workers", 0).map_err(SimError::Config)?),
        };
        if workers == Some(0) {
            return Err(SimError::Config(
                "--workers must be >= 1 (worker threads for the pooled backends; \
                 omit the flag to size to available parallelism)"
                    .into(),
            ));
        }
        let shards = match args.get("shards") {
            None => None,
            Some(_) => Some(args.get_usize("shards", 0).map_err(SimError::Config)?),
        };
        if shards == Some(0) {
            return Err(SimError::Config(
                "--shards must be >= 1 (shard subprocesses for the sharded backend; \
                 omit the flag to default to min(2, cores))"
                    .into(),
            ));
        }
        if shards.is_some() {
            if args.flag("xla") {
                return Err(SimError::Config(
                    "--shards conflicts with --xla (sharded execution uses the \
                     native rust cluster engine per shard)"
                        .into(),
                ));
            }
            match args.get("backend") {
                // `--shards N` alone implies the sharded backend
                None => backend = Backend::Sharded,
                Some(_) if backend == Backend::Sharded => {}
                Some(other) => {
                    return Err(SimError::Config(format!(
                        "--shards requires --backend sharded (got --backend {other:?})"
                    )));
                }
            }
        }
        let shard_timeout_ms = match args.get("shard-timeout-ms") {
            None => None,
            Some(_) => {
                Some(args.get_usize("shard-timeout-ms", 0).map_err(SimError::Config)? as u64)
            }
        };
        let learning = match args.get("learn") {
            None => {
                if args.get("learn-clamp").is_some() {
                    return Err(SimError::Config(
                        "--learn-clamp requires --learn A_PLUS,A_MINUS,TAU_PRE,TAU_POST".into(),
                    ));
                }
                None
            }
            Some(spec) => Some(parse_learning(spec, args.get("learn-clamp"))?),
        };
        Ok(SimOptions {
            topology,
            strategy,
            backend,
            seed,
            artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
            route,
            workers,
            shards,
            shard_timeout_ms,
            learning,
            ..SimOptions::default()
        })
    }

    /// The worker-pool slice of these options (for the pooled engines).
    pub(crate) fn pool_options(&self) -> PoolOptions {
        PoolOptions {
            chunk_words: self.chunk_words,
            route: self.route,
            route_chunk_ptrs: self.route_chunk_ptrs,
            workers: self.workers,
        }
    }

    /// Attach a network (owned [`Network`] or mmap-backed
    /// [`NetSource::Mapped`]), yielding a buildable [`SimConfig`].
    pub fn into_config(self, net: impl Into<NetSource>) -> SimConfig {
        SimConfig { net: net.into(), opts: self }
    }
}

/// The network a [`SimConfig`] builds from. Both variants expose the
/// same borrowed [`NetView`]; [`SimConfig::build`] reads CSR only
/// through that view and never heap-copies it.
#[derive(Clone)]
pub enum NetSource {
    /// Owned heap CSR (builder, converter or `.hsn` v1 reader output).
    Owned(Network),
    /// Shared mmap-backed `.hsn` v2 file — the view's synapse slices
    /// point straight into the mapped bytes (zero-copy cold start).
    Mapped(Arc<NetFile>),
}

impl From<Network> for NetSource {
    fn from(net: Network) -> Self {
        NetSource::Owned(net)
    }
}

impl From<Arc<NetFile>> for NetSource {
    fn from(file: Arc<NetFile>) -> Self {
        NetSource::Mapped(file)
    }
}

impl std::fmt::Debug for NetSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetSource::Owned(net) => f.debug_tuple("Owned").field(net).finish(),
            NetSource::Mapped(file) => f
                .debug_struct("Mapped")
                .field("bytes", &file.byte_len())
                .field("mmap", &file.is_mapped())
                .finish(),
        }
    }
}

impl NetSource {
    /// Open a `.hsn` file as a build source: v2 maps the file zero-copy
    /// ([`NetFile`]); v1 parses into a heap [`Network`]. The cold-start
    /// path behind [`SimConfig::from_path`] and the session protocol's
    /// `configure` op.
    pub fn from_path<P: AsRef<Path>>(path: P) -> Result<NetSource, SimError> {
        NetSource::from_path_cached(path, None)
    }

    /// [`NetSource::from_path`] with an optional shared-mapping cache:
    /// when `cache` is given and the file is `.hsn` v2, sessions
    /// configured from the same canonical path (and mtime) share one
    /// [`Arc<NetFile>`] mapping instead of re-mapping per session. v1
    /// files are heap parses and never cached.
    pub fn from_path_cached<P: AsRef<Path>>(
        path: P,
        cache: Option<&NetCache>,
    ) -> Result<NetSource, SimError> {
        let path = path.as_ref();
        let is_v2 = std::fs::File::open(path)
            .and_then(|mut f| {
                use std::io::Read;
                let mut magic = [0u8; 8];
                f.read_exact(&mut magic).map(|_| magic == *HSN_MAGIC_V2)
            })
            // open/short-read failures fall through to the v1 reader,
            // which reports the typed error
            .unwrap_or(false);
        if is_v2 {
            let file = match cache {
                Some(cache) => cache.open(path).map_err(|e| SimError::Engine(e.into()))?,
                None => open_netfile(path).map_err(|e| SimError::Engine(e.into()))?,
            };
            Ok(NetSource::Mapped(file))
        } else {
            Ok(NetSource::Owned(read_hsn(path)?))
        }
    }

    /// Borrow the CSR view (owned heap arrays or mapped file bytes).
    pub fn view(&self) -> NetView<'_> {
        match self {
            NetSource::Owned(net) => net.view(),
            NetSource::Mapped(file) => file.view(),
        }
    }

    /// On-disk byte size when backed by a file; `None` for owned nets.
    pub fn file_bytes(&self) -> Option<u64> {
        match self {
            NetSource::Owned(_) => None,
            NetSource::Mapped(file) => Some(file.byte_len() as u64),
        }
    }
}

/// Builder for a [`Simulator`] session. See [`crate::sim`] module docs
/// for the lifecycle.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub net: NetSource,
    pub opts: SimOptions,
}

impl SimConfig {
    pub fn new(net: impl Into<NetSource>) -> Self {
        SimOptions::default().into_config(net)
    }

    /// Load a `.hsn` file with default options (v2 → mmap zero-copy,
    /// v1 → heap parse; see [`NetSource::from_path`]).
    pub fn from_path<P: AsRef<Path>>(path: P) -> Result<Self, SimError> {
        Ok(SimConfig { net: NetSource::from_path(path)?, opts: SimOptions::default() })
    }

    /// Build a config straight from parsed CLI args (the deduplicated
    /// topology/strategy/backend/seed flag surface).
    pub fn from_args(net: impl Into<NetSource>, args: &Args) -> Result<Self, SimError> {
        Ok(SimOptions::from_args(args)?.into_config(net))
    }

    /// Cluster topology (servers × FPGAs/server × cores/FPGA).
    pub fn topology(mut self, servers: usize, fpgas: usize, cores: usize) -> Self {
        self.opts.topology =
            ClusterTopology { servers, fpgas_per_server: fpgas, cores_per_fpga: cores };
        self
    }

    /// Per-core capacity bound for the partitioner.
    pub fn capacity(mut self, cap: CoreCapacity) -> Self {
        self.opts.capacity = cap;
        self
    }

    /// HBM slot-assignment strategy.
    pub fn strategy(mut self, strategy: SlotStrategy) -> Self {
        self.opts.strategy = strategy;
        self
    }

    /// Execution backend.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.opts.backend = backend;
        self
    }

    /// Override the network's noise base seed.
    pub fn seed(mut self, seed: u32) -> Self {
        self.opts.seed = Some(seed);
        self
    }

    /// AOT artifact directory for [`Backend::Xla`].
    pub fn artifacts<P: Into<PathBuf>>(mut self, dir: P) -> Self {
        self.opts.artifacts = dir.into();
        self
    }

    /// Sweep chunk granularity (64-bit spike words) for the pooled
    /// backends — exposed for tests and perf experiments.
    pub fn chunk_words(mut self, words: usize) -> Self {
        self.opts.chunk_words = Some(words);
        self
    }

    /// Route-phase work-unit granularity for the pooled backends:
    /// chunk-parallel gather ([`RouteGranularity::Chunk`], the default)
    /// or one worker per core ([`RouteGranularity::Core`]). Both are
    /// bit-identical; the knob exists for parity tests and perf
    /// ablations.
    pub fn route_granularity(mut self, route: RouteGranularity) -> Self {
        self.opts.route = route;
        self
    }

    /// Route gather granularity (pointers per chunk) for the pooled
    /// backends — exposed for tests and perf experiments.
    pub fn route_chunk_ptrs(mut self, ptrs: usize) -> Self {
        self.opts.route_chunk_ptrs = Some(ptrs);
        self
    }

    /// Explicit worker-thread count for the pooled backends (must be
    /// >= 1; [`SimConfig::build`] rejects 0). Makes parallelism a tested
    /// input instead of an `available_parallelism` accident; the pool
    /// still keeps one worker per core for per-core phases.
    pub fn workers(mut self, workers: usize) -> Self {
        self.opts.workers = Some(workers);
        self
    }

    /// Shard-subprocess count (implies [`Backend::Sharded`]; must be
    /// >= 1 and <= the topology's core count, [`SimConfig::build`]
    /// rejects anything else). Spike trains are shard-count-invariant —
    /// this only tunes process-level parallelism.
    pub fn shards(mut self, shards: usize) -> Self {
        self.opts.shards = Some(shards);
        self.opts.backend = Backend::Sharded;
        self
    }

    /// Explicit `hiaer-spike` binary for the shard-worker children
    /// (tests and benches pass `env!("CARGO_BIN_EXE_hiaer-spike")`;
    /// default is runtime discovery from `$HS_BIN` / the running
    /// executable's directory).
    pub fn shard_bin<P: Into<PathBuf>>(mut self, bin: P) -> Self {
        self.opts.shard_bin = Some(bin.into());
        self
    }

    /// Per-frame deadline (ms) for shard-subprocess reads; a shard that
    /// produces nothing within it fails the step with a typed engine
    /// error naming the shard.
    pub fn shard_timeout_ms(mut self, ms: u64) -> Self {
        self.opts.shard_timeout_ms = Some(ms);
        self
    }

    /// Switch on pair-based STDP with the given config (event-driven
    /// backends only; [`SimConfig::build`] rejects it on `dense`).
    pub fn learning(mut self, cfg: PlasticityConfig) -> Self {
        self.opts.learning = Some(cfg);
        self
    }

    /// Compile and spin up the session: applies the seed override,
    /// partitions the network (multi-core), builds HBM images and
    /// starts worker pools. The returned box is the only public
    /// execution handle.
    pub fn build(self) -> Result<Box<dyn Simulator>, SimError> {
        let SimConfig { net: src, opts } = self;
        if opts.workers == Some(0) {
            return Err(SimError::Config(
                "workers must be >= 1 (omit to size to available parallelism)".into(),
            ));
        }
        let n_cores = opts.topology.n_cores();
        if n_cores == 0 {
            return Err(SimError::Config("topology has zero cores".into()));
        }
        if let Some(cfg) = opts.learning {
            cfg.validate().map_err(SimError::Config)?;
            if opts.backend == Backend::Dense {
                return Err(SimError::Config(
                    "learning (STDP) requires an event-driven backend \
                     (rust, pool, xla or sharded); the dense golden model \
                     runs frozen weights only"
                        .into(),
                ));
            }
        }
        if opts.shards.is_some() && opts.backend != Backend::Sharded {
            return Err(SimError::Config(format!(
                "shards is only meaningful with backend `sharded` (got `{}`)",
                opts.backend.name()
            )));
        }
        if opts.backend == Backend::Sharded {
            if opts.shards == Some(0) {
                return Err(SimError::Config(
                    "shards must be >= 1 (omit to default to min(2, cores))".into(),
                ));
            }
            // the shard parent needs the source itself (to hand each
            // subprocess a mappable path), not just a borrowed view
            let sim = crate::cluster::shard::ShardedSim::build(src, &opts)?;
            return Ok(Box::new(sim));
        }
        // The seed override mutates only the Copy view; the CSR arrays
        // stay borrowed from the source (heap or mapping), never copied.
        let mut net = src.view();
        if let Some(seed) = opts.seed {
            net.base_seed = seed;
        }
        if n_cores > 1 && opts.backend != Backend::Rust {
            return Err(SimError::Config(format!(
                "backend `{}` is single-core; multi-core topologies ({n_cores} cores) \
                 require backend `rust` (the partitioned cluster engine)",
                opts.backend.name()
            )));
        }
        match opts.backend {
            Backend::Dense => Ok(Box::new(DenseSim::new(net))),
            Backend::Rust if n_cores > 1 => {
                let engine = MultiCoreEngine::new(
                    net,
                    opts.topology,
                    opts.capacity,
                    opts.strategy,
                    opts.pool_options(),
                    opts.learning,
                )?;
                Ok(Box::new(engine))
            }
            Backend::Rust => {
                let mut engine = CoreEngine::new(net, opts.strategy, RustBackend)?;
                if let Some(cfg) = opts.learning {
                    engine.enable_plasticity(cfg).map_err(|e| SimError::Config(e.to_string()))?;
                }
                Ok(Box::new(engine))
            }
            Backend::Pool => {
                Ok(Box::new(PoolSim::new(net, opts.strategy, opts.pool_options(), opts.learning)?))
            }
            Backend::Xla => {
                if !pjrt_enabled() {
                    return Err(SimError::BackendUnavailable {
                        backend: "xla",
                        reason: "this binary was built without the `pjrt` cargo feature; \
                                 rebuild with `--features pjrt` (plus vendored libxla \
                                 bindings and `make artifacts`) to execute the AOT \
                                 Pallas artifact path"
                            .into(),
                    });
                }
                let rt = Arc::new(Runtime::cpu(&opts.artifacts)?);
                let backend = XlaBackend::new(rt, net.n_neurons())?;
                let mut engine = CoreEngine::new(net, opts.strategy, backend)?;
                if let Some(cfg) = opts.learning {
                    engine.enable_plasticity(cfg).map_err(|e| SimError::Config(e.to_string()))?;
                }
                Ok(Box::new(engine))
            }
            // handled by the early return above (it consumes `src`)
            Backend::Sharded => unreachable!("sharded backend returns before view creation"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn args(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()), &["xla"]).unwrap()
    }

    #[test]
    fn from_args_parses_shared_flags() {
        let a = args(&[
            "--servers", "2", "--fpgas", "3", "--cores", "4", "--strategy", "modulo",
            "--backend", "pool", "--seed", "7",
        ]);
        let o = SimOptions::from_args(&a).unwrap();
        assert_eq!(o.topology.n_cores(), 24);
        assert_eq!(o.strategy, SlotStrategy::Modulo);
        assert_eq!(o.backend, Backend::Pool);
        assert_eq!(o.seed, Some(7));
    }

    #[test]
    fn unknown_backend_lists_options() {
        let err = SimOptions::from_args(&args(&["--backend", "gpu"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("gpu") && msg.contains("dense, rust, pool, xla"), "{msg}");
    }

    #[test]
    fn unknown_strategy_lists_options() {
        let err = SimOptions::from_args(&args(&["--strategy", "zigzag"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("zigzag") && msg.contains("modulo, balance"), "{msg}");
    }

    #[test]
    fn legacy_xla_flag_selects_xla() {
        let o = SimOptions::from_args(&args(&["--xla"])).unwrap();
        assert_eq!(o.backend, Backend::Xla);
    }

    #[test]
    fn workers_flag_is_explicit_and_zero_is_an_error() {
        let o = SimOptions::from_args(&args(&["--workers", "3"])).unwrap();
        assert_eq!(o.workers, Some(3));
        assert_eq!(SimOptions::from_args(&args(&[])).unwrap().workers, None);
        let err = SimOptions::from_args(&args(&["--workers", "0"])).unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        // the builder path rejects 0 at build time too
        let net = crate::snn::Network::from_adj(
            vec![crate::snn::NeuronModel::if_neuron(1); 2],
            &[vec![], vec![]],
            &[vec![crate::snn::Synapse { target: 0, weight: 1 }]],
            vec![0],
            0,
        );
        let err = SimConfig::new(net).backend(Backend::Pool).workers(0).build();
        assert!(matches!(err, Err(SimError::Config(_))));
    }

    #[test]
    fn shards_flag_implies_sharded_backend_and_zero_is_an_error() {
        let o = SimOptions::from_args(&args(&["--shards", "2"])).unwrap();
        assert_eq!(o.shards, Some(2));
        assert_eq!(o.backend, Backend::Sharded);
        assert_eq!(SimOptions::from_args(&args(&[])).unwrap().shards, None);

        let err = SimOptions::from_args(&args(&["--shards", "0"])).unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");

        // an explicit single-process backend conflicts with --shards
        let err =
            SimOptions::from_args(&args(&["--backend", "pool", "--shards", "2"])).unwrap_err();
        assert!(err.to_string().contains("--backend sharded"), "{err}");
        let err = SimOptions::from_args(&args(&["--xla", "--shards", "2"])).unwrap_err();
        assert!(err.to_string().contains("--xla"), "{err}");

        // explicit `--backend sharded --shards N` stays valid
        let o = SimOptions::from_args(&args(&["--backend", "sharded", "--shards", "4"])).unwrap();
        assert_eq!((o.backend, o.shards), (Backend::Sharded, Some(4)));

        let o = SimOptions::from_args(&args(&["--shards", "2", "--shard-timeout-ms", "500"]))
            .unwrap();
        assert_eq!(o.shard_timeout_ms, Some(500));
    }

    #[test]
    fn sharded_backend_parses_and_is_available() {
        assert_eq!(Backend::parse("sharded").unwrap(), Backend::Sharded);
        assert!(Backend::Sharded.available());
        assert_eq!(Backend::Sharded.name(), "sharded");
        let err = Backend::parse("gpu").unwrap_err();
        assert!(err.to_string().contains("sharded"), "{err}");
    }

    #[test]
    fn build_rejects_shards_on_other_backends() {
        let net = crate::snn::Network::from_adj(
            vec![crate::snn::NeuronModel::if_neuron(1); 2],
            &[vec![], vec![]],
            &[vec![crate::snn::Synapse { target: 0, weight: 1 }]],
            vec![0],
            0,
        );
        let mut cfg = SimConfig::new(net).shards(2);
        cfg.opts.backend = Backend::Pool; // bypass the builder coupling
        let err = cfg.build();
        assert!(matches!(err, Err(SimError::Config(_))));
    }

    #[test]
    fn learn_flag_parses_and_rejects_malformed_specs() {
        let o = SimOptions::from_args(&args(&["--learn", "8,9,3,4"])).unwrap();
        let cfg = o.learning.unwrap();
        assert_eq!((cfg.a_plus, cfg.a_minus, cfg.tau_pre, cfg.tau_post), (8, 9, 3, 4));
        assert_eq!(SimOptions::from_args(&args(&[])).unwrap().learning, None);

        let o = SimOptions::from_args(&args(&[
            "--learn", "8,9,3,4", "--learn-clamp", "-100,100",
        ]))
        .unwrap();
        let cfg = o.learning.unwrap();
        assert_eq!((cfg.w_min, cfg.w_max), (-100, 100));

        let err = SimOptions::from_args(&args(&["--learn", "8,9"])).unwrap_err();
        assert!(err.to_string().contains("A_PLUS,A_MINUS,TAU_PRE,TAU_POST"), "{err}");
        let err = SimOptions::from_args(&args(&["--learn-clamp", "0,1"])).unwrap_err();
        assert!(err.to_string().contains("requires --learn"), "{err}");
        let err = SimOptions::from_args(&args(&["--learn", "8,9,3,4", "--learn-clamp", "5,-5"]))
            .unwrap_err();
        assert!(err.to_string().contains("w_min"), "{err}");
    }

    #[test]
    fn dense_backend_rejects_learning() {
        let net = crate::snn::Network::from_adj(
            vec![crate::snn::NeuronModel::if_neuron(1); 2],
            &[vec![], vec![]],
            &[vec![crate::snn::Synapse { target: 0, weight: 1 }]],
            vec![0],
            0,
        );
        let err = SimConfig::new(net)
            .backend(Backend::Dense)
            .learning(crate::plasticity::PlasticityConfig::default())
            .build();
        match err {
            Err(SimError::Config(msg)) => assert!(msg.contains("event-driven"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_route_granularity_lists_options() {
        let err = SimOptions::from_args(&args(&["--route", "warp"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("warp") && msg.contains("core, chunk"), "{msg}");
        let o = SimOptions::from_args(&args(&["--route", "core"])).unwrap();
        assert_eq!(o.route, RouteGranularity::Core);
        assert_eq!(SimOptions::from_args(&args(&[])).unwrap().route, RouteGranularity::Chunk);
    }
}
