//! Energy & latency model (paper §6).
//!
//! The paper derives **energy** as `(HBM accesses per inference) x (energy
//! per HBM access)` and **latency** from FPGA-reported clock cycles. We do
//! exactly that over the counters the HBM/engine simulation produces.
//!
//! The absolute constants are substrate calibration (documented in
//! DESIGN.md §Calibration): they set the scale of the numbers, while the
//! *shape* the paper demonstrates — linearity in neuron count, per-model
//! cost ordering, platform-comparison magnitudes — comes from the counted
//! accesses themselves.

use crate::hbm::AccessCounters;

/// Calibrated energy/latency constants for the simulated substrate.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Energy per HBM row access (nJ). HBM2 ≈ 3.9 pJ/bit -> ≈ 1 nJ per
    /// 32-byte slot row including controller overhead; tuned to land the
    /// small-MLP benchmark near the paper's ~1 uJ.
    pub e_hbm_row_nj: f64,
    /// Energy per URAM access (nJ) — on-chip, ~50x cheaper than HBM.
    pub e_uram_nj: f64,
    /// Energy per BRAM access (nJ).
    pub e_bram_nj: f64,
    /// Core clock (Hz) for converting cycles to latency.
    pub clk_hz: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self { e_hbm_row_nj: 0.75, e_uram_nj: 0.015, e_bram_nj: 0.01, clk_hz: 700e6 }
    }
}

/// Per-inference (or per-step) cost report.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostReport {
    pub hbm_rows: u64,
    pub events: u64,
    pub cycles: u64,
    pub energy_uj: f64,
    pub latency_us: f64,
}

impl EnergyModel {
    pub fn cost(&self, counters: &AccessCounters, cycles: u64) -> CostReport {
        let hbm = counters.hbm_rows();
        let energy_nj = hbm as f64 * self.e_hbm_row_nj
            + counters.uram_accesses as f64 * self.e_uram_nj
            + counters.bram_accesses as f64 * self.e_bram_nj;
        CostReport {
            hbm_rows: hbm,
            events: counters.events,
            cycles,
            energy_uj: energy_nj / 1000.0,
            latency_us: cycles as f64 / self.clk_hz * 1e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_dominated_by_hbm() {
        let m = EnergyModel::default();
        let c = AccessCounters {
            pointer_rows: 100,
            synapse_rows: 900,
            events: 5000,
            uram_accesses: 1000,
            bram_accesses: 100,
        };
        let r = m.cost(&c, 10_000);
        // HBM: 1000 rows * 0.75 nJ = 750 nJ; on-chip: 1000*0.015 + 100*0.01 = 16 nJ
        assert!((r.energy_uj - 0.766).abs() < 1e-9);
        assert!(r.latency_us > 0.0);
        assert_eq!(r.hbm_rows, 1000);
    }

    #[test]
    fn latency_scales_with_cycles() {
        let m = EnergyModel::default();
        let c = AccessCounters::default();
        let r1 = m.cost(&c, 700);
        let r2 = m.cost(&c, 7000);
        assert!((r2.latency_us / r1.latency_us - 10.0).abs() < 1e-9);
        assert!((r1.latency_us - 1.0).abs() < 1e-9); // 700 cycles at 700 MHz = 1 us
    }
}
