//! The multi-core engine: partition -> per-core sub-networks + HBM images
//! -> barrier-stepped execution with HiAER routing in between.
//!
//! Timestep protocol (all cores advance one 1 ms tick together):
//!
//! 1. every core runs its membrane sweep — chunk-parallel across the
//!    whole worker pool (word-aligned chunks, see `cluster::pool`), so
//!    even a lone oversized core saturates the machine;
//! 2. fired global neuron ids + host axon inputs go through the
//!    [`HiaerRouter`] multicast (the barrier);
//! 3. every core routes (host inputs ∪ remote deliveries, as local axons)
//!    through its HBM and accumulates — the gather is chunk-parallel
//!    across the whole pool with a deterministic per-chunk merge, so a
//!    routing hotspot on one core spreads over every worker (see
//!    `cluster::pool`'s ordering contract).
//!
//! Because remote events are delivered within the same tick (the fabric
//! is faster than the 1 ms timestep), a multi-core run is bit-identical
//! to the single-core run of the unpartitioned network — enforced by
//! `rust/tests/cluster_parity.rs`.

use anyhow::Result;

use crate::cluster::pool::{CorePool, PoolOptions};
use crate::energy::{CostReport, EnergyModel};
use crate::engine::{CoreEngine, RustBackend};
use crate::hbm::SlotStrategy;
use crate::partition::{ClusterTopology, CoreCapacity, Partition};
use crate::plasticity::PlasticityConfig;
use crate::router::{split_network, FabricModel, HiaerRouter, RouterStats};
use crate::snn::NetView;

/// Whole-cluster cost of a run: the slowest core bounds the latency (all
/// cores run in lockstep), energies add.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterCost {
    pub energy_uj: f64,
    pub latency_us: f64,
    pub hbm_rows: u64,
    pub router: RouterStats,
}

pub struct MultiCoreEngine {
    /// Persistent one-thread-per-core worker pool (§Perf: replaces the
    /// original per-step thread::scope spawning, which cost more than the
    /// compute at >= 2 cores).
    pool: CorePool,
    pub partition: Partition,
    pub router: HiaerRouter,
    /// global neuron id per (core, local id)
    global_of: Vec<Vec<u32>>,
    /// scratch: per-core fired global ids / merged axon inputs
    fired_by_core: Vec<Vec<u32>>,
    merged_axons: Vec<Vec<u32>>,
    /// all fired global ids this step, ascending (facade `fired()`)
    fired_global: Vec<u32>,
    out_global: Vec<u32>,
    /// local axon id of each (core, global axon), u32::MAX if unused —
    /// addresses live edits whose pre is a global input axon.
    axon_local: Vec<Vec<u32>>,
    /// per core: global source neuron -> local axon its remote synapses
    /// were re-homed under — addresses cross-core live edits.
    remote_axon: Vec<std::collections::HashMap<u32, u32>>,
    /// wall-clock accumulators per sub-phase: `[membrane sweep, HiAER
    /// multicast barrier, route prepare+gather, route merge/accumulate]`
    /// — exposed for the perf harness. The route split mirrors the
    /// pool's `route_wall` (per-core-granularity routing bills entirely
    /// to the gather slot).
    pub phase_wall: [std::time::Duration; 4],
}

impl MultiCoreEngine {
    /// Crate-private: external callers construct clusters through
    /// [`crate::sim::SimConfig`] with a multi-core topology. `pool_opts`
    /// carries the worker pool's knobs (sweep chunk words, route
    /// granularity, worker count; defaults via
    /// [`PoolOptions::default`]).
    pub(crate) fn new<'a>(
        net: impl Into<NetView<'a>>,
        topology: ClusterTopology,
        cap: CoreCapacity,
        strategy: SlotStrategy,
        pool_opts: PoolOptions,
        learning: Option<PlasticityConfig>,
    ) -> Result<Self> {
        // convert once; the Copy view threads through partition + split so
        // an mmap-backed global net is never copied to the heap here
        let net: NetView<'_> = net.into();
        let partition =
            Partition::compute(net, topology, cap).map_err(anyhow::Error::msg)?;
        let split = split_network(net, &partition);
        let mut cores = Vec::with_capacity(split.subnets.len());
        for sub in &split.subnets {
            let mut core = CoreEngine::new(sub, strategy, RustBackend)?;
            // STDP per core: a remote pre-neuron's trace is mirrored by
            // its re-homed local axon (same fire pattern, same decay
            // schedule), so cluster weight updates are bit-identical to
            // the single-core run — see crate::plasticity module docs.
            if let Some(cfg) = learning {
                core.enable_plasticity(cfg)?;
            }
            cores.push(core);
        }
        let router = HiaerRouter::new(topology, FabricModel::default(), split.table);
        let n_cores = cores.len();
        Ok(Self {
            global_of: partition.members.clone(),
            pool: CorePool::with_options(cores, pool_opts),
            partition,
            router,
            fired_by_core: vec![Vec::new(); n_cores],
            merged_axons: vec![Vec::new(); n_cores],
            fired_global: Vec::new(),
            out_global: Vec::new(),
            axon_local: split.axon_local,
            remote_axon: split.remote_axon,
            phase_wall: [std::time::Duration::ZERO; 4],
        })
    }

    pub fn n_neurons(&self) -> usize {
        self.partition.core_of.len()
    }

    pub fn reset(&mut self) {
        for c in 0..self.pool.len() {
            self.pool.core_mut(c).reset();
        }
        self.router.reset_stats();
        self.fired_global.clear();
        self.out_global.clear();
    }

    pub fn reset_cost(&mut self) {
        for c in 0..self.pool.len() {
            self.pool.core_mut(c).reset_cost();
        }
        self.router.reset_stats();
    }

    /// Number of instantiated cores (== topology cores).
    pub fn n_cores(&self) -> usize {
        self.pool.len()
    }

    /// Between-step access to one core engine.
    pub fn core(&self, i: usize) -> &CoreEngine<RustBackend> {
        self.pool.core(i)
    }

    /// One cluster-wide timestep. `axon_inputs` are *global* axon ids,
    /// ascending. Returns fired *global* output-neuron ids, ascending.
    pub fn step(&mut self, axon_inputs: &[u32]) -> Result<&[u32]> {
        // reject malformed stimulus at the boundary rather than panicking
        // deep in the router (exercised by failure-injection tests)
        let n_axons = self.router.table.axon_routes.len() as u32;
        if let Some(&bad) = axon_inputs.iter().find(|&&a| a >= n_axons) {
            anyhow::bail!("axon id {bad} out of range ({n_axons} global axons)");
        }
        // ---- phase A: parallel membrane sweeps (persistent workers)
        let t0 = std::time::Instant::now();
        self.pool.phase_update()?;
        let t1 = std::time::Instant::now();

        for c in 0..self.pool.len() {
            let g = &self.global_of[c];
            let buf = &mut self.fired_by_core[c];
            buf.clear();
            buf.extend(self.pool.core(c).fired().iter().map(|&l| g[l as usize]));
        }
        self.fired_global.clear();
        for buf in &self.fired_by_core {
            self.fired_global.extend_from_slice(buf);
        }
        self.fired_global.sort_unstable();

        // ---- barrier: HiAER multicast
        let pending = self.router.route_step(&self.fired_by_core, axon_inputs);

        // merge host-axon deliveries + remote deliveries per core (the
        // router already returns both as sorted local axon ids)
        for (c, p) in pending.iter().enumerate() {
            self.merged_axons[c].clear();
            self.merged_axons[c].extend_from_slice(p);
        }

        let t2 = std::time::Instant::now();
        // ---- phase B: chunk-parallel gather + per-core accumulate
        // (persistent workers; see cluster::pool's ordering contract)
        let rw0 = self.pool.route_wall;
        self.pool.phase_route(&self.merged_axons)?;
        let rw1 = self.pool.route_wall;
        self.phase_wall[0] += t1 - t0;
        self.phase_wall[1] += t2 - t1;
        self.phase_wall[2] += rw1[0] - rw0[0];
        self.phase_wall[3] += rw1[1] - rw0[1];

        // collect global output spikes
        self.out_global.clear();
        for c in 0..self.pool.len() {
            let g = &self.global_of[c];
            self.out_global
                .extend(self.pool.core(c).output_spikes().iter().map(|&l| g[l as usize]));
        }
        self.out_global.sort_unstable();
        Ok(&self.out_global)
    }

    /// Global-id membrane read.
    pub fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        ids.iter()
            .map(|&g| {
                let c = self.partition.core_of[g as usize] as usize;
                let l = self.partition.local_of[g as usize] as usize;
                self.pool.core(c).v[l]
            })
            .collect()
    }

    /// Resolve a *global* (pre, post) synapse address to the post
    /// neuron's core and that core's local source id. `Ok(None)` means
    /// the source has no presence (local neuron / re-homed axon) on
    /// post's core — the synapse cannot currently exist there.
    fn resolve_edit(
        &self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> Result<Option<(usize, bool, u32, u32)>> {
        let n = self.partition.core_of.len() as u32;
        if post >= n {
            anyhow::bail!("post neuron id {post} out of range ({n} global neurons)");
        }
        let c = self.partition.core_of[post as usize] as usize;
        let lpost = self.partition.local_of[post as usize];
        if pre_is_axon {
            let a = self.axon_local.first().map_or(0, Vec::len) as u32;
            if pre >= a {
                anyhow::bail!("axon id {pre} out of range ({a} global axons)");
            }
            let la = self.axon_local[c][pre as usize];
            if la == u32::MAX {
                return Ok(None);
            }
            Ok(Some((c, true, la, lpost)))
        } else {
            if pre >= n {
                anyhow::bail!("pre neuron id {pre} out of range ({n} global neurons)");
            }
            if self.partition.core_of[pre as usize] as usize == c {
                Ok(Some((c, false, self.partition.local_of[pre as usize], lpost)))
            } else {
                match self.remote_axon[c].get(&pre) {
                    Some(&la) => Ok(Some((c, true, la, lpost))),
                    None => Ok(None),
                }
            }
        }
    }

    /// Global-id live weight edit (all duplicate slots); `Ok(false)` =
    /// absent. See [`CoreEngine::write_synapse`].
    pub fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => {
                self.pool.core_mut(c).write_synapse(ax, lpre, lpost, weight)
            }
            None => Ok(false),
        }
    }

    /// Global-id live synapse read (first duplicate slot).
    pub fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Result<Option<i16>> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => Ok(self.pool.core(c).read_synapse(ax, lpre, lpost)),
            None => Ok(None),
        }
    }

    /// Global-id live structural add (upsert). Creating a synapse whose
    /// source has no presence on the post core would need a new local
    /// axon + routing-table entry in the compiled cluster — that is a
    /// re-partition, reported as an error (compact the session's edit
    /// journal and rebuild instead).
    pub fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => {
                self.pool.core_mut(c).add_synapse(ax, lpre, lpost, weight)
            }
            None => anyhow::bail!(
                "source {} {pre} has no presence on neuron {post}'s core: adding this \
                 synapse needs a new HiAER route — journal compaction required",
                if pre_is_axon { "axon" } else { "neuron" },
            ),
        }
    }

    /// Global-id live structural remove; returns slots cleared.
    pub fn remove_synapse(&mut self, pre_is_axon: bool, pre: u32, post: u32) -> Result<usize> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => self.pool.core_mut(c).remove_synapse(ax, lpre, lpost),
            None => Ok(0),
        }
    }

    /// Aggregate cost since the last `reset_cost`.
    pub fn cost(&self, model: &EnergyModel) -> ClusterCost {
        let mut energy = 0.0;
        let mut max_cycles = 0u64;
        let mut rows = 0u64;
        for c in 0..self.pool.len() {
            let r: CostReport = self.pool.core(c).cost(model);
            energy += r.energy_uj;
            max_cycles = max_cycles.max(r.cycles);
            rows += r.hbm_rows;
        }
        let total_cycles = max_cycles + self.router.stats.cycles;
        ClusterCost {
            energy_uj: energy,
            latency_us: total_cycles as f64 / model.clk_hz * 1e6,
            hbm_rows: rows,
            router: self.router.stats,
        }
    }
}

// ---- facade adapter -------------------------------------------------------

use crate::sim::{CostSummary, SimError, Simulator, StepResult};

/// The partitioned cluster as a [`Simulator`] session: selected by the
/// facade when [`crate::sim::Backend::Rust`] meets a multi-core
/// topology. All ids at this surface are global; fired ids are merged
/// and sorted across cores each step.
impl Simulator for MultiCoreEngine {
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        // uniform facade contract: bad stimulus is SimError::Stimulus on
        // every backend (the inherent step's own range bail! would reach
        // callers as SimError::Engine)
        crate::sim::check_axons(axon_in, self.router.table.axon_routes.len())?;
        MultiCoreEngine::step(self, axon_in)?;
        Ok(StepResult { fired: &self.fired_global, output_spikes: &self.out_global })
    }

    fn fired(&self) -> &[u32] {
        &self.fired_global
    }

    fn output_spikes(&self) -> &[u32] {
        &self.out_global
    }

    fn reset(&mut self) {
        MultiCoreEngine::reset(self);
    }

    fn reset_cost(&mut self) {
        MultiCoreEngine::reset_cost(self);
    }

    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        MultiCoreEngine::read_membrane(self, ids)
    }

    fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        MultiCoreEngine::write_synapse(self, pre_is_axon, pre, post, weight)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Result<Option<i16>, SimError> {
        MultiCoreEngine::read_synapse(self, pre_is_axon, pre, post)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        MultiCoreEngine::add_synapse(self, pre_is_axon, pre, post, weight)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn remove_synapse(&mut self, pre_is_axon: bool, pre: u32, post: u32) -> Result<usize, SimError> {
        MultiCoreEngine::remove_synapse(self, pre_is_axon, pre, post)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn cost(&self, model: &EnergyModel) -> CostSummary {
        let c = MultiCoreEngine::cost(self, model);
        let mut events = 0u64;
        let mut max_cycles = 0u64;
        for i in 0..self.pool.len() {
            events += self.pool.core(i).counters().events;
            max_cycles = max_cycles.max(self.pool.core(i).cycles);
        }
        CostSummary {
            energy_uj: c.energy_uj,
            latency_us: c.latency_us,
            hbm_rows: c.hbm_rows,
            events,
            cycles: max_cycles + self.router.stats.cycles,
            router: Some(c.router),
        }
    }

    fn backend_name(&self) -> &'static str {
        "cluster"
    }

    fn n_neurons(&self) -> usize {
        self.partition.core_of.len()
    }

    fn n_axons(&self) -> usize {
        self.router.table.axon_routes.len()
    }

    fn n_cores(&self) -> usize {
        self.pool.len()
    }

    fn placement(&self) -> Option<&Partition> {
        Some(&self.partition)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DenseEngine;
    use crate::snn::{Network, NetworkBuilder, NeuronModel};
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn random_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
        let models = [
            NeuronModel::if_neuron(rng.range_i32(3, 30)),
            NeuronModel::lif(rng.range_i32(3, 30), -6, 2, true).unwrap(),
        ];
        let mut b = NetworkBuilder::new().seed(rng.next_u32());
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let deg = rng.below(8) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-50, 50)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], models[rng.below(2) as usize], &refs).unwrap();
        }
        for j in 0..a {
            let deg = 1 + rng.below(6) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-50, 50)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_axon(&format!("a{j}"), &refs).unwrap();
        }
        for i in 0..n {
            if rng.chance(0.3) {
                b.add_output(&keys[i]);
            }
        }
        b.build().unwrap().0
    }

    /// THE cluster invariant: multi-core == single-core == dense, even
    /// with stochastic neurons (seeds are per-core deterministic).
    ///
    /// Stochastic note: per-core seeds differ from the single-core seed,
    /// so parity here uses deterministic neurons only.
    fn deterministic_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
        let mut net = random_net(rng, n, a);
        for p in &mut net.params {
            p.flags &= !crate::snn::FLAG_NOISE;
        }
        net
    }

    #[test]
    fn prop_multicore_matches_dense() {
        ptest::check("multicore_vs_dense", 12, |rng| {
            let n = 30 + rng.below(60) as usize;
            let net = deterministic_net(rng, n, 5);
            let topo = ClusterTopology { servers: 2, fpgas_per_server: 2, cores_per_fpga: 2 };
            let cap = CoreCapacity {
                max_neurons: (n / 3).max(4),
                max_synapses: usize::MAX,
            };
            let mut cluster = MultiCoreEngine::new(
                &net,
                topo,
                cap,
                SlotStrategy::Modulo,
                PoolOptions::default(),
                None,
            )
            .map_err(|e| e.to_string())?;
            // per-core base seeds differ but deterministic nets ignore them
            let mut dense = DenseEngine::new(&net);
            let mut is_output = vec![false; n];
            for &o in &net.outputs {
                is_output[o as usize] = true;
            }
            for _t in 0..12 {
                let axons: Vec<u32> =
                    (0..net.n_axons() as u32).filter(|_| rng.chance(0.4)).collect();
                dense.step(&axons);
                let dense_out: Vec<u32> = dense
                    .fired()
                    .into_iter()
                    .filter(|&i| is_output[i as usize])
                    .collect();
                let got = cluster.step(&axons).map_err(|e| e.to_string())?.to_vec();
                ptest::prop_assert_eq(got, dense_out, "output spikes")?;
            }
            // final membranes agree
            let ids: Vec<u32> = (0..n as u32).collect();
            ptest::prop_assert_eq(cluster.read_membrane(&ids), dense.v.clone(), "membranes")?;
            Ok(())
        });
    }

    #[test]
    fn cost_aggregates_router_and_cores() {
        let mut rng = Xorshift32::new(21);
        let net = deterministic_net(&mut rng, 80, 6);
        let topo = ClusterTopology { servers: 1, fpgas_per_server: 2, cores_per_fpga: 2 };
        let cap = CoreCapacity { max_neurons: 25, max_synapses: usize::MAX };
        let mut cluster =
            MultiCoreEngine::new(&net, topo, cap, SlotStrategy::Modulo, PoolOptions::default(), None)
                .unwrap();
        let axons: Vec<u32> = (0..net.n_axons() as u32).collect();
        for _ in 0..5 {
            cluster.step(&axons).unwrap();
        }
        let cost = cluster.cost(&EnergyModel::default());
        assert!(cost.energy_uj > 0.0);
        assert!(cost.latency_us > 0.0);
        assert!(cost.hbm_rows > 0);
    }
}
