//! Persistent core-worker pool.
//!
//! §Perf: the first multi-core implementation spawned two `thread::scope`
//! generations per timestep (one per phase); at 300 steps x 16 cores that
//! is ~10k thread spawns/s and wall-clock throughput *decreased* with
//! core count. This pool pins one OS thread per simulated core for the
//! engine's lifetime and drives phases with a lightweight
//! generation-counter barrier (Mutex+Condvar, no busy wait).
//!
//! Safety model: the pool owns the `CoreEngine`s. `run_phase` hands each
//! worker a raw pointer to its own engine plus a shared borrow of the
//! phase input; workers never touch another worker's engine, and the
//! caller blocks until all workers finish the phase, so no aliasing
//! outlives the call.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::engine::{CoreEngine, RustBackend};

/// Which phase the workers should run this generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Update,
    Route,
    Exit,
}

struct Shared {
    state: Mutex<State>,
    start_cv: Condvar,
    done_cv: Condvar,
    pending: AtomicUsize,
    /// per-core routed axon inputs for the Route phase (set by the driver
    /// before raising the generation).
    inputs: Mutex<Vec<Vec<u32>>>,
    /// engines, one slot per core. Workers take a raw pointer to their
    /// slot; the driver only touches engines between phases.
    engines: Mutex<Vec<*mut CoreEngine<RustBackend>>>,
}

// Raw pointers to engines are only dereferenced by their owning worker
// while the driver is blocked in run_phase.
unsafe impl Send for Shared {}
unsafe impl Sync for Shared {}

struct State {
    generation: u64,
    phase: Phase,
    errors: Vec<String>,
}

pub struct CorePool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// boxed engines; stable addresses for the worker pointers
    cores: Vec<Box<CoreEngine<RustBackend>>>,
    n: usize,
}

impl CorePool {
    pub fn new(mut cores_in: Vec<CoreEngine<RustBackend>>) -> Self {
        let n = cores_in.len();
        let mut cores: Vec<Box<CoreEngine<RustBackend>>> =
            cores_in.drain(..).map(Box::new).collect();
        let ptrs: Vec<*mut CoreEngine<RustBackend>> =
            cores.iter_mut().map(|b| &mut **b as *mut _).collect();
        let shared = Arc::new(Shared {
            state: Mutex::new(State { generation: 0, phase: Phase::Update, errors: Vec::new() }),
            start_cv: Condvar::new(),
            done_cv: Condvar::new(),
            pending: AtomicUsize::new(0),
            inputs: Mutex::new(vec![Vec::new(); n]),
            engines: Mutex::new(ptrs),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("hiaer-core-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("spawn core worker")
            })
            .collect();
        Self { shared, workers, cores, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Immutable access between phases.
    pub fn core(&self, i: usize) -> &CoreEngine<RustBackend> {
        &self.cores[i]
    }

    /// Mutable access between phases (reset, counters).
    pub fn core_mut(&mut self, i: usize) -> &mut CoreEngine<RustBackend> {
        &mut self.cores[i]
    }

    fn run_phase(&self, phase: Phase) -> anyhow::Result<()> {
        let mut st = self.shared.state.lock().unwrap();
        self.shared.pending.store(self.n, Ordering::SeqCst);
        st.phase = phase;
        st.generation += 1;
        self.shared.start_cv.notify_all();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        if !st.errors.is_empty() {
            let msg = st.errors.join("; ");
            st.errors.clear();
            return Err(anyhow::anyhow!("core worker error: {msg}"));
        }
        Ok(())
    }

    /// Phase A: membrane sweep on every core.
    pub fn phase_update(&self) -> anyhow::Result<()> {
        self.run_phase(Phase::Update)
    }

    /// Phase B: routing + accumulate, with per-core axon inputs.
    pub fn phase_route(&self, inputs: &[Vec<u32>]) -> anyhow::Result<()> {
        {
            let mut slot = self.shared.inputs.lock().unwrap();
            for (dst, src) in slot.iter_mut().zip(inputs) {
                dst.clear();
                dst.extend_from_slice(src);
            }
        }
        self.run_phase(Phase::Route)
    }
}

impl Drop for CorePool {
    fn drop(&mut self) {
        let _ = self.run_phase(Phase::Exit);
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    let engine: *mut CoreEngine<RustBackend> = shared.engines.lock().unwrap()[idx];
    let mut seen_gen = 0u64;
    let mut axon_buf: Vec<u32> = Vec::new();
    loop {
        let phase = {
            let mut st = shared.state.lock().unwrap();
            while st.generation == seen_gen {
                st = shared.start_cv.wait(st).unwrap();
            }
            seen_gen = st.generation;
            st.phase
        };
        if phase == Phase::Exit {
            shared.pending.fetch_sub(1, Ordering::SeqCst);
            shared.done_cv.notify_all();
            return;
        }
        // SAFETY: this worker is the only one holding engine `idx`, and
        // the driver is blocked until `pending` reaches zero.
        let result = unsafe {
            let e = &mut *engine;
            match phase {
                Phase::Update => e.phase_update(),
                Phase::Route => {
                    // copy this core's inputs out and RELEASE the lock —
                    // holding it across phase_route would serialise the
                    // whole phase across workers (§Perf iteration 2).
                    axon_buf.clear();
                    {
                        let inputs = shared.inputs.lock().unwrap();
                        axon_buf.extend_from_slice(&inputs[idx]);
                    }
                    e.phase_route(&axon_buf)
                }
                Phase::Exit => unreachable!(),
            }
        };
        if let Err(err) = result {
            shared.state.lock().unwrap().errors.push(format!("core {idx}: {err:#}"));
        }
        if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::SlotStrategy;
    use crate::snn::{Network, NeuronModel, Synapse};
    use crate::util::prng::Xorshift32;

    fn small_net(seed: u32) -> Network {
        let mut rng = Xorshift32::new(seed);
        let n = 40;
        let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
        for adj in neuron_adj.iter_mut() {
            for _ in 0..4 {
                adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(1, 9) as i16 });
            }
        }
        let axon_adj = vec![vec![Synapse { target: 0, weight: 10 }]];
        Network::from_adj(
            vec![NeuronModel::if_neuron(5); n],
            &neuron_adj,
            &axon_adj,
            vec![0, 1],
            seed,
        )
    }

    #[test]
    fn pool_matches_direct_execution() {
        let nets: Vec<Network> = (0..4).map(|i| small_net(i)).collect();
        let mut direct: Vec<CoreEngine<RustBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let pooled: Vec<CoreEngine<RustBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let mut pool = CorePool::new(pooled);
        for step in 0..20 {
            let inputs: Vec<Vec<u32>> =
                (0..4).map(|c| if (step + c) % 3 == 0 { vec![0u32] } else { vec![] }).collect();
            for (c, e) in direct.iter_mut().enumerate() {
                e.phase_update().unwrap();
                e.phase_route(&inputs[c]).unwrap();
            }
            pool.phase_update().unwrap();
            pool.phase_route(&inputs).unwrap();
            for c in 0..4 {
                assert_eq!(pool.core(c).v, direct[c].v, "core {c} step {step}");
            }
        }
        // mutable access between phases works
        pool.core_mut(0).reset();
        assert!(pool.core(0).v.iter().all(|&x| x == 0));
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let nets: Vec<Network> = (0..2).map(small_net).collect();
        let engines = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let pool = CorePool::new(engines);
        pool.phase_update().unwrap();
        drop(pool); // must not hang
    }
}
