//! Persistent worker pool with a chunk-parallel membrane sweep.
//!
//! §Perf: the first multi-core implementation spawned two `thread::scope`
//! generations per timestep (one per phase); at 300 steps x 16 cores that
//! is ~10k thread spawns/s and wall-clock throughput *decreased* with
//! core count. This pool pins persistent OS threads for the engine's
//! lifetime and drives phases with a lightweight generation-counter
//! barrier (Mutex+Condvar, no busy wait).
//!
//! # Chunk-barrier protocol
//!
//! The pool's Update-phase work unit is a **chunk** — a word-aligned
//! slice of one core's membrane sweep (64-neuron multiples, so every
//! chunk owns whole `spike_words` and chunks never share a word). When
//! every core's backend is chunkable (`UpdateBackend::chunkable`, i.e.
//! its `update` is exactly the pure `sweep_chunk` reference kernel), the
//! pool carves all cores into chunks once at construction and, each
//! Update generation:
//!
//! 1. the driver refreshes one `SweepView` per core (raw `v` /
//!    `spike_words` / params pointers plus this step's noise seed) and
//!    resets the shared chunk cursor;
//! 2. every worker — not just the one pinned to a core — pulls chunks
//!    from the cursor (an atomic fetch-add) until the list is drained, so
//!    one big core's sweep spreads across all idle workers;
//! 3. the driver, woken by the generation barrier, runs each engine's
//!    `finish_update` epilogue (counters, fired-id extraction, noise-seed
//!    advance) serially.
//!
//! Because membrane noise is the counter-based per-index
//! `noise17(step_seed, i)` hash, chunked execution is bit-identical to
//! the single-threaded sweep regardless of chunk order or interleaving.
//! Non-chunkable backends fall back to the original one-worker-per-core
//! `phase_update`.
//!
//! # Chunk-parallel Route phase and the merge ordering contract
//!
//! With [`RouteGranularity::Chunk`] (the default, chunkable backends
//! only) the Route phase mirrors the sweep's split — but because HBM
//! routing is order-sensitive where the sweep is not, it runs as **two
//! generations** around a driver-side prologue:
//!
//! 1. the driver runs each engine's `route_prepare` serially — phase-1
//!    pointer fetches (row-burst dedup walks the fired list in order)
//!    and chunk geometry: every core's pointer queue is cut into
//!    fixed-size pointer chunks, one gather buffer per chunk — then
//!    publishes one `RouteView` per core plus the flattened
//!    `(core, chunk)` task list and resets the shared cursor;
//! 2. **RouteGather**: every worker pulls `(core, chunk)` tasks off the
//!    cursor — so one core's gather (or a single-core net's) spreads
//!    across all workers — and streams that chunk's pointers through
//!    `UpdateBackend::gather` into the chunk's own buffer. Chunks only
//!    read the image/backend and write disjoint buffers: no aliasing,
//!    and no ordering requirement *during* the gather;
//! 3. **RouteAccum**: each core's own worker runs `route_finish` — the
//!    accounting plus the merge that restores determinism: buffers are
//!    accumulated in **ascending chunk index order**, which
//!    concatenates to exactly the serial gather stream. Wrapping (or
//!    any future saturating) accumulate arithmetic therefore sees the
//!    same event order for every worker count and chunk size, keeping
//!    all golden transcripts bit-identical to the serial
//!    `phase_route` (`rust/tests/chunked_route.rs` pins this).
//!
//! [`RouteGranularity::Core`] (or a non-chunkable backend) falls back to
//! the original one-worker-per-core Route generation.
//!
//! With chunking enabled the pool may spawn more workers than cores
//! (explicit [`PoolOptions::workers`], else `available_parallelism`
//! bounded by the sweep chunk count) so a single-core engine still
//! sweeps and gathers in parallel; the extra workers idle through
//! per-core generations.
//!
//! Safety model: the pool owns the `CoreEngine`s (boxed, stable
//! addresses). In per-core phases each worker holds a raw pointer to its
//! own engine only; in the chunked Update phase workers form disjoint
//! word-aligned sub-slices of `v`/`spike_words`; in RouteGather they
//! write disjoint gather-buffer slots and only read the image/backend
//! (hence the `B: Sync` spawn bound), so no two threads ever alias. The
//! driver blocks until the generation barrier clears, so no borrow
//! outlives the phase. A panicking worker is caught (`catch_unwind`),
//! reported as a phase error, and the worker survives for the next
//! generation — the barrier can never hang on a dead thread.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError, RwLock};
use std::time::{Duration, Instant};

use crate::engine::backend::sweep_chunk;
use crate::engine::core::{gather_chunk, RouteView, SweepView};
use crate::engine::{mask_words, CoreEngine, RustBackend, UpdateBackend};
use crate::plasticity::trace_chunk;

/// Default chunk granularity: 64 spike words = 4096 neurons. Small enough
/// that a 100k-neuron core splits into ~25 chunks for load balance, large
/// enough that the per-chunk dispatch cost stays invisible.
const DEFAULT_CHUNK_WORDS: usize = 64;

/// Default Route-phase granularity: 32 pointers per gather chunk. A
/// pointer expands to its whole synapse region (often several rows), so
/// chunks this size already amortise the cursor fetch-add while a burst
/// of a few thousand fired sources still fans out across every worker.
const DEFAULT_ROUTE_CHUNK_PTRS: usize = 32;

/// Route-phase work-unit granularity (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteGranularity {
    /// One worker routes one whole core (the pre-chunking behaviour;
    /// also the fallback for non-chunkable backends).
    Core,
    /// The gather spreads over all workers in pointer chunks pulled off
    /// the shared cursor; the per-core merge/accumulate epilogue keeps
    /// the event order bit-identical to `Core`.
    #[default]
    Chunk,
}

/// Construction-time knobs for a [`CorePool`] (the facade surface is
/// [`crate::sim::SimConfig`]; `None` fields take the engine defaults).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolOptions {
    /// Sweep chunk granularity in 64-bit spike words.
    pub chunk_words: Option<usize>,
    /// Route work-unit granularity.
    pub route: RouteGranularity,
    /// Route gather granularity in pointers per chunk.
    pub route_chunk_ptrs: Option<usize>,
    /// Exact worker-thread count (>= 1; the pool still spawns at least
    /// one worker per core for the per-core phases). `None` = size to
    /// `available_parallelism`, bounded by the sweep chunk count.
    pub workers: Option<usize>,
}

/// Which phase the workers should run this generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Update,
    Route,
    RouteGather,
    RouteAccum,
    Exit,
}

/// One word-aligned slice of one core's membrane sweep.
#[derive(Clone, Copy, Debug)]
struct ChunkTask {
    core: usize,
    word_lo: usize,
    word_hi: usize,
}

/// Chunked-sweep state: static chunk geometry plus per-generation views.
struct SweepState {
    /// refreshed by the driver before every Update generation
    views: Vec<SweepView>,
    /// fixed at construction; empty => per-core fallback Update
    chunks: Vec<ChunkTask>,
}

/// One pointer chunk of one core's route gather.
#[derive(Clone, Copy, Debug)]
struct RouteChunk {
    core: usize,
    chunk: usize,
}

/// Chunked-route state, rebuilt by the driver before every RouteGather
/// generation (chunk counts depend on this step's fired sources).
struct RouteState<B> {
    views: Vec<RouteView<B>>,
    chunks: Vec<RouteChunk>,
}

struct Shared<B: UpdateBackend> {
    state: Mutex<State>,
    start_cv: Condvar,
    done_cv: Condvar,
    pending: AtomicUsize,
    /// per-core routed axon inputs for the Route phase (set by the driver
    /// before raising the generation).
    inputs: Mutex<Vec<Vec<u32>>>,
    /// engines, one slot per core. Workers take a raw pointer to their
    /// slot; the driver only touches engines between phases.
    engines: Mutex<Vec<*mut CoreEngine<B>>>,
    /// chunk-parallel sweep state (see module docs).
    sweep: RwLock<SweepState>,
    /// chunk-parallel route state (see module docs).
    route: RwLock<RouteState<B>>,
    /// shared chunk cursor for the Update and RouteGather phases
    /// (generations never overlap, so one cursor serves both).
    next_chunk: AtomicUsize,
}

// Raw pointers to engines/sweep/route views are only dereferenced under
// the protocol in the module docs (own engine in per-core phases,
// disjoint word ranges in Update, disjoint gather buffers + shared
// `&B`/`&HbmImage` reads in RouteGather — hence `B: Sync`) while the
// driver is blocked in run_phase.
unsafe impl<B: UpdateBackend + Send + Sync> Send for Shared<B> {}
unsafe impl<B: UpdateBackend + Send + Sync> Sync for Shared<B> {}

struct State {
    generation: u64,
    phase: Phase,
    errors: Vec<String>,
}

/// Recover the guard even if a panicking worker poisoned the lock — the
/// panic is already surfaced as a phase error, and state behind these
/// locks stays structurally valid (worst case: a half-swept core that the
/// errored phase reports anyway).
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

pub struct CorePool<B: UpdateBackend = RustBackend> {
    shared: Arc<Shared<B>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// boxed engines; stable addresses for the worker pointers
    cores: Vec<Box<CoreEngine<B>>>,
    n: usize,
    n_workers: usize,
    /// chunk-parallel Update enabled (all backends chunkable, >= 1 chunk)
    chunked: bool,
    /// chunk-parallel Route enabled (all backends chunkable + granularity)
    route_chunked: bool,
    /// pointers per route gather chunk
    route_chunk_ptrs: usize,
    /// cumulative wall-clock of the Route sub-phases since construction:
    /// `[prepare + gather, merge/accumulate]` (per-core fallback Route
    /// bills entirely to slot 0). Exposed for the perf harness.
    pub route_wall: [Duration; 2],
}

impl<B: UpdateBackend + Send + Sync + 'static> CorePool<B> {
    /// Crate-private: external callers reach the pool through
    /// [`crate::sim::SimConfig`] with [`crate::sim::Backend::Pool`] (or
    /// implicitly through the multi-core cluster engine).
    pub(crate) fn new(cores_in: Vec<CoreEngine<B>>) -> Self {
        Self::with_options(cores_in, PoolOptions::default())
    }

    /// Build the pool with an explicit sweep-chunk granularity (in 64-bit
    /// spike words, i.e. 64-neuron units). Exposed crate-internally for
    /// tests and perf experiments (`SimConfig::chunk_words` is the public
    /// knob); `new` uses [`DEFAULT_CHUNK_WORDS`].
    pub(crate) fn with_chunk_words(cores_in: Vec<CoreEngine<B>>, chunk_words: usize) -> Self {
        Self::with_options(
            cores_in,
            PoolOptions { chunk_words: Some(chunk_words), ..PoolOptions::default() },
        )
    }

    /// Build the pool from explicit [`PoolOptions`] (the facade maps
    /// `SimConfig`'s chunk_words / route granularity / workers knobs
    /// here).
    pub(crate) fn with_options(mut cores_in: Vec<CoreEngine<B>>, opts: PoolOptions) -> Self {
        let chunk_words = opts.chunk_words.unwrap_or(DEFAULT_CHUNK_WORDS).max(1);
        let n = cores_in.len();
        let mut cores: Vec<Box<CoreEngine<B>>> = cores_in.drain(..).map(Box::new).collect();
        let ptrs: Vec<*mut CoreEngine<B>> =
            cores.iter_mut().map(|b| &mut **b as *mut _).collect();

        let chunkable = cores.iter().all(|c| c.backend_chunkable());
        let mut chunks = Vec::new();
        if chunkable {
            for (c, core) in cores.iter().enumerate() {
                let words = mask_words(core.n_neurons());
                let mut w = 0;
                while w < words {
                    let hi = (w + chunk_words).min(words);
                    chunks.push(ChunkTask { core: c, word_lo: w, word_hi: hi });
                    w = hi;
                }
            }
        }
        let chunked = !chunks.is_empty();
        let route_chunked = chunkable && opts.route == RouteGranularity::Chunk;
        let route_chunk_ptrs = opts.route_chunk_ptrs.unwrap_or(DEFAULT_ROUTE_CHUNK_PTRS).max(1);
        // At least one worker per core (per-core phases need an owner);
        // beyond that, either the explicit count or enough workers to
        // eat the sweep chunk list. Oversubscription (workers > chunks)
        // is allowed — extra workers find the cursor drained and idle.
        let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let extra = opts
            .workers
            .unwrap_or(if chunked { avail.min(chunks.len()) } else { 1 })
            .max(1);
        let n_workers = if n == 0 { 0 } else { n.max(extra) };

        let shared = Arc::new(Shared {
            state: Mutex::new(State { generation: 0, phase: Phase::Update, errors: Vec::new() }),
            start_cv: Condvar::new(),
            done_cv: Condvar::new(),
            pending: AtomicUsize::new(0),
            inputs: Mutex::new(vec![Vec::new(); n]),
            engines: Mutex::new(ptrs),
            sweep: RwLock::new(SweepState { views: Vec::new(), chunks }),
            route: RwLock::new(RouteState { views: Vec::new(), chunks: Vec::new() }),
            next_chunk: AtomicUsize::new(0),
        });
        let workers = (0..n_workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("hiaer-core-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("spawn core worker")
            })
            .collect();
        Self {
            shared,
            workers,
            cores,
            n,
            n_workers,
            chunked,
            route_chunked,
            route_chunk_ptrs,
            route_wall: [Duration::ZERO; 2],
        }
    }

    /// Test-support constructor for the failure-injection integration
    /// suite: one engine per network over an arbitrary (usually
    /// fault-injecting) backend. Hidden — not a stable API; real callers
    /// go through [`crate::sim::SimConfig`].
    #[doc(hidden)]
    pub fn with_backend_for_tests(
        nets: &[Network],
        backend: B,
        opts: PoolOptions,
    ) -> anyhow::Result<Self>
    where
        B: Clone,
    {
        let mut engines = Vec::with_capacity(nets.len());
        for net in nets {
            engines.push(CoreEngine::new(net, SlotStrategy::Modulo, backend.clone())?);
        }
        Ok(Self::with_options(engines, opts))
    }
}

impl<B: UpdateBackend> CorePool<B> {
    pub fn len(&self) -> usize {
        self.n
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Immutable access between phases.
    pub fn core(&self, i: usize) -> &CoreEngine<B> {
        &self.cores[i]
    }

    /// Mutable access between phases (reset, counters).
    pub fn core_mut(&mut self, i: usize) -> &mut CoreEngine<B> {
        &mut self.cores[i]
    }

    fn run_phase(&self, phase: Phase) -> anyhow::Result<()> {
        let mut st = plock(&self.shared.state);
        self.shared.pending.store(self.n_workers, Ordering::SeqCst);
        st.phase = phase;
        st.generation += 1;
        self.shared.start_cv.notify_all();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            st = self.shared.done_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        if !st.errors.is_empty() {
            let msg = st.errors.join("; ");
            st.errors.clear();
            return Err(anyhow::anyhow!("core worker error: {msg}"));
        }
        Ok(())
    }

    /// Phase A: membrane sweep on every core — chunk-parallel across all
    /// workers when the backend allows it (see module docs).
    pub fn phase_update(&mut self) -> anyhow::Result<()> {
        if !self.chunked {
            return self.run_phase(Phase::Update);
        }
        {
            let mut sweep =
                self.shared.sweep.write().unwrap_or_else(PoisonError::into_inner);
            sweep.views.clear();
            for core in self.cores.iter_mut() {
                sweep.views.push(core.sweep_view());
            }
        }
        self.shared.next_chunk.store(0, Ordering::SeqCst);
        let result = self.run_phase(Phase::Update);
        // Epilogue per core: counters, fired extraction, seed advance —
        // run it even when a worker errored, so cores whose chunks all
        // completed end the generation fully consistent (same as the
        // per-core fallback, where a non-failing core's phase_update runs
        // to completion). A failed core's membranes may be half-swept;
        // the propagated error marks the whole step invalid.
        for core in self.cores.iter_mut() {
            core.finish_update();
        }
        result
    }

    /// Phase B: routing + accumulate, with per-core axon inputs.
    /// `inputs.len()` must equal the core count; every input slot is
    /// cleared up front so a malformed call can never replay the previous
    /// step's deliveries into tail cores.
    ///
    /// With [`RouteGranularity::Chunk`] this runs the three-stage
    /// pipeline of the module docs (serial prepare, chunk-parallel
    /// RouteGather, per-core RouteAccum); otherwise one Route generation
    /// with one worker per core. Either way the result is bit-identical
    /// to calling each engine's `phase_route` serially.
    pub fn phase_route(&mut self, inputs: &[Vec<u32>]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        {
            let mut slot = plock(&self.shared.inputs);
            for dst in slot.iter_mut() {
                dst.clear();
            }
            if inputs.len() != self.n {
                anyhow::bail!(
                    "phase_route: {} input vecs for {} cores (one per core required)",
                    inputs.len(),
                    self.n
                );
            }
            for (dst, src) in slot.iter_mut().zip(inputs) {
                dst.extend_from_slice(src);
            }
        }
        if !self.route_chunked {
            let result = self.run_phase(Phase::Route);
            self.route_wall[0] += t0.elapsed();
            return result;
        }
        // Driver-side prologue: serial phase-1 per core (burst dedup is
        // order-dependent), then publish views + the flat task list.
        {
            let mut route = self.shared.route.write().unwrap_or_else(PoisonError::into_inner);
            route.views.clear();
            route.chunks.clear();
            let slot = plock(&self.shared.inputs);
            for (c, core) in self.cores.iter_mut().enumerate() {
                core.route_prepare(&slot[c], self.route_chunk_ptrs);
                let view = core.route_view();
                for k in 0..view.n_chunks {
                    route.chunks.push(RouteChunk { core: c, chunk: k });
                }
                route.views.push(view);
            }
        }
        self.shared.next_chunk.store(0, Ordering::SeqCst);
        let gather = self.run_phase(Phase::RouteGather);
        self.route_wall[0] += t0.elapsed();
        let t1 = Instant::now();
        // Merge/accumulate epilogue per core — run it even when a gather
        // worker errored, so every engine leaves the step structurally
        // consistent (counters, outputs); the propagated error marks the
        // whole step invalid, mirroring phase_update's epilogue policy.
        let accum = self.run_phase(Phase::RouteAccum);
        self.route_wall[1] += t1.elapsed();
        gather.and(accum)
    }
}

// ---- facade adapter -------------------------------------------------------

use crate::energy::EnergyModel;
use crate::hbm::SlotStrategy;
use crate::sim::{CostSummary, SimError, Simulator, StepResult};
use crate::snn::{NetView, Network};

/// [`Simulator`] session running one core chunk-parallel across the
/// whole worker pool ([`crate::sim::Backend::Pool`]): both the membrane
/// sweep and the route gather of a single (possibly huge) core spread
/// over all workers (explicit [`PoolOptions::workers`], else up to
/// `available_parallelism`); only phase-1 pointer fetches and the
/// ordered merge/accumulate stay serial.
pub struct PoolSim {
    pool: CorePool<RustBackend>,
    /// reusable one-slot input buffer for `phase_route`
    inputs: Vec<Vec<u32>>,
    n_axons: usize,
}

impl PoolSim {
    pub(crate) fn new<'a>(
        net: impl Into<NetView<'a>>,
        strategy: SlotStrategy,
        opts: PoolOptions,
        learning: Option<crate::plasticity::PlasticityConfig>,
    ) -> anyhow::Result<Self> {
        let net: NetView<'_> = net.into();
        let mut engine = CoreEngine::new(net, strategy, RustBackend)?;
        if let Some(cfg) = learning {
            engine.enable_plasticity(cfg)?;
        }
        let pool = CorePool::with_options(vec![engine], opts);
        Ok(Self { pool, inputs: vec![Vec::new()], n_axons: net.n_axons() })
    }
}

impl Simulator for PoolSim {
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        crate::sim::check_axons(axon_in, self.n_axons)?;
        self.inputs[0].clear();
        self.inputs[0].extend_from_slice(axon_in);
        self.pool.phase_update()?;
        self.pool.phase_route(&self.inputs)?;
        let core = self.pool.core(0);
        Ok(StepResult { fired: core.fired(), output_spikes: core.output_spikes() })
    }

    fn fired(&self) -> &[u32] {
        self.pool.core(0).fired()
    }

    fn output_spikes(&self) -> &[u32] {
        self.pool.core(0).output_spikes()
    }

    fn reset(&mut self) {
        self.pool.core_mut(0).reset();
    }

    fn reset_cost(&mut self) {
        self.pool.core_mut(0).reset_cost();
    }

    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        self.pool.core(0).read_membrane(ids)
    }

    fn cost(&self, model: &EnergyModel) -> CostSummary {
        self.pool.core(0).cost(model).into()
    }

    fn backend_name(&self) -> &'static str {
        "pool"
    }

    fn n_neurons(&self) -> usize {
        self.pool.core(0).n_neurons()
    }

    fn n_axons(&self) -> usize {
        self.n_axons
    }

    fn hbm_stats(&self) -> Option<crate::hbm::LayoutStats> {
        Some(self.pool.core(0).hbm.image.stats)
    }

    fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        self.pool
            .core_mut(0)
            .write_synapse(pre_is_axon, pre, post, weight)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn read_synapse(
        &self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> Result<Option<i16>, SimError> {
        Ok(self.pool.core(0).read_synapse(pre_is_axon, pre, post))
    }

    fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        self.pool
            .core_mut(0)
            .add_synapse(pre_is_axon, pre, post, weight)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn remove_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> Result<usize, SimError> {
        self.pool
            .core_mut(0)
            .remove_synapse(pre_is_axon, pre, post)
            .map_err(|e| SimError::Config(e.to_string()))
    }
}

impl<B: UpdateBackend> Drop for CorePool<B> {
    fn drop(&mut self) {
        let _ = self.run_phase(Phase::Exit);
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Run the branch-free kernel over one chunk of a core's sweep.
///
/// SAFETY: caller must guarantee this word range of this view is owned
/// exclusively by the current thread for the duration of the call, and
/// that the view's pointers are live (engine boxed, driver blocked).
unsafe fn run_chunk(view: &SweepView, word_lo: usize, word_hi: usize) {
    let lo = word_lo * 64;
    let hi = (word_hi * 64).min(view.n);
    if lo >= hi {
        return;
    }
    let v = std::slice::from_raw_parts_mut(view.v.add(lo), hi - lo);
    let spikes = std::slice::from_raw_parts_mut(view.spikes.add(word_lo), word_hi - word_lo);
    let params = &*view.params;
    sweep_chunk(v, params.slice(lo, hi), view.step_seed, spikes, lo as u32);
    // STDP trace columns ride the same chunk: per-lane independent, so
    // any chunking/worker interleaving matches the serial trace pass
    // bit-for-bit (null when plasticity is off).
    if !view.trace_pre.is_null() {
        let pre = std::slice::from_raw_parts_mut(view.trace_pre.add(lo), hi - lo);
        let post = std::slice::from_raw_parts_mut(view.trace_post.add(lo), hi - lo);
        trace_chunk(spikes, pre, post, view.tau_pre, view.tau_post);
    }
}

/// Gather one pointer chunk of a prepared route view into the chunk's
/// own buffer (RouteGather work unit).
///
/// SAFETY: caller must guarantee chunk `chunk` of this view is owned
/// exclusively by the current thread for the duration of the call
/// (cursor protocol), the view's pointers are live (engine boxed,
/// driver blocked between `route_prepare` and `route_finish`), and `B`
/// is `Sync` (the backend reference is shared across workers).
unsafe fn run_route_chunk<B: UpdateBackend>(view: &RouteView<B>, chunk: usize) {
    let queue = std::slice::from_raw_parts(view.ptrs, view.n_ptrs);
    let buf = &mut *view.bufs.add(chunk);
    // the one shared chunk implementation (engine::core::gather_chunk):
    // serial and pooled routing cannot diverge on boundary math
    gather_chunk(&*view.image, &*view.backend, queue, chunk, view.chunk_ptrs, buf);
}

fn worker_loop<B: UpdateBackend>(shared: Arc<Shared<B>>, idx: usize) {
    // Workers beyond the core count (chunk helpers) have no engine.
    let engine: *mut CoreEngine<B> =
        plock(&shared.engines).get(idx).copied().unwrap_or(std::ptr::null_mut());
    let mut seen_gen = 0u64;
    let mut axon_buf: Vec<u32> = Vec::new();
    loop {
        let phase = {
            let mut st = plock(&shared.state);
            while st.generation == seen_gen {
                st = shared.start_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            seen_gen = st.generation;
            st.phase
        };
        if phase == Phase::Exit {
            // Same lost-wakeup guard as below: take the state mutex before
            // notifying so the notify can't land in the driver's window
            // between its `pending` load and `done_cv.wait`.
            if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                let _guard = plock(&shared.state);
                shared.done_cv.notify_all();
            }
            return;
        }
        // Panic guard: a worker must always reach the pending decrement,
        // or the driver (and Drop) would wait on done_cv forever.
        let work = catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<()> {
            match phase {
                Phase::Update => {
                    let sweep =
                        shared.sweep.read().unwrap_or_else(PoisonError::into_inner);
                    if sweep.chunks.is_empty() {
                        if engine.is_null() {
                            return Ok(());
                        }
                        // SAFETY: this worker is the only one holding
                        // engine `idx`, and the driver is blocked until
                        // `pending` reaches zero.
                        unsafe { (*engine).phase_update() }
                    } else {
                        loop {
                            let k = shared.next_chunk.fetch_add(1, Ordering::SeqCst);
                            let Some(t) = sweep.chunks.get(k) else { break };
                            let view = sweep.views[t.core];
                            // SAFETY: the cursor hands each chunk to
                            // exactly one worker; chunks cover disjoint
                            // word-aligned ranges (module docs).
                            unsafe { run_chunk(&view, t.word_lo, t.word_hi) };
                        }
                        Ok(())
                    }
                }
                Phase::Route => {
                    if engine.is_null() {
                        return Ok(());
                    }
                    // copy this core's inputs out and RELEASE the lock —
                    // holding it across phase_route would serialise the
                    // whole phase across workers (§Perf iteration 2).
                    axon_buf.clear();
                    {
                        let inputs = plock(&shared.inputs);
                        axon_buf.extend_from_slice(&inputs[idx]);
                    }
                    // SAFETY: as above — exclusive engine, blocked driver.
                    unsafe { (*engine).phase_route(&axon_buf) }
                }
                Phase::RouteGather => {
                    let route =
                        shared.route.read().unwrap_or_else(PoisonError::into_inner);
                    loop {
                        let k = shared.next_chunk.fetch_add(1, Ordering::SeqCst);
                        let Some(t) = route.chunks.get(k) else { break };
                        // SAFETY: the cursor hands each (core, chunk) to
                        // exactly one worker; chunks write disjoint
                        // gather buffers and only read the image/backend
                        // (module docs).
                        unsafe { run_route_chunk(&route.views[t.core], t.chunk) };
                    }
                    Ok(())
                }
                Phase::RouteAccum => {
                    if engine.is_null() {
                        return Ok(());
                    }
                    // SAFETY: as above — exclusive engine, blocked driver.
                    unsafe { (*engine).route_finish() }
                }
                Phase::Exit => unreachable!(),
            }
        }));
        match work {
            Ok(Ok(())) => {}
            Ok(Err(err)) => plock(&shared.state).errors.push(format!("core {idx}: {err:#}")),
            Err(payload) => plock(&shared.state)
                .errors
                .push(format!("worker {idx} panicked: {}", panic_message(&*payload))),
        }
        if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = plock(&shared.state);
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::SlotStrategy;
    use crate::snn::{Network, NeuronModel, Synapse};
    use crate::util::prng::Xorshift32;

    fn small_net(seed: u32) -> Network {
        let mut rng = Xorshift32::new(seed);
        let n = 40;
        let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); n];
        for adj in neuron_adj.iter_mut() {
            for _ in 0..4 {
                adj.push(Synapse { target: rng.below(n as u32), weight: rng.range_i32(1, 9) as i16 });
            }
        }
        let axon_adj = vec![vec![Synapse { target: 0, weight: 10 }]];
        Network::from_adj(
            vec![NeuronModel::if_neuron(5); n],
            &neuron_adj,
            &axon_adj,
            vec![0, 1],
            seed,
        )
    }

    #[test]
    fn pool_matches_direct_execution() {
        let nets: Vec<Network> = (0..4).map(|i| small_net(i)).collect();
        let mut direct: Vec<CoreEngine<RustBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let pooled: Vec<CoreEngine<RustBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let mut pool = CorePool::new(pooled);
        for step in 0..20 {
            let inputs: Vec<Vec<u32>> =
                (0..4).map(|c| if (step + c) % 3 == 0 { vec![0u32] } else { vec![] }).collect();
            for (c, e) in direct.iter_mut().enumerate() {
                e.phase_update().unwrap();
                e.phase_route(&inputs[c]).unwrap();
            }
            pool.phase_update().unwrap();
            pool.phase_route(&inputs).unwrap();
            for c in 0..4 {
                assert_eq!(pool.core(c).v, direct[c].v, "core {c} step {step}");
            }
        }
        // mutable access between phases works
        pool.core_mut(0).reset();
        assert!(pool.core(0).v.iter().all(|&x| x == 0));
    }

    /// One core's sweep split across many single-word chunks must stay
    /// bit-exact with the unchunked engine — including noise, which is
    /// per-index and therefore chunking-invariant.
    #[test]
    fn chunked_sweep_matches_direct_engine_with_noise() {
        let mut net = small_net(0xC0FFEE);
        for p in &mut net.params {
            *p = NeuronModel::lif(40, -2, 4, true).unwrap(); // stochastic
        }
        let mut direct = CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap();
        let pooled = vec![CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap()];
        let mut pool = CorePool::with_chunk_words(pooled, 1); // force max chunking
        for step in 0..25 {
            let inputs = if step % 2 == 0 { vec![0u32] } else { vec![] };
            direct.phase_update().unwrap();
            direct.phase_route(&inputs).unwrap();
            pool.phase_update().unwrap();
            pool.phase_route(std::slice::from_ref(&inputs)).unwrap();
            assert_eq!(pool.core(0).fired(), direct.fired(), "fired step {step}");
            assert_eq!(pool.core(0).v, direct.v, "membranes step {step}");
        }
    }

    /// Tentpole invariant, unit-level: the chunk-parallel Route phase —
    /// every granularity from one pointer per chunk upward, with and
    /// without oversubscribed workers — must stay bit-exact with direct
    /// serial engines, including HBM access counters and cycles (the
    /// merge epilogue reconstructs the same totals the serial path
    /// counts inline).
    #[test]
    fn chunked_route_matches_direct_engines_at_every_granularity() {
        for (route_chunk, workers) in [(1, 1), (1, 8), (2, 3), (7, 2), (64, 8)] {
            let nets: Vec<Network> = (0..3).map(|i| small_net(0xBEE + i)).collect();
            let mut direct: Vec<CoreEngine<RustBackend>> = nets
                .iter()
                .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
                .collect();
            let pooled: Vec<CoreEngine<RustBackend>> = nets
                .iter()
                .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
                .collect();
            let mut pool = CorePool::with_options(
                pooled,
                PoolOptions {
                    route_chunk_ptrs: Some(route_chunk),
                    workers: Some(workers),
                    ..PoolOptions::default()
                },
            );
            for step in 0..15 {
                let inputs: Vec<Vec<u32>> =
                    (0..3).map(|c| if (step + c) % 2 == 0 { vec![0u32] } else { vec![] }).collect();
                for (c, e) in direct.iter_mut().enumerate() {
                    e.phase_update().unwrap();
                    e.phase_route(&inputs[c]).unwrap();
                }
                pool.phase_update().unwrap();
                pool.phase_route(&inputs).unwrap();
                for c in 0..3 {
                    let tag = format!("k={route_chunk} w={workers} core {c} step {step}");
                    assert_eq!(pool.core(c).v, direct[c].v, "membranes {tag}");
                    assert_eq!(pool.core(c).fired(), direct[c].fired(), "fired {tag}");
                    assert_eq!(
                        pool.core(c).counters(),
                        direct[c].counters(),
                        "access counters {tag}"
                    );
                    assert_eq!(pool.core(c).cycles, direct[c].cycles, "cycles {tag}");
                }
            }
        }
    }

    /// Core-granularity routing (the pre-chunking work unit) must stay
    /// available and bit-identical to the chunked default.
    #[test]
    fn route_granularity_core_matches_chunk() {
        let net = small_net(0xD0);
        let build = |route| {
            CorePool::with_options(
                vec![CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap()],
                PoolOptions { route, workers: Some(4), ..PoolOptions::default() },
            )
        };
        let mut per_core = build(RouteGranularity::Core);
        let mut chunked = build(RouteGranularity::Chunk);
        for step in 0..12 {
            let inputs = vec![if step % 3 == 0 { vec![0u32] } else { vec![] }];
            per_core.phase_update().unwrap();
            per_core.phase_route(&inputs).unwrap();
            chunked.phase_update().unwrap();
            chunked.phase_route(&inputs).unwrap();
            assert_eq!(per_core.core(0).v, chunked.core(0).v, "step {step}");
            assert_eq!(per_core.core(0).counters(), chunked.core(0).counters(), "step {step}");
        }
    }

    /// Satellite regression: a short `inputs` slice used to leave the
    /// previous step's deliveries in the tail cores' slots and replay
    /// them. Now every slot is cleared first and the arity mismatch is an
    /// error, never a silent replay.
    #[test]
    fn short_input_slice_errors_and_never_replays() {
        let nets: Vec<Network> = (0..2).map(small_net).collect();
        let mut direct: Vec<CoreEngine<RustBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let pooled: Vec<CoreEngine<RustBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let mut pool = CorePool::new(pooled);

        // step 1: both cores receive axon 0
        let full = vec![vec![0u32], vec![0u32]];
        pool.phase_update().unwrap();
        pool.phase_route(&full).unwrap();
        for (c, e) in direct.iter_mut().enumerate() {
            e.phase_update().unwrap();
            e.phase_route(&full[c]).unwrap();
        }

        // step 2: caller passes too few input vecs -> hard error
        pool.phase_update().unwrap();
        let err = pool.phase_route(&[vec![0u32]]).unwrap_err().to_string();
        assert!(err.contains("1 input vecs for 2 cores"), "{err}");

        // completing the step with correct arity and EMPTY inputs must
        // behave as empty — core 1 must not see step 1's [0] again
        pool.phase_route(&[vec![], vec![]]).unwrap();
        for e in direct.iter_mut() {
            e.phase_update().unwrap();
            e.phase_route(&[]).unwrap();
        }
        for c in 0..2 {
            assert_eq!(pool.core(c).v, direct[c].v, "stale inputs replayed into core {c}");
        }
    }

    /// Satellite regression: a panicking worker used to leave `pending`
    /// stuck and hang the driver (and `Drop`) on `done_cv` forever. The
    /// guard converts the panic into a phase error and keeps the worker
    /// alive for later generations.
    #[test]
    fn worker_panic_reports_error_and_pool_still_shuts_down() {
        #[derive(Clone, Copy, Debug)]
        struct PanickingBackend;
        impl UpdateBackend for PanickingBackend {
            fn update(
                &mut self,
                _v: &mut [i32],
                _params: &crate::engine::CoreParams,
                _step_seed: u32,
                _spikes: &mut [u64],
            ) -> anyhow::Result<()> {
                panic!("injected backend panic");
            }
            fn accumulate(&mut self, _v: &mut [i32], _e: &[(u32, i32)]) -> anyhow::Result<()> {
                Ok(())
            }
            fn name(&self) -> &'static str {
                "panicking"
            }
        }

        let nets: Vec<Network> = (0..2).map(small_net).collect();
        let engines: Vec<CoreEngine<PanickingBackend>> = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, PanickingBackend).unwrap())
            .collect();
        let mut pool = CorePool::new(engines);
        let err = pool.phase_update().unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("injected backend panic"), "{err}");
        // the pool survives: routing still runs, and a second failing
        // update still reports instead of hanging
        pool.phase_route(&[vec![], vec![]]).unwrap();
        let err = pool.phase_update().unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        drop(pool); // must not hang
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let nets: Vec<Network> = (0..2).map(small_net).collect();
        let engines = nets
            .iter()
            .map(|n| CoreEngine::new(n, SlotStrategy::Modulo, RustBackend).unwrap())
            .collect();
        let mut pool = CorePool::new(engines);
        pool.phase_update().unwrap();
        drop(pool); // must not hang
    }
}
