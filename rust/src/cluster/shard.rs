//! Multi-process sharded execution — [`crate::sim::Backend::Sharded`].
//!
//! The partitioned cluster engine ([`crate::cluster::MultiCoreEngine`])
//! runs every core in one address space. This module splits the same
//! partition across `--shards` **worker subprocesses** (`hiaer-spike
//! shard-worker`), each running its own [`CorePool`] over a contiguous
//! block of cores, with the parent process acting as the HiAER tree
//! router: per-step spikes travel as compact length-prefixed **binary
//! AER frames** over the children's stdin/stdout pipes. Every worker
//! maps the shared `.hsn` v2 file read-only ([`crate::model_fmt::NetFile`]),
//! so N shards share one physical copy of the network via the page
//! cache.
//!
//! # Determinism contract
//!
//! A sharded run is **bit-identical** to the single-process cluster
//! (`Backend::Rust` on the same multi-core topology) — spikes,
//! membranes and the [`CostSummary`]. Three ingredients:
//!
//! * every process (parent and all workers) recomputes the *same*
//!   [`Partition`] and [`split_network`] from the same file + flags, so
//!   core membership, local ids, remote-axon numbering and per-core
//!   noise seeds (`base_seed + core`) agree everywhere;
//! * the parent merges per-core fired lists in **core index order** and
//!   runs the one [`HiaerRouter`] exactly as the in-process cluster
//!   does, so delivery lists (sorted, deduped local axons) are
//!   identical;
//! * cost is shipped as raw per-core [`AccessCounters`] + cycles and
//!   folded through [`EnergyModel::cost`] in core index order, so the
//!   floating-point energy sum associates identically.
//!
//! `rust/tests/sim_facade.rs` pins the parity matrix across shard
//! counts {1, 2, 4} × worker counts.
//!
//! # AER frame wire format
//!
//! Every frame is `u32 len (LE) | u8 kind | payload`, where `len`
//! counts the kind byte plus the payload. All integers little-endian.
//!
//! Parent → shard:
//!
//! | kind | name       | payload                                              |
//! |------|------------|------------------------------------------------------|
//! | 0x01 | UPDATE     | `u64 epoch` — run the membrane sweep                 |
//! | 0x02 | DELIVER    | `u64 epoch, u32 n_blocks, n×{u32 core, u32 n, n×u32 local_axon}` — route phase inputs (sorted); fire-and-forget |
//! | 0x03 | READ_MEM   | `u32 n, n×{u32 core, u32 local}` — membrane probe    |
//! | 0x04 | RESET      | empty                                                |
//! | 0x05 | RESET_COST | empty                                                |
//! | 0x06 | COST       | empty                                                |
//! | 0x07 | SHUTDOWN   | empty — exit the frame loop                          |
//! | 0x08 | EDIT       | `u8 op (0 write, 1 add, 2 remove, 3 read), u8 pre_is_axon, u32 core, u32 local_pre, u32 local_post, i32 weight` — live synapse edit on one core |
//!
//! Shard → parent:
//!
//! | kind | name  | payload                                                   |
//! |------|-------|-----------------------------------------------------------|
//! | 0x80 | READY | `u32 shard, u32 n_cores` — engines built, pool warm       |
//! | 0x81 | FIRED | `u64 epoch, u32 n_blocks, n×{u32 core, u32 n, n×u32 local_fired}` (ascending) |
//! | 0x83 | MEMB  | `u32 n, n×i32` — membrane values in request order         |
//! | 0x84 | ACK   | `u8 kind` — echoes RESET / RESET_COST                     |
//! | 0x86 | COSTR | `u32 n_blocks, n×{u32 core, 5×u64 counters, u64 cycles}` (ascending core order) |
//! | 0x87 | EDITR | `u8 status` then: 0 (ok) `i32 value` — write 1/0 matched, add 1 created / 0 re-weighted, remove slot count, read the weight; 2 (absent, read only) empty; 1 (edit failed) UTF-8 message — the shard stays alive |
//! | 0xEE | ERR   | UTF-8 message — the shard is failing; parent surfaces it  |
//!
//! # Tree topology and the step loop
//!
//! The routing hierarchy is the paper's HiAER tree (level 0 on-core,
//! 1 NoC, 2 FireFly, 3 Ethernet — see [`crate::router`]); shards take
//! contiguous core ranges, so a core's NoC neighbours stay in-process
//! and only upper-tree traffic crosses the pipes. Per step the parent:
//!
//! 1. broadcasts `UPDATE` — all shards sweep membranes concurrently;
//! 2. collects `FIRED` (epoch-checked) and merges in core order;
//! 3. runs [`HiaerRouter::route_step`] with the merged fired lists +
//!    host axon inputs;
//! 4. broadcasts `DELIVER` **without awaiting a reply** — shards run
//!    their route phase while the parent already returns to the caller
//!    (pipe FIFO ordering keeps any later `READ_MEM`/`COST` behind the
//!    route phase; a route-phase failure therefore surfaces on the
//!    *next* frame exchange).
//!
//! # Fault model
//!
//! Every awaited frame has a deadline (`SimOptions::shard_timeout_ms`,
//! default 30 s): a killed or hung shard yields a typed
//! [`SimError::Engine`] naming the shard id, never a hang. One reader
//! thread per child drains its stdout into a channel, so workers can
//! never block on a full pipe. [`ShardedSim`]'s `Drop` reaps the
//! children: best-effort `SHUTDOWN`, stdin EOF, a bounded `try_wait`
//! poll, then `SIGKILL`. `rust/tests/shard_faults.rs` injects the
//! failures.

use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::cluster::pool::{CorePool, PoolOptions, RouteGranularity};
use crate::energy::{CostReport, EnergyModel};
use crate::engine::{CoreEngine, RustBackend};
use crate::hbm::{AccessCounters, SlotStrategy};
use crate::model_fmt::write_hsn;
use crate::partition::{ClusterTopology, CoreCapacity, Partition};
use crate::router::{split_network, FabricModel, HiaerRouter};
use crate::sim::frames::{
    put_i32, put_u32, put_u64, read_frame, write_frame, Payload, MAX_FRAME_BYTES,
};
use crate::sim::{
    check_axons, CostSummary, NetSource, SimError, SimOptions, Simulator, StepResult,
};
use crate::util::cli::Args;

// ---- frame kinds ----------------------------------------------------------
//
// The `len | kind | payload` codec itself lives in [`crate::sim::frames`]
// (shared with the session protocol's binary wire since PR 10); only the
// shard-pipe kind space is defined here.

/// Parent → shard frame kinds.
pub(crate) const K_UPDATE: u8 = 0x01;
pub(crate) const K_DELIVER: u8 = 0x02;
pub(crate) const K_READ_MEM: u8 = 0x03;
pub(crate) const K_RESET: u8 = 0x04;
pub(crate) const K_RESET_COST: u8 = 0x05;
pub(crate) const K_COST: u8 = 0x06;
pub(crate) const K_SHUTDOWN: u8 = 0x07;
pub(crate) const K_EDIT: u8 = 0x08;

/// EDIT-frame op codes.
pub(crate) const EDIT_WRITE: u8 = 0;
pub(crate) const EDIT_ADD: u8 = 1;
pub(crate) const EDIT_REMOVE: u8 = 2;
pub(crate) const EDIT_READ: u8 = 3;

/// Shard → parent frame kinds.
pub(crate) const K_READY: u8 = 0x80;
pub(crate) const K_FIRED: u8 = 0x81;
pub(crate) const K_MEMB: u8 = 0x83;
pub(crate) const K_ACK: u8 = 0x84;
pub(crate) const K_COSTR: u8 = 0x86;
pub(crate) const K_EDITR: u8 = 0x87;
pub(crate) const K_ERR: u8 = 0xEE;

fn kind_name(kind: u8) -> &'static str {
    match kind {
        K_UPDATE => "UPDATE",
        K_DELIVER => "DELIVER",
        K_READ_MEM => "READ_MEM",
        K_RESET => "RESET",
        K_RESET_COST => "RESET_COST",
        K_COST => "COST",
        K_SHUTDOWN => "SHUTDOWN",
        K_EDIT => "EDIT",
        K_READY => "READY",
        K_FIRED => "FIRED",
        K_MEMB => "MEMB",
        K_ACK => "ACK",
        K_COSTR => "COSTR",
        K_EDITR => "EDITR",
        K_ERR => "ERR",
        _ => "?",
    }
}

// ---- shard geometry -------------------------------------------------------

/// Contiguous core range of shard `s` out of `shards`: `n_cores` split
/// into near-equal blocks, the first `n_cores % shards` one core
/// larger. Contiguity keeps NoC-level neighbours in one process.
pub(crate) fn shard_core_range(n_cores: usize, shards: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < shards && shards <= n_cores.max(1));
    let base = n_cores / shards;
    let rem = n_cores % shards;
    let lo = s * base + s.min(rem);
    let hi = lo + base + usize::from(s < rem);
    (lo, hi)
}

/// Inverse of [`shard_core_range`]: which shard owns `core`.
fn shard_of_core(n_cores: usize, shards: usize, core: usize) -> usize {
    for s in 0..shards {
        let (lo, hi) = shard_core_range(n_cores, shards, s);
        if core >= lo && core < hi {
            return s;
        }
    }
    unreachable!("core {core} outside every shard range ({n_cores} cores, {shards} shards)")
}

// local strategy/route name maps: the `sim::config` parsers are private
// to the facade module, and the worker needs the reverse direction too.
fn strategy_name(s: SlotStrategy) -> &'static str {
    match s {
        SlotStrategy::Modulo => "modulo",
        SlotStrategy::BalanceFanIn => "balance",
    }
}

fn strategy_from_name(s: &str) -> anyhow::Result<SlotStrategy> {
    match s {
        "modulo" => Ok(SlotStrategy::Modulo),
        "balance" => Ok(SlotStrategy::BalanceFanIn),
        other => bail!("shard-worker: unknown --strategy {other:?}"),
    }
}

fn route_name(r: RouteGranularity) -> &'static str {
    match r {
        RouteGranularity::Core => "core",
        RouteGranularity::Chunk => "chunk",
    }
}

fn route_from_name(s: &str) -> anyhow::Result<RouteGranularity> {
    match s {
        "core" => Ok(RouteGranularity::Core),
        "chunk" => Ok(RouteGranularity::Chunk),
        other => bail!("shard-worker: unknown --route {other:?}"),
    }
}

// ---- parent side ----------------------------------------------------------

/// Default per-frame deadline when `SimOptions::shard_timeout_ms` is
/// unset.
const DEFAULT_FRAME_TIMEOUT: Duration = Duration::from_secs(30);

/// READY can legitimately take much longer than a step frame (the
/// worker maps the net, partitions, splits and compiles every HBM
/// image first), so the build deadline is at least this.
const MIN_READY_TIMEOUT: Duration = Duration::from_secs(600);

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Guard for a temp-exported `.hsn` handed to the workers (owned
/// in-memory nets have no path of their own); deletes the file on drop.
struct TempNet {
    path: PathBuf,
}

impl Drop for TempNet {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}

/// Resolve the `hiaer-spike` binary to spawn as `shard-worker`:
/// explicit option, `$HS_BIN`, the running executable itself (when it
/// *is* the CLI), then `hiaer-spike` next to it / one dir up (covers
/// `target/{debug,release}/deps/<test-bin>`).
fn resolve_shard_bin(opts: &SimOptions) -> Result<PathBuf, SimError> {
    if let Some(bin) = &opts.shard_bin {
        return Ok(bin.clone());
    }
    if let Ok(env_bin) = std::env::var("HS_BIN") {
        if !env_bin.is_empty() {
            return Ok(PathBuf::from(env_bin));
        }
    }
    if let Ok(exe) = std::env::current_exe() {
        if exe.file_stem().map(|s| s == "hiaer-spike").unwrap_or(false) {
            return Ok(exe);
        }
        for dir in [exe.parent(), exe.parent().and_then(Path::parent)].into_iter().flatten() {
            let cand = dir.join("hiaer-spike");
            if cand.is_file() {
                return Ok(cand);
            }
        }
    }
    Err(SimError::Config(
        "cannot locate the `hiaer-spike` binary for shard workers; set $HS_BIN or \
         SimConfig::shard_bin"
            .into(),
    ))
}

fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One live worker subprocess: its pipes, the reader thread draining
/// its stdout into `rx`, and the reaping logic.
struct ShardLink {
    shard: usize,
    child: Child,
    stdin: Option<std::process::ChildStdin>,
    rx: mpsc::Receiver<io::Result<(u8, Vec<u8>)>>,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl ShardLink {
    fn spawn(bin: &Path, shard: usize, worker_args: &[String]) -> Result<ShardLink, SimError> {
        let mut child = Command::new(bin)
            .arg("shard-worker")
            .args(worker_args)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| {
                SimError::Engine(anyhow!("spawning shard {shard} ({}): {e}", bin.display()))
            })?;
        let stdin = child.stdin.take();
        let stdout = child.stdout.take().expect("piped stdout");
        let (tx, rx) = mpsc::channel();
        // One reader per child: drains stdout continuously so the worker
        // can never block writing a large FIRED frame, and converts EOF /
        // IO errors into channel disconnection the parent can type.
        let reader = std::thread::Builder::new()
            .name(format!("hiaer-shard-rx-{shard}"))
            .spawn(move || {
                let mut r = io::BufReader::new(stdout);
                loop {
                    match read_frame(&mut r) {
                        Ok(Some(frame)) => {
                            if tx.send(Ok(frame)).is_err() {
                                break; // parent gone
                            }
                        }
                        Ok(None) => break, // clean EOF
                        Err(e) => {
                            tx.send(Err(e)).ok();
                            break;
                        }
                    }
                }
            })
            .expect("spawn shard reader thread");
        Ok(ShardLink { shard, child, stdin, rx, reader: Some(reader) })
    }

    fn send(&mut self, kind: u8, payload: &[u8]) -> Result<(), SimError> {
        let shard = self.shard;
        let w = self.stdin.as_mut().ok_or_else(|| {
            SimError::Engine(anyhow!("shard {shard}: stdin already closed"))
        })?;
        write_frame(w, kind, payload)
            .and_then(|_| w.flush())
            .map_err(|e| SimError::Engine(anyhow!("shard {shard}: writing {} frame: {e}", kind_name(kind))))
    }

    /// Await the next frame with a deadline. ERR frames and dead/hung
    /// shards become typed engine errors naming the shard.
    fn recv(&mut self, want: u8, timeout: Duration) -> Result<Vec<u8>, SimError> {
        let shard = self.shard;
        match self.rx.recv_timeout(timeout) {
            Ok(Ok((kind, payload))) if kind == want => Ok(payload),
            Ok(Ok((kind, payload))) if kind == K_ERR => {
                let msg = String::from_utf8_lossy(&payload).into_owned();
                Err(SimError::Engine(anyhow!("shard {shard} failed: {msg}")))
            }
            Ok(Ok((kind, _))) => Err(SimError::Engine(anyhow!(
                "shard {shard}: protocol error — expected {} frame, got {} (0x{kind:02x})",
                kind_name(want),
                kind_name(kind),
            ))),
            Ok(Err(e)) => {
                Err(SimError::Engine(anyhow!("shard {shard}: pipe read failed: {e}")))
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Err(SimError::Engine(anyhow!(
                "shard {shard}: no {} frame within {timeout:?} (worker hung or overloaded)",
                kind_name(want),
            ))),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let status = self
                    .child
                    .try_wait()
                    .ok()
                    .flatten()
                    .map(|s| format!(" (exit status: {s})"))
                    .unwrap_or_default();
                Err(SimError::Engine(anyhow!(
                    "shard {shard}: worker process died mid-session{status}"
                )))
            }
        }
    }
}

impl Drop for ShardLink {
    fn drop(&mut self) {
        // best-effort orderly shutdown: SHUTDOWN frame, then stdin EOF
        if let Some(mut w) = self.stdin.take() {
            let _ = write_frame(&mut w, K_SHUTDOWN, &[]).and_then(|_| w.flush());
            drop(w);
        }
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                _ => {
                    self.child.kill().ok();
                    self.child.wait().ok();
                    break;
                }
            }
        }
        // child dead => its stdout is EOF => the reader thread exits
        if let Some(h) = self.reader.take() {
            h.join().ok();
        }
    }
}

/// The sharded cluster as a [`Simulator`]: parent-side router plus the
/// worker links. See the module docs for the protocol and contracts.
pub struct ShardedSim {
    partition: Partition,
    router: HiaerRouter,
    /// Worker links behind a mutex so the `&self` trait surface
    /// (`cost`, `read_membrane`) can exchange frames. Declared before
    /// `temp_net` so children are reaped before the file is deleted.
    links: Mutex<Vec<ShardLink>>,
    shards: usize,
    n_axons: usize,
    is_output: Vec<bool>,
    /// live-edit addressing (same maps as the in-process cluster)
    axon_local: Vec<Vec<u32>>,
    remote_axon: Vec<std::collections::HashMap<u32, u32>>,
    fired_by_core: Vec<Vec<u32>>,
    fired_global: Vec<u32>,
    out_global: Vec<u32>,
    epoch: u64,
    timeout: Duration,
    _temp_net: Option<TempNet>,
}

impl ShardedSim {
    /// Build the sharded session. Hidden from docs: external callers go
    /// through [`crate::sim::SimConfig::build`]; integration tests use
    /// this to reach [`ShardedSim::shard_pids`].
    #[doc(hidden)]
    pub fn build(src: NetSource, opts: &SimOptions) -> Result<ShardedSim, SimError> {
        let n_cores = opts.topology.n_cores();
        let shards = match opts.shards {
            Some(0) => {
                return Err(SimError::Config(
                    "shards must be >= 1 (every shard runs at least one core)".into(),
                ))
            }
            Some(n) => n,
            None => n_cores.min(2).max(1),
        };
        if shards > n_cores {
            return Err(SimError::Config(format!(
                "shards ({shards}) exceeds the topology's core count ({n_cores}); \
                 each shard needs at least one core"
            )));
        }
        let bin = resolve_shard_bin(opts)?;

        // Hand every worker a mappable path. Mapped sources already have
        // one; owned nets (and pathless mapped handles) are exported to
        // a temp `.hsn` v2 that lives as long as the session.
        let (net_path, temp_net) = match &src {
            NetSource::Mapped(file) if file.path().is_some() => {
                (file.path().unwrap().to_path_buf(), None)
            }
            _ => {
                let path = std::env::temp_dir().join(format!(
                    "hiaer_shard_{}_{}.hsn",
                    std::process::id(),
                    TEMP_COUNTER.fetch_add(1, Ordering::Relaxed),
                ));
                write_hsn(src.view(), &path)
                    .map_err(|e| SimError::Engine(anyhow!("exporting net for shards: {e}")))?;
                (path.clone(), Some(TempNet { path }))
            }
        };

        // The parent recomputes the partition + split for the routing
        // table (the subnets themselves live only in the workers).
        let mut view = src.view();
        if let Some(seed) = opts.seed {
            view.base_seed = seed;
        }
        let partition = Partition::compute(view, opts.topology, opts.capacity)
            .map_err(|e| SimError::Engine(anyhow!(e)))?;
        let split = split_network(view, &partition);
        let router = HiaerRouter::new(opts.topology, FabricModel::default(), split.table);
        drop(split.subnets);
        let (axon_local, remote_axon) = (split.axon_local, split.remote_axon);
        let n_axons = view.n_axons();
        let mut is_output = vec![false; view.n_neurons()];
        for &o in view.outputs {
            is_output[o as usize] = true;
        }

        let mut worker_args: Vec<String> = vec![
            "--net".into(),
            net_path.display().to_string(),
            "--shards".into(),
            shards.to_string(),
            "--servers".into(),
            opts.topology.servers.to_string(),
            "--fpgas".into(),
            opts.topology.fpgas_per_server.to_string(),
            "--cores".into(),
            opts.topology.cores_per_fpga.to_string(),
            "--strategy".into(),
            strategy_name(opts.strategy).into(),
            "--route".into(),
            route_name(opts.route).into(),
            "--cap-neurons".into(),
            opts.capacity.max_neurons.to_string(),
            "--cap-synapses".into(),
            opts.capacity.max_synapses.to_string(),
        ];
        if let Some(seed) = opts.seed {
            worker_args.extend(["--seed".into(), seed.to_string()]);
        }
        if let Some(w) = opts.workers {
            worker_args.extend(["--workers".into(), w.to_string()]);
        }
        if let Some(cw) = opts.chunk_words {
            worker_args.extend(["--chunk-words".into(), cw.to_string()]);
        }
        if let Some(rp) = opts.route_chunk_ptrs {
            worker_args.extend(["--route-chunk-ptrs".into(), rp.to_string()]);
        }
        if let Some(cfg) = opts.learning {
            // every worker enables the same STDP config on its cores,
            // so a sharded learning run stays bit-identical to the
            // in-process cluster (weight updates are purely core-local)
            worker_args.extend([
                "--learn".into(),
                format!("{},{},{},{}", cfg.a_plus, cfg.a_minus, cfg.tau_pre, cfg.tau_post),
                "--learn-clamp".into(),
                format!("{},{}", cfg.w_min, cfg.w_max),
            ]);
        }

        let timeout = opts
            .shard_timeout_ms
            .map(Duration::from_millis)
            .unwrap_or(DEFAULT_FRAME_TIMEOUT);
        let mut links = Vec::with_capacity(shards);
        for s in 0..shards {
            let mut args = worker_args.clone();
            args.extend(["--shard".into(), s.to_string()]);
            links.push(ShardLink::spawn(&bin, s, &args)?);
        }
        // Await READY from every worker (build can dwarf a step frame).
        let ready_timeout = timeout.max(MIN_READY_TIMEOUT);
        for (s, link) in links.iter_mut().enumerate() {
            let payload = link.recv(K_READY, ready_timeout)?;
            let mut p = Payload::new(&payload);
            let got_shard = (|| -> anyhow::Result<(u32, u32)> {
                let a = p.u32()?;
                let b = p.u32()?;
                p.done()?;
                Ok((a, b))
            })()
            .map_err(|e| SimError::Engine(anyhow!("shard {s}: bad READY frame: {e}")))?;
            let (lo, hi) = shard_core_range(n_cores, shards, s);
            if got_shard != (s as u32, (hi - lo) as u32) {
                return Err(SimError::Engine(anyhow!(
                    "shard {s}: READY mismatch — got shard {} with {} cores, expected \
                     shard {s} with {} cores (binary/flag skew?)",
                    got_shard.0,
                    got_shard.1,
                    hi - lo,
                )));
            }
        }

        Ok(ShardedSim {
            fired_by_core: vec![Vec::new(); n_cores],
            partition,
            router,
            links: Mutex::new(links),
            shards,
            n_axons,
            is_output,
            axon_local,
            remote_axon,
            fired_global: Vec::new(),
            out_global: Vec::new(),
            epoch: 0,
            timeout,
            _temp_net: temp_net,
        })
    }

    /// Worker subprocess pids, in shard order (fault-injection tests).
    #[doc(hidden)]
    pub fn shard_pids(&self) -> Vec<u32> {
        plock(&self.links).iter().map(|l| l.child.id()).collect()
    }

    /// Shard count behind this session.
    pub fn n_shards(&self) -> usize {
        self.shards
    }

    /// Resolve a global (pre, post) synapse address to the post
    /// neuron's core + that core's local source id (see
    /// `MultiCoreEngine::resolve_edit` — same maps, same semantics).
    /// `Ok(None)` = the source has no presence on post's core.
    fn resolve_edit(
        &self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> Result<Option<(usize, bool, u32, u32)>, SimError> {
        let n = self.partition.core_of.len() as u32;
        if post >= n {
            return Err(SimError::Config(format!(
                "post neuron id {post} out of range ({n} global neurons)"
            )));
        }
        let c = self.partition.core_of[post as usize] as usize;
        let lpost = self.partition.local_of[post as usize];
        if pre_is_axon {
            if pre as usize >= self.n_axons {
                return Err(SimError::Config(format!(
                    "axon id {pre} out of range ({} global axons)",
                    self.n_axons
                )));
            }
            match self.axon_local[c][pre as usize] {
                u32::MAX => Ok(None),
                la => Ok(Some((c, true, la, lpost))),
            }
        } else {
            if pre >= n {
                return Err(SimError::Config(format!(
                    "pre neuron id {pre} out of range ({n} global neurons)"
                )));
            }
            if self.partition.core_of[pre as usize] as usize == c {
                Ok(Some((c, false, self.partition.local_of[pre as usize], lpost)))
            } else {
                Ok(self.remote_axon[c].get(&pre).map(|&la| (c, true, la, lpost)))
            }
        }
    }

    /// One EDIT/EDITR frame exchange with the shard owning `core`.
    /// `Ok(None)` = absent (read op); edit failures (e.g. a full HBM
    /// row) come back as [`SimError::Config`] without killing the shard.
    fn edit_frame(
        &self,
        op: u8,
        pre_is_axon: bool,
        core: usize,
        lpre: u32,
        lpost: u32,
        weight: i16,
    ) -> Result<Option<i32>, SimError> {
        let n_cores = self.partition.topology.n_cores();
        let s = shard_of_core(n_cores, self.shards, core);
        let mut payload = Vec::with_capacity(18);
        payload.push(op);
        payload.push(pre_is_axon as u8);
        put_u32(&mut payload, core as u32);
        put_u32(&mut payload, lpre);
        put_u32(&mut payload, lpost);
        put_i32(&mut payload, weight as i32);
        let mut links = plock(&self.links);
        let link = &mut links[s];
        link.send(K_EDIT, &payload)?;
        let reply = link.recv(K_EDITR, self.timeout)?;
        drop(links);
        let mut p = Payload::new(&reply);
        let status = p
            .u8()
            .map_err(|e| SimError::Engine(anyhow!("shard {s}: bad EDITR frame: {e}")))?;
        match status {
            0 => {
                let v = p
                    .i32()
                    .and_then(|v| p.done().map(|_| v))
                    .map_err(|e| SimError::Engine(anyhow!("shard {s}: bad EDITR frame: {e}")))?;
                Ok(Some(v))
            }
            2 => Ok(None),
            1 => {
                let msg = String::from_utf8_lossy(p.buf.get(p.pos..).unwrap_or(&[])).into_owned();
                Err(SimError::Config(msg))
            }
            other => Err(SimError::Engine(anyhow!(
                "shard {s}: bad EDITR status {other}"
            ))),
        }
    }

    fn step_inner(&mut self, axon_in: &[u32]) -> Result<(), SimError> {
        self.epoch += 1;
        let epoch = self.epoch;
        let n_cores = self.partition.topology.n_cores();
        let mut links = plock(&self.links);

        // phase A: broadcast UPDATE — every shard sweeps concurrently
        let mut update = Vec::with_capacity(8);
        put_u64(&mut update, epoch);
        for link in links.iter_mut() {
            link.send(K_UPDATE, &update)?;
        }

        // collect FIRED; merge per-core lists in core index order
        for buf in &mut self.fired_by_core {
            buf.clear();
        }
        for link in links.iter_mut() {
            let shard = link.shard;
            let payload = link.recv(K_FIRED, self.timeout)?;
            let mut p = Payload::new(&payload);
            (|| -> anyhow::Result<()> {
                let got_epoch = p.u64()?;
                if got_epoch != epoch {
                    bail!("FIRED epoch {got_epoch}, expected {epoch} (desynchronised)");
                }
                let (lo, hi) = shard_core_range(n_cores, self.shards, shard);
                let n_blocks = p.u32()? as usize;
                for _ in 0..n_blocks {
                    let core = p.u32()? as usize;
                    if core < lo || core >= hi {
                        bail!("FIRED block for core {core} outside shard range {lo}..{hi}");
                    }
                    let n = p.u32()? as usize;
                    let bytes = p.take(n * 4)?;
                    let g = &self.partition.members[core];
                    let buf = &mut self.fired_by_core[core];
                    for c in bytes.chunks_exact(4) {
                        let local = u32::from_le_bytes(c.try_into().unwrap()) as usize;
                        let global = *g
                            .get(local)
                            .ok_or_else(|| anyhow!("fired local id {local} out of range on core {core}"))?;
                        buf.push(global);
                    }
                }
                p.done()
            })()
            .map_err(|e| SimError::Engine(anyhow!("shard {shard}: bad FIRED frame: {e}")))?;
        }
        self.fired_global.clear();
        for buf in &self.fired_by_core {
            self.fired_global.extend_from_slice(buf);
        }
        self.fired_global.sort_unstable();

        // barrier: the parent-side HiAER multicast (identical inputs to
        // the in-process cluster => identical sorted delivery lists)
        let pending = self.router.route_step(&self.fired_by_core, axon_in);

        // phase B: DELIVER fire-and-forget — shards route while we return
        for link in links.iter_mut() {
            let shard = link.shard;
            let (lo, hi) = shard_core_range(n_cores, self.shards, shard);
            let mut payload = Vec::new();
            put_u64(&mut payload, epoch);
            let n_blocks = pending[lo..hi].iter().filter(|p| !p.is_empty()).count();
            put_u32(&mut payload, n_blocks as u32);
            for (c, axons) in pending[lo..hi].iter().enumerate() {
                if axons.is_empty() {
                    continue;
                }
                put_u32(&mut payload, (lo + c) as u32);
                put_u32(&mut payload, axons.len() as u32);
                for &a in axons {
                    put_u32(&mut payload, a);
                }
            }
            link.send(K_DELIVER, &payload)?;
        }

        // outputs: out_buf is the fired-set filtered per core, so the
        // global concat+sort equals filtering the merged fired list
        self.out_global.clear();
        self.out_global
            .extend(self.fired_global.iter().copied().filter(|&g| self.is_output[g as usize]));
        Ok(())
    }
}

impl Simulator for ShardedSim {
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        check_axons(axon_in, self.n_axons)?;
        self.step_inner(axon_in)?;
        Ok(StepResult { fired: &self.fired_global, output_spikes: &self.out_global })
    }

    fn fired(&self) -> &[u32] {
        &self.fired_global
    }

    fn output_spikes(&self) -> &[u32] {
        &self.out_global
    }

    fn reset(&mut self) {
        let mut links = plock(&self.links);
        for link in links.iter_mut() {
            // &mut self but no Result surface: a dead shard will surface
            // a typed error on the next step's frame exchange
            if link.send(K_RESET, &[]).is_ok() {
                link.recv(K_ACK, self.timeout).ok();
            }
        }
        drop(links);
        self.router.reset_stats();
        self.fired_global.clear();
        self.out_global.clear();
        for buf in &mut self.fired_by_core {
            buf.clear();
        }
    }

    fn reset_cost(&mut self) {
        let mut links = plock(&self.links);
        for link in links.iter_mut() {
            if link.send(K_RESET_COST, &[]).is_ok() {
                link.recv(K_ACK, self.timeout).ok();
            }
        }
        drop(links);
        self.router.reset_stats();
    }

    fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => Ok(self
                .edit_frame(EDIT_WRITE, ax, c, lpre, lpost, weight)?
                .is_some_and(|v| v != 0)),
            None => Ok(false),
        }
    }

    fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Result<Option<i16>, SimError> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => Ok(self
                .edit_frame(EDIT_READ, ax, c, lpre, lpost, 0)?
                .map(|v| v as i16)),
            None => Ok(None),
        }
    }

    fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => Ok(self
                .edit_frame(EDIT_ADD, ax, c, lpre, lpost, weight)?
                .is_some_and(|v| v != 0)),
            None => Err(SimError::Config(format!(
                "source {} {pre} has no presence on neuron {post}'s core: adding this \
                 synapse needs a new HiAER route — journal compaction required",
                if pre_is_axon { "axon" } else { "neuron" },
            ))),
        }
    }

    fn remove_synapse(&mut self, pre_is_axon: bool, pre: u32, post: u32) -> Result<usize, SimError> {
        match self.resolve_edit(pre_is_axon, pre, post)? {
            Some((c, ax, lpre, lpost)) => Ok(self
                .edit_frame(EDIT_REMOVE, ax, c, lpre, lpost, 0)?
                .map_or(0, |v| v.max(0) as usize)),
            None => Ok(0),
        }
    }

    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        // group the probe by owning shard, preserving result order
        let n_cores = self.partition.topology.n_cores();
        let mut per_shard: Vec<Vec<u8>> = vec![Vec::new(); self.shards];
        let mut counts: Vec<u32> = vec![0; self.shards];
        let mut slot: Vec<(usize, u32)> = Vec::with_capacity(ids.len());
        for &g in ids {
            let core = self.partition.core_of[g as usize] as usize;
            let local = self.partition.local_of[g as usize];
            let s = shard_of_core(n_cores, self.shards, core);
            slot.push((s, counts[s]));
            counts[s] += 1;
            put_u32(&mut per_shard[s], core as u32);
            put_u32(&mut per_shard[s], local);
        }
        let mut replies: Vec<Vec<i32>> = Vec::with_capacity(self.shards);
        let mut links = plock(&self.links);
        for (s, link) in links.iter_mut().enumerate() {
            if counts[s] == 0 {
                replies.push(Vec::new());
                continue;
            }
            let mut payload = Vec::with_capacity(4 + per_shard[s].len());
            put_u32(&mut payload, counts[s]);
            payload.extend_from_slice(&per_shard[s]);
            // the trait surface has no Result here; failure is a contract
            // violation the fault tests catch on `step` instead
            link.send(K_MEMB_REQ, &payload)
                .and_then(|_| link.recv(K_MEMB, self.timeout))
                .map(|reply| {
                    let mut p = Payload::new(&reply);
                    let mut vals = Vec::new();
                    if let Ok(n) = p.u32() {
                        for _ in 0..n {
                            match p.i32() {
                                Ok(v) => vals.push(v),
                                Err(_) => break,
                            }
                        }
                    }
                    replies.push(vals);
                })
                .unwrap_or_else(|e| panic!("shard {s}: membrane read failed: {e}"));
        }
        drop(links);
        slot.iter()
            .map(|&(s, i)| replies[s].get(i as usize).copied().unwrap_or_else(|| {
                panic!("shard {s}: short MEMB reply ({} values)", replies[s].len())
            }))
            .collect()
    }

    fn cost(&self, model: &EnergyModel) -> CostSummary {
        // fold per-core reports in core index order — bit-identical f64
        // association with the in-process cluster
        let n_cores = self.partition.topology.n_cores();
        let mut energy = 0.0f64;
        let mut max_cycles = 0u64;
        let mut rows = 0u64;
        let mut events = 0u64;
        let mut links = plock(&self.links);
        for link in links.iter_mut() {
            let shard = link.shard;
            let reply = link
                .send(K_COST, &[])
                .and_then(|_| link.recv(K_COSTR, self.timeout))
                .unwrap_or_else(|e| panic!("shard {shard}: cost read failed: {e}"));
            let mut p = Payload::new(&reply);
            let parse = (|| -> anyhow::Result<()> {
                let (lo, hi) = shard_core_range(n_cores, self.shards, shard);
                let n_blocks = p.u32()? as usize;
                if n_blocks != hi - lo {
                    bail!("COSTR has {n_blocks} blocks, expected {}", hi - lo);
                }
                let mut expect_core = lo as u32;
                for _ in 0..n_blocks {
                    let core = p.u32()?;
                    if core != expect_core {
                        bail!("COSTR block for core {core}, expected {expect_core}");
                    }
                    expect_core += 1;
                    let counters = AccessCounters {
                        pointer_rows: p.u64()?,
                        synapse_rows: p.u64()?,
                        events: p.u64()?,
                        uram_accesses: p.u64()?,
                        bram_accesses: p.u64()?,
                    };
                    let cycles = p.u64()?;
                    let r: CostReport = model.cost(&counters, cycles);
                    energy += r.energy_uj;
                    max_cycles = max_cycles.max(r.cycles);
                    rows += r.hbm_rows;
                    events += counters.events;
                }
                p.done()
            })();
            if let Err(e) = parse {
                panic!("shard {shard}: bad COSTR frame: {e}");
            }
        }
        drop(links);
        let total_cycles = max_cycles + self.router.stats.cycles;
        CostSummary {
            energy_uj: energy,
            latency_us: total_cycles as f64 / model.clk_hz * 1e6,
            hbm_rows: rows,
            events,
            cycles: total_cycles,
            router: Some(self.router.stats),
        }
    }

    fn backend_name(&self) -> &'static str {
        "sharded"
    }

    fn n_neurons(&self) -> usize {
        self.partition.core_of.len()
    }

    fn n_axons(&self) -> usize {
        self.n_axons
    }

    fn n_cores(&self) -> usize {
        self.partition.topology.n_cores()
    }

    fn placement(&self) -> Option<&Partition> {
        Some(&self.partition)
    }
}

/// READ_MEM under its protocol name (the parent-side alias keeps the
/// send-site readable).
const K_MEMB_REQ: u8 = K_READ_MEM;

// ---- worker side ----------------------------------------------------------

/// Entry point of the `hiaer-spike shard-worker` subcommand: configure
/// this shard's core block from `--net`, then serve binary AER frames
/// on stdin/stdout until SHUTDOWN / EOF. All logging goes to stderr —
/// stdout carries frames only.
pub fn shard_worker_main(args: &Args) -> anyhow::Result<()> {
    let result = shard_worker_run(args);
    if let Err(e) = &result {
        // best-effort typed error to the parent before exiting nonzero
        let stdout = io::stdout();
        let mut w = stdout.lock();
        let msg = format!("{e:#}");
        write_frame(&mut w, K_ERR, msg.as_bytes()).and_then(|_| w.flush()).ok();
    }
    result
}

fn shard_worker_run(args: &Args) -> anyhow::Result<()> {
    let net_path = args.get("net").context("shard-worker: missing --net")?;
    let shard = args.get_usize("shard", 0).map_err(anyhow::Error::msg)?;
    let shards = args.get_usize("shards", 1).map_err(anyhow::Error::msg)?;
    let topology = ClusterTopology {
        servers: args.get_usize("servers", 1).map_err(anyhow::Error::msg)?,
        fpgas_per_server: args.get_usize("fpgas", 1).map_err(anyhow::Error::msg)?,
        cores_per_fpga: args.get_usize("cores", 1).map_err(anyhow::Error::msg)?,
    };
    let default_cap = CoreCapacity::default();
    let cap = CoreCapacity {
        max_neurons: args
            .get_usize("cap-neurons", default_cap.max_neurons)
            .map_err(anyhow::Error::msg)?,
        max_synapses: args
            .get_usize("cap-synapses", default_cap.max_synapses)
            .map_err(anyhow::Error::msg)?,
    };
    let strategy = strategy_from_name(args.get_or("strategy", "balance"))?;
    let route = route_from_name(args.get_or("route", "chunk"))?;
    let pool_opts = PoolOptions {
        chunk_words: match args.get("chunk-words") {
            None => None,
            Some(_) => Some(args.get_usize("chunk-words", 0).map_err(anyhow::Error::msg)?),
        },
        route,
        route_chunk_ptrs: match args.get("route-chunk-ptrs") {
            None => None,
            Some(_) => Some(args.get_usize("route-chunk-ptrs", 0).map_err(anyhow::Error::msg)?),
        },
        workers: match args.get("workers") {
            None => None,
            Some(_) => Some(args.get_usize("workers", 0).map_err(anyhow::Error::msg)?),
        },
    };
    let n_cores = topology.n_cores();
    if shards == 0 || shard >= shards || shards > n_cores {
        bail!("shard-worker: bad geometry (shard {shard} of {shards}, {n_cores} cores)");
    }
    let learning = match args.get("learn") {
        None => None,
        Some(spec) => Some(
            crate::sim::parse_learning(spec, args.get("learn-clamp"))
                .map_err(|e| anyhow!("shard-worker: {e}"))?,
        ),
    };

    // Identical partition + split as the parent (and every sibling): the
    // determinism contract rests on this recomputation agreeing.
    let src = NetSource::from_path(net_path).map_err(|e| anyhow!("{e}"))?;
    let mut view = src.view();
    if args.get("seed").is_some() {
        view.base_seed = args.get_u32("seed", 0).map_err(anyhow::Error::msg)?;
    }
    let partition = Partition::compute(view, topology, cap).map_err(anyhow::Error::msg)?;
    let split = split_network(view, &partition);
    let (lo, hi) = shard_core_range(n_cores, shards, shard);
    let mut cores = Vec::with_capacity(hi - lo);
    for sub in split.subnets.into_iter().skip(lo).take(hi - lo) {
        let mut core = CoreEngine::new(&sub, strategy, RustBackend)?;
        if let Some(cfg) = learning {
            core.enable_plasticity(cfg)?;
        }
        cores.push(core);
    }
    let n_local = cores.len();
    let mut pool = CorePool::with_options(cores, pool_opts);

    let stdin = io::stdin();
    let mut r = stdin.lock();
    let stdout = io::stdout();
    let mut w = io::BufWriter::new(stdout.lock());

    let mut ready = Vec::with_capacity(8);
    put_u32(&mut ready, shard as u32);
    put_u32(&mut ready, n_local as u32);
    write_frame(&mut w, K_READY, &ready)?;
    w.flush()?;

    let mut last_epoch = 0u64;
    let mut inputs: Vec<Vec<u32>> = vec![Vec::new(); n_local];
    let mut out = Vec::new();
    loop {
        let Some((kind, payload)) = read_frame(&mut r)? else {
            break; // parent closed our stdin: clean shutdown
        };
        let mut p = Payload::new(&payload);
        match kind {
            K_UPDATE => {
                last_epoch = p.u64()?;
                p.done()?;
                pool.phase_update()?;
                out.clear();
                put_u64(&mut out, last_epoch);
                let n_blocks = (0..n_local).filter(|&c| !pool.core(c).fired().is_empty()).count();
                put_u32(&mut out, n_blocks as u32);
                for c in 0..n_local {
                    let fired = pool.core(c).fired();
                    if fired.is_empty() {
                        continue;
                    }
                    put_u32(&mut out, (lo + c) as u32);
                    put_u32(&mut out, fired.len() as u32);
                    for &l in fired {
                        put_u32(&mut out, l);
                    }
                }
                write_frame(&mut w, K_FIRED, &out)?;
                w.flush()?;
            }
            K_DELIVER => {
                let epoch = p.u64()?;
                if epoch != last_epoch {
                    bail!("DELIVER epoch {epoch}, expected {last_epoch} (desynchronised)");
                }
                for buf in &mut inputs {
                    buf.clear();
                }
                let n_blocks = p.u32()? as usize;
                for _ in 0..n_blocks {
                    let core = p.u32()? as usize;
                    if core < lo || core >= hi {
                        bail!("DELIVER block for core {core} outside shard range {lo}..{hi}");
                    }
                    let n = p.u32()? as usize;
                    let bytes = p.take(n * 4)?;
                    let buf = &mut inputs[core - lo];
                    buf.reserve(n);
                    for c in bytes.chunks_exact(4) {
                        buf.push(u32::from_le_bytes(c.try_into().unwrap()));
                    }
                }
                p.done()?;
                // fire-and-forget: no reply — the parent overlaps this
                // route phase with its own return to the caller
                pool.phase_route(&inputs)?;
            }
            K_READ_MEM => {
                let n = p.u32()? as usize;
                out.clear();
                put_u32(&mut out, n as u32);
                for _ in 0..n {
                    let core = p.u32()? as usize;
                    let local = p.u32()? as usize;
                    if core < lo || core >= hi {
                        bail!("READ_MEM probe for core {core} outside shard range {lo}..{hi}");
                    }
                    let v = *pool
                        .core(core - lo)
                        .v
                        .get(local)
                        .ok_or_else(|| anyhow!("READ_MEM local id {local} out of range on core {core}"))?;
                    put_i32(&mut out, v);
                }
                p.done()?;
                write_frame(&mut w, K_MEMB, &out)?;
                w.flush()?;
            }
            K_RESET => {
                p.done()?;
                for c in 0..n_local {
                    pool.core_mut(c).reset();
                }
                write_frame(&mut w, K_ACK, &[K_RESET])?;
                w.flush()?;
            }
            K_RESET_COST => {
                p.done()?;
                for c in 0..n_local {
                    pool.core_mut(c).reset_cost();
                }
                write_frame(&mut w, K_ACK, &[K_RESET_COST])?;
                w.flush()?;
            }
            K_COST => {
                p.done()?;
                out.clear();
                put_u32(&mut out, n_local as u32);
                for c in 0..n_local {
                    let core = pool.core(c);
                    let counters = core.counters();
                    put_u32(&mut out, (lo + c) as u32);
                    put_u64(&mut out, counters.pointer_rows);
                    put_u64(&mut out, counters.synapse_rows);
                    put_u64(&mut out, counters.events);
                    put_u64(&mut out, counters.uram_accesses);
                    put_u64(&mut out, counters.bram_accesses);
                    put_u64(&mut out, core.cycles);
                }
                write_frame(&mut w, K_COSTR, &out)?;
                w.flush()?;
            }
            K_EDIT => {
                let op = p.u8()?;
                let ax = p.u8()? != 0;
                let core = p.u32()? as usize;
                let lpre = p.u32()?;
                let lpost = p.u32()?;
                let weight = p.i32()? as i16;
                p.done()?;
                if core < lo || core >= hi {
                    bail!("EDIT for core {core} outside shard range {lo}..{hi}");
                }
                let engine = pool.core_mut(core - lo);
                let res: anyhow::Result<Option<i32>> = match op {
                    EDIT_WRITE => {
                        engine.write_synapse(ax, lpre, lpost, weight).map(|b| Some(b as i32))
                    }
                    EDIT_ADD => {
                        engine.add_synapse(ax, lpre, lpost, weight).map(|b| Some(b as i32))
                    }
                    EDIT_REMOVE => {
                        engine.remove_synapse(ax, lpre, lpost).map(|n| Some(n as i32))
                    }
                    EDIT_READ => Ok(engine.read_synapse(ax, lpre, lpost).map(|w| w as i32)),
                    other => bail!("shard-worker: unknown EDIT op {other}"),
                };
                out.clear();
                match res {
                    Ok(Some(v)) => {
                        out.push(0);
                        put_i32(&mut out, v);
                    }
                    Ok(None) => out.push(2),
                    // an edit that fails (e.g. full HBM row) keeps the
                    // worker alive — the parent types it as a config error
                    Err(e) => {
                        out.push(1);
                        out.extend_from_slice(format!("{e:#}").as_bytes());
                    }
                }
                write_frame(&mut w, K_EDITR, &out)?;
                w.flush()?;
            }
            K_SHUTDOWN => break,
            other => bail!("shard-worker: unknown frame kind 0x{other:02x}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, K_UPDATE, &7u64.to_le_bytes()).unwrap();
        write_frame(&mut buf, K_ACK, &[K_RESET]).unwrap();
        write_frame(&mut buf, K_SHUTDOWN, &[]).unwrap();
        let mut r = io::Cursor::new(buf);
        let (k, p) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!((k, p.as_slice()), (K_UPDATE, &7u64.to_le_bytes()[..]));
        let (k, p) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!((k, p.as_slice()), (K_ACK, &[K_RESET][..]));
        let (k, p) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!((k, p.len()), (K_SHUTDOWN, 0));
        // clean EOF at the length prefix
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, K_FIRED, &[1, 2, 3, 4]).unwrap();
        buf.truncate(buf.len() - 2); // cut mid-payload
        let mut r = io::Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        buf.push(K_FIRED);
        let mut r = io::Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
        // zero-length frames (no kind byte) are malformed too
        let mut r = io::Cursor::new(0u32.to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn payload_cursor_checks_bounds_and_trailers() {
        let bytes = [1u8, 0, 0, 0, 9];
        let mut p = Payload::new(&bytes);
        assert_eq!(p.u32().unwrap(), 1);
        assert!(p.done().is_err()); // trailing byte
        assert_eq!(p.u8().unwrap(), 9);
        assert!(p.done().is_ok());
        assert!(p.u64().is_err()); // past the end
    }

    #[test]
    fn shard_ranges_cover_all_cores_contiguously() {
        for n_cores in 1..=12 {
            for shards in 1..=n_cores {
                let mut next = 0;
                for s in 0..shards {
                    let (lo, hi) = shard_core_range(n_cores, shards, s);
                    assert_eq!(lo, next, "{n_cores} cores / {shards} shards, shard {s}");
                    assert!(hi > lo, "every shard owns at least one core");
                    for c in lo..hi {
                        assert_eq!(shard_of_core(n_cores, shards, c), s);
                    }
                    next = hi;
                }
                assert_eq!(next, n_cores, "ranges cover all cores");
            }
        }
        // block sizes differ by at most one
        let sizes: Vec<usize> = (0..3).map(|s| {
            let (lo, hi) = shard_core_range(8, 3, s);
            hi - lo
        }).collect();
        assert_eq!(sizes, vec![3, 3, 2]);
    }

    #[test]
    fn strategy_and_route_names_roundtrip() {
        for s in [SlotStrategy::Modulo, SlotStrategy::BalanceFanIn] {
            assert_eq!(strategy_from_name(strategy_name(s)).unwrap(), s);
        }
        for r in [RouteGranularity::Core, RouteGranularity::Chunk] {
            assert_eq!(route_from_name(route_name(r)).unwrap(), r);
        }
        assert!(strategy_from_name("zigzag").is_err());
        assert!(route_from_name("warp").is_err());
    }
}
