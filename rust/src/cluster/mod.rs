//! Cluster orchestration: the multi-core / multi-FPGA / multi-server
//! execution engine (paper §3, Fig 9) and the NSG-portal-style job queue.

mod jobs;
mod pool;
mod multicore;
pub mod shard;

pub use jobs::{
    parse_stimulus, run_job, AdmissionGate, GatePermit, Job, JobQueue, JobResult, JobStatus,
};
pub use multicore::{ClusterCost, MultiCoreEngine};
pub use pool::{CorePool, PoolOptions, PoolSim, RouteGranularity};
