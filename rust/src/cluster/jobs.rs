//! NSG-portal-style job management (paper §3/§5: users submit Python
//! scripts over the Neuroscience Gateway; here a job is a network file +
//! a stimulus file executed on the simulated cluster).
//!
//! Stimulus format (text, one line per timestep): whitespace-separated
//! global axon ids to activate that step; blank line = no input. Results
//! report per-step output spikes and the energy/latency cost.

use std::collections::{BTreeSet, VecDeque};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::energy::EnergyModel;
use crate::model_fmt::read_hsn;
use crate::sim::{SimOptions, Simulator};

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub net_path: PathBuf,
    /// per-step axon activations (ascending ids per step)
    pub stimulus: Vec<Vec<u32>>,
    /// deployment choices (topology, backend, strategy, seed)
    pub options: SimOptions,
}

#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed(String),
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub status: JobStatus,
    /// output-neuron spikes per step (global ids)
    pub spikes: Vec<Vec<u32>>,
    pub energy_uj: f64,
    pub latency_us: f64,
}

/// Parse a stimulus file: one line per step, axon ids separated by
/// whitespace.
pub fn parse_stimulus(text: &str) -> Result<Vec<Vec<u32>>> {
    let mut steps = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.starts_with('#') {
            continue;
        }
        let mut ids = Vec::new();
        for tok in line.split_whitespace() {
            ids.push(
                tok.parse::<u32>()
                    .with_context(|| format!("stimulus line {}: bad axon id {tok:?}", ln + 1))?,
            );
        }
        ids.sort_unstable();
        ids.dedup();
        steps.push(ids);
    }
    Ok(steps)
}

/// Execute one job synchronously through the [`Simulator`] facade.
pub fn run_job(job: &Job, energy: &EnergyModel) -> JobResult {
    let inner = || -> Result<(Vec<Vec<u32>>, f64, f64)> {
        let net = read_hsn(&job.net_path)?;
        let mut sim = job.options.clone().into_config(net).build()?;
        let rec = sim.run(&job.stimulus, energy)?;
        Ok((rec.spikes, rec.cost.energy_uj, rec.cost.latency_us))
    };
    match inner() {
        Ok((spikes, e, l)) => JobResult {
            id: job.id,
            status: JobStatus::Done,
            spikes,
            energy_uj: e,
            latency_us: l,
        },
        Err(e) => JobResult {
            id: job.id,
            status: JobStatus::Failed(e.to_string()),
            spikes: Vec::new(),
            energy_uj: 0.0,
            latency_us: 0.0,
        },
    }
}

/// A bounded multi-worker job queue (the head-node scheduler).
///
/// Signalling uses **two** condvars: `work_cv` is only ever waited on by
/// idle workers (notified per submitted job), `done_cv` only by
/// [`JobQueue::drain`]/[`JobQueue::shutdown`] (notified per completed
/// job). A single shared condvar could hand a submit wakeup to a blocked
/// `drain` instead of an idle worker — the classic lost-wakeup that
/// leaves a queued job unserved until some unrelated notification.
pub struct JobQueue {
    inner: Arc<QueueInner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

struct QueueInner {
    state: Mutex<QueueState>,
    /// Workers wait here for new jobs.
    work_cv: Condvar,
    /// `drain`/`shutdown` wait here for completions.
    done_cv: Condvar,
    energy: EnergyModel,
}

#[derive(Default)]
struct QueueState {
    /// Pending jobs tagged with their submission sequence number.
    queue: VecDeque<(u64, Job)>,
    /// Completed jobs tagged with their submission sequence number.
    results: Vec<(u64, JobResult)>,
    next_seq: u64,
    shutdown: bool,
    in_flight: usize,
}

impl JobQueue {
    pub fn start(workers: usize, energy: EnergyModel) -> Self {
        let inner = Arc::new(QueueInner {
            state: Mutex::new(QueueState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            energy,
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        Self { inner, workers: handles }
    }

    pub fn submit(&self, job: Job) {
        let mut st = self.inner.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        st.queue.push_back((seq, job));
        self.inner.work_cv.notify_one();
    }

    /// Block until all submitted jobs finish; returns results in
    /// **submission order** (not sorted by caller-chosen job id).
    pub fn drain(&self) -> Vec<JobResult> {
        let mut st = self.inner.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            st = self.inner.done_cv.wait(st).unwrap();
        }
        take_results(&mut st)
    }

    /// Stop promptly: jobs still queued are **discarded**, in-flight
    /// jobs finish, workers exit. Returns every completed result not yet
    /// collected by [`JobQueue::drain`], in submission order — results
    /// raced with worker completion are never lost.
    pub fn shutdown(mut self) -> Vec<JobResult> {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            st.queue.clear();
            self.inner.work_cv.notify_all();
            self.inner.done_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            h.join().ok();
        }
        let mut st = self.inner.state.lock().unwrap();
        take_results(&mut st)
    }
}

fn take_results(st: &mut QueueState) -> Vec<JobResult> {
    let mut tagged = std::mem::take(&mut st.results);
    tagged.sort_by_key(|(seq, _)| *seq);
    tagged.into_iter().map(|(_, r)| r).collect()
}

fn worker_loop(inner: Arc<QueueInner>) {
    loop {
        let (seq, job) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                // shutdown first: queued-but-unstarted jobs are
                // discarded, never silently executed post-shutdown
                if st.shutdown {
                    return;
                }
                if let Some(tagged) = st.queue.pop_front() {
                    st.in_flight += 1;
                    break tagged;
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        let result = run_job(&job, &inner.energy);
        let mut st = inner.state.lock().unwrap();
        st.results.push((seq, result));
        st.in_flight -= 1;
        inner.done_cv.notify_all();
    }
}

fn lock_gate(gate: &AdmissionGate) -> MutexGuard<'_, GateState> {
    gate.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// FIFO fair-queueing admission gate: at most `permits` holders at once,
/// waiters admitted strictly in arrival order, each wait bounded by a
/// caller-supplied deadline. This is the scheduling layer the serving
/// tier (`sim::serve`) puts in front of simulator work so one greedy
/// session cannot starve the others, grown out of this module's
/// head-node job queue.
///
/// Unlike a plain semaphore, a timed-out waiter leaves a tombstone
/// (its ticket) that the admission scan skips, so an abandoned head of
/// the queue can never block the sessions behind it.
pub struct AdmissionGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    permits: usize,
    active: usize,
    /// Next ticket to hand out (arrival order).
    next_ticket: u64,
    /// Lowest ticket not yet admitted or skipped.
    admitted: u64,
    /// Tickets whose waiter gave up before being admitted.
    abandoned: BTreeSet<u64>,
}

impl AdmissionGate {
    pub fn new(permits: usize) -> Self {
        AdmissionGate {
            state: Mutex::new(GateState { permits: permits.max(1), ..Default::default() }),
            cv: Condvar::new(),
        }
    }

    /// Permits currently held.
    pub fn active(&self) -> usize {
        lock_gate(self).active
    }

    /// Waiters queued behind the gate right now (excludes holders and
    /// abandoned tickets).
    pub fn queue_depth(&self) -> usize {
        let st = lock_gate(self);
        (st.next_ticket - st.admitted) as usize - st.abandoned.len()
    }

    /// Wait (FIFO) for a permit for at most `deadline`. `None` means the
    /// deadline passed first; the caller's queue slot is relinquished so
    /// later arrivals are not blocked behind a ghost.
    pub fn acquire(&self, deadline: Duration) -> Option<GatePermit<'_>> {
        let t0 = Instant::now();
        let mut st = lock_gate(self);
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        loop {
            // skip tombstones so an abandoned head never wedges the queue
            while st.abandoned.remove(&st.admitted) {
                st.admitted += 1;
            }
            if st.admitted == ticket && st.active < st.permits {
                st.admitted += 1;
                st.active += 1;
                // with >1 permits the next ticket may be admissible too
                self.cv.notify_all();
                return Some(GatePermit { gate: self });
            }
            let elapsed = t0.elapsed();
            if elapsed >= deadline {
                st.abandoned.insert(ticket);
                self.cv.notify_all();
                return None;
            }
            let (g, _) = self
                .cv
                .wait_timeout(st, deadline - elapsed)
                .unwrap_or_else(PoisonError::into_inner);
            st = g;
        }
    }
}

/// RAII permit from [`AdmissionGate::acquire`]; releasing (dropping)
/// wakes the next waiter in FIFO order. Dropping during a panic unwind
/// still releases — a crashed holder cannot leak capacity.
pub struct GatePermit<'a> {
    gate: &'a AdmissionGate,
}

impl Drop for GatePermit<'_> {
    fn drop(&mut self) {
        let mut st = lock_gate(self.gate);
        st.active = st.active.saturating_sub(1);
        self.gate.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_fmt::write_hsn;
    use crate::snn::{NetworkBuilder, NeuronModel};

    fn tiny_net_path(name: &str) -> PathBuf {
        let m = NeuronModel::if_neuron(0);
        let mut b = NetworkBuilder::new();
        b.add_neuron("x", m, &[("y", 1)]).unwrap();
        b.add_neuron("y", m, &[]).unwrap();
        b.add_axon("in", &[("x", 1)]).unwrap();
        b.add_output("y");
        let net = b.build().unwrap().0;
        let p = std::env::temp_dir().join(format!("job_{}_{name}.hsn", std::process::id()));
        write_hsn(&net, &p).unwrap();
        p
    }

    #[test]
    fn parse_stimulus_lines() {
        let s = parse_stimulus("0 2 1\n\n# comment\n3\n").unwrap();
        assert_eq!(s, vec![vec![0, 1, 2], vec![], vec![3]]);
        assert!(parse_stimulus("xyz").is_err());
    }

    #[test]
    fn run_job_propagates_spike() {
        let p = tiny_net_path("prop");
        let job = Job {
            id: 1,
            net_path: p.clone(),
            // axon fires at t0: x gets +1 (integrated at end of t0),
            // x spikes during t1 (1 > 0), y integrates, y spikes at t2
            stimulus: vec![vec![0], vec![], vec![]],
            options: SimOptions::default(),
        };
        let r = run_job(&job, &EnergyModel::default());
        std::fs::remove_file(&p).ok();
        assert_eq!(r.status, JobStatus::Done);
        assert_eq!(r.spikes, vec![vec![], vec![], vec![1]]);
        assert!(r.energy_uj > 0.0);
    }

    #[test]
    fn queue_runs_jobs_in_parallel_and_reports_failures() {
        let p = tiny_net_path("queue");
        let q = JobQueue::start(3, EnergyModel::default());
        for id in 0..6 {
            q.submit(Job {
                id,
                net_path: if id == 3 { PathBuf::from("/nonexistent.hsn") } else { p.clone() },
                stimulus: vec![vec![0], vec![]],
                options: SimOptions::default(),
            });
        }
        let results = q.drain();
        q.shutdown();
        std::fs::remove_file(&p).ok();
        assert_eq!(results.len(), 6);
        for r in &results {
            if r.id == 3 {
                assert!(matches!(r.status, JobStatus::Failed(_)));
            } else {
                assert_eq!(r.status, JobStatus::Done);
            }
        }
    }

    /// Regression (PR 6): results come back in submission order, not
    /// sorted by the caller-chosen job id.
    #[test]
    fn drain_returns_results_in_submission_order() {
        let p = tiny_net_path("order");
        let q = JobQueue::start(3, EnergyModel::default());
        for id in [5u64, 3, 9, 3] {
            q.submit(Job {
                id,
                net_path: p.clone(),
                stimulus: vec![vec![0], vec![]],
                options: SimOptions::default(),
            });
        }
        let results = q.drain();
        q.shutdown();
        std::fs::remove_file(&p).ok();
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![5, 3, 9, 3], "submission order, duplicates preserved");
    }

    /// Regression (PR 6): `shutdown` with jobs still queued must (a) not
    /// run the whole backlog, (b) return — not lose — the results that
    /// raced with worker completion, in submission order.
    #[test]
    fn shutdown_with_queued_jobs_discards_backlog_and_keeps_results() {
        let p = tiny_net_path("shutqueue");
        let q = JobQueue::start(1, EnergyModel::default());
        let backlog = 64u64;
        for id in 0..backlog {
            q.submit(Job {
                id,
                net_path: p.clone(),
                // enough steps that one worker cannot clear 64 jobs in
                // the microseconds before shutdown grabs the lock
                stimulus: vec![vec![0]; 512],
                options: SimOptions::default(),
            });
        }
        let results = q.shutdown();
        std::fs::remove_file(&p).ok();
        assert!(
            (results.len() as u64) < backlog,
            "shutdown ran the whole {backlog}-job backlog ({} results)",
            results.len()
        );
        // whatever did complete is reported once each, in submission order
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        let want: Vec<u64> = (0..results.len() as u64).collect();
        assert_eq!(ids, want, "completed prefix must be in submission order");
        for r in &results {
            assert_eq!(r.status, JobStatus::Done, "job {}: {:?}", r.id, r.status);
        }
    }

    /// Stress the two-condvar signalling: concurrent submitters racing a
    /// draining collector must never hang (watchdogged) or lose results.
    #[test]
    fn concurrent_submit_drain_never_hangs_or_loses_results() {
        let p = tiny_net_path("stress");
        let path = p.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let q = Arc::new(JobQueue::start(3, EnergyModel::default()));
            let mut handles = Vec::new();
            for t in 0..4u64 {
                let q = q.clone();
                let path = path.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..25u64 {
                        q.submit(Job {
                            id: t * 100 + i,
                            net_path: path.clone(),
                            stimulus: vec![vec![0], vec![]],
                            options: SimOptions::default(),
                        });
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            let results = q.drain();
            let leftovers = Arc::try_unwrap(q).ok().expect("sole owner").shutdown();
            tx.send((results.len(), leftovers.len())).ok();
        });
        let (drained, leftovers) = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("job queue hung under concurrent submit/drain");
        std::fs::remove_file(&p).ok();
        assert_eq!(drained + leftovers, 100, "lost {} results", 100 - drained - leftovers);
    }

    #[test]
    fn admission_gate_is_fifo_and_respects_permits() {
        let gate = Arc::new(AdmissionGate::new(1));
        let held = gate.acquire(Duration::from_secs(5)).expect("free gate");
        assert_eq!(gate.active(), 1);

        let (tx, rx) = std::sync::mpsc::channel();
        let mut handles = Vec::new();
        for label in ["first", "second"] {
            let gate = gate.clone();
            let tx = tx.clone();
            // queue deterministically: wait until the previous waiter is
            // visibly queued before spawning the next
            handles.push(std::thread::spawn(move || {
                let permit = gate.acquire(Duration::from_secs(30)).expect("admitted");
                tx.send(label).unwrap();
                drop(permit);
            }));
            let want_depth = if label == "first" { 1 } else { 2 };
            while gate.queue_depth() < want_depth {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        drop(held); // admit the queue head
        let a = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let b = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!((a, b), ("first", "second"), "admission must be FIFO");
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(gate.active(), 0);
        assert_eq!(gate.queue_depth(), 0);
    }

    #[test]
    fn admission_gate_timeout_leaves_no_ghost_in_the_queue() {
        let gate = Arc::new(AdmissionGate::new(1));
        let held = gate.acquire(Duration::from_secs(5)).expect("free gate");

        // a waiter that gives up quickly...
        let g2 = gate.clone();
        let quitter =
            std::thread::spawn(move || g2.acquire(Duration::from_millis(30)).is_none());
        while gate.queue_depth() < 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // ...then a patient waiter queued *behind* the quitter
        let g3 = gate.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let patient = std::thread::spawn(move || {
            let got = g3.acquire(Duration::from_secs(30)).is_some();
            tx.send(got).unwrap();
        });
        assert!(quitter.join().unwrap(), "quitter must time out while the gate is held");
        drop(held);
        // the abandoned head ticket must not block the patient waiter
        assert!(
            rx.recv_timeout(Duration::from_secs(30)).unwrap(),
            "waiter behind an abandoned ticket was never admitted"
        );
        patient.join().unwrap();
        assert_eq!(gate.queue_depth(), 0);
    }
}
