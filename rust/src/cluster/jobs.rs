//! NSG-portal-style job management (paper §3/§5: users submit Python
//! scripts over the Neuroscience Gateway; here a job is a network file +
//! a stimulus file executed on the simulated cluster).
//!
//! Stimulus format (text, one line per timestep): whitespace-separated
//! global axon ids to activate that step; blank line = no input. Results
//! report per-step output spikes and the energy/latency cost.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{Context, Result};

use crate::energy::EnergyModel;
use crate::model_fmt::read_hsn;
use crate::sim::{SimOptions, Simulator};

#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub net_path: PathBuf,
    /// per-step axon activations (ascending ids per step)
    pub stimulus: Vec<Vec<u32>>,
    /// deployment choices (topology, backend, strategy, seed)
    pub options: SimOptions,
}

#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed(String),
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub status: JobStatus,
    /// output-neuron spikes per step (global ids)
    pub spikes: Vec<Vec<u32>>,
    pub energy_uj: f64,
    pub latency_us: f64,
}

/// Parse a stimulus file: one line per step, axon ids separated by
/// whitespace.
pub fn parse_stimulus(text: &str) -> Result<Vec<Vec<u32>>> {
    let mut steps = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.starts_with('#') {
            continue;
        }
        let mut ids = Vec::new();
        for tok in line.split_whitespace() {
            ids.push(
                tok.parse::<u32>()
                    .with_context(|| format!("stimulus line {}: bad axon id {tok:?}", ln + 1))?,
            );
        }
        ids.sort_unstable();
        ids.dedup();
        steps.push(ids);
    }
    Ok(steps)
}

/// Execute one job synchronously through the [`Simulator`] facade.
pub fn run_job(job: &Job, energy: &EnergyModel) -> JobResult {
    let inner = || -> Result<(Vec<Vec<u32>>, f64, f64)> {
        let net = read_hsn(&job.net_path)?;
        let mut sim = job.options.clone().into_config(net).build()?;
        let rec = sim.run(&job.stimulus, energy)?;
        Ok((rec.spikes, rec.cost.energy_uj, rec.cost.latency_us))
    };
    match inner() {
        Ok((spikes, e, l)) => JobResult {
            id: job.id,
            status: JobStatus::Done,
            spikes,
            energy_uj: e,
            latency_us: l,
        },
        Err(e) => JobResult {
            id: job.id,
            status: JobStatus::Failed(e.to_string()),
            spikes: Vec::new(),
            energy_uj: 0.0,
            latency_us: 0.0,
        },
    }
}

/// A bounded multi-worker job queue (the head-node scheduler).
pub struct JobQueue {
    inner: Arc<QueueInner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

struct QueueInner {
    state: Mutex<QueueState>,
    cv: Condvar,
    energy: EnergyModel,
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Job>,
    results: Vec<JobResult>,
    shutdown: bool,
    in_flight: usize,
}

impl JobQueue {
    pub fn start(workers: usize, energy: EnergyModel) -> Self {
        let inner = Arc::new(QueueInner {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            energy,
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        Self { inner, workers: handles }
    }

    pub fn submit(&self, job: Job) {
        let mut st = self.inner.state.lock().unwrap();
        st.queue.push_back(job);
        self.inner.cv.notify_one();
    }

    /// Block until all submitted jobs finish; returns results sorted by id.
    pub fn drain(&self) -> Vec<JobResult> {
        let mut st = self.inner.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            st = self.inner.cv.wait(st).unwrap();
        }
        let mut out = std::mem::take(&mut st.results);
        out.sort_by_key(|r| r.id);
        out
    }

    pub fn shutdown(mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            h.join().ok();
        }
    }
}

fn worker_loop(inner: Arc<QueueInner>) {
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    st.in_flight += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        let result = run_job(&job, &inner.energy);
        let mut st = inner.state.lock().unwrap();
        st.results.push(result);
        st.in_flight -= 1;
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_fmt::write_hsn;
    use crate::snn::{NetworkBuilder, NeuronModel};

    fn tiny_net_path(name: &str) -> PathBuf {
        let m = NeuronModel::if_neuron(0);
        let mut b = NetworkBuilder::new();
        b.add_neuron("x", m, &[("y", 1)]).unwrap();
        b.add_neuron("y", m, &[]).unwrap();
        b.add_axon("in", &[("x", 1)]).unwrap();
        b.add_output("y");
        let net = b.build().unwrap().0;
        let p = std::env::temp_dir().join(format!("job_{}_{name}.hsn", std::process::id()));
        write_hsn(&net, &p).unwrap();
        p
    }

    #[test]
    fn parse_stimulus_lines() {
        let s = parse_stimulus("0 2 1\n\n# comment\n3\n").unwrap();
        assert_eq!(s, vec![vec![0, 1, 2], vec![], vec![3]]);
        assert!(parse_stimulus("xyz").is_err());
    }

    #[test]
    fn run_job_propagates_spike() {
        let p = tiny_net_path("prop");
        let job = Job {
            id: 1,
            net_path: p.clone(),
            // axon fires at t0: x gets +1 (integrated at end of t0),
            // x spikes during t1 (1 > 0), y integrates, y spikes at t2
            stimulus: vec![vec![0], vec![], vec![]],
            options: SimOptions::default(),
        };
        let r = run_job(&job, &EnergyModel::default());
        std::fs::remove_file(&p).ok();
        assert_eq!(r.status, JobStatus::Done);
        assert_eq!(r.spikes, vec![vec![], vec![], vec![1]]);
        assert!(r.energy_uj > 0.0);
    }

    #[test]
    fn queue_runs_jobs_in_parallel_and_reports_failures() {
        let p = tiny_net_path("queue");
        let q = JobQueue::start(3, EnergyModel::default());
        for id in 0..6 {
            q.submit(Job {
                id,
                net_path: if id == 3 { PathBuf::from("/nonexistent.hsn") } else { p.clone() },
                stimulus: vec![vec![0], vec![]],
                options: SimOptions::default(),
            });
        }
        let results = q.drain();
        q.shutdown();
        std::fs::remove_file(&p).ok();
        assert_eq!(results.len(), 6);
        for r in &results {
            if r.id == 3 {
                assert!(matches!(r.status, JobStatus::Failed(_)));
            } else {
                assert_eq!(r.status, JobStatus::Done);
            }
        }
    }
}
