//! Inference runner: drives a converted model on the core engine with the
//! paper's presentation/readout protocols (§6).
//!
//! * `Membrane` readout (binarized-MNIST ANN models): present the image's
//!   axons at step 0, run `T + L - 1` steps so the output layer's
//!   membrane holds the logits after the last integrate, argmax V.
//! * `Rate` readout (spiking CNNs): present the T event frames at steps
//!   0..T-1, run `T + L` total steps (L = pipeline depth in layers),
//!   count output spikes; ties break on final membrane.
//!
//! Energy/latency are per inference (counters reset before each sample),
//! exactly the paper's Table-2 accounting.

use super::Converted;
use crate::energy::EnergyModel;
use crate::sim::{CostSummary, SimError, Simulator};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Readout {
    Membrane,
    Rate,
}

/// One classification result.
#[derive(Clone, Debug)]
pub struct Inference {
    pub prediction: usize,
    pub cost: CostSummary,
    /// per-output spike counts (Rate) or membrane (Membrane)
    pub scores: Vec<i64>,
}

/// Run one sample on any [`Simulator`] session (the engine is reset and
/// reused — build it once per model, not per sample). `frames[t]` =
/// active input-axon ids presented at step t (ascending). `layers` =
/// pipeline depth of the converted graph.
pub fn run_inference<S: Simulator + ?Sized>(
    engine: &mut S,
    conv: &Converted,
    frames: &[Vec<u32>],
    layers: usize,
    readout: Readout,
    energy: &EnergyModel,
) -> Result<Inference, SimError> {
    engine.reset();
    let t_frames = frames.len();
    let total_steps = match readout {
        Readout::Membrane => (t_frames + layers).saturating_sub(1),
        Readout::Rate => t_frames + layers,
    };
    let n_out = conv.output_neurons.len();
    let mut counts = vec![0i64; n_out];
    let out_base = conv.output_neurons[0];

    let mut axon_buf: Vec<u32> = Vec::new();
    for step in 0..total_steps {
        axon_buf.clear();
        if step < t_frames {
            axon_buf.extend_from_slice(&frames[step]);
        }
        if let Some(b) = conv.bias_axon {
            axon_buf.push(b); // bias axon fires every step (sorted: last id)
        }
        let out = engine.step(&axon_buf)?;
        for &o in out.output_spikes {
            counts[(o - out_base) as usize] += 1;
        }
    }

    let membranes = engine.read_membrane(&conv.output_neurons);
    let scores: Vec<i64> = match readout {
        // bias folded into the threshold drops out of the raw membrane;
        // add it back so the readout equals the trained logits
        Readout::Membrane => membranes
            .iter()
            .zip(&conv.output_bias)
            .map(|(&v, &b)| v as i64 + b as i64)
            .collect(),
        Readout::Rate => counts
            .iter()
            .zip(&membranes)
            .map(|(&c, &v)| c * 1_000_000 + (v as i64).clamp(-500_000, 500_000))
            .collect(),
    };
    let prediction = scores
        .iter()
        .enumerate()
        .max_by_key(|(i, &s)| (s, std::cmp::Reverse(*i)))
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok(Inference { prediction, cost: engine.cost(energy), scores })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::{convert, BiasMode};
    use crate::engine::{CoreEngine, RustBackend};
    use crate::hbm::SlotStrategy;
    use crate::model_fmt::{Layer, LayerGraph, NeuronKind};

    /// 4-input, 2-output single-FC binary model with hand weights: output
    /// 0 sums inputs {0,1}, output 1 sums {2,3}; theta 1 -> needs 2 active.
    fn tiny_graph() -> LayerGraph {
        LayerGraph {
            neuron_kind: NeuronKind::AnnBinary,
            in_c: 1,
            in_h: 2,
            in_w: 2,
            timesteps: 1,
            layers: vec![Layer::Fc {
                out_features: 2,
                theta: 0,
                weights: vec![1, 1, 0, 0, 0, 0, 1, 1],
                bias: None,
            }],
        }
    }

    #[test]
    fn membrane_readout_picks_strongest() {
        let g = tiny_graph();
        let conv = convert(&g, BiasMode::Threshold, 0).unwrap();
        let mut e = CoreEngine::new(&conv.net, SlotStrategy::Modulo, RustBackend).unwrap();
        let em = EnergyModel::default();
        // inputs 2,3 active -> output 1 membrane = 2 > output 0 = 0
        let inf =
            run_inference(&mut e, &conv, &[vec![2, 3]], 1, Readout::Membrane, &em).unwrap();
        assert_eq!(inf.prediction, 1);
        assert_eq!(inf.scores, vec![0, 2]);
        // inputs 0,1 -> output 0
        let inf =
            run_inference(&mut e, &conv, &[vec![0, 1]], 1, Readout::Membrane, &em).unwrap();
        assert_eq!(inf.prediction, 0);
        assert!(inf.cost.hbm_rows > 0);
    }

    #[test]
    fn rate_readout_counts_spikes() {
        let mut g = tiny_graph();
        g.neuron_kind = NeuronKind::IntegrateFire;
        g.timesteps = 3;
        // IF theta 1: spikes when membrane sums 2 active inputs
        if let Layer::Fc { theta, .. } = &mut g.layers[0] {
            *theta = 1;
        }
        let conv = convert(&g, BiasMode::Threshold, 0).unwrap();
        let mut e = CoreEngine::new(&conv.net, SlotStrategy::Modulo, RustBackend).unwrap();
        let em = EnergyModel::default();
        let frames = vec![vec![2, 3], vec![2, 3], vec![0u32]];
        let inf = run_inference(&mut e, &conv, &frames, 1, Readout::Rate, &em).unwrap();
        assert_eq!(inf.prediction, 1); // output 1 spiked twice, output 0 never
    }

    #[test]
    fn cost_reset_between_inferences() {
        let g = tiny_graph();
        let conv = convert(&g, BiasMode::Threshold, 0).unwrap();
        let mut e = CoreEngine::new(&conv.net, SlotStrategy::Modulo, RustBackend).unwrap();
        let em = EnergyModel::default();
        let a = run_inference(&mut e, &conv, &[vec![0, 1]], 1, Readout::Membrane, &em).unwrap();
        let b = run_inference(&mut e, &conv, &[vec![0, 1]], 1, Readout::Membrane, &em).unwrap();
        assert_eq!(a.cost.hbm_rows, b.cost.hbm_rows, "per-inference accounting");
    }
}
