//! PyTorch-model -> HiAER-Spike network conversion (Supplementary A.2).
//!
//! The Python training pipeline exports a quantized feed-forward layer
//! graph (`.hsl`); this module maps it onto axons/neurons/synapses:
//!
//! * the input image becomes one axon per (channel, y, x) pixel;
//! * each conv layer's output feature-map pixels become neurons; a
//!   sliding window over an index tensor (exactly the A.2 technique)
//!   connects every presynaptic axon/neuron in the receptive field to the
//!   feature-map neuron with the kernel weight;
//! * max-pool layers become threshold-OR neurons (theta = 0, weight 1 —
//!   they spike iff any input in the window spiked, exact for binary
//!   activations);
//! * fully-connected layers get all-to-all synapses;
//! * biases are subtracted from the neuron threshold (the A.2 first
//!   method) or attached to an always-on bias axon (second method).
//!
//! Neuron models: ANN binary neurons for binarized-MNIST style models,
//! IF neurons (LIF with lam = 63) for rate-coded spiking CNNs.

use anyhow::{bail, Result};

use crate::model_fmt::{Layer, LayerGraph, NeuronKind};
use crate::snn::{EdgeList, Network, NeuronModel, WEIGHT_MAX, WEIGHT_MIN};

/// How to realise trained biases in the spiking network (Supp A.2 lists
/// both; the threshold method is exact and free, the axon method keeps
/// thresholds uniform at the cost of one always-active axon).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BiasMode {
    /// theta_i = layer_theta - bias_i
    Threshold,
    /// A dedicated axon (activated every timestep by the runner) carries
    /// weight = bias_i to each biased neuron.
    Axon,
}

/// Conversion result: the network plus the index maps the runner needs.
#[derive(Clone, Debug)]
pub struct Converted {
    pub net: Network,
    /// Axon id of input pixel (c, y, x) = c*H*W + y*W + x.
    pub n_input_axons: usize,
    /// Present when BiasMode::Axon was used: activate this axon every step.
    pub bias_axon: Option<u32>,
    /// Neuron ids of the final layer (the model outputs, in order).
    pub output_neurons: Vec<u32>,
    /// Trained bias of each output neuron. In `BiasMode::Threshold` the
    /// bias is folded into the threshold, which preserves *spiking*
    /// exactly but drops out of the raw membrane value; the membrane
    /// readout must add it back (`scores = V + output_bias`).
    pub output_bias: Vec<i32>,
    /// Rate-coding timesteps the model was trained for.
    pub timesteps: usize,
}

/// Convert a trained layer graph into a flat HiAER-Spike network.
pub fn convert(graph: &LayerGraph, bias_mode: BiasMode, base_seed: u32) -> Result<Converted> {
    let shapes = graph.shapes()?;
    let n_inputs = graph.n_inputs();

    // count neurons: every layer's output elements
    let mut layer_base = Vec::with_capacity(graph.layers.len());
    let mut total = 0usize;
    for s in &shapes[1..] {
        layer_base.push(total);
        total += count(s);
    }

    let neuron_model = |theta: i32| -> NeuronModel {
        match graph.neuron_kind {
            NeuronKind::AnnBinary => NeuronModel::ann(theta, 0, false).expect("nu=0 valid"),
            NeuronKind::IntegrateFire => NeuronModel::if_neuron(theta),
        }
    };

    let mut params: Vec<NeuronModel> = vec![neuron_model(0); total];
    let n_axons = n_inputs + usize::from(bias_mode == BiasMode::Axon);
    // Sources are visited postsynaptic-first (the sliding window walks
    // output pixels), so synapses arrive in arbitrary presynaptic order;
    // the flat EdgeList absorbs that and counting-sorts into CSR once.
    let mut edges = EdgeList::new(total, n_axons);
    let bias_axon = (bias_mode == BiasMode::Axon).then_some(n_inputs as u32);

    // Push a synapse from presynaptic element `pre` (layer -1 = axons) to
    // neuron `post`.
    let connect = |pre_layer: isize,
                       pre_idx: usize,
                       post: usize,
                       w: i32,
                       layer_base: &[usize],
                       edges: &mut EdgeList|
     -> Result<()> {
        if w == 0 {
            return Ok(()); // pruned — the CSR stores sparse nets
        }
        if !(WEIGHT_MIN..=WEIGHT_MAX).contains(&w) {
            bail!("weight {w} outside int16 after quantization");
        }
        if pre_layer < 0 {
            edges.push_axon(pre_idx as u32, post as u32, w as i16);
        } else {
            edges.push_neuron(
                (layer_base[pre_layer as usize] + pre_idx) as u32,
                post as u32,
                w as i16,
            );
        }
        Ok(())
    };

    for (li, layer) in graph.layers.iter().enumerate() {
        let (ic, ih, iw) = shapes[li];
        let (oc, oh, ow) = shapes[li + 1];
        let pre_layer = li as isize - 1;
        let base = layer_base[li];
        match layer {
            Layer::Conv { out_c, kh, kw, stride, pad, theta, weights, bias } => {
                debug_assert_eq!(*out_c, oc);
                for f in 0..oc {
                    let b = bias.as_ref().map(|b| b[f]).unwrap_or(0);
                    let th = match bias_mode {
                        BiasMode::Threshold => theta.saturating_sub(b),
                        BiasMode::Axon => *theta,
                    };
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let post = base + (f * oh + oy) * ow + ox;
                            params[post] = neuron_model(th);
                            if bias_mode == BiasMode::Axon && b != 0 {
                                edges.push_axon(
                                    n_inputs as u32,
                                    post as u32,
                                    b.clamp(WEIGHT_MIN, WEIGHT_MAX) as i16,
                                );
                            }
                            // sliding window over the input index tensor
                            for c in 0..ic {
                                for ky in 0..*kh {
                                    for kx in 0..*kw {
                                        let y = (oy * stride + ky) as isize - *pad as isize;
                                        let x = (ox * stride + kx) as isize - *pad as isize;
                                        if y < 0 || x < 0 || y >= ih as isize || x >= iw as isize
                                        {
                                            continue;
                                        }
                                        let pre = (c * ih + y as usize) * iw + x as usize;
                                        let w = weights
                                            [((f * ic + c) * kh + ky) * kw + kx]
                                            as i32;
                                        connect(
                                            pre_layer,
                                            pre,
                                            post,
                                            w,
                                            &layer_base,
                                            &mut edges,
                                        )?;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            Layer::Fc { out_features, theta, weights, bias } => {
                let in_features = if ih == usize::MAX { ic } else { ic * ih * iw };
                for o in 0..*out_features {
                    let b = bias.as_ref().map(|b| b[o]).unwrap_or(0);
                    let th = match bias_mode {
                        BiasMode::Threshold => theta.saturating_sub(b),
                        BiasMode::Axon => *theta,
                    };
                    let post = base + o;
                    params[post] = neuron_model(th);
                    if bias_mode == BiasMode::Axon && b != 0 {
                        edges.push_axon(
                            n_inputs as u32,
                            post as u32,
                            b.clamp(WEIGHT_MIN, WEIGHT_MAX) as i16,
                        );
                    }
                    for i in 0..in_features {
                        let w = weights[o * in_features + i] as i32;
                        connect(pre_layer, i, post, w, &layer_base, &mut edges)?;
                    }
                }
            }
            Layer::MaxPool { k, stride } => {
                // threshold-OR: theta=0 (strict >, weight 1 => spikes iff
                // any input spiked)
                for c in 0..oc {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let post = base + (c * oh + oy) * ow + ox;
                            params[post] = neuron_model(0);
                            for ky in 0..*k {
                                for kx in 0..*k {
                                    let y = oy * stride + ky;
                                    let x = ox * stride + kx;
                                    if y >= ih || x >= iw {
                                        continue;
                                    }
                                    let pre = (c * ih + y) * iw + x;
                                    connect(pre_layer, pre, post, 1, &layer_base, &mut edges)?;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    let last_base = *layer_base.last().unwrap_or(&0);
    let out_count = count(shapes.last().unwrap());
    let output_neurons: Vec<u32> = (last_base..last_base + out_count).map(|i| i as u32).collect();
    // In Axon mode the bias axon already delivers b into the membrane, so
    // the readout correction applies to Threshold mode only.
    let output_bias: Vec<i32> = match (bias_mode, graph.layers.last()) {
        (BiasMode::Threshold, Some(Layer::Fc { bias: Some(b), .. })) => b.clone(),
        (BiasMode::Threshold, Some(Layer::Conv { bias: Some(b), out_c, .. })) => {
            // per-feature-map bias broadcast over positions
            let per_map = out_count / out_c;
            (0..out_count).map(|i| b[i / per_map]).collect()
        }
        _ => vec![0; out_count],
    };

    let net = edges.into_network(params, output_neurons.clone(), base_seed);
    net.validate().map_err(|e| anyhow::anyhow!("converted network invalid: {e}"))?;
    Ok(Converted {
        net,
        n_input_axons: n_inputs,
        bias_axon,
        output_neurons,
        output_bias,
        timesteps: graph.timesteps.max(1),
    })
}

fn count(s: &(usize, usize, usize)) -> usize {
    if s.1 == usize::MAX {
        s.0
    } else {
        s.0 * s.1 * s.2
    }
}

/// Direct (dense, float-free) forward pass of the layer graph over a
/// binary input — the oracle the converter is tested against: running the
/// converted network for one step per layer must reproduce these
/// activations exactly (binary neurons).
pub fn reference_forward_binary(graph: &LayerGraph, input: &[i32]) -> Result<Vec<Vec<i32>>> {
    let shapes = graph.shapes()?;
    let mut act: Vec<i32> = input.to_vec();
    let mut all = Vec::new();
    for (li, layer) in graph.layers.iter().enumerate() {
        let (ic, ih, iw) = shapes[li];
        let (oc, oh, ow) = shapes[li + 1];
        let next = match layer {
            Layer::Conv { kh, kw, stride, pad, theta, weights, bias, .. } => {
                let mut out = vec![0i32; oc * oh * ow];
                for f in 0..oc {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut acc: i64 =
                                bias.as_ref().map(|b| b[f] as i64).unwrap_or(0);
                            for c in 0..ic {
                                for ky in 0..*kh {
                                    for kx in 0..*kw {
                                        let y = (oy * stride + ky) as isize - *pad as isize;
                                        let x = (ox * stride + kx) as isize - *pad as isize;
                                        if y < 0
                                            || x < 0
                                            || y >= ih as isize
                                            || x >= iw as isize
                                        {
                                            continue;
                                        }
                                        let pre = (c * ih + y as usize) * iw + x as usize;
                                        acc += act[pre] as i64
                                            * weights[((f * ic + c) * kh + ky) * kw + kx]
                                                as i64;
                                    }
                                }
                            }
                            out[(f * oh + oy) * ow + ox] = (acc > *theta as i64) as i32;
                        }
                    }
                }
                out
            }
            Layer::Fc { out_features, theta, weights, bias } => {
                let in_features = act.len();
                let mut out = vec![0i32; *out_features];
                for o in 0..*out_features {
                    let mut acc: i64 = bias.as_ref().map(|b| b[o] as i64).unwrap_or(0);
                    for i in 0..in_features {
                        acc += act[i] as i64 * weights[o * in_features + i] as i64;
                    }
                    out[o] = (acc > *theta as i64) as i32;
                }
                out
            }
            Layer::MaxPool { k, stride } => {
                let mut out = vec![0i32; oc * oh * ow];
                for c in 0..oc {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut m = 0;
                            for ky in 0..*k {
                                for kx in 0..*k {
                                    let y = oy * stride + ky;
                                    let x = ox * stride + kx;
                                    if y < ih && x < iw {
                                        m = m.max(act[(c * ih + y) * iw + x]);
                                    }
                                }
                            }
                            out[(c * oh + oy) * ow + ox] = m;
                        }
                    }
                }
                out
            }
        };
        act = next.clone();
        all.push(next);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DenseEngine;
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn random_graph(rng: &mut Xorshift32, kind: NeuronKind) -> LayerGraph {
        let in_c = 1 + rng.below(2) as usize;
        let in_h = 6 + rng.below(6) as usize;
        let in_w = in_h;
        let mut layers = Vec::new();
        let (mut c, mut h, mut w) = (in_c, in_h, in_w);
        // conv
        let out_c = 1 + rng.below(4) as usize;
        let k = 3;
        let stride = 1 + rng.below(2) as usize;
        let pad = rng.below(2) as usize;
        let weights: Vec<i16> =
            (0..out_c * c * k * k).map(|_| rng.range_i32(-40, 40) as i16).collect();
        let bias = rng
            .chance(0.5)
            .then(|| (0..out_c).map(|_| rng.range_i32(-50, 50)).collect::<Vec<i32>>());
        layers.push(Layer::Conv {
            out_c,
            kh: k,
            kw: k,
            stride,
            pad,
            theta: rng.range_i32(-5, 30),
            weights,
            bias,
        });
        h = (h + 2 * pad - k) / stride + 1;
        w = (w + 2 * pad - k) / stride + 1;
        c = out_c;
        // optional pool
        if rng.chance(0.5) && h >= 2 && w >= 2 {
            layers.push(Layer::MaxPool { k: 2, stride: 2 });
            h = (h - 2) / 2 + 1;
            w = (w - 2) / 2 + 1;
        }
        // fc head
        let in_features = c * h * w;
        let out_features = 3;
        let weights: Vec<i16> =
            (0..out_features * in_features).map(|_| rng.range_i32(-30, 30) as i16).collect();
        layers.push(Layer::Fc {
            out_features,
            theta: rng.range_i32(-5, 40),
            weights,
            bias: Some((0..out_features).map(|_| rng.range_i32(-40, 40)).collect()),
        });
        LayerGraph { neuron_kind: kind, in_c, in_h, in_w, timesteps: 1, layers }
    }

    /// Run the converted network with the dense engine: present the input
    /// for one step, then propagate one extra step per layer; collect each
    /// layer's spike wave. ANN binary neurons make the network a pure
    /// pipeline, so layer L's activations appear at step L.
    fn run_converted_binary(conv: &Converted, graph: &LayerGraph, input: &[i32]) -> Vec<Vec<i32>> {
        let mut e = DenseEngine::new(&conv.net);
        let axons: Vec<u32> = input
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0)
            .map(|(i, _)| i as u32)
            .chain(conv.bias_axon.iter().copied())
            .collect();
        let shapes = graph.shapes().unwrap();
        let sizes: Vec<usize> = shapes[1..].iter().map(count).collect();
        let mut base = Vec::new();
        let mut acc = 0;
        for s in &sizes {
            base.push(acc);
            acc += s;
        }
        // inputs presented at step 0 integrate at the END of step 0, so
        // layer li's data-driven wave fires during step li + 1.
        let mut waves = Vec::new();
        for t in 0..=graph.layers.len() {
            let inputs: Vec<u32> = if t == 0 {
                axons.clone()
            } else {
                conv.bias_axon.iter().copied().collect()
            };
            e.step(&inputs);
            if t >= 1 {
                let li = t - 1;
                let mut layer = vec![0i32; sizes[li]];
                for &f in &e.fired() {
                    let f = f as usize;
                    if f >= base[li] && f < base[li] + sizes[li] {
                        layer[f - base[li]] = 1;
                    }
                }
                waves.push(layer);
            }
        }
        waves
    }

    #[test]
    fn prop_converted_network_matches_reference_forward() {
        ptest::check("convert_equals_reference", 20, |rng| {
            let graph = random_graph(rng, NeuronKind::AnnBinary);
            let conv = convert(&graph, BiasMode::Threshold, 0)
                .map_err(|e| format!("convert: {e}"))?;
            let n_in = graph.n_inputs();
            let input: Vec<i32> = (0..n_in).map(|_| rng.chance(0.3) as i32).collect();
            let want = reference_forward_binary(&graph, &input)
                .map_err(|e| format!("ref: {e}"))?;
            let got = run_converted_binary(&conv, &graph, &input);
            for (li, (w, g)) in want.iter().zip(&got).enumerate() {
                ptest::prop_assert_eq(g.clone(), w.clone(), &format!("layer {li}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn bias_modes_agree_on_binary_pipeline() {
        ptest::check("bias_threshold_equals_axon", 10, |rng| {
            let graph = random_graph(rng, NeuronKind::AnnBinary);
            let a = convert(&graph, BiasMode::Threshold, 0).map_err(|e| e.to_string())?;
            let b = convert(&graph, BiasMode::Axon, 0).map_err(|e| e.to_string())?;
            let n_in = graph.n_inputs();
            let input: Vec<i32> = (0..n_in).map(|_| rng.chance(0.3) as i32).collect();
            let wa = run_converted_binary(&a, &graph, &input);
            let wb = run_converted_binary(&b, &graph, &input);
            ptest::prop_assert_eq(wa.last().cloned(), wb.last().cloned(), "final layer")
        });
    }

    #[test]
    fn pruned_zero_weights_not_stored() {
        let graph = LayerGraph {
            neuron_kind: NeuronKind::AnnBinary,
            in_c: 1,
            in_h: 2,
            in_w: 2,
            timesteps: 1,
            layers: vec![Layer::Fc {
                out_features: 2,
                theta: 0,
                weights: vec![1, 0, 0, 0, 0, 0, 0, 2],
                bias: None,
            }],
        };
        let conv = convert(&graph, BiasMode::Threshold, 0).unwrap();
        assert_eq!(conv.net.n_synapses(), 2);
    }

    #[test]
    fn output_neurons_are_last_layer() {
        let mut rng = Xorshift32::new(5);
        let graph = random_graph(&mut rng, NeuronKind::IntegrateFire);
        let conv = convert(&graph, BiasMode::Threshold, 0).unwrap();
        assert_eq!(conv.output_neurons.len(), 3);
        assert_eq!(conv.net.outputs, conv.output_neurons);
        assert_eq!(conv.timesteps, 1);
    }
}

pub mod runner;
pub use runner::{run_inference, Inference, Readout};
