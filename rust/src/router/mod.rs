//! Hierarchical address-event routing — the "white matter" (paper Fig 1,
//! refs [7, 8]).
//!
//! Spikes leaving a core are multicast to every core that stores synapses
//! of the firing source. The fabric has four levels with very different
//! costs:
//!
//! | level | fabric              | scope             |
//! |-------|---------------------|-------------------|
//! | 0     | on-core             | same core         |
//! | 1     | NoC                 | cores on one FPGA |
//! | 2     | FireFly (1 Tbps x4) | FPGAs in a server |
//! | 3     | Ethernet (Arista)   | between servers   |
//!
//! The router maintains the multicast tables (source -> destination cores
//! + the destination-local axon id), delivers events within the 1 ms
//! timestep (the system is faster-than-real-time, so events always make
//! the next membrane sweep), and accounts per-level traffic, bandwidth
//! and latency for the scaling model.

use crate::partition::{ClusterTopology, Partition};
use crate::snn::{NetView, Network};

/// Per-level fabric timing/bandwidth model (cycles at the core clock).
#[derive(Clone, Copy, Debug)]
pub struct FabricModel {
    /// hop latency in core-clock cycles per level (index 0 unused)
    pub hop_latency: [u64; 4],
    /// events per cycle a level can move (aggregate, per direction)
    pub events_per_cycle: [f64; 4],
}

impl Default for FabricModel {
    fn default() -> Self {
        FabricModel {
            hop_latency: [0, 40, 280, 1400], // NoC / FireFly / Ethernet
            events_per_cycle: [f64::INFINITY, 8.0, 2.0, 0.5],
        }
    }
}

/// A routed event: deliver `local_axon` on `core`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Delivery {
    pub core: u32,
    pub local_axon: u32,
}

/// Multicast routing tables for a partitioned network.
///
/// Remote synapses are re-homed: if neuron `g` (on core A) targets
/// neurons on core B, core B's sub-network stores those synapses under a
/// *remote axon* and this table records (g -> B, axon id). Global input
/// axons route the same way.
#[derive(Clone, Debug, Default)]
pub struct RoutingTable {
    /// per global neuron: remote deliveries (cores other than home).
    pub neuron_routes: Vec<Vec<Delivery>>,
    /// per global axon: deliveries (an axon may fan out to many cores).
    pub axon_routes: Vec<Vec<Delivery>>,
}

/// Traffic/latency accounting for one run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RouterStats {
    /// events moved per level (level 0 = stayed on core).
    pub events_by_level: [u64; 4],
    /// accumulated serialization + hop cycles (critical-path estimate).
    pub cycles: u64,
}

pub struct HiaerRouter {
    pub topology: ClusterTopology,
    pub fabric: FabricModel,
    pub table: RoutingTable,
    pub stats: RouterStats,
    /// scratch: per-core delivery lists for the current step
    pending: Vec<Vec<u32>>,
}

impl HiaerRouter {
    pub fn new(topology: ClusterTopology, fabric: FabricModel, table: RoutingTable) -> Self {
        let n_cores = topology.n_cores();
        Self { topology, fabric, table, stats: RouterStats::default(), pending: vec![Vec::new(); n_cores] }
    }

    pub fn reset_stats(&mut self) {
        self.stats = RouterStats::default();
    }

    /// Route one step's spikes. `fired_by_core[c]` are global neuron ids
    /// that fired on core c; `axon_inputs` are fired global axons.
    /// Returns per-core sorted local-axon activation lists (the remote
    /// inputs for each core's routing phase). Level-0 (home-core) events
    /// are NOT produced here — the home core handles its own neurons'
    /// synapses directly from its HBM.
    pub fn route_step(
        &mut self,
        fired_by_core: &[Vec<u32>],
        axon_inputs: &[u32],
    ) -> &[Vec<u32>] {
        for p in &mut self.pending {
            p.clear();
        }
        let mut level_events = [0u64; 4];
        // neuron multicast
        for (src_core, fired) in fired_by_core.iter().enumerate() {
            for &g in fired {
                for d in &self.table.neuron_routes[g as usize] {
                    let lvl = self.topology.level(src_core, d.core as usize);
                    level_events[lvl as usize] += 1;
                    self.pending[d.core as usize].push(d.local_axon);
                }
            }
        }
        // input axon fan-out (host -> cores over PCIe; level = NoC-ish,
        // counted as level 1)
        for &a in axon_inputs {
            for d in &self.table.axon_routes[a as usize] {
                level_events[1] += 1;
                self.pending[d.core as usize].push(d.local_axon);
            }
        }
        // latency model: serialization at the busiest level + one hop each
        let mut cycles = 0u64;
        for lvl in 1..4 {
            if level_events[lvl] > 0 {
                let ser =
                    (level_events[lvl] as f64 / self.fabric.events_per_cycle[lvl]).ceil() as u64;
                cycles = cycles.max(self.fabric.hop_latency[lvl] + ser);
            }
            self.stats.events_by_level[lvl] += level_events[lvl];
        }
        self.stats.cycles += cycles;
        for p in &mut self.pending {
            p.sort_unstable();
            p.dedup(); // a multicast delivers once per (source, core) pair
        }
        &self.pending
    }
}

/// Build per-core sub-networks + routing tables from a partition.
///
/// Core-local neuron indices follow `partition.members[c]` order. Remote
/// sources become local axons appended after the core's share of global
/// axons. Returns (sub-networks, table, per-core map global axon -> local
/// axon id).
pub struct SplitNetwork {
    pub subnets: Vec<Network>,
    pub table: RoutingTable,
    /// local axon id of each (core, global axon) pair, u32::MAX if unused.
    pub axon_local: Vec<Vec<u32>>,
    /// per core: global source neuron -> the local axon its remote
    /// synapses were re-homed under. Needed to address a (pre, post)
    /// synapse on the post neuron's core when pre lives elsewhere
    /// (live edits, plasticity bookkeeping).
    pub remote_axon: Vec<std::collections::HashMap<u32, u32>>,
}

/// Two-pass CSR extraction: pass 1 walks the global CSR once to discover
/// remote/local axons and count per-source degrees; pass 2 allocates each
/// sub-network's flat arrays in one shot and fills them through write
/// cursors derived from the offset tables. No per-source Vec churn — the
/// seed's nested-Vec assembly allocated one Vec per (core, source).
///
/// Generic over the borrowed-CSR view: the *global* network is only read
/// through [`NetView`] slices (so an mmap-backed `.hsn` v2 splits without
/// ever materialising the global CSR on the heap); the per-core subnets
/// are owned by construction — re-homing remote sources rewrites targets
/// and appends local axons, which cannot alias the source arrays.
pub fn split_network<'a>(net: impl Into<NetView<'a>>, part: &Partition) -> SplitNetwork {
    let net: NetView<'_> = net.into();
    let n_cores = part.topology.n_cores();
    let n = net.n_neurons();
    let a = net.n_axons();

    // output sets per core
    let mut is_output = vec![false; n];
    for &o in net.outputs {
        is_output[o as usize] = true;
    }

    let mut neuron_routes: Vec<Vec<Delivery>> = vec![Vec::new(); n];
    let mut axon_routes: Vec<Vec<Delivery>> = vec![Vec::new(); a];
    let mut axon_local: Vec<Vec<u32>> = vec![vec![u32::MAX; a]; n_cores];
    // remote axon id per (core, global source neuron)
    let mut remote_axon: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); n_cores];

    // ---- pass 1: allocate local axon ids + count per-source degrees
    let mut neuron_deg: Vec<Vec<u32>> =
        part.members.iter().map(|m| vec![0u32; m.len()]).collect();
    let mut axon_deg: Vec<Vec<u32>> = vec![Vec::new(); n_cores];

    // helper: get/create the local axon on `core` for a remote neuron or
    // a global axon (the degree table doubles as the id allocator).
    fn local_axon_for(
        axon_deg: &mut [Vec<u32>],
        axon_local: &mut [Vec<u32>],
        remote_axon: &mut [std::collections::HashMap<u32, u32>],
        core: usize,
        is_global_axon: bool,
        src: u32,
    ) -> u32 {
        if is_global_axon {
            if axon_local[core][src as usize] == u32::MAX {
                let id = axon_deg[core].len() as u32;
                axon_deg[core].push(0);
                axon_local[core][src as usize] = id;
            }
            axon_local[core][src as usize]
        } else {
            *remote_axon[core].entry(src).or_insert_with(|| {
                let id = axon_deg[core].len() as u32;
                axon_deg[core].push(0);
                id
            })
        }
    }

    for g in 0..n as u32 {
        let home = part.core_of[g as usize] as usize;
        let gl = part.local_of[g as usize] as usize;
        let mut touched_cores: Vec<usize> = Vec::new();
        for &t in net.neuron_targets(g as usize) {
            let tc = part.core_of[t as usize] as usize;
            if tc == home {
                neuron_deg[home][gl] += 1;
            } else {
                let la =
                    local_axon_for(&mut axon_deg, &mut axon_local, &mut remote_axon, tc, false, g);
                axon_deg[tc][la as usize] += 1;
                if !touched_cores.contains(&tc) {
                    touched_cores.push(tc);
                }
            }
        }
        for tc in touched_cores {
            let la = remote_axon[tc][&g];
            neuron_routes[g as usize].push(Delivery { core: tc as u32, local_axon: la });
        }
    }
    for ga in 0..a as u32 {
        let mut touched: Vec<usize> = Vec::new();
        for &t in net.axon_targets(ga as usize) {
            let tc = part.core_of[t as usize] as usize;
            let la =
                local_axon_for(&mut axon_deg, &mut axon_local, &mut remote_axon, tc, true, ga);
            axon_deg[tc][la as usize] += 1;
            if !touched.contains(&tc) {
                touched.push(tc);
            }
        }
        for tc in touched {
            axon_routes[ga as usize]
                .push(Delivery { core: tc as u32, local_axon: axon_local[tc][ga as usize] });
        }
    }

    // ---- pass 2: CSR skeletons from the degree tables, fill by cursor
    let mut subnets: Vec<Network> = (0..n_cores)
        .map(|c| {
            let members = &part.members[c];
            let params = members.iter().map(|&g| net.params[g as usize]).collect();
            let outputs = members
                .iter()
                .enumerate()
                .filter(|(_, &g)| is_output[g as usize])
                .map(|(li, _)| li as u32)
                .collect();
            Network::with_degrees(
                params,
                &neuron_deg[c],
                &axon_deg[c],
                outputs,
                net.base_seed.wrapping_add(c as u32),
            )
        })
        .collect();

    // write cursor per source slot (local neurons, then local axons)
    let mut cursor: Vec<Vec<u32>> = subnets
        .iter()
        .map(|s| {
            s.neuron_off[..s.n_neurons()]
                .iter()
                .chain(s.axon_off[..s.n_axons()].iter())
                .copied()
                .collect()
        })
        .collect();

    fn put(
        subnets: &mut [Network],
        cursor: &mut [Vec<u32>],
        core: usize,
        slot: usize,
        target: u32,
        weight: i16,
    ) {
        let k = cursor[core][slot] as usize;
        subnets[core].syn_targets[k] = target;
        subnets[core].syn_weights[k] = weight;
        cursor[core][slot] += 1;
    }

    for g in 0..n as u32 {
        let home = part.core_of[g as usize] as usize;
        let gl = part.local_of[g as usize] as usize;
        let (tg, wt) = net.neuron_syns(g as usize);
        for (&t, &w) in tg.iter().zip(wt) {
            let tc = part.core_of[t as usize] as usize;
            let tl = part.local_of[t as usize];
            if tc == home {
                put(&mut subnets, &mut cursor, home, gl, tl, w);
            } else {
                let la = remote_axon[tc][&g] as usize;
                let slot = subnets[tc].n_neurons() + la;
                put(&mut subnets, &mut cursor, tc, slot, tl, w);
            }
        }
    }
    for ga in 0..a as u32 {
        let (tg, wt) = net.axon_syns(ga as usize);
        for (&t, &w) in tg.iter().zip(wt) {
            let tc = part.core_of[t as usize] as usize;
            let tl = part.local_of[t as usize];
            let la = axon_local[tc][ga as usize] as usize;
            let slot = subnets[tc].n_neurons() + la;
            put(&mut subnets, &mut cursor, tc, slot, tl, w);
        }
    }
    for s in &mut subnets {
        s.sort_synapses();
    }

    SplitNetwork { subnets, table: RoutingTable { neuron_routes, axon_routes }, axon_local, remote_axon }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::CoreCapacity;
    use crate::snn::{NetworkBuilder, NeuronModel};
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn random_net(rng: &mut Xorshift32, n: usize, a: usize) -> Network {
        let m = NeuronModel::if_neuron(rng.range_i32(3, 20));
        let mut b = NetworkBuilder::new();
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let deg = rng.below(6) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-40, 40)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], m, &refs).unwrap();
        }
        for j in 0..a {
            let deg = 1 + rng.below(5) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-40, 40)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_axon(&format!("a{j}"), &refs).unwrap();
        }
        for i in 0..n {
            if rng.chance(0.25) {
                b.add_output(&keys[i]);
            }
        }
        b.build().unwrap().0
    }

    #[test]
    fn prop_split_conserves_synapses() {
        ptest::check("split_conserves_synapses", 25, |rng| {
            let n = 20 + rng.below(80) as usize;
            let net = random_net(rng, n, 6);
            let topo = ClusterTopology { servers: 2, fpgas_per_server: 2, cores_per_fpga: 2 };
            let cap = CoreCapacity { max_neurons: n.div_ceil(3).max(4), max_synapses: usize::MAX };
            let part = Partition::compute(&net, topo, cap).map_err(|e| e)?;
            let split = split_network(&net, &part);
            let total: usize = split.subnets.iter().map(|s| s.n_synapses()).sum();
            ptest::prop_assert_eq(total, net.n_synapses(), "synapse conservation")?;
            for (c, sub) in split.subnets.iter().enumerate() {
                sub.validate().map_err(|e| format!("core {c}: {e}"))?;
            }
            // every remote route's local axon exists
            for routes in &split.table.neuron_routes {
                for d in routes {
                    ptest::prop_assert(
                        (d.local_axon as usize) < split.subnets[d.core as usize].n_axons(),
                        "route target axon in range",
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn route_step_delivers_and_counts_levels() {
        let mut rng = Xorshift32::new(9);
        let net = random_net(&mut rng, 60, 4);
        let topo = ClusterTopology { servers: 2, fpgas_per_server: 2, cores_per_fpga: 2 };
        let cap = CoreCapacity { max_neurons: 10, max_synapses: usize::MAX };
        let part = Partition::compute(&net, topo, cap).unwrap();
        let split = split_network(&net, &part);
        let mut router = HiaerRouter::new(topo, FabricModel::default(), split.table.clone());

        // fire every neuron once
        let mut fired_by_core: Vec<Vec<u32>> = vec![Vec::new(); topo.n_cores()];
        for g in 0..net.n_neurons() as u32 {
            fired_by_core[part.core_of[g as usize] as usize].push(g);
        }
        let axons: Vec<u32> = (0..net.n_axons() as u32).collect();
        let pending = router.route_step(&fired_by_core, &axons);
        // every axon route delivered
        let delivered: usize = pending.iter().map(Vec::len).sum();
        assert!(delivered > 0);
        for (c, p) in pending.iter().enumerate() {
            assert!(p.windows(2).all(|w| w[0] < w[1]), "core {c} deliveries sorted+deduped");
        }
        let s = router.stats;
        assert!(s.events_by_level[1] + s.events_by_level[2] + s.events_by_level[3] > 0);
        assert!(s.cycles > 0);
    }

    #[test]
    fn no_remote_routes_on_single_core() {
        let mut rng = Xorshift32::new(10);
        let net = random_net(&mut rng, 30, 3);
        let topo = ClusterTopology::single_core();
        let part = Partition::compute(&net, topo, CoreCapacity::default()).unwrap();
        let split = split_network(&net, &part);
        assert!(split.table.neuron_routes.iter().all(|r| r.is_empty()));
        // all global axons land on core 0
        assert!(split.table.axon_routes.iter().all(|r| r.len() <= 1));
        assert_eq!(split.subnets[0].n_synapses(), net.n_synapses());
    }
}
