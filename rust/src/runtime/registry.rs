//! Artifact shape registry: which AOT variants exist and which to pick
//! for a given core size / event batch. Mirrors the size lists in
//! `python/compile/aot.py`.

/// Neuron-update capacities lowered by aot.py (ascending).
pub const NEURON_UPDATE_SIZES: &[usize] = &[1024, 4096, 16384, 65536, 131072];

/// (N, E) synapse-accumulate variants lowered by aot.py.
pub const SYNAPSE_ACCUM_SIZES: &[(usize, usize)] = &[
    (1024, 4096),
    (4096, 16384),
    (16384, 16384),
    (16384, 65536),
    (65536, 65536),
    (131072, 65536),
];

/// Artifact name selection for a core with `n` neurons.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    /// padded neuron capacity
    pub n_pad: usize,
    pub neuron_update: String,
    /// (event capacity, artifact name), ascending by capacity
    pub accum: Vec<(usize, String)>,
}

impl ArtifactRegistry {
    /// Pick the smallest lowered variant that fits `n` neurons.
    pub fn for_core(n: usize) -> Option<ArtifactRegistry> {
        let n_pad = *NEURON_UPDATE_SIZES.iter().find(|&&s| s >= n)?;
        let accum: Vec<(usize, String)> = SYNAPSE_ACCUM_SIZES
            .iter()
            .filter(|&&(an, _)| an == n_pad)
            .map(|&(an, ae)| (ae, format!("synapse_accum_n{an}_e{ae}")))
            .collect();
        if accum.is_empty() {
            return None;
        }
        Some(ArtifactRegistry {
            n_pad,
            neuron_update: format!("neuron_update_n{n_pad}"),
            accum,
        })
    }

    /// Smallest accumulate variant with capacity >= `events`; falls back
    /// to the largest (caller chunks).
    pub fn accum_for(&self, events: usize) -> (usize, &str) {
        for (cap, name) in &self.accum {
            if *cap >= events {
                return (*cap, name);
            }
        }
        let (cap, name) = self.accum.last().expect("non-empty by construction");
        (*cap, name)
    }

    pub fn max_accum_capacity(&self) -> usize {
        self.accum.last().map(|(c, _)| *c).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_smallest_fitting() {
        let r = ArtifactRegistry::for_core(100).unwrap();
        assert_eq!(r.n_pad, 1024);
        assert_eq!(r.neuron_update, "neuron_update_n1024");
        let r = ArtifactRegistry::for_core(1024).unwrap();
        assert_eq!(r.n_pad, 1024);
        let r = ArtifactRegistry::for_core(1025).unwrap();
        assert_eq!(r.n_pad, 4096);
        let r = ArtifactRegistry::for_core(120_000).unwrap();
        assert_eq!(r.n_pad, 131072);
    }

    #[test]
    fn too_large_is_none() {
        assert!(ArtifactRegistry::for_core(200_000).is_none());
    }

    #[test]
    fn accum_selection_and_chunk_fallback() {
        let r = ArtifactRegistry::for_core(10_000).unwrap();
        // n_pad = 16384 has E in {16384, 65536}
        assert_eq!(r.accum_for(100).0, 16384);
        assert_eq!(r.accum_for(20_000).0, 65536);
        // beyond max capacity -> largest returned, caller chunks
        assert_eq!(r.accum_for(1_000_000).0, 65536);
        assert_eq!(r.max_accum_capacity(), 65536);
    }
}
