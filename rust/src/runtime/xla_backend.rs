//! The XLA [`UpdateBackend`]: executes the AOT Pallas/JAX artifacts via
//! PJRT — the simulated equivalent of dispatching the FPGA bitstream's
//! membrane-update pipeline.
//!
//! Padding contract (see aot.py): state is padded to the artifact
//! capacity `n_pad` with `theta = i32::MAX`, `flags = 0` (ANN,
//! deterministic), so pad lanes never spike and hold V = 0. Accumulate
//! events are padded with `target = n_pad`, which the scatter drops.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::{lit_i32, lit_u32_scalar, xla, ArtifactRegistry, Runtime};
use crate::engine::backend::{mask_words, set_mask_bit, CoreParams, UpdateBackend};

pub struct XlaBackend {
    rt: Arc<Runtime>,
    reg: ArtifactRegistry,
    // padded parameter literals, built lazily on first update()
    params_lit: Option<[xla::Literal; 4]>,
    // reusable padded host buffers
    v_pad: Vec<i32>,
    spikes_pad: Vec<i32>,
    tgt_pad: Vec<i32>,
    wgt_pad: Vec<i32>,
}

impl XlaBackend {
    /// Backend for a core of `n` neurons. Fails if no lowered variant is
    /// large enough (the partitioner never produces such cores).
    /// Crate-private: external callers select this path through
    /// [`crate::sim::SimConfig`] with [`crate::sim::Backend::Xla`].
    pub(crate) fn new(rt: Arc<Runtime>, n: usize) -> Result<Self> {
        let reg = ArtifactRegistry::for_core(n)
            .ok_or_else(|| anyhow!("no AOT variant fits a core of {n} neurons"))?;
        // compile eagerly so request-path latency excludes compilation
        rt.load(&reg.neuron_update)?;
        for (_, name) in &reg.accum {
            rt.load(name)?;
        }
        Ok(Self {
            v_pad: vec![0; reg.n_pad],
            spikes_pad: vec![0; reg.n_pad],
            tgt_pad: Vec::new(),
            wgt_pad: Vec::new(),
            params_lit: None,
            reg,
            rt,
        })
    }

    pub fn n_pad(&self) -> usize {
        self.reg.n_pad
    }

    fn build_params(&mut self, params: &CoreParams) {
        let n_pad = self.reg.n_pad;
        let pad = |src: &[i32], fill: i32| -> Vec<i32> {
            let mut v = Vec::with_capacity(n_pad);
            v.extend_from_slice(src);
            v.resize(n_pad, fill);
            v
        };
        let theta = pad(&params.theta, i32::MAX);
        let nu = pad(&params.nu, 0);
        let lam = pad(&params.lam, 0);
        let flags: Vec<i32> = params
            .flags
            .iter()
            .map(|&f| f as i32)
            .chain(std::iter::repeat(0))
            .take(n_pad)
            .collect();
        self.params_lit =
            Some([lit_i32(&theta), lit_i32(&nu), lit_i32(&lam), lit_i32(&flags)]);
    }
}

impl UpdateBackend for XlaBackend {
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> Result<()> {
        let n = v.len();
        debug_assert_eq!(spikes.len(), mask_words(n));
        if self.params_lit.is_none() {
            self.build_params(params);
        }
        self.v_pad[..n].copy_from_slice(v);
        self.v_pad[n..].iter_mut().for_each(|x| *x = 0);
        let [theta, nu, lam, flags] = self.params_lit.as_ref().unwrap();
        let args = [
            lit_i32(&self.v_pad),
            theta.clone(),
            nu.clone(),
            lam.clone(),
            flags.clone(),
            lit_u32_scalar(step_seed),
        ];
        let out = self.rt.execute(&self.reg.neuron_update, &args)?;
        out[0].copy_raw_to(&mut self.v_pad)?;
        out[1].copy_raw_to(&mut self.spikes_pad)?;
        v.copy_from_slice(&self.v_pad[..n]);
        // pack the artifact's 0/1 vector into the engine's bitmask words
        spikes.fill(0);
        for (i, &s) in self.spikes_pad[..n].iter().enumerate() {
            if s != 0 {
                set_mask_bit(spikes, i);
            }
        }
        Ok(())
    }

    fn accumulate(&mut self, v: &mut [i32], events: &[(u32, i32)]) -> Result<()> {
        let n = v.len();
        let n_pad = self.reg.n_pad;
        self.v_pad[..n].copy_from_slice(v);
        self.v_pad[n..].iter_mut().for_each(|x| *x = 0);

        // chunk through the largest variant if the event batch overflows
        let mut off = 0;
        while off < events.len() || off == 0 {
            let remaining = events.len() - off;
            let (cap, name) = self.reg.accum_for(remaining);
            let take = remaining.min(cap);
            self.tgt_pad.clear();
            self.wgt_pad.clear();
            for &(t, w) in &events[off..off + take] {
                self.tgt_pad.push(t as i32);
                self.wgt_pad.push(w);
            }
            self.tgt_pad.resize(cap, n_pad as i32); // dropped by scatter
            self.wgt_pad.resize(cap, 0);
            let args = [lit_i32(&self.v_pad), lit_i32(&self.tgt_pad), lit_i32(&self.wgt_pad)];
            let out = self.rt.execute(name, &args)?;
            out[0].copy_raw_to(&mut self.v_pad)?;
            off += take;
            if events.is_empty() {
                break;
            }
        }
        v.copy_from_slice(&self.v_pad[..n]);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::RustBackend;
    use crate::runtime::{artifacts_dir, have_artifacts};
    use crate::util::prng::Xorshift32;

    fn rand_params(rng: &mut Xorshift32, n: usize) -> (CoreParams, Vec<i32>) {
        let mut p = CoreParams::default();
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            p.theta.push(rng.range_i32(0, 1 << 16));
            p.nu.push(rng.range_i32(-32, 32));
            p.lam.push(rng.range_i32(0, 64));
            p.flags.push(rng.below(4));
            v.push(rng.range_i32(-(1 << 20), 1 << 20));
        }
        (p, v)
    }

    #[test]
    fn xla_backend_matches_rust_backend() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Arc::new(Runtime::cpu(artifacts_dir()).unwrap());
        let mut rng = Xorshift32::new(77);
        let n = 300; // deliberately not a multiple of the pad size
        let (params, v0) = rand_params(&mut rng, n);
        let mut xla_b = XlaBackend::new(rt, n).unwrap();
        let mut rust_b = RustBackend;

        let mut v1 = v0.clone();
        let mut s1 = vec![0u64; mask_words(n)];
        rust_b.update(&mut v1, &params, 0xABCD, &mut s1).unwrap();
        let mut v2 = v0.clone();
        let mut s2 = vec![0u64; mask_words(n)];
        xla_b.update(&mut v2, &params, 0xABCD, &mut s2).unwrap();
        assert_eq!(s1, s2, "spike masks diverge");
        assert_eq!(v1, v2, "membranes diverge");

        // accumulate parity incl. empty batch
        let events: Vec<(u32, i32)> =
            (0..500).map(|_| (rng.below(n as u32), rng.range_i32(-100, 100))).collect();
        rust_b.accumulate(&mut v1, &events).unwrap();
        xla_b.accumulate(&mut v2, &events).unwrap();
        assert_eq!(v1, v2);
        rust_b.accumulate(&mut v1, &[]).unwrap();
        xla_b.accumulate(&mut v2, &[]).unwrap();
        assert_eq!(v1, v2);
    }
}
