//! PJRT/XLA runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (HLO text, see `python/compile/aot.py`) and serves them to the
//! engines. This is the only place the `xla` crate is touched.
//!
//! Python never runs here: `make artifacts` produced the HLO once; this
//! module compiles it on the PJRT CPU client at startup and executes it
//! on the request path.
//!
//! # The `pjrt` cargo feature
//!
//! Default builds compile only the in-tree [`xla`] offline stub: the
//! same API surface, but artifact loading reports a clean error, and
//! `SimConfig::backend(Backend::Xla)` fails fast with
//! `SimError::BackendUnavailable` (see [`pjrt_enabled`]). Building with
//! `--features pjrt` declares that the real PJRT bindings are linked in
//! place of the stub (swap the `xla` module for the vendored bindings
//! crate here — a one-line change); the facade then constructs the XLA
//! backend and any remaining failure is a real artifact/linker error.
//! Artifact-dependent tests and benches probe for `artifacts/` first
//! and skip, so the stub never changes behavior of a default checkout.

mod registry;
pub mod xla;
mod xla_backend;

pub use registry::{ArtifactRegistry, NEURON_UPDATE_SIZES, SYNAPSE_ACCUM_SIZES};
pub use xla_backend::XlaBackend;

/// True when this binary was built with the `pjrt` cargo feature, i.e.
/// the XLA/PJRT execution path is meant to be live. The facade
/// (`sim::Backend::Xla`) refuses to construct the backend when this is
/// false, so default builds fail fast instead of erroring deep inside
/// artifact loading.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A PJRT client plus a cache of compiled executables keyed by artifact
/// name. Compilation happens once per name (lazily); execution is
/// thread-safe through PJRT itself — the mutex only guards the cache map.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// CPU-PJRT runtime over an artifact directory (usually `artifacts/`).
    pub fn cpu<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.hlo.txt` (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact. All our artifacts are lowered with
    /// `return_tuple=True`, so the single result literal is a tuple that
    /// we decompose for the caller.
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        self.execute_loaded(&exe, args, name)
    }

    /// Execute a pre-loaded executable (hot-path variant: no cache lock).
    pub fn execute_loaded(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
        name: &str,
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing artifact {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        lit.to_tuple().context("decomposing result tuple")
    }
}

/// Helper for int32 literals.
pub fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Helper for scalar u32 literals (the step seed).
pub fn lit_u32_scalar(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
pub(crate) fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
pub(crate) fn have_artifacts() -> bool {
    artifacts_dir().join("neuron_update_n1024.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_and_execute_synapse_accum() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu(artifacts_dir()).unwrap();
        let n = 1024usize;
        let e = 4096usize;
        let mut v = vec![0i32; n];
        v[7] = 5;
        let mut targets = vec![n as i32; e]; // all dropped
        let mut weights = vec![0i32; e];
        targets[0] = 7;
        weights[0] = 3;
        targets[1] = 0;
        weights[1] = -2;
        let out = rt
            .execute(
                "synapse_accum_n1024_e4096",
                &[lit_i32(&v), lit_i32(&targets), lit_i32(&weights)],
            )
            .unwrap();
        let got = out[0].to_vec::<i32>().unwrap();
        assert_eq!(got[7], 8);
        assert_eq!(got[0], -2);
        assert!(got[1..7].iter().all(|&x| x == 0));
    }

    #[test]
    fn executable_cache_hit() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::cpu(artifacts_dir()).unwrap();
        let a = rt.load("synapse_accum_n1024_e4096").unwrap();
        let b = rt.load("synapse_accum_n1024_e4096").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }
}
