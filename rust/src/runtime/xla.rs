//! Offline stand-in for the `xla` PJRT bindings crate.
//!
//! The real bindings (xla-rs) need libxla shared objects and a network
//! fetch, neither of which exists in this fully-offline build. This
//! module mirrors the small API surface the runtime touches so the crate
//! compiles and runs everywhere; any attempt to actually parse/compile/
//! execute an artifact returns a clean "XLA support not built" error.
//! Artifact-gated tests and benches already skip when `artifacts/` is
//! absent, so the stub only ever surfaces as a diagnostic. Swapping in
//! the real crate is a one-line change in `runtime/mod.rs` plus the
//! Cargo dependency.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    let hint = if cfg!(feature = "pjrt") {
        "the `pjrt` feature is enabled but the real libxla bindings are not \
         vendored into this offline build — swap this stub for the bindings \
         crate in runtime/mod.rs"
    } else {
        "rebuild with `--features pjrt` and the vendored `xla` bindings to \
         execute AOT artifacts"
    };
    Err(Error(format!(
        "{what}: XLA/PJRT support is not built into this binary (offline stub); {hint}"
    )))
}

/// Stub PJRT client: constructible (so the runtime can start and report
/// a useful platform name) but unable to compile.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling HLO computation")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!("loading HLO text {}", path.as_ref().display()))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing artifact")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching device buffer")
    }
}

/// Opaque host literal; never holds data in the stub because no
/// executable can produce or consume one.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_v: &[i32]) -> Literal {
        Literal
    }

    pub fn scalar(_v: u32) -> Literal {
        Literal
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("decomposing result tuple")
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("copying literal")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("reading literal")
    }
}
