//! Evaluation harness shared by the benches and examples: loads the
//! trained-model manifest (`models/manifest.json`), converts `.hsl`
//! layer graphs, evaluates them on `.hsd` test sets with the paper's
//! readout protocols, and prints Table-2-style rows.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::convert::{convert, run_inference, BiasMode, Converted, Readout};
use crate::energy::EnergyModel;
use crate::metrics::CostSeries;
use crate::model_fmt::{hsl::read_hsl, read_hsd, LayerGraph, TestSet};
use crate::sim::SimOptions;
use crate::util::json::Json;

/// One entry of models/manifest.json.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub task: String,
    pub kind: String,
    pub readout: Readout,
    pub input: (usize, usize, usize),
    pub timesteps: usize,
    pub acc_float: f64,
    pub acc_quant: f64,
    pub params: u64,
}

pub fn load_manifest(models_dir: &Path) -> Result<Vec<ModelEntry>> {
    let path = models_dir.join("manifest.json");
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "reading {} — run `make models` (python -m train.train_all) first",
            path.display()
        )
    })?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
    let obj = match &j {
        Json::Obj(m) => m,
        _ => return Err(anyhow!("manifest is not an object")),
    };
    let mut entries = Vec::new();
    for (name, v) in obj {
        let f = |k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let s = |k: &str| v.get(k).and_then(Json::as_str).unwrap_or("").to_string();
        let input = v
            .get("input")
            .and_then(Json::int_vec)
            .unwrap_or_else(|| vec![1, 1, 1]);
        entries.push(ModelEntry {
            name: name.clone(),
            task: s("task"),
            kind: s("kind"),
            readout: if s("readout") == "rate" { Readout::Rate } else { Readout::Membrane },
            input: (input[0] as usize, input[1] as usize, input[2] as usize),
            timesteps: f("timesteps") as usize,
            acc_float: f("acc_float"),
            acc_quant: f("acc_quant"),
            params: f("params") as u64,
        });
    }
    // stable, readable order: by task then size
    entries.sort_by(|a, b| (a.task.clone(), a.params).cmp(&(b.task.clone(), b.params)));
    Ok(entries)
}

/// Default models dir: $HIAER_MODELS or <manifest dir>/models.
pub fn models_dir() -> PathBuf {
    if let Ok(d) = std::env::var("HIAER_MODELS") {
        return PathBuf::from(d);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("models")
}

/// Load + convert one model.
pub fn load_model(models_dir: &Path, name: &str) -> Result<(LayerGraph, Converted)> {
    let graph = read_hsl(models_dir.join(format!("{name}.hsl")))?;
    let conv = convert(&graph, BiasMode::Threshold, 0)?;
    Ok((graph, conv))
}

/// Result of evaluating a model on its test set.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub name: String,
    pub accuracy: f64,
    pub n_samples: usize,
    pub axons: usize,
    pub neurons: usize,
    pub weights: usize,
    pub energy_mean: f64,
    pub energy_std: f64,
    pub latency_mean: f64,
    pub latency_std: f64,
    pub series: CostSeries,
}

/// Evaluate `name` on its `.hsd` test set (at most `max_samples`). The
/// deployment (backend, topology, HBM strategy) comes from `opts`; one
/// [`crate::sim::Simulator`] session is built per model and reused
/// (reset between) across every sample.
pub fn evaluate_model(
    models_dir: &Path,
    entry: &ModelEntry,
    max_samples: usize,
    opts: &SimOptions,
) -> Result<EvalResult> {
    let (graph, conv) = load_model(models_dir, &entry.name)?;
    let ts: TestSet = read_hsd(models_dir.join(format!("{}.hsd", entry.name)))?;
    let mut engine = opts.clone().into_config(conv.net.clone()).build()?;
    let energy = EnergyModel::default();
    let layers = graph.layers.len();

    let mut series = CostSeries::default();
    let mut correct = 0usize;
    let n = ts.samples.len().min(max_samples);
    for sample in &ts.samples[..n] {
        let inf =
            run_inference(&mut *engine, &conv, &sample.frames, layers, entry.readout, &energy)?;
        if inf.prediction == sample.label as usize {
            correct += 1;
        }
        series.push(&inf.cost);
    }
    let (em, es) = series.energy_mean_std();
    let (lm, ls) = series.latency_mean_std();
    Ok(EvalResult {
        name: entry.name.clone(),
        accuracy: correct as f64 / n.max(1) as f64,
        n_samples: n,
        axons: conv.net.n_axons(),
        neurons: conv.net.n_neurons(),
        weights: conv.net.n_synapses(),
        energy_mean: em,
        energy_std: es,
        latency_mean: lm,
        latency_std: ls,
        series,
    })
}

/// Print a Table-2 style row.
pub fn print_row(entry: &ModelEntry, r: &EvalResult) {
    println!(
        "{:<12} {:>14} {:<12} {:>7} {:>8} {:>9}  {:>8.2}  {:>8.2}  {:>12}  {:>14}",
        entry.name,
        format!("({},{},{})", entry.input.0, entry.input.1, entry.input.2),
        entry.task,
        r.axons,
        r.neurons,
        r.weights,
        entry.acc_quant * 100.0,
        r.accuracy * 100.0,
        format!("{:.1}±{:.1}", r.energy_mean, r.energy_std),
        format!("{:.1}±{:.1}", r.latency_mean, r.latency_std),
    );
}

pub fn print_header() {
    println!(
        "{:<12} {:>14} {:<12} {:>7} {:>8} {:>9}  {:>8}  {:>8}  {:>12}  {:>14}",
        "Model", "Input", "Task", "Axons", "Neurons", "Weights", "SW Acc%", "HiAER%",
        "Energy(uJ)", "Latency(us)"
    );
    println!("{}", "-".repeat(118));
}
