//! The network -> HBM image compiler (Fig 7 + §4 packing rules).

use std::collections::HashMap;

use thiserror::Error;

use super::{Pointer, SynEntry, CORE_HBM_BYTES, ROW_SLOTS, SLOT_BYTES, SYN_OUTPUT, SYN_VALID};
use crate::snn::{NetView, NeuronModel};

#[derive(Debug, Error)]
pub enum LayoutError {
    #[error("network does not fit core HBM: needs {need} bytes > {cap}")]
    Capacity { need: usize, cap: usize },
    #[error("invalid network: {0}")]
    BadNetwork(String),
}

/// Postsynaptic-neuron slot assignment strategy — the packing-density
/// knob the paper's compiler turns. Benchmarked by the ablation bench
/// (`hot_path --ablation`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotStrategy {
    /// slot = local neuron id % 16 (no optimisation).
    Modulo,
    /// Balance total fan-in across the 16 slots (greedy, descending
    /// fan-in) so each source's synapses spread evenly over slots,
    /// minimising its row count.
    BalanceFanIn,
}

/// Layout quality numbers (reported by benches and `info`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayoutStats {
    pub synapse_rows: usize,
    pub filled_slots: usize,
    pub dummy_slots: usize,
    /// filled / (rows * 16)
    pub packing_density: f64,
    pub total_bytes: usize,
}

/// A compiled per-core HBM image.
#[derive(Clone, Debug)]
pub struct HbmImage {
    pub n_neurons: usize,
    pub n_axons: usize,
    /// Neuron-model directory (deduplicated), section 0.
    pub models: Vec<NeuronModel>,
    /// Per-neuron model index into `models`.
    pub model_of: Vec<u16>,
    /// Slot (0..16) of each local neuron — its membrane-lane binding.
    pub slot_of: Vec<u8>,
    /// Section-1 pointers by axon id.
    pub axon_ptr: Vec<Pointer>,
    /// Section-2 pointers by local neuron id.
    pub neuron_ptr: Vec<Pointer>,
    /// Pointer-row address of each axon (section-relative row).
    pub axon_ptr_row: Vec<u32>,
    /// Pointer-row address of each neuron. Grouped by model (Supp A.3),
    /// so neurons sharing a model sit in adjacent pointer rows.
    pub neuron_ptr_row: Vec<u32>,
    /// Section 3: the synapse rows.
    pub syn_rows: Vec<[SynEntry; ROW_SLOTS]>,
    /// Per-row occupancy bitmask (bit s = slot s holds a valid, non-zero
    /// synapse). §Perf: the phase-2 stream skips empty slots via
    /// trailing_zeros instead of scanning all 16 entries — packing
    /// density is ~0.3 on converted models, so this roughly 3x-es the
    /// region-read inner loop. Purely an iteration index: the modelled
    /// HBM traffic (row reads) is unchanged.
    pub row_mask: Vec<u16>,
    pub stats: LayoutStats,
}

impl HbmImage {
    /// Stream the valid entries of one synapse region in row/slot order,
    /// without access accounting — the counting wrapper for the serial
    /// engine is [`crate::hbm::HbmSim::read_region`]; the chunk-parallel
    /// route gather calls this from many worker threads (`&self`) and
    /// reconstructs per-region row/event totals in the merge epilogue
    /// (rows = `ptr.rows`, events = entries emitted).
    #[inline]
    pub fn scan_region<F: FnMut(&SynEntry)>(&self, ptr: Pointer, mut f: F) {
        let (s, e) = (ptr.start_row as usize, (ptr.start_row + ptr.rows) as usize);
        let masks = &self.row_mask[s..e];
        for (row, &mask) in self.syn_rows[s..e].iter().zip(masks) {
            let mut m = mask;
            while m != 0 {
                let slot = m.trailing_zeros() as usize;
                m &= m - 1;
                f(&row[slot]);
            }
        }
    }

    /// Compile a network (one core's partition) into an HBM image.
    ///
    /// Generic over the borrowed-CSR view: pass `&Network` or an
    /// mmap-backed [`crate::model_fmt::NetFile`] view — compilation
    /// reads the CSR slices in place either way.
    pub fn compile<'a>(
        net: impl Into<NetView<'a>>,
        strategy: SlotStrategy,
    ) -> Result<HbmImage, LayoutError> {
        let net: NetView<'_> = net.into();
        net.validate().map_err(LayoutError::BadNetwork)?;
        let n = net.n_neurons();
        let a = net.n_axons();

        // --- model directory: dedupe params, group neurons by model
        let mut models: Vec<NeuronModel> = Vec::new();
        let mut model_ids: HashMap<NeuronModel, u16> = HashMap::new();
        let mut model_of = vec![0u16; n];
        for (i, p) in net.params.iter().enumerate() {
            let id = *model_ids.entry(*p).or_insert_with(|| {
                models.push(*p);
                (models.len() - 1) as u16
            });
            model_of[i] = id;
        }

        // --- slot assignment
        let slot_of = assign_slots(net, strategy);

        // --- synapse section: place sources one after another, each
        // streaming its contiguous CSR (targets, weights) slice — no
        // per-neuron Vec chasing. Order: axons first (Fig 7 walks
        // axons), then neurons grouped by model (Supp A.3 groups neuron
        // pointers by model).
        let mut rows: Vec<[SynEntry; ROW_SLOTS]> = Vec::new();
        let mut filled = 0usize;
        let mut dummy = 0usize;
        // per-slot fill depth within the current source's region (reused)
        let mut depth = [0usize; ROW_SLOTS];

        let mut place_source =
            |targets: &[u32], weights: &[i16], is_output_src: bool| -> Pointer {
                // rows needed = max synapses landing in one slot
                depth.fill(0);
                for &t in targets {
                    depth[slot_of[t as usize] as usize] += 1;
                }
                let mut need = depth.iter().copied().max().unwrap_or(0);
                if targets.is_empty() {
                    // Leaf source (output or not): one row of 16
                    // zero-weight dummy synapses, so "every neuron has a
                    // space in the synapse section" (Supp A.3).
                    need = 1;
                }
                let start = rows.len();
                rows.resize(start + need, [SynEntry::default(); ROW_SLOTS]);
                depth.fill(0);
                for (&t, &w) in targets.iter().zip(weights) {
                    let slot = slot_of[t as usize] as usize;
                    rows[start + depth[slot]][slot] =
                        SynEntry { target: t, weight: w, flags: SYN_VALID };
                    depth[slot] += 1;
                    filled += 1;
                }
                if targets.is_empty() {
                    // fill the dummy row with zero-weight valid slots
                    for slot in 0..ROW_SLOTS {
                        rows[start][slot] = SynEntry { target: 0, weight: 0, flags: SYN_VALID };
                        dummy += 1;
                    }
                }
                if is_output_src {
                    // set the output flag on the first valid entry
                    'outer: for r in rows[start..start + need].iter_mut() {
                        for e in r.iter_mut() {
                            if e.is_valid() {
                                e.flags |= SYN_OUTPUT;
                                break 'outer;
                            }
                        }
                    }
                }
                Pointer { start_row: start as u32, rows: need as u32 }
            };

        let is_output: Vec<bool> = {
            let mut v = vec![false; n];
            for &o in net.outputs {
                v[o as usize] = true;
            }
            v
        };

        let axon_ptr: Vec<Pointer> = (0..a)
            .map(|i| {
                let (tg, wt) = net.axon_syns(i);
                place_source(tg, wt, false)
            })
            .collect();

        // neurons in model-grouped order
        let mut grouped: Vec<u32> = (0..n as u32).collect();
        grouped.sort_by_key(|&i| (model_of[i as usize], i));
        let mut neuron_ptr = vec![Pointer::default(); n];
        let mut neuron_ptr_row = vec![0u32; n];
        for (pos, &i) in grouped.iter().enumerate() {
            let (tg, wt) = net.neuron_syns(i as usize);
            neuron_ptr[i as usize] = place_source(tg, wt, is_output[i as usize]);
            neuron_ptr_row[i as usize] = (pos / ROW_SLOTS) as u32;
        }
        let axon_ptr_row: Vec<u32> = (0..a).map(|i| (i / ROW_SLOTS) as u32).collect();

        let synapse_rows = rows.len();
        let ptr_rows = a.div_ceil(ROW_SLOTS) + n.div_ceil(ROW_SLOTS);
        let model_rows = models.len(); // one row per model definition
        let total_bytes = (synapse_rows + ptr_rows + model_rows) * ROW_SLOTS * SLOT_BYTES;
        if total_bytes > CORE_HBM_BYTES {
            return Err(LayoutError::Capacity { need: total_bytes, cap: CORE_HBM_BYTES });
        }
        let stats = LayoutStats {
            synapse_rows,
            filled_slots: filled,
            dummy_slots: dummy,
            packing_density: if synapse_rows == 0 {
                1.0
            } else {
                filled as f64 / (synapse_rows * ROW_SLOTS) as f64
            },
            total_bytes,
        };

        let row_mask: Vec<u16> = rows
            .iter()
            .map(|row| {
                let mut m = 0u16;
                for (s, e) in row.iter().enumerate() {
                    if e.is_valid() && e.weight != 0 {
                        m |= 1 << s;
                    }
                }
                m
            })
            .collect();

        Ok(HbmImage {
            n_neurons: n,
            n_axons: a,
            models,
            model_of,
            slot_of,
            axon_ptr,
            neuron_ptr,
            axon_ptr_row,
            neuron_ptr_row,
            syn_rows: rows,
            row_mask,
            stats,
        })
    }

    /// Structural invariants — exercised by the property tests:
    /// 1. regions are disjoint and in-bounds;
    /// 2. every network synapse appears exactly once, slot-aligned;
    /// 3. every valid entry lies inside exactly one region;
    /// 4. output neurons carry the flag; leaf neurons have the dummy row.
    pub fn validate<'a>(&self, net: impl Into<NetView<'a>>) -> Result<(), String> {
        let net: NetView<'_> = net.into();
        let nrows = self.syn_rows.len();
        let mut owner: Vec<i64> = vec![-1; nrows];
        let mut check_region = |ptr: &Pointer, id: i64| -> Result<(), String> {
            let (s, e) = (ptr.start_row as usize, (ptr.start_row + ptr.rows) as usize);
            if e > nrows {
                return Err(format!("region of source {id} out of bounds"));
            }
            for r in s..e {
                if owner[r] != -1 {
                    return Err(format!("row {r} owned by {} and {id}", owner[r]));
                }
                owner[r] = id;
            }
            Ok(())
        };
        for (i, p) in self.axon_ptr.iter().enumerate() {
            check_region(p, i as i64)?;
        }
        for (i, p) in self.neuron_ptr.iter().enumerate() {
            check_region(p, (self.n_axons + i) as i64)?;
        }

        // every valid entry belongs to a region
        for (r, row) in self.syn_rows.iter().enumerate() {
            for (slot, e) in row.iter().enumerate() {
                if e.is_valid() && owner[r] == -1 {
                    return Err(format!("orphan valid entry at row {r} slot {slot}"));
                }
                if !e.is_valid() && e.flags != 0 {
                    return Err(format!("flags on invalid entry at row {r} slot {slot}"));
                }
            }
        }

        // synapse multiset per source matches the network, slot aligned
        let collect = |ptr: &Pointer| -> Vec<(u32, i16)> {
            let mut v = Vec::new();
            for r in ptr.start_row..ptr.start_row + ptr.rows {
                for (slot, e) in self.syn_rows[r as usize].iter().enumerate() {
                    if e.is_valid() && e.weight != 0 {
                        if self.slot_of[e.target as usize] as usize != slot {
                            // caught below through the error string
                            v.push((u32::MAX, 0));
                        } else {
                            v.push((e.target, e.weight));
                        }
                    }
                }
            }
            v.sort_unstable();
            v
        };
        let norm = |tg: &[u32], wt: &[i16]| -> Vec<(u32, i16)> {
            let mut v: Vec<(u32, i16)> = tg
                .iter()
                .zip(wt)
                .filter(|(_, &w)| w != 0)
                .map(|(&t, &w)| (t, w))
                .collect();
            v.sort_unstable();
            v
        };
        for (i, p) in self.axon_ptr.iter().enumerate() {
            let (tg, wt) = net.axon_syns(i);
            if collect(p) != norm(tg, wt) {
                return Err(format!("axon {i} synapse mismatch"));
            }
        }
        for (i, p) in self.neuron_ptr.iter().enumerate() {
            let (tg, wt) = net.neuron_syns(i);
            if collect(p) != norm(tg, wt) {
                return Err(format!("neuron {i} synapse mismatch"));
            }
        }

        // output flags
        let mut is_output = vec![false; self.n_neurons];
        for &o in net.outputs {
            is_output[o as usize] = true;
        }
        for (i, p) in self.neuron_ptr.iter().enumerate() {
            let mut has_flag = false;
            for r in p.start_row..p.start_row + p.rows {
                for e in &self.syn_rows[r as usize] {
                    if e.flags & SYN_OUTPUT != 0 {
                        has_flag = true;
                    }
                }
            }
            if has_flag != is_output[i] {
                return Err(format!("neuron {i}: output flag {has_flag} != {}", is_output[i]));
            }
            if p.rows == 0 {
                return Err(format!("neuron {i} has empty region"));
            }
        }
        Ok(())
    }
}

/// Choose each neuron's slot (membrane lane).
fn assign_slots(net: NetView<'_>, strategy: SlotStrategy) -> Vec<u8> {
    let n = net.n_neurons();
    match strategy {
        SlotStrategy::Modulo => (0..n).map(|i| (i % ROW_SLOTS) as u8).collect(),
        SlotStrategy::BalanceFanIn => {
            // Greedy: neurons in descending fan-in order go to the slot
            // with the least accumulated fan-in. Sources whose targets
            // spread evenly over slots need fewer rows.
            let fan_in = net.fan_in();
            let mut order: Vec<u32> = (0..n as u32).collect();
            order.sort_by_key(|&i| std::cmp::Reverse(fan_in[i as usize]));
            let mut load = [0u64; ROW_SLOTS];
            let mut slot_of = vec![0u8; n];
            for &i in &order {
                let best = (0..ROW_SLOTS).min_by_key(|&s| load[s]).unwrap();
                slot_of[i as usize] = best as u8;
                load[best] += fan_in[i as usize] as u64 + 1;
            }
            slot_of
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{NetworkBuilder, NeuronModel};
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn fig6() -> Network {
        let lif_ab = NeuronModel::lif(3, 0, 63, false).unwrap();
        let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
        let ann_d = NeuronModel::ann(5, 0, true).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("a", lif_ab, &[("b", 1), ("d", 2)]).unwrap();
        b.add_neuron("b", lif_ab, &[]).unwrap();
        b.add_neuron("c", lif_c, &[]).unwrap();
        b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
        b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
        b.add_axon("beta", &[("b", 3)]).unwrap();
        b.add_output("a");
        b.add_output("b");
        b.build().unwrap().0
    }

    pub fn arbitrary_network(rng: &mut Xorshift32, max_n: usize) -> Network {
        let n = rng.below(max_n as u32).max(1) as usize;
        let a = rng.below(32).max(1) as usize;
        let models = [
            NeuronModel::lif(rng.range_i32(1, 100), 0, 63, false).unwrap(),
            NeuronModel::ann(rng.range_i32(1, 50), -4, true).unwrap(),
            NeuronModel::lif(rng.range_i32(1, 80), -8, 3, true).unwrap(),
        ];
        let mut b = NetworkBuilder::new();
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let deg = rng.below(20) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-100, 100)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], models[rng.below(3) as usize], &refs).unwrap();
        }
        for i in 0..a {
            let deg = rng.below(12) as usize;
            let syns: Vec<(String, i32)> = (0..deg)
                .map(|_| (keys[rng.below(n as u32) as usize].clone(), rng.range_i32(-100, 100)))
                .collect();
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_axon(&format!("a{i}"), &refs).unwrap();
        }
        for i in 0..n {
            if rng.chance(0.2) {
                b.add_output(&keys[i]);
            }
        }
        b.build().unwrap().0
    }

    #[test]
    fn fig6_layout_valid_both_strategies() {
        let net = fig6();
        for strat in [SlotStrategy::Modulo, SlotStrategy::BalanceFanIn] {
            let img = HbmImage::compile(&net, strat).unwrap();
            img.validate(&net).unwrap();
            assert_eq!(img.n_neurons, 4);
            assert_eq!(img.models.len(), 3);
        }
    }

    #[test]
    fn leaf_neurons_get_dummy_row() {
        let net = fig6();
        let img = HbmImage::compile(&net, SlotStrategy::Modulo).unwrap();
        // neurons b and c have no outgoing synapses -> full dummy rows
        for i in [1usize, 2] {
            let p = img.neuron_ptr[i];
            assert_eq!(p.rows, 1);
            let row = &img.syn_rows[p.start_row as usize];
            assert!(row.iter().all(|e| e.is_valid() && e.weight == 0));
        }
        assert!(img.stats.dummy_slots >= 32);
    }

    #[test]
    fn slot_alignment_constraint() {
        let net = fig6();
        let img = HbmImage::compile(&net, SlotStrategy::BalanceFanIn).unwrap();
        for p in img.axon_ptr.iter().chain(img.neuron_ptr.iter()) {
            for r in p.start_row..p.start_row + p.rows {
                for (slot, e) in img.syn_rows[r as usize].iter().enumerate() {
                    if e.is_valid() && e.weight != 0 {
                        assert_eq!(img.slot_of[e.target as usize] as usize, slot);
                    }
                }
            }
        }
    }

    #[test]
    fn prop_layout_invariants_random_networks() {
        ptest::check("hbm_layout_invariants", 60, |rng| {
            let net = arbitrary_network(rng, 200);
            for strat in [SlotStrategy::Modulo, SlotStrategy::BalanceFanIn] {
                let img = HbmImage::compile(&net, strat)
                    .map_err(|e| format!("compile failed: {e}"))?;
                img.validate(&net)?;
            }
            Ok(())
        });
    }

    #[test]
    fn balanced_strategy_never_worse_on_heavy_fan_in() {
        // A hub network: all sources target the same few neurons. Modulo
        // numbering puts hot targets in few slots; balancing spreads them.
        let m = NeuronModel::if_neuron(10);
        let mut b = NetworkBuilder::new();
        for i in 0..64 {
            b.add_neuron(&format!("n{i}"), m, &[]).unwrap();
        }
        // rebuild with synapses: sources 0..32 each hit targets 32..36
        let mut b2 = NetworkBuilder::new();
        for i in 0..64u32 {
            let syns: Vec<(String, i32)> = if i < 32 {
                (32..36).map(|t| (format!("n{t}"), 5)).collect()
            } else {
                vec![]
            };
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b2.add_neuron(&format!("n{i}"), m, &refs).unwrap();
        }
        drop(b);
        let net = b2.build().unwrap().0;
        let naive = HbmImage::compile(&net, SlotStrategy::Modulo).unwrap();
        let opt = HbmImage::compile(&net, SlotStrategy::BalanceFanIn).unwrap();
        naive.validate(&net).unwrap();
        opt.validate(&net).unwrap();
        assert!(opt.stats.synapse_rows <= naive.stats.synapse_rows);
        assert!(opt.stats.packing_density >= naive.stats.packing_density);
    }

    #[test]
    fn capacity_error() {
        // A network whose synapse section alone exceeds the per-core HBM
        // budget (simulate by row math, not allocation: 256M rows needed).
        // We can't build a billion synapses in a unit test; instead check
        // the arithmetic boundary via a tiny fake: CORE_HBM_BYTES rows.
        // (Real capacity handling is exercised by the partitioner tests.)
        let need_rows = CORE_HBM_BYTES / (ROW_SLOTS * SLOT_BYTES) + 1;
        assert!(need_rows > 1_000_000); // sanity: budget is large
    }
}
