//! HBM synaptic routing-table simulator (paper §4, Fig 2, Fig 7, Supp A.3).
//!
//! Each FPGA core owns a slice of the 8 GB on-module HBM, organised as:
//!
//! ```text
//! +------------------+  section 0: neuron model definitions
//! | model directory  |
//! +------------------+  section 1: axon pointers   (16 pointers / row)
//! | axon pointers    |
//! +------------------+  section 2: neuron pointers (grouped by model)
//! | neuron pointers  |
//! +------------------+  section 3: synapses        (16 slots / row)
//! | synapse rows     |
//! +------------------+
//! ```
//!
//! A row holds 16 slots; a segment spans two rows (the HBM burst unit for
//! the paper's 16-neuron-parallel core). Each slot stores one pointer or
//! one synapse. The *alignment constraint*: a synapse must occupy the slot
//! number of its postsynaptic neuron (`slot == slot_of[target]`), because
//! the 16 membrane-update lanes are bound to slot positions. Pointers
//! store `(start_row, n_rows)` — base + length, not absolute addresses —
//! and all synapses of one source occupy a contiguous, exclusive row range.
//!
//! The compiler ([`layout`]) packs the network into this structure and can
//! renumber neurons across slots to maximise packing density (the paper's
//! "adjusts the neuron and axon assignments"). The simulator ([`sim`])
//! serves the two-phase spike routing with per-row access counting, which
//! the energy/latency model consumes exactly the way the paper derives
//! energy from FPGA-reported HBM access counts.

pub mod layout;
pub mod sim;

pub use layout::{HbmImage, LayoutError, LayoutStats, SlotStrategy};
pub use sim::{AccessCounters, HbmSim};

/// Slots per HBM row (pointer or synapse entries).
pub const ROW_SLOTS: usize = 16;
/// Rows per segment (the two-row burst granule of Fig 2).
pub const SEGMENT_ROWS: usize = 2;
/// Bytes per slot (64-bit: 32b target + 16b weight + 8b flags + pad).
pub const SLOT_BYTES: usize = 8;
/// Per-core HBM budget: 8 GB per FPGA split over 32 cores.
pub const CORE_HBM_BYTES: usize = 8 * (1 << 30) / 32;

/// Synapse entry flags.
pub const SYN_VALID: u8 = 1;
/// Marks the *source* neuron of this region as an output neuron
/// (Supp A.3: "a special flag must be set in the synapse definitions").
pub const SYN_OUTPUT: u8 = 2;

/// One synapse slot in the synapse section.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SynEntry {
    pub target: u32,
    pub weight: i16,
    pub flags: u8,
}

impl SynEntry {
    pub fn is_valid(&self) -> bool {
        self.flags & SYN_VALID != 0
    }
}

/// A base + length pointer into the synapse section.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Pointer {
    pub start_row: u32,
    pub rows: u32,
}
