//! HBM access simulation: serves the two-phase spike routing with per-row
//! access counting (the quantity the paper's energy model is built on) and
//! a cycle model for the latency numbers.

use super::{HbmImage, Pointer, SynEntry, ROW_SLOTS};

/// Per-section HBM row-access counters plus on-chip access counters.
/// Cleared per inference by the engine (`reset`), accumulated into
/// [`crate::energy::InferenceReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AccessCounters {
    /// Pointer-section row reads (phase 1).
    pub pointer_rows: u64,
    /// Synapse-section row reads (phase 2).
    pub synapse_rows: u64,
    /// Synapse entries actually consumed (events delivered).
    pub events: u64,
    /// URAM membrane-register accesses (neuron update sweeps).
    pub uram_accesses: u64,
    /// BRAM axon/spike-register accesses.
    pub bram_accesses: u64,
}

impl AccessCounters {
    pub fn hbm_rows(&self) -> u64 {
        self.pointer_rows + self.synapse_rows
    }

    pub fn add(&mut self, other: &AccessCounters) {
        self.pointer_rows += other.pointer_rows;
        self.synapse_rows += other.synapse_rows;
        self.events += other.events;
        self.uram_accesses += other.uram_accesses;
        self.bram_accesses += other.bram_accesses;
    }
}

/// The HBM port of one core: wraps a compiled [`HbmImage`] with access
/// accounting. The engine calls `fetch_axon_pointers` /
/// `fetch_neuron_pointers` (phase 1) and `read_region` (phase 2).
#[derive(Clone, Debug)]
pub struct HbmSim {
    pub image: HbmImage,
    pub counters: AccessCounters,
}

impl HbmSim {
    pub fn new(image: HbmImage) -> Self {
        Self { image, counters: AccessCounters::default() }
    }

    pub fn reset_counters(&mut self) {
        self.counters = AccessCounters::default();
    }

    /// Phase 1 for axons: fetch pointers for the fired axon ids.
    ///
    /// Pointer rows hold 16 pointers each, so a batch of fired sources
    /// whose pointers share a row costs a single row read (HBM burst) —
    /// `fired` must be sorted ascending for the dedup to be exact, which
    /// the engine guarantees (spike registers are scanned in order).
    pub fn fetch_axon_pointers(&mut self, fired: &[u32], out: &mut Vec<Pointer>) {
        let mut last_row = u32::MAX;
        for &a in fired {
            let row = self.image.axon_ptr_row[a as usize];
            if row != last_row {
                self.counters.pointer_rows += 1;
                last_row = row;
            }
            out.push(self.image.axon_ptr[a as usize]);
        }
    }

    /// Phase 1 for neurons (same row-burst dedup; `fired` sorted by the
    /// engine in model-grouped pointer order).
    pub fn fetch_neuron_pointers(&mut self, fired: &[u32], out: &mut Vec<Pointer>) {
        let mut last_row = u32::MAX;
        for &nidx in fired {
            let row = self.image.neuron_ptr_row[nidx as usize];
            if row != last_row {
                self.counters.pointer_rows += 1;
                last_row = row;
            }
            out.push(self.image.neuron_ptr[nidx as usize]);
        }
    }

    /// Phase 2: stream a source's synapse region, invoking `f` per valid
    /// entry. Counts one row access per region row.
    ///
    /// §Perf: [`HbmImage::scan_region`] iterates set bits of the row
    /// occupancy mask rather than scanning all 16 slots (regions are
    /// ~30% dense on converted nets). Accounting is unchanged — rows are
    /// still fetched whole. The chunk-parallel route gather uses the
    /// counter-free `scan_region` directly and accounts per chunk.
    #[inline]
    pub fn read_region<F: FnMut(&SynEntry)>(&mut self, ptr: Pointer, mut f: F) {
        self.counters.synapse_rows += ptr.rows as u64;
        let events = &mut self.counters.events;
        self.image.scan_region(ptr, |e| {
            *events += 1;
            f(e);
        });
    }

    /// Cycle cost of this step's routing phases under the paper's
    /// microarchitecture: the HBM port streams one row per clock after a
    /// fixed access setup, 16 lanes consume a row in parallel.
    pub fn phase_cycles(&self, pointer_rows: u64, synapse_rows: u64) -> u64 {
        // CAS-to-data overhead amortised over bursts: model as +2 cycles
        // per row stream (segment = 2 rows).
        pointer_rows * 2 + synapse_rows * 2
    }

    /// Membrane-update sweep cycles: N neurons over 16 parallel lanes.
    pub fn update_cycles(&self) -> u64 {
        (self.image.n_neurons as u64).div_ceil(ROW_SLOTS as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::SlotStrategy;
    use crate::snn::{NetworkBuilder, NeuronModel};

    fn chain_net(n: usize) -> crate::snn::Network {
        // n neurons in a chain, one axon driving neuron 0
        let m = NeuronModel::if_neuron(0);
        let mut b = NetworkBuilder::new();
        for i in 0..n {
            let next = format!("n{}", i + 1);
            let syns: Vec<(&str, i32)> =
                if i + 1 < n { vec![(next.as_str(), 1)] } else { vec![] };
            b.add_neuron(&format!("n{i}"), m, &syns).unwrap();
        }
        b.add_axon("in", &[("n0", 1)]).unwrap();
        b.add_output(&format!("n{}", n - 1));
        b.build().unwrap().0
    }

    #[test]
    fn pointer_row_dedup() {
        let net = chain_net(40);
        let img = HbmImage::compile(&net, SlotStrategy::Modulo).unwrap();
        let mut sim = HbmSim::new(img);
        // 20 fired neurons with consecutive ids share pointer rows (16/row)
        let fired: Vec<u32> = (0..20).collect();
        let mut ptrs = Vec::new();
        sim.fetch_neuron_pointers(&fired, &mut ptrs);
        assert_eq!(ptrs.len(), 20);
        // ids 0..15 -> row 0, ids 16..19 -> row 1 (model-grouped order is
        // identity here: single model)
        assert_eq!(sim.counters.pointer_rows, 2);
    }

    #[test]
    fn region_read_counts_rows_and_events() {
        let net = chain_net(8);
        let img = HbmImage::compile(&net, SlotStrategy::Modulo).unwrap();
        let mut sim = HbmSim::new(img);
        let ptr = sim.image.neuron_ptr[0];
        let mut seen = Vec::new();
        sim.read_region(ptr, |e| seen.push((e.target, e.weight)));
        assert_eq!(seen, vec![(1, 1)]);
        assert_eq!(sim.counters.synapse_rows, ptr.rows as u64);
        assert_eq!(sim.counters.events, 1);
    }

    #[test]
    fn dummy_rows_do_not_emit_events() {
        let net = chain_net(4);
        let img = HbmImage::compile(&net, SlotStrategy::Modulo).unwrap();
        let mut sim = HbmSim::new(img);
        // last neuron is a leaf: dummy row, no events (weight 0 filtered)
        let ptr = sim.image.neuron_ptr[3];
        let mut count = 0;
        sim.read_region(ptr, |_| count += 1);
        assert_eq!(count, 0);
        assert_eq!(sim.counters.synapse_rows, 1);
    }

    #[test]
    fn counters_accumulate_and_reset() {
        let mut c = AccessCounters::default();
        c.add(&AccessCounters { pointer_rows: 2, synapse_rows: 3, events: 5, ..Default::default() });
        c.add(&AccessCounters { pointer_rows: 1, synapse_rows: 1, events: 1, ..Default::default() });
        assert_eq!(c.hbm_rows(), 7);
        assert_eq!(c.events, 6);
    }
}
