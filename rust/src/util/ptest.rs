//! Property-test microframework (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded
//! RNGs. On failure it re-runs with the failing seed to confirm, then
//! panics with the seed so the case can be replayed deterministically:
//!
//! ```ignore
//! ptest::check("hbm_layout_roundtrip", 200, |rng| {
//!     let net = arbitrary_network(rng);
//!     let img = HbmImage::compile(&net)?;
//!     prop_assert(img.validate().is_ok(), "layout invariants");
//!     Ok(())
//! });
//! ```
//!
//! Failures return `Err(String)` (or panic) from the closure; `prop_assert`
//! is a convenience for readable messages. A fixed base seed keeps CI
//! deterministic; set `PTEST_SEED` to explore a different region, or
//! `PTEST_CASES` to scale the number of cases.

use super::prng::Xorshift32;

/// Assert inside a property closure with a formatted message.
pub fn prop_assert(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

fn base_seed() -> u32 {
    std::env::var("PTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0001)
}

fn case_count(requested: usize) -> usize {
    match std::env::var("PTEST_CASES").ok().and_then(|s| s.parse::<f64>().ok()) {
        Some(scale) => ((requested as f64) * scale).max(1.0) as usize,
        None => requested,
    }
}

/// Run `body` over `cases` deterministic seeds; panic with the replay seed
/// on the first failure.
pub fn check<F>(name: &str, cases: usize, mut body: F)
where
    F: FnMut(&mut Xorshift32) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..case_count(cases) {
        let seed = base.wrapping_add(case as u32).wrapping_mul(0x9E37_79B9) | 1;
        let mut rng = Xorshift32::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay: PTEST_SEED={base}, \
                 case seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_clean_property() {
        check("add_commutes", 50, |rng| {
            let a = rng.range_i32(-1000, 1000);
            let b = rng.range_i32(-1000, 1000);
            prop_assert_eq(a + b, b + a, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn reports_failure_with_seed() {
        check("always_fails", 10, |_rng| Err("boom".to_string()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("capture", 5, |rng| {
            first.push(rng.next_u32());
            Ok(())
        });
        let mut second = Vec::new();
        check("capture", 5, |rng| {
            second.push(rng.next_u32());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
