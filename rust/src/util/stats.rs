//! Small statistics helpers: mean/std reporting (Table 2's "mean ± SD per
//! inference") and ordinary-least-squares linear regression (the Fig-10 /
//! §6 scaling fits, reported with slope, intercept and R²).

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Result of an OLS fit y = slope * x + intercept.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    pub r2: f64,
    pub n: usize,
}

/// Ordinary least squares over (x, y) pairs. Returns None for n < 2 or
/// degenerate x.
pub fn linear_fit(points: &[(f64, f64)]) -> Option<LinearFit> {
    let n = points.len();
    if n < 2 {
        return None;
    }
    let nf = n as f64;
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let mx = sx / nf;
    let my = sy / nf;
    let sxx: f64 = points.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let sxy: f64 = points.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    if sxx == 0.0 {
        return None;
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_tot: f64 = points.iter().map(|p| (p.1 - my) * (p.1 - my)).sum();
    let ss_res: f64 = points
        .iter()
        .map(|p| {
            let e = p.1 - (slope * p.0 + intercept);
            e * e
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Some(LinearFit { slope, intercept, r2, n })
}

/// Percentile (nearest-rank) — used for latency distributions.
pub fn percentile(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * xs.len() as f64).ceil().max(1.0) as usize - 1;
    xs[rank.min(xs.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_line() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 3.0 * i as f64 - 7.0)).collect();
        let f = linear_fit(&pts).unwrap();
        assert!((f.slope - 3.0).abs() < 1e-12);
        assert!((f.intercept + 7.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_r2() {
        let pts: Vec<(f64, f64)> = (0..100)
            .map(|i| {
                let x = i as f64;
                (x, 2.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            })
            .collect();
        let f = linear_fit(&pts).unwrap();
        assert!((f.slope - 2.0).abs() < 0.01);
        assert!(f.r2 > 0.99);
    }

    #[test]
    fn degenerate_cases() {
        assert!(linear_fit(&[]).is_none());
        assert!(linear_fit(&[(1.0, 1.0)]).is_none());
        assert!(linear_fit(&[(1.0, 1.0), (1.0, 2.0)]).is_none());
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&mut xs, 50.0), 3.0);
        assert_eq!(percentile(&mut xs, 100.0), 5.0);
    }
}
