//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Known boolean flag names (set before parse).
    bool_flags: Vec<&'static str>,
}

impl Args {
    /// `bool_flags` lists options that take no value (everything else with
    /// a `--` prefix consumes the next token as its value unless it uses
    /// `--key=value` syntax).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        iter: I,
        bool_flags: &[&'static str],
    ) -> Result<Args, String> {
        let mut args = Args { bool_flags: bool_flags.to_vec(), ..Default::default() };
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if args.bool_flags.contains(&body) {
                    args.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("option --{body} expects a value"))?;
                    args.options.insert(body.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn parse_env(bool_flags: &[&'static str]) -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1), bool_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn get_u32(&self, name: &str, default: u32) -> Result<u32, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad float {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&'static str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--steps", "10", "net.hsn", "--verbose"], &["verbose"]);
        assert_eq!(a.positional, vec!["run", "net.hsn"]);
        assert_eq!(a.get("steps"), Some("10"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn eq_syntax() {
        let a = parse(&["--k=v", "--n=3"], &[]);
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse_from(vec!["--steps".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--x", "2.5"], &[]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("y", 1.5).unwrap(), 1.5);
        assert!(parse(&["--n", "zz"], &[]).get_usize("n", 0).is_err());
    }
}
