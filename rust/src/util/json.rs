//! Minimal JSON parser/serialiser (serde is unavailable offline).
//!
//! Supports the full JSON value model; numbers are kept as f64 with an
//! i64 fast path (golden vectors are integers and must round-trip
//! exactly — every int32 is exactly representable in f64 anyway).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of integers -> Vec<i64> (common golden-vector shape).
    pub fn int_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(Json::as_i64).collect()
    }

    /// Array of integers -> Vec<i32>.
    pub fn i32_vec(&self) -> Option<Vec<i32>> {
        self.int_vec().map(|v| v.into_iter().map(|x| x as i32).collect())
    }

    // ---- serialisation ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_i64(v: impl IntoIterator<Item = i64>) -> Json {
    Json::Arr(v.into_iter().map(Json::Int).collect())
}

pub fn arr_f64(v: impl IntoIterator<Item = f64>) -> Json {
    Json::Arr(v.into_iter().map(Json::Num).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // surrogate pairs unsupported (not needed for our files)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // re-decode utf8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("bad utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        if text.is_empty() || text == "-" {
            return Err(self.err("bad number"));
        }
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -42 ").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let j = obj(vec![
            ("ints", arr_i64([1, -2, 3])),
            ("s", Json::Str("q\"uote".into())),
            ("f", Json::Num(2.25)),
        ]);
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn int_vec_helper() {
        let j = Json::parse("[1, 2, -3]").unwrap();
        assert_eq!(j.int_vec().unwrap(), vec![1, 2, -3]);
        assert_eq!(j.i32_vec().unwrap(), vec![1i32, 2, -3]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☂\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☂"));
    }

    #[test]
    fn large_int_exact() {
        // int32 golden values must round-trip exactly
        let j = Json::parse("[-2147483648, 2147483647]").unwrap();
        assert_eq!(j.i32_vec().unwrap(), vec![i32::MIN, i32::MAX]);
    }
}
