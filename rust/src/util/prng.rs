//! Deterministic PRNG primitives, bit-exact with the Python/JAX side.
//!
//! Three generators live here:
//!
//! * [`mix_seed`] / [`noise17`] — the counter-based membrane-noise hash
//!   used by the neuron update. These MUST match
//!   `python/compile/kernels/ref.py` (and hence the Pallas kernel and the
//!   AOT artifacts) bit-for-bit; `artifacts/golden/prng.json` pins them.
//! * [`Xorshift32`] — a small stream PRNG for test-data generation and the
//!   property-test microframework (not used by the hardware model).

/// 2^32 / phi, the Weyl increment used to decorrelate lanes.
pub const PHI32: u32 = 0x9E37_79B9;

#[inline]
fn xorshift_round(mut x: u32) -> u32 {
    x ^= x << 13;
    x ^= x >> 17;
    x ^= x << 5;
    x
}

/// Per-step seed derivation: one xorshift round over `base ^ step*phi`,
/// low bit forced to 1 to avoid the all-zero fixed point.
///
/// Matches `ref.mix_seed`.
#[inline]
pub fn mix_seed(base_seed: u32, step: u32) -> u32 {
    xorshift_round(base_seed ^ step.wrapping_mul(PHI32)) | 1
}

/// 17-bit odd membrane noise for neuron `idx` at seed `step_seed`:
/// double-round xorshift32 hash -> low 17 bits -> [-2^16, 2^16) -> LSB=1.
///
/// Matches `ref.noise17`.
#[inline]
pub fn noise17(step_seed: u32, idx: u32) -> i32 {
    let mut x = step_seed ^ idx.wrapping_mul(PHI32);
    x = xorshift_round(x);
    x = xorshift_round(x);
    let lo = (x & 0x1_FFFF) as i32; // [0, 2^17)
    (lo - (1 << 16)) | 1
}

/// The nu scaling shift applied to raw noise: left shift for nu >= 0,
/// arithmetic right shift for nu < 0; shift amounts clamp to [0, 31].
///
/// Matches `ref.shift_noise` (wrapping on left shift, like int32 HLO).
#[inline]
pub fn shift_noise(xi: i32, nu: i32) -> i32 {
    if nu >= 0 {
        xi.wrapping_shl(nu.min(31) as u32)
    } else {
        xi >> (-nu).min(31)
    }
}

/// Small xorshift32 stream PRNG for deterministic test data.
#[derive(Clone, Debug)]
pub struct Xorshift32 {
    state: u32,
}

impl Xorshift32 {
    pub fn new(seed: u32) -> Self {
        Self { state: if seed == 0 { 0xBAD_5EED } else { seed } }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.state = xorshift_round(self.state);
        self.state
    }

    /// Uniform in [0, bound) via rejection-free multiply-shift.
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform in [lo, hi) (i64 domain to allow full i32 ranges).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        let span = (hi - lo) as u64;
        let r = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        lo + (r % span) as i64
    }

    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64) as i32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u32() as f64 / u32::MAX as f64) < p
    }

    /// Random permutation of 0..n (Fisher-Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u32 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise17_is_odd_and_bounded() {
        for idx in 0..100_000u32 {
            let v = noise17(12345, idx);
            assert_eq!(v & 1, 1, "noise LSB must be 1");
            assert!((-(1 << 16)..(1 << 16)).contains(&v));
        }
    }

    #[test]
    fn noise17_balanced_around_zero() {
        let sum: i64 = (0..1_000_000u32).map(|i| noise17(7, i) as i64).sum();
        let mean = sum as f64 / 1e6;
        assert!(mean.abs() < 100.0, "mean {mean} too far from 0");
    }

    #[test]
    fn mix_seed_never_zero_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..10_000 {
            let s = mix_seed(1, step);
            assert_ne!(s, 0);
            assert!(seen.insert(s), "collision at step {step}");
        }
    }

    #[test]
    fn shift_noise_semantics() {
        assert_eq!(shift_noise(3, 2), 12);
        assert_eq!(shift_noise(-1001, -2), -251); // arithmetic shift floors
        assert_eq!(shift_noise(5, 0), 5);
        // clamp: shifting by 99 behaves as 31
        assert_eq!(shift_noise(1, 99), 1i32.wrapping_shl(31));
        assert_eq!(shift_noise(-1, -99), -1);
    }

    #[test]
    fn xorshift_stream_basic() {
        let mut a = Xorshift32::new(42);
        let mut b = Xorshift32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Xorshift32::new(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn below_in_range() {
        let mut r = Xorshift32::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Xorshift32::new(5);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257u32).collect::<Vec<_>>());
    }
}
