//! Offline-substitution utilities.
//!
//! The build environment has no network access and the vendored crate
//! mirror lacks `rand`, `serde`, `clap`, `criterion` and `proptest`, so the
//! small pieces of those we need are implemented here (see DESIGN.md
//! "Substitutions"). Everything is deliberately minimal and heavily tested.

pub mod cli;
pub mod json;
pub mod prng;
pub mod ptest;
pub mod stats;
