//! Execution engines ("grey matter", paper §4).
//!
//! * [`backend`] — the membrane-update compute backend trait with a
//!   native-Rust implementation; the XLA/PJRT implementation that runs the
//!   AOT Pallas artifacts lives in [`crate::runtime`] and plugs in here.
//! * [`dense`] — the Fig-8 dense-matrix software simulator (the CPU
//!   baseline the paper compares throughput against, and the golden model
//!   in parity tests).
//! * [`core`] — the event-driven single-core engine: two-phase HBM spike
//!   routing with access/cycle accounting.

pub mod backend;
pub mod core;
pub mod dense;

pub use backend::{
    extract_fired, mask_bit, mask_words, set_mask_bit, sweep_chunk, CoreParams, ParamSlice,
    RustBackend, UpdateBackend,
};
pub use core::{CoreEngine, StepOutput};
pub use dense::{DenseEngine, DenseSim};
