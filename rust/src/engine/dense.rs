//! Dense-matrix software simulator (Fig 8) — the CPU-baseline engine and
//! the golden model for the event-driven core. Bit-exact with the numpy
//! simulator in `python/hs_api/simulator.py` and the `dense_step` HLO
//! artifact.

use crate::engine::backend::{mask_bit, mask_words, CoreParams, RustBackend, UpdateBackend};
use crate::snn::NetView;
use crate::util::prng::mix_seed;

/// One core's network as dense int32 weight matrices.
#[derive(Clone, Debug)]
pub struct DenseEngine {
    pub n: usize,
    pub a: usize,
    params: CoreParams,
    /// w_neuron[i * n + j]: weight of synapse i -> j (pre-major).
    w_neuron: Vec<i32>,
    /// w_axon[i * n + j]
    w_axon: Vec<i32>,
    pub v: Vec<i32>,
    pub base_seed: u32,
    pub step_num: u32,
    backend: RustBackend,
    /// packed backend output
    spike_words: Vec<u64>,
    /// unpacked 0/1 mask — the engine's public step contract
    spike_buf: Vec<i32>,
}

impl DenseEngine {
    /// Crate-private: external callers construct engines through
    /// [`crate::sim::SimConfig`] with [`crate::sim::Backend::Dense`].
    pub(crate) fn new<'a>(net: impl Into<NetView<'a>>) -> Self {
        let net: NetView<'_> = net.into();
        let n = net.n_neurons();
        let a = net.n_axons();
        let mut w_neuron = vec![0i32; n * n];
        for i in 0..n {
            let (tg, wt) = net.neuron_syns(i);
            for (&t, &w) in tg.iter().zip(wt) {
                w_neuron[i * n + t as usize] += w as i32;
            }
        }
        let mut w_axon = vec![0i32; a * n];
        for i in 0..a {
            let (tg, wt) = net.axon_syns(i);
            for (&t, &w) in tg.iter().zip(wt) {
                w_axon[i * n + t as usize] += w as i32;
            }
        }
        Self {
            n,
            a,
            params: CoreParams::from_network(net),
            w_neuron,
            w_axon,
            v: vec![0; n],
            base_seed: net.base_seed,
            step_num: 0,
            backend: RustBackend,
            spike_words: vec![0; mask_words(n)],
            spike_buf: vec![0; n],
        }
    }

    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0);
        self.step_num = 0;
    }

    /// One timestep; `axon_in` lists the fired axon ids. Returns the 0/1
    /// spike mask (borrow of an internal buffer).
    pub fn step(&mut self, axon_in: &[u32]) -> &[i32] {
        let ss = mix_seed(self.base_seed, self.step_num);
        self.backend
            .update(&mut self.v, &self.params, ss, &mut self.spike_words)
            .expect("rust backend is infallible");
        for (i, s) in self.spike_buf.iter_mut().enumerate() {
            *s = mask_bit(&self.spike_words, i) as i32;
        }

        // phase 4: dense row accumulation for fired neurons + axons
        let n = self.n;
        for (i, &s) in self.spike_buf.iter().enumerate() {
            if s != 0 {
                let row = &self.w_neuron[i * n..(i + 1) * n];
                for (vj, &w) in self.v.iter_mut().zip(row) {
                    *vj = vj.wrapping_add(w);
                }
            }
        }
        for &ax in axon_in {
            let row = &self.w_axon[ax as usize * n..(ax as usize + 1) * n];
            for (vj, &w) in self.v.iter_mut().zip(row) {
                *vj = vj.wrapping_add(w);
            }
        }
        self.step_num += 1;
        &self.spike_buf
    }

    /// Fired neuron ids from the last step.
    pub fn fired(&self) -> Vec<u32> {
        self.spike_buf
            .iter()
            .enumerate()
            .filter(|(_, &s)| s != 0)
            .map(|(i, _)| i as u32)
            .collect()
    }
}

// ---- facade adapter -------------------------------------------------------

use crate::energy::EnergyModel;
use crate::sim::{CostSummary, SimError, Simulator, StepResult};

/// [`Simulator`] session over the dense engine ([`crate::sim::Backend::Dense`]).
/// Adds the fired-id / output-subset bookkeeping the facade contract
/// requires; reports zero hardware cost (it is the software baseline).
pub struct DenseSim {
    engine: DenseEngine,
    is_output: Vec<bool>,
    n_axons: usize,
    fired_buf: Vec<u32>,
    out_buf: Vec<u32>,
}

impl DenseSim {
    pub(crate) fn new<'a>(net: impl Into<NetView<'a>>) -> Self {
        let net: NetView<'_> = net.into();
        let mut is_output = vec![false; net.n_neurons()];
        for &o in net.outputs {
            is_output[o as usize] = true;
        }
        Self {
            engine: DenseEngine::new(net),
            is_output,
            n_axons: net.n_axons(),
            fired_buf: Vec::new(),
            out_buf: Vec::new(),
        }
    }
}

impl Simulator for DenseSim {
    // no step_many override: the software baseline keeps the default
    // trait body (whole-batch validation, per-step loop) — only the hot
    // event-driven engine amortises the per-step re-check
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        crate::sim::check_axons(axon_in, self.n_axons)?;
        self.engine.step(axon_in);
        self.fired_buf.clear();
        self.out_buf.clear();
        for (i, &s) in self.engine.spike_buf.iter().enumerate() {
            if s != 0 {
                self.fired_buf.push(i as u32);
                if self.is_output[i] {
                    self.out_buf.push(i as u32);
                }
            }
        }
        Ok(StepResult { fired: &self.fired_buf, output_spikes: &self.out_buf })
    }

    fn fired(&self) -> &[u32] {
        &self.fired_buf
    }

    fn output_spikes(&self) -> &[u32] {
        &self.out_buf
    }

    fn reset(&mut self) {
        self.engine.reset();
        self.fired_buf.clear();
        self.out_buf.clear();
    }

    fn reset_cost(&mut self) {
        // the software baseline counts no hardware accesses
    }

    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        ids.iter().map(|&i| self.engine.v[i as usize]).collect()
    }

    fn cost(&self, _model: &EnergyModel) -> CostSummary {
        CostSummary::default()
    }

    fn backend_name(&self) -> &'static str {
        "dense"
    }

    fn n_neurons(&self) -> usize {
        self.engine.n
    }

    fn n_axons(&self) -> usize {
        self.n_axons
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{Network, NetworkBuilder, NeuronModel};

    fn fig6() -> Network {
        let lif_ab = NeuronModel::lif(3, 0, 63, false).unwrap();
        let lif_c = NeuronModel::lif(4, 0, 2, false).unwrap();
        let ann_d = NeuronModel::ann(5, 0, true).unwrap();
        let mut b = NetworkBuilder::new();
        b.add_neuron("a", lif_ab, &[("b", 1), ("d", 2)]).unwrap();
        b.add_neuron("b", lif_ab, &[]).unwrap();
        b.add_neuron("c", lif_c, &[]).unwrap();
        b.add_neuron("d", ann_d, &[("c", 1)]).unwrap();
        b.add_axon("alpha", &[("a", 3), ("c", 2)]).unwrap();
        b.add_axon("beta", &[("b", 3)]).unwrap();
        b.add_output("a");
        b.add_output("b");
        b.build().unwrap().0
    }

    /// Mirrors python/tests/test_hs_api.py::test_fig6_steps — the same
    /// trace must hold in both languages.
    #[test]
    fn fig6_trace_matches_python() {
        let net = fig6();
        let outputs = net.outputs.clone(); // a=0, b=1
        let mut e = DenseEngine::new(&net);
        let fired_outputs = |e: &DenseEngine| -> Vec<u32> {
            e.fired().into_iter().filter(|i| outputs.contains(i)).collect()
        };
        // step 1: alpha(0) + beta(1)
        e.step(&[0, 1]);
        assert_eq!(fired_outputs(&e), Vec::<u32>::new());
        assert_eq!(e.v[0], 3); // a
        assert_eq!(e.v[1], 3); // b
        // step 2 (the stochastic non-output neuron "d" may fire; the
        // python test observes outputs only, so we do too)
        e.step(&[0, 1]);
        assert_eq!(fired_outputs(&e), Vec::<u32>::new());
        assert_eq!(e.v[0], 6);
        // step 3: a and b spike (6 > 3)
        e.step(&[]);
        let fired = e.fired();
        assert!(fired.contains(&0) && fired.contains(&1));
        assert_eq!(e.v[0], 0);
        assert!(e.v[1] >= 1); // received a's synapse after reset
    }

    #[test]
    fn reset_restores_initial_state() {
        let net = fig6();
        let mut e = DenseEngine::new(&net);
        e.step(&[0]);
        e.reset();
        assert!(e.v.iter().all(|&x| x == 0));
        assert_eq!(e.step_num, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let net = fig6();
        let mut e1 = DenseEngine::new(&net);
        let mut e2 = DenseEngine::new(&net);
        for t in 0..20 {
            let inp: &[u32] = if t % 3 == 0 { &[0, 1] } else { &[] };
            assert_eq!(e1.step(inp), e2.step(inp));
        }
        assert_eq!(e1.v, e2.v);
    }
}
