//! The membrane-update compute backend: phases 1-3 (noise, spike+reset,
//! leak) and phase 4 (synaptic accumulate).
//!
//! Two implementations exist:
//! * [`RustBackend`] — drives the branch-free [`sweep_chunk`] kernel,
//!   bit-exact with the Pallas kernel and `ref.py` (see `util::prng`);
//! * [`crate::runtime::XlaBackend`] — executes the AOT-compiled JAX/Pallas
//!   artifacts via PJRT (the "FPGA bitstream" of this reproduction).
//!
//! # The branch-free kernel contract
//!
//! [`sweep_chunk`] is the phases 1-3 inner kernel. It operates on one
//! **word-aligned chunk** — a contiguous `(v, params, spike_words)` range
//! starting at a 64-neuron multiple, so each chunk owns whole `u64` spike
//! words and never shares a word with a neighbour. The per-neuron
//! `FLAG_NOISE`/`FLAG_LIF` branches of the original scalar loop are
//! replaced by unconditional mask arithmetic (spike reset and leak/clear
//! select via all-ones/all-zero masks) plus one per-word flag summary
//! that hoists the noise hash out of words with no stochastic lane — a
//! straight-line SoA body the autovectorizer can chew on. Because
//! membrane noise is the counter-based `noise17(step_seed, global_index)`
//! hash (no sequential PRNG state), splitting a sweep into chunks in any
//! order produces bit-identical results to one full scalar pass; the
//! `prop_chunked_sweep_matches_scalar_reference` property test pins this
//! against a literal transcription of the pre-rewrite branchy loop.
//! `cluster::CorePool` exploits the same property to run one core's sweep
//! chunk-parallel across worker threads (backends opt in via
//! [`UpdateBackend::chunkable`]).
//!
//! When runtime plasticity is enabled, the per-neuron STDP eligibility
//! traces are advanced by [`crate::plasticity::trace_chunk`] — a
//! branch-free extension of this kernel that runs over the same
//! word-aligned chunks, immediately after each chunk's sweep, and is
//! per-lane independent so the chunking invariance above carries over
//! verbatim (weight mutation itself stays in the serial route
//! epilogue; see the `plasticity` module docs' ordering contract).
//!
//! Spike output is a packed `u64` bitmask (bit `i` = neuron `i` fired),
//! matching the hardware's BRAM spike registers; fired ids are decoded
//! word-at-a-time with [`extract_fired`] instead of an O(N) scalar scan.
//! Phase-4 events arrive as interleaved `(target, weight)` buffers so
//! the gather writes and the accumulate read stream the same cache lines.
//!
//! # The route-phase contract (gather + ordered accumulate)
//!
//! Phase 2 is [`UpdateBackend::gather`]: stream one HBM pointer's
//! synapse region into an event buffer. It takes `&self` and must be
//! pure with respect to backend state — `cluster::CorePool` calls it
//! concurrently from many worker threads, one **pointer chunk** per
//! worker, each writing its own buffer. Phase 4 is
//! [`UpdateBackend::accumulate_bufs`]: consume the per-chunk buffers
//! **in ascending chunk order**, which concatenates to exactly the
//! serial gather stream — so wrapping (or any future saturating)
//! accumulate arithmetic sees the same event order regardless of how
//! many workers gathered, and every golden transcript stays
//! bit-identical. `rust/tests/chunked_route.rs` pins this against the
//! serial `phase_route` reference.
//!
//! Cross-backend parity is enforced by `rust/tests/sim_facade.rs`.

use crate::hbm::{HbmImage, Pointer};
use crate::snn::{NetView, FLAG_LIF, FLAG_NOISE};
use crate::util::prng::{noise17, shift_noise};

/// Number of `u64` bitmask words covering `n` neurons.
#[inline]
pub fn mask_words(n: usize) -> usize {
    n.div_ceil(64)
}

/// Read bit `i` of a spike bitmask.
#[inline]
pub fn mask_bit(words: &[u64], i: usize) -> bool {
    words[i >> 6] & (1u64 << (i & 63)) != 0
}

/// Set bit `i` of a spike bitmask.
#[inline]
pub fn set_mask_bit(words: &mut [u64], i: usize) {
    words[i >> 6] |= 1u64 << (i & 63);
}

/// Decode fired ids (ascending) from a spike bitmask. Skips zero words
/// whole and walks set bits with `trailing_zeros` — at sparse activity
/// this visits ~64x fewer positions than the seed's per-neuron scan.
pub fn extract_fired(words: &[u64], out: &mut Vec<u32>) {
    out.clear();
    for (wi, &word) in words.iter().enumerate() {
        let mut m = word;
        while m != 0 {
            out.push((wi as u32) * 64 + m.trailing_zeros());
            m &= m - 1;
        }
    }
}

/// SoA per-neuron parameters, the engine-side mirror of the HBM
/// neuron-model section.
#[derive(Clone, Debug, Default)]
pub struct CoreParams {
    pub theta: Vec<i32>,
    pub nu: Vec<i32>,
    pub lam: Vec<i32>,
    pub flags: Vec<u32>,
}

impl CoreParams {
    pub fn from_network<'a>(net: impl Into<NetView<'a>>) -> Self {
        let net: NetView<'_> = net.into();
        let n = net.n_neurons();
        let mut p = CoreParams {
            theta: Vec::with_capacity(n),
            nu: Vec::with_capacity(n),
            lam: Vec::with_capacity(n),
            flags: Vec::with_capacity(n),
        };
        for m in net.params {
            p.theta.push(m.theta);
            p.nu.push(m.nu);
            p.lam.push(m.lam);
            p.flags.push(m.flags);
        }
        p
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    /// Borrow the SoA columns for neurons `[lo, hi)` (a chunk view).
    pub fn slice(&self, lo: usize, hi: usize) -> ParamSlice<'_> {
        ParamSlice {
            theta: &self.theta[lo..hi],
            nu: &self.nu[lo..hi],
            lam: &self.lam[lo..hi],
            flags: &self.flags[lo..hi],
        }
    }
}

/// Borrowed SoA parameter columns for one sweep chunk.
#[derive(Clone, Copy, Debug)]
pub struct ParamSlice<'a> {
    pub theta: &'a [i32],
    pub nu: &'a [i32],
    pub lam: &'a [i32],
    pub flags: &'a [u32],
}

/// Phases 2-3 for one lane, branch-free: spike+reset selects through an
/// all-ones/all-zero mask instead of a branch, and the leak-vs-clear
/// choice is the same shift arithmetic masked by `FLAG_LIF` (non-LIF
/// lanes fall through to zero). `x - (x >> s)` cannot overflow: the
/// shifted value has the same sign as `x` and no larger magnitude.
#[inline(always)]
fn fire_reset_leak(x: i32, theta: i32, lam: i32, flags: u32) -> (i32, u32) {
    let fired = (x > theta) as u32;
    // fired -> mask 0 (reset), quiet -> mask all-ones (keep)
    let x = x & (fired as i32).wrapping_sub(1);
    let leaked = x - (x >> lam.clamp(0, 31));
    let lif_mask = (((flags & FLAG_LIF) != 0) as i32).wrapping_neg();
    (leaked & lif_mask, fired)
}

/// Branch-free membrane kernel (phases 1-3) over one word-aligned chunk.
///
/// `v`, `p`, and `spikes` cover the same neurons; `first_neuron` is the
/// core-global index of `v[0]` and MUST be a multiple of 64 so the chunk
/// owns whole spike words. Every word of `spikes` is fully assigned
/// (stale bits cleared, bits past `v.len()` never set). Noise is the
/// per-index `noise17(step_seed, first_neuron + i)` counter hash, so any
/// chunking of a sweep is bit-exact with one full pass.
pub fn sweep_chunk(
    v: &mut [i32],
    p: ParamSlice<'_>,
    step_seed: u32,
    spikes: &mut [u64],
    first_neuron: u32,
) {
    let n = v.len();
    debug_assert_eq!(p.theta.len(), n);
    debug_assert_eq!(p.nu.len(), n);
    debug_assert_eq!(p.lam.len(), n);
    debug_assert_eq!(p.flags.len(), n);
    debug_assert_eq!(spikes.len(), mask_words(n));
    debug_assert_eq!(first_neuron % 64, 0, "chunks must start on a word boundary");
    for (w, word_out) in spikes.iter_mut().enumerate() {
        let base = w * 64;
        let valid = 64.min(n - base);
        let mut word = 0u64;
        // per-word flag summary: hoist the noise hash out of words with
        // no stochastic lane (the common case for deterministic nets)
        let any_noise = p.flags[base..base + valid].iter().any(|f| f & FLAG_NOISE != 0);
        if any_noise {
            for lane in 0..valid {
                let i = base + lane;
                let noise_mask = (((p.flags[i] & FLAG_NOISE) != 0) as i32).wrapping_neg();
                let xi = shift_noise(noise17(step_seed, first_neuron + i as u32), p.nu[i]);
                let x = v[i].wrapping_add(xi & noise_mask);
                let (x, fired) = fire_reset_leak(x, p.theta[i], p.lam[i], p.flags[i]);
                v[i] = x;
                word |= (fired as u64) << lane;
            }
        } else {
            for lane in 0..valid {
                let i = base + lane;
                let (x, fired) = fire_reset_leak(v[i], p.theta[i], p.lam[i], p.flags[i]);
                v[i] = x;
                word |= (fired as u64) << lane;
            }
        }
        *word_out = word;
    }
}

/// Backend for the two compute phases of a timestep.
pub trait UpdateBackend {
    /// Phases 1-3 over all neurons. Updates `v` in place and writes the
    /// packed spike bitmask into `spikes` (`mask_words(v.len())` words;
    /// the backend zeroes them first and never sets bits >= `v.len()`).
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> anyhow::Result<()>;

    /// Phase 4: `v[target] += weight` (wrapping int32) for every
    /// interleaved `(target, weight)` event.
    fn accumulate(&mut self, v: &mut [i32], events: &[(u32, i32)]) -> anyhow::Result<()>;

    /// Phase 2: stream one HBM pointer's synapse region, appending an
    /// interleaved `(target, weight)` event per valid slot to `out` in
    /// row/slot order. Must be pure w.r.t. backend state (`&self`):
    /// `cluster::CorePool` runs it chunk-parallel across worker threads
    /// during the Route phase, several threads gathering different
    /// pointer chunks of the same core concurrently. Access accounting
    /// is the engine's job (per-chunk totals are reconstructed in the
    /// merge epilogue), not the gather's.
    fn gather(&self, image: &HbmImage, ptr: Pointer, out: &mut Vec<(u32, i32)>) {
        image.scan_region(ptr, |e| out.push((e.target, e.weight as i32)));
    }

    /// Phase 4 over an **ordered list** of per-chunk event buffers: the
    /// chunk-parallel route gather fills `bufs[0..]` in pointer-queue
    /// order, and consuming them in ascending index order is
    /// bit-identical to accumulating the one serial gather stream. The
    /// default forwards each buffer to [`UpdateBackend::accumulate`];
    /// overrides must preserve the buffer order.
    fn accumulate_bufs(&mut self, v: &mut [i32], bufs: &[Vec<(u32, i32)>]) -> anyhow::Result<()> {
        for b in bufs {
            self.accumulate(v, b)?;
        }
        Ok(())
    }

    /// True when `update` is exactly the pure [`sweep_chunk`] reference
    /// kernel, so a driver (`cluster::CorePool`) may run the sweep
    /// word-chunk-parallel across threads instead of calling `update`
    /// (and the route gather pointer-chunk-parallel through
    /// [`UpdateBackend::gather`]). Backends with their own state or
    /// execution path (e.g. PJRT) must leave this false.
    fn chunkable(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Native implementation — the reference semantics, executed through the
/// branch-free [`sweep_chunk`] kernel as one full-range chunk.
#[derive(Clone, Copy, Debug, Default)]
pub struct RustBackend;

impl UpdateBackend for RustBackend {
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> anyhow::Result<()> {
        debug_assert_eq!(v.len(), params.len());
        debug_assert_eq!(spikes.len(), mask_words(v.len()));
        let n = v.len();
        sweep_chunk(v, params.slice(0, n), step_seed, spikes, 0);
        Ok(())
    }

    fn accumulate(&mut self, v: &mut [i32], events: &[(u32, i32)]) -> anyhow::Result<()> {
        for &(t, w) in events {
            let slot = &mut v[t as usize];
            *slot = slot.wrapping_add(w);
        }
        Ok(())
    }

    fn chunkable(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::NeuronModel;
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    /// Literal transcription of the pre-rewrite branchy scalar loop — the
    /// reference the branch-free kernel must stay bit-exact with.
    fn scalar_reference(v: &mut [i32], p: &CoreParams, step_seed: u32, spikes: &mut [u64]) {
        spikes.fill(0);
        for i in 0..v.len() {
            let flags = p.flags[i];
            let mut x = v[i];
            if flags & FLAG_NOISE != 0 {
                x = x.wrapping_add(shift_noise(noise17(step_seed, i as u32), p.nu[i]));
            }
            if x > p.theta[i] {
                x = 0;
                set_mask_bit(spikes, i);
            }
            if flags & FLAG_LIF != 0 {
                x -= x >> p.lam[i].clamp(0, 31);
            } else {
                x = 0;
            }
            v[i] = x;
        }
    }

    /// Tentpole property: the branch-free kernel, run whole or split into
    /// arbitrary word-aligned chunks, matches the branchy scalar loop
    /// bit-for-bit — membranes and spike words — across random mixes of
    /// IF/LIF/ANN lanes with and without noise, extreme membrane values,
    /// and ragged tail words.
    #[test]
    fn prop_chunked_sweep_matches_scalar_reference() {
        ptest::check("chunked_vs_scalar_sweep", 60, |rng| {
            let n = 1 + rng.below(300) as usize;
            let mut p = CoreParams::default();
            for _ in 0..n {
                p.theta.push(rng.range_i32(-1000, 1000));
                p.nu.push(rng.range_i32(-10, 10));
                p.lam.push(rng.range_i32(0, 40)); // > 31 exercises the clamp
                p.flags.push(match rng.below(4) {
                    0 => 0,
                    1 => FLAG_LIF,
                    2 => FLAG_NOISE,
                    _ => FLAG_LIF | FLAG_NOISE,
                });
            }
            let step_seed = rng.next_u32();
            let v0: Vec<i32> = (0..n)
                .map(|k| match k % 7 {
                    0 => i32::MAX - rng.range_i32(0, 3),
                    1 => i32::MIN + rng.range_i32(0, 3),
                    _ => rng.range_i32(-100_000, 100_000),
                })
                .collect();
            let words = mask_words(n);

            let mut v_ref = v0.clone();
            let mut s_ref = vec![u64::MAX; words]; // dirty buffers everywhere
            scalar_reference(&mut v_ref, &p, step_seed, &mut s_ref);

            let mut v_full = v0.clone();
            let mut s_full = vec![u64::MAX; words];
            RustBackend.update(&mut v_full, &p, step_seed, &mut s_full).unwrap();
            ptest::prop_assert_eq(v_full, v_ref.clone(), "full kernel membranes")?;
            ptest::prop_assert_eq(s_full, s_ref.clone(), "full kernel spike words")?;

            // random word-aligned chunking, applied out of order
            let mut v_chunk = v0;
            let mut s_chunk = vec![u64::MAX; words];
            let mut ranges = Vec::new();
            let mut w = 0;
            while w < words {
                let hi = (w + 1 + rng.below(words as u32) as usize).min(words);
                ranges.push((w, hi));
                w = hi;
            }
            if rng.chance(0.5) {
                ranges.reverse();
            }
            for &(lo_w, hi_w) in &ranges {
                let lo = lo_w * 64;
                let hi = (hi_w * 64).min(n);
                sweep_chunk(
                    &mut v_chunk[lo..hi],
                    p.slice(lo, hi),
                    step_seed,
                    &mut s_chunk[lo_w..hi_w],
                    lo as u32,
                );
            }
            ptest::prop_assert_eq(v_chunk, v_ref, "chunked membranes")?;
            ptest::prop_assert_eq(s_chunk, s_ref, "chunked spike words")?;
            Ok(())
        });
    }

    fn params_of(models: &[NeuronModel]) -> CoreParams {
        let mut p = CoreParams::default();
        for m in models {
            p.theta.push(m.theta);
            p.nu.push(m.nu);
            p.lam.push(m.lam);
            p.flags.push(m.flags);
        }
        p
    }

    #[test]
    fn strict_threshold_and_reset() {
        let m = NeuronModel::if_neuron(100);
        let p = params_of(&[m, m, m]);
        let mut v = vec![100, 101, 99];
        let mut s = vec![0u64; 1];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(s[0], 0b010);
        assert_eq!(v, vec![100, 0, 99]); // lam=63 -> clamp 31 -> v -= v>>31 = v
    }

    #[test]
    fn ann_clears() {
        let m = NeuronModel::ann(1000, 0, false).unwrap();
        let p = params_of(&[m]);
        let mut v = vec![37];
        let mut s = vec![0u64; 1];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(v, vec![0]);
        assert_eq!(s[0], 0);
    }

    #[test]
    fn lif_leak_floor() {
        let m = NeuronModel::lif(1 << 30, 0, 2, false).unwrap();
        let p = params_of(&[m, m]);
        let mut v = vec![1000, -1000];
        let mut s = vec![0u64; 1];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(v, vec![750, -750]); // floor division both signs
    }

    #[test]
    fn stale_mask_bits_cleared() {
        let m = NeuronModel::if_neuron(100);
        let p = params_of(&[m]);
        let mut v = vec![0];
        let mut s = vec![u64::MAX; 1]; // dirty buffer from a prior step
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(s[0], 0);
    }

    #[test]
    fn accumulate_wraps() {
        let mut v = vec![i32::MAX, 0];
        RustBackend
            .accumulate(&mut v, &[(0, 1), (1, 5), (1, -2)])
            .unwrap();
        assert_eq!(v, vec![i32::MIN, 3]);
    }

    /// Satellite regression test: bitmask fired-extraction equals the
    /// scalar scan for random masks, including all-zero and all-ones
    /// words and a ragged tail word.
    #[test]
    fn extract_fired_matches_scalar_scan() {
        let scalar = |words: &[u64], n: usize| -> Vec<u32> {
            (0..n as u32).filter(|&i| mask_bit(words, i as usize)).collect()
        };
        let mut rng = Xorshift32::new(0xB17);
        let mut out = Vec::new();
        for n in [0usize, 1, 63, 64, 65, 128, 1000] {
            for case in 0..20 {
                let words: Vec<u64> = (0..mask_words(n))
                    .map(|wi| {
                        let mut w = match case % 4 {
                            0 => 0u64,        // all-zero word
                            1 => u64::MAX,    // all-ones word
                            _ => ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64,
                        };
                        // keep bits >= n clear in the tail word (backend contract)
                        if (wi + 1) * 64 > n {
                            let valid = n - wi * 64;
                            w &= if valid == 64 { u64::MAX } else { (1u64 << valid) - 1 };
                        }
                        w
                    })
                    .collect();
                extract_fired(&words, &mut out);
                assert_eq!(out, scalar(&words, n), "n={n} case={case}");
            }
        }
    }
}
