//! The membrane-update compute backend: phases 1-3 (noise, spike+reset,
//! leak) and phase 4 (synaptic accumulate).
//!
//! Two implementations exist:
//! * [`RustBackend`] — native scalar loop, bit-exact with the Pallas
//!   kernel and `ref.py` (see `util::prng`);
//! * [`crate::runtime::XlaBackend`] — executes the AOT-compiled JAX/Pallas
//!   artifacts via PJRT (the "FPGA bitstream" of this reproduction).
//!
//! Cross-backend parity is enforced by `rust/tests/parity.rs`.

use crate::snn::{Network, FLAG_LIF, FLAG_NOISE};
use crate::util::prng::{noise17, shift_noise};

/// SoA per-neuron parameters, the engine-side mirror of the HBM
/// neuron-model section.
#[derive(Clone, Debug, Default)]
pub struct CoreParams {
    pub theta: Vec<i32>,
    pub nu: Vec<i32>,
    pub lam: Vec<i32>,
    pub flags: Vec<u32>,
}

impl CoreParams {
    pub fn from_network(net: &Network) -> Self {
        let n = net.n_neurons();
        let mut p = CoreParams {
            theta: Vec::with_capacity(n),
            nu: Vec::with_capacity(n),
            lam: Vec::with_capacity(n),
            flags: Vec::with_capacity(n),
        };
        for m in &net.params {
            p.theta.push(m.theta);
            p.nu.push(m.nu);
            p.lam.push(m.lam);
            p.flags.push(m.flags);
        }
        p
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }
}

/// Backend for the two compute phases of a timestep.
pub trait UpdateBackend {
    /// Phases 1-3 over all neurons. Updates `v` in place and writes the
    /// 0/1 spike mask into `spikes`.
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [i32],
    ) -> anyhow::Result<()>;

    /// Phase 4: `v[targets[k]] += weights[k]` (wrapping int32).
    fn accumulate(
        &mut self,
        v: &mut [i32],
        targets: &[u32],
        weights: &[i32],
    ) -> anyhow::Result<()>;

    fn name(&self) -> &'static str;
}

/// Native scalar implementation — the reference semantics.
#[derive(Clone, Copy, Debug, Default)]
pub struct RustBackend;

impl UpdateBackend for RustBackend {
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [i32],
    ) -> anyhow::Result<()> {
        debug_assert_eq!(v.len(), params.len());
        debug_assert_eq!(spikes.len(), v.len());
        for i in 0..v.len() {
            let flags = params.flags[i];
            let mut x = v[i];
            // 1. noise
            if flags & FLAG_NOISE != 0 {
                x = x.wrapping_add(shift_noise(noise17(step_seed, i as u32), params.nu[i]));
            }
            // 2. spike + reset (strict >)
            let s = (x > params.theta[i]) as i32;
            if s != 0 {
                x = 0;
            }
            // 3. leak / clear
            if flags & FLAG_LIF != 0 {
                x -= x >> params.lam[i].clamp(0, 31);
            } else {
                x = 0;
            }
            v[i] = x;
            spikes[i] = s;
        }
        Ok(())
    }

    fn accumulate(
        &mut self,
        v: &mut [i32],
        targets: &[u32],
        weights: &[i32],
    ) -> anyhow::Result<()> {
        debug_assert_eq!(targets.len(), weights.len());
        for (&t, &w) in targets.iter().zip(weights) {
            let slot = &mut v[t as usize];
            *slot = slot.wrapping_add(w);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::NeuronModel;

    fn params_of(models: &[NeuronModel]) -> CoreParams {
        let mut p = CoreParams::default();
        for m in models {
            p.theta.push(m.theta);
            p.nu.push(m.nu);
            p.lam.push(m.lam);
            p.flags.push(m.flags);
        }
        p
    }

    #[test]
    fn strict_threshold_and_reset() {
        let m = NeuronModel::if_neuron(100);
        let p = params_of(&[m, m, m]);
        let mut v = vec![100, 101, 99];
        let mut s = vec![0; 3];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(s, vec![0, 1, 0]);
        assert_eq!(v, vec![100, 0, 99]); // lam=63 -> clamp 31 -> v -= v>>31 = v
    }

    #[test]
    fn ann_clears() {
        let m = NeuronModel::ann(1000, 0, false).unwrap();
        let p = params_of(&[m]);
        let mut v = vec![37];
        let mut s = vec![0];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(v, vec![0]);
        assert_eq!(s, vec![0]);
    }

    #[test]
    fn lif_leak_floor() {
        let m = NeuronModel::lif(1 << 30, 0, 2, false).unwrap();
        let p = params_of(&[m, m]);
        let mut v = vec![1000, -1000];
        let mut s = vec![0; 2];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(v, vec![750, -750]); // floor division both signs
    }

    #[test]
    fn accumulate_wraps() {
        let mut v = vec![i32::MAX, 0];
        RustBackend.accumulate(&mut v, &[0, 1, 1], &[1, 5, -2]).unwrap();
        assert_eq!(v, vec![i32::MIN, 3]);
    }
}
