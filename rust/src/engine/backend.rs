//! The membrane-update compute backend: phases 1-3 (noise, spike+reset,
//! leak) and phase 4 (synaptic accumulate).
//!
//! Two implementations exist:
//! * [`RustBackend`] — native scalar loop, bit-exact with the Pallas
//!   kernel and `ref.py` (see `util::prng`);
//! * [`crate::runtime::XlaBackend`] — executes the AOT-compiled JAX/Pallas
//!   artifacts via PJRT (the "FPGA bitstream" of this reproduction).
//!
//! Spike output is a packed `u64` bitmask (bit `i` = neuron `i` fired),
//! matching the hardware's BRAM spike registers; fired ids are decoded
//! word-at-a-time with [`extract_fired`] instead of an O(N) scalar scan.
//! Phase-4 events arrive as one interleaved `(target, weight)` buffer so
//! the gather writes and the accumulate read stream the same cache lines.
//!
//! Cross-backend parity is enforced by `rust/tests/parity.rs`.

use crate::snn::{Network, FLAG_LIF, FLAG_NOISE};
use crate::util::prng::{noise17, shift_noise};

/// Number of `u64` bitmask words covering `n` neurons.
#[inline]
pub fn mask_words(n: usize) -> usize {
    n.div_ceil(64)
}

/// Read bit `i` of a spike bitmask.
#[inline]
pub fn mask_bit(words: &[u64], i: usize) -> bool {
    words[i >> 6] & (1u64 << (i & 63)) != 0
}

/// Set bit `i` of a spike bitmask.
#[inline]
pub fn set_mask_bit(words: &mut [u64], i: usize) {
    words[i >> 6] |= 1u64 << (i & 63);
}

/// Decode fired ids (ascending) from a spike bitmask. Skips zero words
/// whole and walks set bits with `trailing_zeros` — at sparse activity
/// this visits ~64x fewer positions than the seed's per-neuron scan.
pub fn extract_fired(words: &[u64], out: &mut Vec<u32>) {
    out.clear();
    for (wi, &word) in words.iter().enumerate() {
        let mut m = word;
        while m != 0 {
            out.push((wi as u32) * 64 + m.trailing_zeros());
            m &= m - 1;
        }
    }
}

/// SoA per-neuron parameters, the engine-side mirror of the HBM
/// neuron-model section.
#[derive(Clone, Debug, Default)]
pub struct CoreParams {
    pub theta: Vec<i32>,
    pub nu: Vec<i32>,
    pub lam: Vec<i32>,
    pub flags: Vec<u32>,
}

impl CoreParams {
    pub fn from_network(net: &Network) -> Self {
        let n = net.n_neurons();
        let mut p = CoreParams {
            theta: Vec::with_capacity(n),
            nu: Vec::with_capacity(n),
            lam: Vec::with_capacity(n),
            flags: Vec::with_capacity(n),
        };
        for m in &net.params {
            p.theta.push(m.theta);
            p.nu.push(m.nu);
            p.lam.push(m.lam);
            p.flags.push(m.flags);
        }
        p
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }
}

/// Backend for the two compute phases of a timestep.
pub trait UpdateBackend {
    /// Phases 1-3 over all neurons. Updates `v` in place and writes the
    /// packed spike bitmask into `spikes` (`mask_words(v.len())` words;
    /// the backend zeroes them first and never sets bits >= `v.len()`).
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> anyhow::Result<()>;

    /// Phase 4: `v[target] += weight` (wrapping int32) for every
    /// interleaved `(target, weight)` event.
    fn accumulate(&mut self, v: &mut [i32], events: &[(u32, i32)]) -> anyhow::Result<()>;

    fn name(&self) -> &'static str;
}

/// Native scalar implementation — the reference semantics.
#[derive(Clone, Copy, Debug, Default)]
pub struct RustBackend;

impl UpdateBackend for RustBackend {
    fn update(
        &mut self,
        v: &mut [i32],
        params: &CoreParams,
        step_seed: u32,
        spikes: &mut [u64],
    ) -> anyhow::Result<()> {
        debug_assert_eq!(v.len(), params.len());
        debug_assert_eq!(spikes.len(), mask_words(v.len()));
        spikes.fill(0);
        for i in 0..v.len() {
            let flags = params.flags[i];
            let mut x = v[i];
            // 1. noise
            if flags & FLAG_NOISE != 0 {
                x = x.wrapping_add(shift_noise(noise17(step_seed, i as u32), params.nu[i]));
            }
            // 2. spike + reset (strict >)
            if x > params.theta[i] {
                x = 0;
                set_mask_bit(spikes, i);
            }
            // 3. leak / clear
            if flags & FLAG_LIF != 0 {
                x -= x >> params.lam[i].clamp(0, 31);
            } else {
                x = 0;
            }
            v[i] = x;
        }
        Ok(())
    }

    fn accumulate(&mut self, v: &mut [i32], events: &[(u32, i32)]) -> anyhow::Result<()> {
        for &(t, w) in events {
            let slot = &mut v[t as usize];
            *slot = slot.wrapping_add(w);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::NeuronModel;
    use crate::util::prng::Xorshift32;

    fn params_of(models: &[NeuronModel]) -> CoreParams {
        let mut p = CoreParams::default();
        for m in models {
            p.theta.push(m.theta);
            p.nu.push(m.nu);
            p.lam.push(m.lam);
            p.flags.push(m.flags);
        }
        p
    }

    #[test]
    fn strict_threshold_and_reset() {
        let m = NeuronModel::if_neuron(100);
        let p = params_of(&[m, m, m]);
        let mut v = vec![100, 101, 99];
        let mut s = vec![0u64; 1];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(s[0], 0b010);
        assert_eq!(v, vec![100, 0, 99]); // lam=63 -> clamp 31 -> v -= v>>31 = v
    }

    #[test]
    fn ann_clears() {
        let m = NeuronModel::ann(1000, 0, false).unwrap();
        let p = params_of(&[m]);
        let mut v = vec![37];
        let mut s = vec![0u64; 1];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(v, vec![0]);
        assert_eq!(s[0], 0);
    }

    #[test]
    fn lif_leak_floor() {
        let m = NeuronModel::lif(1 << 30, 0, 2, false).unwrap();
        let p = params_of(&[m, m]);
        let mut v = vec![1000, -1000];
        let mut s = vec![0u64; 1];
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(v, vec![750, -750]); // floor division both signs
    }

    #[test]
    fn stale_mask_bits_cleared() {
        let m = NeuronModel::if_neuron(100);
        let p = params_of(&[m]);
        let mut v = vec![0];
        let mut s = vec![u64::MAX; 1]; // dirty buffer from a prior step
        RustBackend.update(&mut v, &p, 1, &mut s).unwrap();
        assert_eq!(s[0], 0);
    }

    #[test]
    fn accumulate_wraps() {
        let mut v = vec![i32::MAX, 0];
        RustBackend
            .accumulate(&mut v, &[(0, 1), (1, 5), (1, -2)])
            .unwrap();
        assert_eq!(v, vec![i32::MIN, 3]);
    }

    /// Satellite regression test: bitmask fired-extraction equals the
    /// scalar scan for random masks, including all-zero and all-ones
    /// words and a ragged tail word.
    #[test]
    fn extract_fired_matches_scalar_scan() {
        let scalar = |words: &[u64], n: usize| -> Vec<u32> {
            (0..n as u32).filter(|&i| mask_bit(words, i as usize)).collect()
        };
        let mut rng = Xorshift32::new(0xB17);
        let mut out = Vec::new();
        for n in [0usize, 1, 63, 64, 65, 128, 1000] {
            for case in 0..20 {
                let words: Vec<u64> = (0..mask_words(n))
                    .map(|wi| {
                        let mut w = match case % 4 {
                            0 => 0u64,        // all-zero word
                            1 => u64::MAX,    // all-ones word
                            _ => ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64,
                        };
                        // keep bits >= n clear in the tail word (backend contract)
                        if (wi + 1) * 64 > n {
                            let valid = n - wi * 64;
                            w &= if valid == 64 { u64::MAX } else { (1u64 << valid) - 1 };
                        }
                        w
                    })
                    .collect();
                extract_fired(&words, &mut out);
                assert_eq!(out, scalar(&words, n), "n={n} case={case}");
            }
        }
    }
}
